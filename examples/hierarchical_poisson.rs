//! Composed inference: HMC-within-Gibbs on the hierarchical Poisson model,
//! plus the MiniBatch context for scaled-likelihood (stochastic VI style)
//! evaluation — exercising contexts and blocked samplers from the public
//! API.
//!
//! ```sh
//! cargo run --release --example hierarchical_poisson
//! ```

use dynamicppl::context::Context;
use dynamicppl::inference::{Gibbs, GibbsBlock};
use dynamicppl::model::{init_typed, typed_logp};
use dynamicppl::models::build;
use dynamicppl::prelude::*;
use dynamicppl::util::stats;

fn main() {
    let bm = build("hier_poisson", 11);
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);

    // ---- blocked Gibbs: HMC over (a0, b), random-walk over σ -----------
    let gibbs = Gibbs::new(vec![
        GibbsBlock::hmc(&["a0", "b"], 0.02, 8),
        GibbsBlock::rwmh(&["sigma"], 0.4),
    ]);
    let mut rng = Xoshiro256pp::seed_from_u64(12);
    let out = gibbs.sample(bm.model.as_ref(), &tvi, 1500, 4000, &mut rng);
    println!(
        "Gibbs: {} sweeps, within-block acceptance {:.2}",
        out.rows.len(),
        out.stats.accept_rate
    );

    // column order follows the trace: a0, sigma, b[0..10]
    let a0: Vec<f64> = out.rows.iter().map(|r| r[0]).collect();
    let sigma: Vec<f64> = out.rows.iter().map(|r| r[1]).collect();
    println!(
        "posterior a0 ≈ {:.3} ± {:.3}  (ground truth 1.0)",
        stats::mean(&a0),
        stats::std(&a0)
    );
    println!(
        "posterior σ  ≈ {:.3} ± {:.3}  (ground truth 0.5)",
        stats::mean(&sigma),
        stats::std(&sigma)
    );
    assert!((stats::mean(&a0) - 1.0).abs() < 0.5);

    // ---- contexts: the paper's §3.1 quartet on the same trace ----------
    let theta = tvi.unconstrained.clone();
    let joint = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
    let prior = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Prior);
    let lik = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Likelihood);
    let mb = typed_logp(
        bm.model.as_ref(),
        &tvi,
        &theta,
        Context::MiniBatch { scale: 10.0 },
    );
    println!("\ncontexts at the prior draw:");
    println!("  log joint       = {joint:.3}");
    println!("  log prior       = {prior:.3}");
    println!("  log likelihood  = {lik:.3}");
    println!("  minibatch(×10)  = {mb:.3}");
    assert!((joint - (prior + lik)).abs() < 1e-10);
    assert!((mb - (prior + 10.0 * lik)).abs() < 1e-10);
    println!("\ncontext algebra verified: joint = prior + lik; minibatch scales lik only");
}
