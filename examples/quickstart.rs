//! Quickstart: define a model with the tilde DSL, run NUTS, inspect the
//! chain — the 60-second tour of the library.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dynamicppl::gradient::{Backend, NativeDensity};
use dynamicppl::inference::{sample_chain, Nuts, SamplerKind};
use dynamicppl::model::init_typed;
use dynamicppl::prelude::*;

model! {
    /// Eight-schools-style partial pooling:
    /// mu ~ Normal(0,5); tau ~ HalfCauchy(5);
    /// theta[j] ~ Normal(mu, tau); y[j] ~ Normal(theta[j], sigma[j]).
    pub EightSchools {
        y: Vec<f64>,
        sigma: Vec<f64>,
    }
    fn body<T>(this, api) {
        let mu = tilde!(api, mu ~ Normal(c(0.0), c(5.0)));
        let tau = tilde!(api, tau ~ HalfCauchy(c(5.0)));
        check_reject!(api);
        for j in 0..this.y.len() {
            let theta_j = tilde!(api, theta[j] ~ Normal(mu, tau));
            obs!(api, this.y[j] => Normal(theta_j, c(this.sigma[j])));
        }
    }
}

fn main() {
    // The classic eight-schools data (Rubin 1981).
    let model = EightSchools {
        y: vec![28.0, 8.0, -3.0, 7.0, -1.0, 1.0, 18.0, 12.0],
        sigma: vec![15.0, 10.0, 16.0, 11.0, 9.0, 11.0, 10.0, 18.0],
    };

    // 1. First contact: run the model once with the dynamic (untyped)
    //    trace, discovering every random variable, then specialize.
    let mut rng = Xoshiro256pp::seed_from_u64(2026);
    let tvi = init_typed(&model, &mut rng);
    println!(
        "trace specialized: {} variables, {} unconstrained dims",
        tvi.slots().len(),
        tvi.dim()
    );

    // 2. Sample with NUTS over the typed trace (reverse-tape gradients).
    let ld = NativeDensity::new(&model, &tvi, Backend::Reverse);
    let chain = sample_chain(
        &ld,
        &tvi,
        &SamplerKind::Nuts(Nuts::default()),
        1000,
        2000,
        7,
    );

    // 3. Inspect.
    println!("\n{}", chain.summary());
    println!(
        "acceptance = {:.2}, divergences = {}",
        chain.stats.accept_rate, chain.stats.divergences
    );
    let mu = chain.mean("mu").unwrap();
    let tau = chain.mean("tau").unwrap();
    println!("\nposterior: mu ≈ {mu:.2}, tau ≈ {tau:.2} (pooling strength)");
    assert!(mu > 0.0 && mu < 20.0, "mu should be mildly positive");
    assert!(tau > 0.0);
}
