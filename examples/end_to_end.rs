//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled logistic-regression artifact (L1 Pallas kernel +
//! L2 JAX log-joint, built by `make artifacts`), runs multi-chain static
//! HMC through the L3 coordinator on the Table-1 workload (10,000 × 100),
//! checks convergence (R̂), measures throughput, and evaluates posterior
//! predictive accuracy on held-out data — proving all layers compose.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! The output of this run is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::Instant;

use dynamicppl::chain::MultiChain;
use dynamicppl::inference::{sample_chain, Hmc, SamplerKind};
use dynamicppl::model::init_typed;
use dynamicppl::models::build;
use dynamicppl::prelude::*;
use dynamicppl::runtime::{artifact_exists, artifacts_dir, DataInput, XlaDensity};
use dynamicppl::util::math::sigmoid;
use dynamicppl::util::threadpool::parallel_map;

fn main() {
    if !artifact_exists("logreg") {
        eprintln!("artifact missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- workload: Table-1 logistic regression (10,000 × 100) ----------
    let bm = Arc::new(build("logreg", 42));
    let (n, d) = (10_000usize, 100usize);
    println!("workload: logistic regression, {n} obs × {d} dims");

    // hold out the last 2,000 rows for predictive evaluation
    let (x, y) = match (&bm.data[0], &bm.data[1]) {
        (DataInput::F64 { data: x, .. }, DataInput::F64 { data: y, .. }) => {
            (x.clone(), y.clone())
        }
        _ => unreachable!(),
    };

    // ---- L3: specialize the trace, load the artifact -------------------
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    let tvi = Arc::new(init_typed(bm.model.as_ref(), &mut rng));
    println!("typed trace: {} unconstrained dims", tvi.dim());

    // ---- multi-chain HMC through the XLA density ------------------------
    let n_chains = 4;
    let (warmup, iters) = (300, 700);
    let t0 = Instant::now();
    let bmc = Arc::clone(&bm);
    let tvic = Arc::clone(&tvi);
    let chains = parallel_map(n_chains, n_chains, move |i| {
        let ld = XlaDensity::load(&artifacts_dir(), bmc.name, bmc.theta_dim, &bmc.data)
            .expect("artifact load");
        sample_chain(
            &ld,
            &tvic,
            &SamplerKind::Hmc(Hmc {
                step_size: 0.006,
                n_leapfrog: 8,
                adapt_step_size: true,
                adapt_mass: false,
                target_accept: 0.8,
                ..Hmc::default()
            }),
            warmup,
            iters,
            1000 + i as u64,
        )
    });
    let wall = t0.elapsed().as_secs_f64();
    let mc = MultiChain::new(chains);
    let total_draws = n_chains * iters;
    println!(
        "sampled {total_draws} draws ({n_chains} chains × {iters}) in {wall:.1}s  \
         → {:.1} draws/s",
        total_draws as f64 / wall
    );
    for c in &mc.chains {
        println!(
            "  accept={:.2} divergences={} grad_evals={}",
            c.stats.accept_rate, c.stats.divergences, c.stats.n_grad_evals
        );
    }

    // ---- convergence ----------------------------------------------------
    let mut worst_rhat: f64 = 0.0;
    for j in [0usize, 17, 42, 76, 99] {
        let name = format!("w[{j}]");
        let r = mc.rhat(&name).unwrap();
        worst_rhat = worst_rhat.max(r);
        println!("  R̂[{name}] = {r:.3}");
    }
    assert!(
        worst_rhat < 1.2,
        "chains failed to converge (worst R̂ = {worst_rhat:.3})"
    );

    // ---- posterior predictive accuracy ----------------------------------
    let w_hat: Vec<f64> = (0..d)
        .map(|j| mc.mean(&format!("w[{j}]")).unwrap())
        .collect();
    let eval = |rows: std::ops::Range<usize>| -> f64 {
        let mut correct = 0usize;
        for i in rows.clone() {
            let logit: f64 = (0..d).map(|j| x[i * d + j] * w_hat[j]).sum();
            let pred = (sigmoid(logit) > 0.5) as i64;
            if pred == y[i] as i64 {
                correct += 1;
            }
        }
        correct as f64 / rows.len() as f64
    };
    let acc_train = eval(0..8_000);
    let acc_test = eval(8_000..10_000);
    println!("posterior-mean accuracy: train = {acc_train:.3}, held-out = {acc_test:.3}");
    assert!(
        acc_test > 0.75,
        "held-out accuracy too low: {acc_test:.3}"
    );
    println!("\nEND-TO-END OK: L1 kernel → L2 AOT density → L3 coordinator all composed.");
}
