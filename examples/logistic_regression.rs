//! Backend comparison on the logistic-regression workload: the same model,
//! the same sampler, four gradient engines — the paper's Table-1 story on
//! one model, from the public API.
//!
//! ```sh
//! make artifacts && cargo run --release --example logistic_regression
//! ```

use dynamicppl::gradient::{Backend, LogDensity, NativeDensity, UntypedDensity};
use dynamicppl::inference::Hmc;
use dynamicppl::model::{init_trace, init_typed};
use dynamicppl::models::logreg::logreg_n;
use dynamicppl::prelude::*;
use dynamicppl::runtime::{artifact_exists, artifacts_dir, XlaDensity};
use dynamicppl::stanlike::stanlike_density;
use dynamicppl::util::timing::bench;

fn main() {
    // A reduced workload so the slow (deliberately dynamic) paths finish
    // quickly; relative ordering matches the full Table-1 run.
    let bm = logreg_n(7, 2000, 50);
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let vi = init_trace(bm.model.as_ref(), &mut rng);
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let theta0: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.1).collect();

    let iters = 50;
    let hmc = Hmc::paper(bm.step_size);
    let mut results: Vec<(String, f64)> = Vec::new();

    let mut time_backend = |label: &str, ld: &dyn LogDensity| {
        let m = bench(label, 1, 3, || {
            let mut rng = Xoshiro256pp::seed_from_u64(99);
            let out = hmc.sample(ld, &theta0, 0, iters, &mut rng);
            std::hint::black_box(out.logps.last().copied());
        });
        println!("{:<14} {}", label, m.display());
        results.push((label.to_string(), m.mean()));
    };

    println!("static HMC({} leapfrog) × {iters} iters, logreg 2000×50:\n", 4);
    let untyped = UntypedDensity::new(bm.model.as_ref(), &vi, Backend::Reverse);
    time_backend("untyped", &untyped);
    let tape = NativeDensity::new(bm.model.as_ref(), &tvi, Backend::Reverse);
    time_backend("typed+tape", &tape);
    let stan = stanlike_density(&bm);
    time_backend("stanlike", stan.as_ref());
    // The AOT artifact is compiled for the full 10,000×100 workload; load
    // it only to show the call path (numbers reported separately).
    if artifact_exists("logreg") {
        let full = dynamicppl::models::build("logreg", 42);
        let xla = XlaDensity::load(&artifacts_dir(), "logreg", full.theta_dim, &full.data)
            .expect("artifact");
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let ftvi = init_typed(full.model.as_ref(), &mut rng);
        let ftheta: Vec<f64> = ftvi.unconstrained.iter().map(|x| x * 0.1).collect();
        let m = bench("typed+xla*", 1, 3, || {
            let mut rng = Xoshiro256pp::seed_from_u64(99);
            let out = hmc.sample(&xla, &ftheta, 0, iters, &mut rng);
            std::hint::black_box(out.logps.last().copied());
        });
        println!("{:<14} {}   (*full 10,000×100 workload — 5× the data)", "typed+xla*", m.display());
    } else {
        println!("typed+xla      skipped (run `make artifacts`)");
    }

    // the ordering claim of the paper
    let get = |l: &str| results.iter().find(|(n, _)| n == l).map(|(_, v)| *v).unwrap();
    assert!(
        get("stanlike") < get("typed+tape") && get("typed+tape") <= get("untyped") * 1.5,
        "expected stanlike < typed+tape ≲ untyped"
    );
    println!("\nordering holds: stanlike < typed+tape ≤ untyped (dynamic-dispatch tax)");
}
