//! The paper's §3.5 probability queries, end to end: prior, likelihood,
//! joint, and posterior-predictive (chain) queries against the linreg
//! model — the `prob"..."` string-macro API.
//!
//! ```sh
//! cargo run --release --example queries
//! ```

use dynamicppl::chain::Chain;
use dynamicppl::coordinator::query_registry;
use dynamicppl::query::{eval_query, Query};

fn show(q: &str, chain: Option<&Chain>) -> f64 {
    let parsed = Query::parse(q).expect("parse");
    let r = eval_query(&parsed, &query_registry(), chain).expect("eval");
    println!("prob\"{q}\"\n  → log p = {:+.4}   p = {:.4e}\n", r.log_prob, r.prob());
    r.log_prob
}

fn main() {
    println!("== paper §3.5 query forms ==\n");

    // 1. likelihood of a new observation given parameters
    show(
        "X = [1.0, 2.0], y = [2.0] | w = [0.5, 0.0], s = 1.0, model = linreg",
        None,
    );

    // 2. prior probability of parameter values
    let prior = show("w = [1.0, 1.0], s = 1.0 | model = linreg", None);

    // 3. joint probability of data and parameters
    let joint = show(
        "X = [1.0, 2.0], y = [2.0], w = [0.0, 0.0], s = 1.0 | model = linreg",
        None,
    );
    assert!(joint < prior, "joint adds a likelihood term");

    // 4. posterior predictive via an MCMC chain
    let mut chain = Chain::new(vec!["s".into(), "w[0]".into(), "w[1]".into()]);
    // pretend-posterior draws around w = (0.5, 0), s = 1
    for i in 0..100 {
        let jitter = (i as f64 / 100.0 - 0.5) * 0.1;
        chain.push(vec![1.0 + jitter.abs(), 0.5 + jitter, jitter / 2.0], 0.0);
    }
    show(
        "X = [1.0, 2.0], y = [2.0] | chain, model = linreg",
        Some(&chain),
    );

    println!("all four query forms evaluated ✓");
}
