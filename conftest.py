"""Pytest bootstrap: make `compile.*` importable when pytest is invoked
from the repo root (`pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
