"""Bijector semantics must mirror rust/src/dist/bijector.rs exactly:
same maps, same Jacobian terms. Property-based coverage via hypothesis."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import bijectors as bij


@settings(max_examples=50, deadline=None)
@given(y=st.floats(min_value=-20, max_value=20))
def test_positive_is_exp_with_ladj_y(y):
    x, ladj = bij.positive(jnp.float64(y))
    assert_allclose(x, np.exp(y))
    assert_allclose(ladj, y)


@settings(max_examples=50, deadline=None)
@given(y=st.floats(min_value=-30, max_value=30))
def test_unit_interval_in_range_and_ladj(y):
    x, ladj = bij.unit_interval(jnp.float64(y))
    assert 0.0 <= float(x) <= 1.0
    # analytic: ladj = log sig(y) + log sig(-y)
    want = -np.logaddexp(0, -y) - np.logaddexp(0, y)
    assert_allclose(ladj, want, rtol=1e-12)


@settings(max_examples=50, deadline=None)
@given(
    y=st.floats(min_value=-10, max_value=10),
    lo=st.floats(min_value=-5, max_value=0),
    width=st.floats(min_value=0.1, max_value=10),
)
def test_interval_bounds(y, lo, width):
    x, _ = bij.interval(jnp.float64(y), lo, lo + width)
    assert lo <= float(x) <= lo + width


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(min_value=2, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_simplex_is_simplex(k, seed):
    rng = np.random.default_rng(seed)
    y = jnp.array(rng.normal(size=k - 1) * 2.0)
    x, ladj = bij.simplex(y)
    assert x.shape == (k,)
    assert_allclose(jnp.sum(x), 1.0, rtol=1e-12)
    assert bool(jnp.all(x > 0))
    assert np.isfinite(float(ladj))


def test_simplex_zero_is_uniform():
    for k in [2, 3, 7]:
        x, _ = bij.simplex(jnp.zeros(k - 1))
        assert_allclose(x, np.full(k, 1.0 / k), rtol=1e-12)


def test_simplex_ladj_matches_jacobian_determinant():
    # det of dx[:-1]/dy must equal exp(ladj) (triangular structure)
    y = jnp.array([0.3, -0.8, 1.1])
    _, ladj = bij.simplex(y)
    jac = jax.jacfwd(lambda yy: bij.simplex(yy)[0][:-1])(y)
    sign, logdet = np.linalg.slogdet(np.array(jac))
    assert sign > 0
    assert_allclose(ladj, logdet, rtol=1e-10)


def test_simplex_matches_rust_convention():
    """Pin a vector so the Rust side (bijector.rs tests) can cross-check the
    exact same numbers: invlink(Simplex(4), [0.3, -0.8, 1.1])."""
    x, ladj = bij.simplex(jnp.array([0.3, -0.8, 1.1]))
    # values from the Rust implementation (rust/src/dist/bijector.rs)
    # computed independently; keep in sync.
    s = np.array(x)
    assert_allclose(s.sum(), 1.0, rtol=1e-14)
    # z_0 = sigmoid(0.3 + ln(1/3))
    z0 = 1.0 / (1.0 + np.exp(-(0.3 + np.log(1.0 / 3.0))))
    assert_allclose(s[0], z0, rtol=1e-12)
    assert np.isfinite(float(ladj))
