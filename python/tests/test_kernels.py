"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; assert_allclose against ref.py. This
is the core correctness signal for the kernel layer.
"""

import jax

jax.config.update("jax_enable_x64", True)

from compile import config as _config

_config.set_impl("pallas")  # test the real kernels, not the jnp fallback

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.gauss_logpdf import gauss_logpdf, sq_sum
from compile.kernels.logreg import logreg_loglik
from compile.kernels.ref import (
    gauss_logpdf_ref,
    logreg_loglik_ref,
    softmax_mix_ref,
)
from compile.kernels.softmax_mix import softmax_mix


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    mu=st.floats(min_value=-5, max_value=5),
    sigma=st.floats(min_value=0.05, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    block=st.sampled_from([64, 257, 1024, 2048]),
)
def test_gauss_logpdf_matches_ref(n, mu, sigma, seed, block):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=n))
    got = gauss_logpdf(x, jnp.float64(mu), jnp.float64(sigma), block=block)
    want = gauss_logpdf_ref(x, mu, sigma)
    assert_allclose(got, want, rtol=1e-10)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=2000),
    d=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    block=st.sampled_from([32, 100, 512]),
)
def test_logreg_loglik_matches_ref(n, d, seed, block):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(n, d)))
    w = jnp.array(rng.normal(size=d))
    y = jnp.array(rng.integers(0, 2, size=n).astype(np.float64))
    got = logreg_loglik(x, w, y, block_n=block)
    want = logreg_loglik_ref(x, w, y)
    assert_allclose(got, want, rtol=1e-10)


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=12),
    n=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    block=st.sampled_from([128, 1000, 2048]),
)
def test_softmax_mix_matches_ref(k, n, seed, block):
    rng = np.random.default_rng(seed)
    lw = jnp.array(rng.normal(size=k))
    lc = jnp.array(rng.normal(size=(k, n)) * 3.0)
    got = softmax_mix(lw, lc, block_n=block)
    want = softmax_mix_ref(lw, lc)
    assert_allclose(got, want, rtol=1e-10)


def test_gauss_gradient_matches_autodiff_of_ref():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=300))
    mu, sigma = jnp.float64(0.4), jnp.float64(1.7)
    g_kernel = jax.grad(lambda xx, m, s: gauss_logpdf(xx, m, s), argnums=(0, 1, 2))(
        x, mu, sigma
    )
    g_ref = jax.grad(gauss_logpdf_ref, argnums=(0, 1, 2))(x, mu, sigma)
    for a, b in zip(g_kernel, g_ref):
        assert_allclose(a, b, rtol=1e-9)


def test_logreg_gradient_matches_autodiff_of_ref():
    rng = np.random.default_rng(4)
    x = jnp.array(rng.normal(size=(200, 7)))
    w = jnp.array(rng.normal(size=7))
    y = jnp.array(rng.integers(0, 2, size=200).astype(np.float64))
    gk = jax.grad(lambda ww: logreg_loglik(x, ww, y))(w)
    gr = jax.grad(lambda ww: logreg_loglik_ref(x, ww, y))(w)
    assert_allclose(gk, gr, rtol=1e-9)


def test_softmax_mix_gradient_matches_autodiff_of_ref():
    rng = np.random.default_rng(5)
    lw = jnp.array(rng.normal(size=4))
    lc = jnp.array(rng.normal(size=(4, 50)))
    gk = jax.grad(lambda a, b: softmax_mix(a, b), argnums=(0, 1))(lw, lc)
    gr = jax.grad(softmax_mix_ref, argnums=(0, 1))(lw, lc)
    for a, b in zip(gk, gr):
        assert_allclose(a, b, rtol=1e-9)


def test_sq_sum_extreme_values_stable():
    x = jnp.array([1e8, -1e8, 0.0])
    s = sq_sum(x, jnp.float64(0.0), jnp.float64(1.0))
    assert_allclose(s, 2e16, rtol=1e-12)


@pytest.mark.parametrize("n", [1, 63, 64, 65, 2047, 2048, 2049])
def test_block_boundary_sizes(n):
    """Padding/masking must be exact at every block boundary."""
    rng = np.random.default_rng(n)
    x = jnp.array(rng.normal(size=n))
    assert_allclose(
        gauss_logpdf(x, jnp.float64(0.1), jnp.float64(2.0), block=64),
        gauss_logpdf_ref(x, 0.1, 2.0),
        rtol=1e-10,
    )
