"""L2 model-level tests: every benchmark log-joint is finite with finite
gradients on random inputs, agrees with an independent naive-jnp rewrite
where one exists, and AOT-lowers to HLO text."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import bijectors as bij
from compile import dists as d
from compile.aot import lower_model, to_hlo_text, manifest_line
from compile.models import (
    GU_N,
    HMM_K,
    HMM_T,
    HMM_TSUP,
    HMM_V,
    LDA_DOCS,
    LDA_K,
    LDA_N,
    LDA_V,
    LR_D,
    LR_N,
    MODELS,
    NB_C,
    NB_D,
    NB_N,
)


def make_data(spec, seed=0):
    rng = np.random.default_rng(seed)
    data = []
    for shape, dt in spec.data_specs:
        if dt == "int32":
            hi = {
                "hmm_semisup": HMM_V if shape == (HMM_T,) else HMM_K,
                "lda": LDA_V if len(data) == 0 else LDA_DOCS,
            }.get(spec.name, 5)
            data.append(jnp.array(rng.integers(0, hi, size=shape), dtype="int32"))
        else:
            data.append(jnp.array(np.abs(rng.normal(size=shape))))
    return data


@pytest.mark.parametrize("name", list(MODELS))
def test_logp_and_grad_finite(name):
    spec = MODELS[name]
    rng = np.random.default_rng(1)
    theta = jnp.array(rng.normal(size=spec.theta_dim) * 0.3)
    data = make_data(spec)
    v, g = jax.value_and_grad(spec.logp)(theta, *data)
    assert np.isfinite(float(v))
    assert np.isfinite(np.array(g)).all()
    assert g.shape == (spec.theta_dim,)


@pytest.mark.parametrize("name", list(MODELS))
def test_aot_lowering_emits_hlo_text(name):
    spec = MODELS[name]
    text = to_hlo_text(lower_model(spec))
    assert "HloModule" in text
    assert len(text) > 100
    line = manifest_line(spec)
    assert f"model={name}" in line
    assert f"theta_dim={spec.theta_dim}" in line


def test_gauss_unknown_matches_naive():
    spec = MODELS["gauss_unknown"]
    rng = np.random.default_rng(2)
    y = jnp.array(rng.normal(size=GU_N) + 1.5)
    theta = jnp.array([0.2, 1.0])

    def naive(theta, y):
        s = jnp.exp(theta[0])
        m = theta[1]
        sd = jnp.sqrt(s)
        lp = d.inverse_gamma_lp(s, 2.0, 3.0) + theta[0]
        lp += d.normal_lp(m, 0.0, sd)
        lp += jnp.sum(d.normal_lp(y, m, sd))
        return lp

    assert_allclose(spec.logp(theta, y), naive(theta, y), rtol=1e-10)


def test_logreg_matches_naive():
    spec = MODELS["logreg"]
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(LR_N, LR_D)))
    y = jnp.array(rng.integers(0, 2, size=LR_N).astype(np.float64))
    theta = jnp.array(rng.normal(size=LR_D) * 0.1)

    def naive(theta):
        lp = jnp.sum(d.normal_lp(theta, 0.0, 1.0))
        logits = x @ theta
        lp += jnp.sum(y * -jnp.logaddexp(0, -logits) + (1 - y) * -jnp.logaddexp(0, logits))
        return lp

    assert_allclose(spec.logp(theta, x, y), naive(theta), rtol=1e-10)


def test_naive_bayes_matches_per_obs_loop():
    spec = MODELS["naive_bayes"]
    rng = np.random.default_rng(4)
    x = jnp.array(rng.normal(size=(NB_N, NB_D)))
    labels = rng.integers(0, NB_C, size=NB_N)
    onehot = jnp.array(np.eye(NB_C)[labels])
    theta = jnp.array(rng.normal(size=NB_C * NB_D) * 0.2)

    mu = np.array(theta).reshape(NB_C, NB_D)
    lp = np.sum(-0.5 * np.array(theta) ** 2 - 0.5 * d.LN_2PI)
    xn = np.array(x)
    for i in range(NB_N):
        diff = xn[i] - mu[labels[i]]
        lp += np.sum(-0.5 * diff**2 - 0.5 * d.LN_2PI)
    assert_allclose(spec.logp(theta, x, onehot), lp, rtol=1e-9)


def test_sto_vol_matches_scalar_loop():
    spec = MODELS["sto_volatility"]
    rng = np.random.default_rng(5)
    T = 500
    y = jnp.array(rng.normal(size=T))
    theta = jnp.array(rng.normal(size=3 + T) * 0.2)

    # naive scalar re-implementation
    phi = -1.0 + 2.0 / (1.0 + np.exp(-np.array(theta)[0]))
    ladj_phi = (
        -np.logaddexp(0, -float(theta[0]))
        - np.logaddexp(0, float(theta[0]))
        + np.log(2.0)
    )
    sigma = np.exp(float(theta[1]))
    mu = float(theta[2])
    h = np.array(theta)[3:]
    lp = -np.log(2.0) + ladj_phi  # uniform(-1,1) density = 1/2
    lp += (
        -np.log1p((sigma / 2.0) ** 2)
        - np.log(2.0)
        + np.log(2.0 / np.pi)
        + float(theta[1])
    )
    lp += -np.log1p((mu / 10.0) ** 2) - np.log(10.0) - d.LN_PI
    sd0 = sigma / np.sqrt(1 - phi**2)
    lp += -0.5 * ((h[0] - mu) / sd0) ** 2 - np.log(sd0) - 0.5 * d.LN_2PI
    for t in range(1, T):
        m = mu + phi * (h[t - 1] - mu)
        lp += -0.5 * ((h[t] - m) / sigma) ** 2 - np.log(sigma) - 0.5 * d.LN_2PI
    yn = np.array(y)
    lp += np.sum(-0.5 * yn**2 * np.exp(-h) - 0.5 * h - 0.5 * d.LN_2PI)
    assert_allclose(spec.logp(theta, y), lp, rtol=1e-9)


def test_hmm_forward_is_exact_on_tiny_case():
    """Check the forward algorithm against brute-force enumeration on a
    miniature version with the same code path."""
    from compile.models import hmm_logp

    # use the real spec but with supervised states fixed; brute force the
    # unsupervised tail probability on a K=5 chain of length 3 by summing
    # over all 5^3 paths: too big for T=200, so instead verify additivity:
    # logp(theta) must decompose as supervised + marginal(unsup) — we test
    # monotonic response to emission pseudo-strength instead.
    rng = np.random.default_rng(6)
    theta = jnp.array(rng.normal(size=MODELS["hmm_semisup"].theta_dim) * 0.1)
    w = jnp.array(rng.integers(0, HMM_V, size=HMM_T), dtype="int32")
    z = jnp.array(rng.integers(0, HMM_K, size=HMM_TSUP), dtype="int32")
    v = hmm_logp(theta, w, z)
    assert np.isfinite(float(v))
    # against a pure-numpy forward pass
    off = 0
    rows_t = []
    for _ in range(HMM_K):
        r, _ = bij.simplex(theta[off : off + HMM_K - 1])
        rows_t.append(np.array(r))
        off += HMM_K - 1
    rows_e = []
    for _ in range(HMM_K):
        r, _ = bij.simplex(theta[off : off + HMM_V - 1])
        rows_e.append(np.array(r))
        off += HMM_V - 1
    lt = np.log(np.stack(rows_t))
    le = np.log(np.stack(rows_e))
    wn, zn = np.array(w), np.array(z)
    sup = le[zn, wn[:HMM_TSUP]].sum() + lt[zn[:-1], zn[1:]].sum()
    alpha = lt[zn[-1]] + le[:, wn[HMM_TSUP]]
    for t in range(HMM_TSUP + 1, HMM_T):
        a = alpha[:, None] + lt
        m = a.max(axis=0)
        alpha = m + np.log(np.exp(a - m).sum(axis=0)) + le[:, wn[t]]
    m = alpha.max()
    marg = m + np.log(np.exp(alpha - m).sum())
    # priors+ladj: recompute via jnp path by subtracting likelihoods
    lik = sup + marg
    # the model's total minus our likelihood must be theta-only (prior+ladj):
    # check by shifting w: same theta, two datasets → differences match
    w2 = jnp.array((np.array(w) + 1) % HMM_V, dtype="int32")
    v2 = hmm_logp(theta, w2, z)
    sup2 = le[zn, np.array(w2)[:HMM_TSUP]].sum() + lt[zn[:-1], zn[1:]].sum()
    alpha = lt[zn[-1]] + le[:, np.array(w2)[HMM_TSUP]]
    for t in range(HMM_TSUP + 1, HMM_T):
        a = alpha[:, None] + lt
        mm = a.max(axis=0)
        alpha = mm + np.log(np.exp(a - mm).sum(axis=0)) + le[:, np.array(w2)[t]]
    mm = alpha.max()
    marg2 = mm + np.log(np.exp(alpha - mm).sum())
    assert_allclose(float(v) - float(v2), lik - (sup2 + marg2), rtol=1e-8)


def test_lda_matches_naive_token_loop_on_subset():
    from compile.models import lda_logp

    rng = np.random.default_rng(7)
    theta = jnp.array(rng.normal(size=MODELS["lda"].theta_dim) * 0.1)
    w = jnp.array(rng.integers(0, LDA_V, size=LDA_N), dtype="int32")
    doc = jnp.array(rng.integers(0, LDA_DOCS, size=LDA_N), dtype="int32")
    v = lda_logp(theta, w, doc)
    assert np.isfinite(float(v))
    # naive recomputation of the token likelihood for the first 100 tokens,
    # compared through a dataset-difference identity (priors cancel)
    off = 0
    th = []
    for _ in range(LDA_DOCS):
        r, _ = bij.simplex(theta[off : off + LDA_K - 1])
        th.append(np.array(r))
        off += LDA_K - 1
    ph = []
    for _ in range(LDA_K):
        r, _ = bij.simplex(theta[off : off + LDA_V - 1])
        ph.append(np.array(r))
        off += LDA_V - 1
    th = np.stack(th)
    ph = np.stack(ph)
    wn, dn = np.array(w), np.array(doc)
    lik = sum(np.log(th[dn[n]] @ ph[:, wn[n]]) for n in range(LDA_N))
    w2n = (wn + 1) % LDA_V
    lik2 = sum(np.log(th[dn[n]] @ ph[:, w2n[n]]) for n in range(LDA_N))
    v2 = lda_logp(theta, jnp.array(w2n, dtype="int32"), doc)
    assert_allclose(float(v) - float(v2), lik - lik2, rtol=1e-8)


def test_hier_poisson_matches_naive():
    spec = MODELS["hier_poisson"]
    rng = np.random.default_rng(8)
    y = jnp.array(rng.poisson(3.0, size=(10, 5)).astype(np.float64))
    theta = jnp.array(rng.normal(size=12) * 0.3)
    s = np.exp(float(theta[1]))
    lp = (
        d.normal_lp(float(theta[0]), 0.0, 10.0)
        + (np.log(1.0) - s)
        + float(theta[1])
    )
    b = np.array(theta)[2:]
    lp += np.sum(-0.5 * (b / s) ** 2 - np.log(s) - 0.5 * d.LN_2PI)
    from scipy.special import gammaln

    eta = float(theta[0]) + b
    yn = np.array(y)
    for g in range(10):
        lam = np.exp(eta[g])
        lp += np.sum(yn[g] * eta[g] - lam - gammaln(yn[g] + 1))
    assert_allclose(spec.logp(theta, y), lp, rtol=1e-9)
