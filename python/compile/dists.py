"""Log-density helpers mirroring rust/src/dist (same parameterizations).

Used by the L2 model definitions; kept scalar/vector-generic jnp so the
whole log-joint traces into one HLO module.
"""

import jax.numpy as jnp
from jax.scipy.special import gammaln

LN_2PI = 1.8378770664093454835606594728112353
LN_PI = 1.1447298858494001741434273513530587


def normal_lp(x, mu, sigma):
    z = (x - mu) / sigma
    return -0.5 * z * z - jnp.log(sigma) - 0.5 * LN_2PI


def cauchy_lp(x, loc, scale):
    z = (x - loc) / scale
    return -jnp.log1p(z * z) - jnp.log(scale) - LN_PI


def half_cauchy_lp(x, scale):
    z = x / scale
    return -jnp.log1p(z * z) - jnp.log(scale) + jnp.log(2.0 / jnp.pi)


def uniform_lp(x, lo, hi):
    del x
    return -jnp.log(hi - lo)


def exponential_lp(x, rate):
    return jnp.log(rate) - rate * x


def inverse_gamma_lp(x, shape, scale):
    return shape * jnp.log(scale) - gammaln(shape) - (shape + 1.0) * jnp.log(x) - scale / x


def dirichlet_lp(x, alpha):
    """alpha: (K,) concrete; x: (K,) on the simplex."""
    norm = gammaln(jnp.sum(alpha)) - jnp.sum(gammaln(alpha))
    return norm + jnp.sum((alpha - 1.0) * jnp.log(x))


def poisson_lp(k, rate):
    """k float-valued counts."""
    return k * jnp.log(rate) - rate - gammaln(k + 1.0)
