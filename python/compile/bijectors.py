"""Constrained <-> unconstrained transforms, mirroring rust/src/dist/bijector.rs.

The AOT-compiled log-densities (L2) must agree bit-for-bit in *semantics*
with the Rust typed executor: same transforms, same log-Jacobian terms, same
parameter ordering. Every function here takes unconstrained coordinates and
returns ``(constrained, log_abs_det_jacobian)``.
"""

import jax.numpy as jnp


def identity(y):
    """R^n -> R^n."""
    return y, jnp.zeros(())


def positive(y):
    """R -> (0, inf): x = exp(y), ladj = sum(y)."""
    return jnp.exp(y), jnp.sum(y)


def log_sigmoid(x):
    # stable -log1p(exp(-x))
    return -jnp.logaddexp(0.0, -x)


def unit_interval(y):
    """R -> (0,1): x = sigmoid(y), ladj = log sig(y) + log sig(-y)."""
    x = jnp.where(y >= 0, 1.0 / (1.0 + jnp.exp(-y)), jnp.exp(y) / (1.0 + jnp.exp(y)))
    ladj = jnp.sum(log_sigmoid(y) + log_sigmoid(-y))
    return x, ladj


def interval(y, lo, hi):
    """R -> (lo, hi): scaled sigmoid."""
    x, ladj = unit_interval(y)
    return lo + (hi - lo) * x, ladj + jnp.log(hi - lo) * jnp.size(y)


def simplex(y):
    """R^(K-1) -> K-simplex via Stan's stick-breaking (with the K-offset so
    y = 0 maps to the uniform simplex); returns (x[K], ladj).

    Mirrors Domain::Simplex in bijector.rs exactly.
    """
    k = y.shape[-1] + 1
    offsets = jnp.log(1.0 / jnp.arange(k - 1, 0, -1))
    adj = y + offsets
    z = jnp.where(
        adj >= 0, 1.0 / (1.0 + jnp.exp(-adj)), jnp.exp(adj) / (1.0 + jnp.exp(adj))
    )

    # sticks: x_i = z_i * prod_{j<i}(1 - z_j)
    one_minus = jnp.concatenate([jnp.ones((1,)), jnp.cumprod(1.0 - z)])
    x_head = z * one_minus[:-1]
    x_last = one_minus[-1]
    x = jnp.concatenate([x_head, x_last[None]])
    # ladj: sum_i [log z_i + log(1-z_i) + log stick_i] with stick_i = one_minus[i]
    ladj = jnp.sum(log_sigmoid(adj) + log_sigmoid(-adj) + jnp.log(one_minus[:-1]))
    return x, ladj
