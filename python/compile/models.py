"""L2: the 8 Table-1 benchmark models as JAX log-joints over unconstrained
parameters, calling the L1 Pallas kernels for their compute hot-spots.

Every model here mirrors — statement for statement, transform for
transform — the corresponding Rust DSL model in ``rust/src/models/``: the
Rust typed executor and the AOT artifact must produce the *same* scalar at
the same unconstrained point (checked by `rust/tests/runtime_aot.rs`).

Parameter layout (the typed trace's visit order) per model is documented on
each ``ModelSpec``; data buffers are runtime inputs to the compiled
artifact, in the order of ``data_specs``.
"""

from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from . import bijectors as bij
from . import dists as d
from .kernels.gauss_logpdf import gauss_logpdf
from .kernels.logreg import logreg_loglik
from .kernels.softmax_mix import softmax_mix


@dataclass
class ModelSpec:
    name: str
    theta_dim: int
    # (shape, dtype) per data input, in artifact argument order after theta
    data_specs: List[Tuple[Tuple[int, ...], str]]
    logp: Callable  # logp(theta, *data) -> scalar
    # Table-1 workload description (for DESIGN/EXPERIMENTS cross-reference)
    workload: str = ""
    meta: dict = field(default_factory=dict)


# ---------------------------------------------------------------- T1.1
# 10,000-D Gaussian: x ~ IsoNormal(0, 1, 10_000); no data.
GAUSS_DIM = 10_000


def gaussian_10kd_logp(theta):
    return gauss_logpdf(theta, jnp.float64(0.0), jnp.float64(1.0))


# ---------------------------------------------------------------- T1.2
# Gauss Unknown (gdemo at scale): s ~ InverseGamma(2,3); m ~ Normal(0, √s);
# y .~ Normal(m, √s), 10,000 observations.
GU_N = 10_000


def gauss_unknown_logp(theta, y):
    s, ladj_s = bij.positive(theta[0])
    m = theta[1]
    sd = jnp.sqrt(s)
    lp = d.inverse_gamma_lp(s, 2.0, 3.0) + ladj_s
    lp = lp + d.normal_lp(m, 0.0, sd)
    lp = lp + gauss_logpdf(y, m, sd)
    return lp


# ---------------------------------------------------------------- T1.3
# Naive Bayes: C=10 classes, D=40 features (synthetic PCA-MNIST), N=1000
# labelled observations. mu[c] ~ IsoNormal(0,1,D); x_i ~ Normal(mu[c_i], 1).
NB_C, NB_D, NB_N = 10, 40, 1000


def naive_bayes_logp(theta, x, onehot):
    mu = theta.reshape(NB_C, NB_D)
    # prior via the Pallas reduction over the flattened means
    lp = gauss_logpdf(theta, jnp.float64(0.0), jnp.float64(1.0))
    # likelihood: -0.5 Σ_i ||x_i - mu_{c_i}||² - N·D/2 ln 2π
    mu_per_obs = onehot @ mu  # (N, D)
    diff = x - mu_per_obs
    lp = lp - 0.5 * jnp.sum(diff * diff) - 0.5 * NB_N * NB_D * d.LN_2PI
    return lp


# ---------------------------------------------------------------- T1.4
# Logistic Regression: D=100, N=10,000. w ~ IsoNormal(0,1,D);
# y .~ BernoulliLogit(X w).
LR_N, LR_D = 10_000, 100


def logreg_logp(theta, x, y):
    lp = gauss_logpdf(theta, jnp.float64(0.0), jnp.float64(1.0))
    lp = lp + logreg_loglik(x, theta, y)
    return lp


# ---------------------------------------------------------------- T1.5
# Hierarchical Poisson: G=10 groups × M=5 obs = 50 observations.
# a0 ~ Normal(0,10); σ ~ Exponential(1); b[g] ~ Normal(0,σ);
# y_gm ~ Poisson(exp(a0 + b_g)).
HP_G, HP_M = 10, 5


def hier_poisson_logp(theta, y):
    a0 = theta[0]
    sigma, ladj = bij.positive(theta[1])
    b = theta[2:]
    lp = d.normal_lp(a0, 0.0, 10.0)
    lp = lp + d.exponential_lp(sigma, 1.0) + ladj
    lp = lp + jnp.sum(d.normal_lp(b, 0.0, sigma))
    eta = a0 + b  # (G,)
    rate = jnp.exp(eta)
    lp = lp + jnp.sum(d.poisson_lp(y, rate[:, None]))
    return lp


# ---------------------------------------------------------------- T1.6
# Stochastic Volatility: T=500. φ ~ Uniform(-1,1); σ ~ HalfCauchy(2);
# μ ~ Cauchy(0,10); h₀ ~ N(μ, σ/√(1-φ²)); h_t ~ N(μ+φ(h_{t-1}-μ), σ);
# y_t ~ N(0, exp(h_t/2)).
SV_T = 500


def sto_vol_logp(theta, y):
    phi, ladj_phi = bij.interval(theta[0], -1.0, 1.0)
    sigma, ladj_sig = bij.positive(theta[1])
    mu = theta[2]
    h = theta[3:]
    lp = d.uniform_lp(phi, -1.0, 1.0) + ladj_phi
    lp = lp + d.half_cauchy_lp(sigma, 2.0) + ladj_sig
    lp = lp + d.cauchy_lp(mu, 0.0, 10.0)
    lp = lp + d.normal_lp(h[0], mu, sigma / jnp.sqrt(1.0 - phi * phi))
    lp = lp + jnp.sum(d.normal_lp(h[1:], mu + phi * (h[:-1] - mu), sigma))
    # y_t ~ Normal(0, exp(h_t / 2))
    lp = lp + jnp.sum(-0.5 * y * y * jnp.exp(-h) - 0.5 * h - 0.5 * d.LN_2PI)
    return lp


# ---------------------------------------------------------------- T1.7
# Semi-supervised HMM: K=5 states, V=20 symbols, T=300 steps of which the
# first 100 have supervised states; the last 200 are marginalized by the
# forward algorithm. trans[k] ~ Dirichlet(1,K); emit[k] ~ Dirichlet(1,V).
HMM_K, HMM_V, HMM_T, HMM_TSUP = 5, 20, 300, 100


def hmm_logp(theta, w, z_sup):
    """w: (T,) int32 observations; z_sup: (TSUP,) int32 supervised states."""
    off = 0
    rows_t = []
    ladj = jnp.zeros(())
    for _ in range(HMM_K):
        r, la = bij.simplex(theta[off : off + HMM_K - 1])
        rows_t.append(r)
        ladj = ladj + la
        off += HMM_K - 1
    rows_e = []
    for _ in range(HMM_K):
        r, la = bij.simplex(theta[off : off + HMM_V - 1])
        rows_e.append(r)
        ladj = ladj + la
        off += HMM_V - 1
    trans = jnp.stack(rows_t)  # (K, K)
    emit = jnp.stack(rows_e)  # (K, V)
    alpha_conc = jnp.ones((HMM_K,))
    beta_conc = jnp.ones((HMM_V,))
    lp = ladj
    for k in range(HMM_K):
        lp = lp + d.dirichlet_lp(trans[k], alpha_conc)
        lp = lp + d.dirichlet_lp(emit[k], beta_conc)

    log_trans = jnp.log(trans)
    log_emit = jnp.log(emit)

    # supervised segment
    w_sup = w[:HMM_TSUP]
    lp = lp + jnp.sum(log_emit[z_sup, w_sup])
    lp = lp + jnp.sum(log_trans[z_sup[:-1], z_sup[1:]])

    # unsupervised segment: forward algorithm from the last supervised state
    w_unsup = w[HMM_TSUP:]
    alpha0 = log_trans[z_sup[-1]] + log_emit[:, w_unsup[0]]

    def step(alpha, wt):
        a = alpha[:, None] + log_trans  # (K, K)
        m = jnp.max(a, axis=0)
        nxt = m + jnp.log(jnp.sum(jnp.exp(a - m[None, :]), axis=0)) + log_emit[:, wt]
        return nxt, ()

    alpha_fin, _ = jax.lax.scan(step, alpha0, w_unsup[1:])
    m = jnp.max(alpha_fin)
    lp = lp + m + jnp.log(jnp.sum(jnp.exp(alpha_fin - m)))
    return lp


# ---------------------------------------------------------------- T1.8
# LDA: K=5 topics, V=100 vocabulary, DOCS=10 documents × ~1000 tokens
# (N=10,000 total). theta[d] ~ Dirichlet(1,K); phi[k] ~ Dirichlet(1,V);
# token n: w_n ~ Categorical(Σ_k theta[doc_n] φ_k) (z marginalized).
LDA_K, LDA_V, LDA_DOCS, LDA_N = 5, 100, 10, 10_000


def lda_logp(theta, w, doc):
    off = 0
    ladj = jnp.zeros(())
    th_rows = []
    for _ in range(LDA_DOCS):
        r, la = bij.simplex(theta[off : off + LDA_K - 1])
        th_rows.append(r)
        ladj = ladj + la
        off += LDA_K - 1
    ph_rows = []
    for _ in range(LDA_K):
        r, la = bij.simplex(theta[off : off + LDA_V - 1])
        ph_rows.append(r)
        ladj = ladj + la
        off += LDA_V - 1
    th = jnp.stack(th_rows)  # (DOCS, K)
    ph = jnp.stack(ph_rows)  # (K, V)
    lp = ladj
    for r in th_rows:
        lp = lp + d.dirichlet_lp(r, jnp.ones((LDA_K,)))
    for r in ph_rows:
        lp = lp + d.dirichlet_lp(r, jnp.ones((LDA_V,)))

    # token mixture via the Pallas LSE kernel: comps[k, n] = log θ[doc_n, k]
    # + log φ[k, w_n]; weights zero.
    log_th = jnp.log(th)  # (DOCS, K)
    log_ph = jnp.log(ph)  # (K, V)
    comps = log_th[doc].T + log_ph[:, w]  # (K, N)
    lp = lp + softmax_mix(jnp.zeros((LDA_K,)), comps)
    return lp


# ------------------------------------------------------------ registry

MODELS = {
    "gaussian_10kd": ModelSpec(
        name="gaussian_10kd",
        theta_dim=GAUSS_DIM,
        data_specs=[],
        logp=gaussian_10kd_logp,
        workload="single 10,000-dim standard normal parameter",
    ),
    "gauss_unknown": ModelSpec(
        name="gauss_unknown",
        theta_dim=2,
        data_specs=[((GU_N,), "float64")],
        logp=gauss_unknown_logp,
        workload="10,000 scalar observations, unknown mean and variance",
    ),
    "naive_bayes": ModelSpec(
        name="naive_bayes",
        theta_dim=NB_C * NB_D,
        data_specs=[((NB_N, NB_D), "float64"), ((NB_N, NB_C), "float64")],
        logp=naive_bayes_logp,
        workload="1,000 obs × 40 dims, 10 classes (synthetic PCA-MNIST)",
    ),
    "logreg": ModelSpec(
        name="logreg",
        theta_dim=LR_D,
        data_specs=[((LR_N, LR_D), "float64"), ((LR_N,), "float64")],
        logp=logreg_logp,
        workload="10,000 obs × 100 dims logistic regression",
    ),
    "hier_poisson": ModelSpec(
        name="hier_poisson",
        theta_dim=2 + HP_G,
        data_specs=[((HP_G, HP_M), "float64")],
        logp=hier_poisson_logp,
        workload="50 obs hierarchical Poisson (10 groups × 5)",
    ),
    "sto_volatility": ModelSpec(
        name="sto_volatility",
        theta_dim=3 + SV_T,
        data_specs=[((SV_T,), "float64")],
        logp=sto_vol_logp,
        workload="500-step stochastic volatility",
    ),
    "hmm_semisup": ModelSpec(
        name="hmm_semisup",
        theta_dim=HMM_K * (HMM_K - 1) + HMM_K * (HMM_V - 1),
        data_specs=[((HMM_T,), "int32"), ((HMM_TSUP,), "int32")],
        logp=hmm_logp,
        workload="K=5, V=20, 300 obs (200 unsupervised, forward-marginalized)",
    ),
    "lda": ModelSpec(
        name="lda",
        theta_dim=LDA_DOCS * (LDA_K - 1) + LDA_K * (LDA_V - 1),
        data_specs=[((LDA_N,), "int32"), ((LDA_N,), "int32")],
        logp=lda_logp,
        workload="V=100, K=5, 10 docs × ~1,000 words (topics marginalized)",
    ),
}
