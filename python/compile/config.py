"""Kernel implementation switch for the L1 hot-spots.

``KERNEL_IMPL`` selects how the three L1 kernels execute inside the L2
log-joints:

- ``"pallas"`` — the Pallas kernels under ``interpret=True``. This is the
  *validation* configuration: it exercises the real kernel code (BlockSpec
  schedule, masking, VMEM tiling structure) with CPU-numpy semantics.
  Interpret-mode lowering produces a grid loop of dynamic-slice ops, which
  the CPU PJRT backend executes slowly — it is NOT a performance proxy
  (see DESIGN.md §Hardware-Adaptation).
- ``"jnp"`` — the pure-jnp reference expressions (ref.py), which XLA fuses
  into tight CPU loops. This is the *runtime* configuration used by the
  Table-1 artifacts: on a real TPU the Pallas kernel would play this role.

`make artifacts` builds the runtime artifacts with "jnp" and one
validation artifact per kernel-bearing model with "pallas"
(``<model>.pallas.hlo.txt``); `rust/tests/runtime_aot.rs` checks both
against the Rust typed executor.
"""

KERNEL_IMPL = "jnp"


def use_pallas() -> bool:
    return KERNEL_IMPL == "pallas"


def set_impl(impl: str) -> None:
    global KERNEL_IMPL
    assert impl in ("pallas", "jnp"), impl
    KERNEL_IMPL = impl
