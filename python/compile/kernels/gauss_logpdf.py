"""L1 Pallas kernel: blocked iid-Gaussian squared-error reduction.

The compute hot-spot of the 10,000-D Gaussian and Gauss-Unknown benchmarks:
``S = sum(((x - mu)/sigma)^2)`` over a long vector, tiled so each grid step
streams one block through VMEM and writes one partial sum. On a real TPU
each (block,) tile is a single HBM->VMEM DMA and the reduction runs on the
VPU; under ``interpret=True`` (mandatory on CPU PJRT) the same schedule
executes with numpy semantics.

The wrapper is differentiable via an analytic ``custom_vjp`` (the backward
pass is closed-form and XLA fuses it), so ``jax.value_and_grad`` of any
model using this kernel AOT-lowers cleanly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _sq_sum_kernel(x_ref, mask_ref, mu_ref, inv_sigma_ref, out_ref):
    z = (x_ref[...] - mu_ref[0]) * inv_sigma_ref[0] * mask_ref[...]
    out_ref[0] = jnp.sum(z * z)


def _sq_sum_partials(x, mu, sigma, block):
    from .. import config

    if not config.use_pallas():
        z = (x - mu) / sigma
        return jnp.sum(z * z)
    n = x.shape[0]
    nb = -(-n // block)  # ceil div
    pad = nb * block - n
    xp = jnp.pad(x, (0, pad))
    # mask via iota (not a literal constant: large constants are elided by
    # the HLO text printer, which would corrupt the AOT artifact)
    mask = (jnp.arange(nb * block) < n).astype(x.dtype)
    partials = pl.pallas_call(
        _sq_sum_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), x.dtype),
        interpret=True,
    )(xp, mask, mu[None], (1.0 / sigma)[None])
    return jnp.sum(partials)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def sq_sum(x, mu, sigma, block=DEFAULT_BLOCK):
    """``sum(((x - mu)/sigma)^2)`` with a Pallas forward pass."""
    return _sq_sum_partials(x, mu, sigma, block)


def _sq_sum_fwd(x, mu, sigma, block):
    s = _sq_sum_partials(x, mu, sigma, block)
    return s, (x, mu, sigma, s)


def _sq_sum_bwd(block, res, g):
    x, mu, sigma, s = res
    z = (x - mu) / sigma
    dx = g * 2.0 * z / sigma
    dmu = -jnp.sum(dx)
    dsigma = -g * 2.0 * s / sigma
    return dx, dmu, dsigma


sq_sum.defvjp(_sq_sum_fwd, _sq_sum_bwd)


def gauss_logpdf(x, mu, sigma, block=DEFAULT_BLOCK):
    """Sum of iid Normal(mu, sigma) log-densities via the Pallas reduction."""
    n = x.shape[0]
    from .ref import LN_2PI

    return -0.5 * sq_sum(x, mu, sigma, block) - n * jnp.log(sigma) - 0.5 * n * LN_2PI
