"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: pytest sweeps shapes and dtypes
(hypothesis) and asserts the Pallas kernels' outputs match these to within
float tolerance. They are also what the kernels' HLO is compared against in
the Rust runtime tests (via the AOT artifacts).
"""

import jax.numpy as jnp

LN_2PI = 1.8378770664093454835606594728112353


def gauss_logpdf_ref(x, mu, sigma):
    """Sum over iid Normal(mu, sigma) log-densities of a vector x."""
    z = (x - mu) / sigma
    n = x.shape[0]
    return -0.5 * jnp.sum(z * z) - n * jnp.log(sigma) - 0.5 * n * LN_2PI


def logreg_loglik_ref(xm, w, y):
    """Bernoulli-logit log-likelihood: sum_i log sigmoid((2 y_i - 1) x_i.w).

    ``xm``: (N, D) float; ``w``: (D,) float; ``y``: (N,) float in {0, 1}.
    """
    logits = xm @ w
    sign = 2.0 * y - 1.0
    return jnp.sum(-jnp.logaddexp(0.0, -sign * logits))


def softmax_mix_ref(log_weights, log_comps):
    """Mixture log-likelihood: sum_n LSE_k(log_weights[k] + log_comps[k, n]).

    ``log_weights``: (K,); ``log_comps``: (K, N).
    """
    a = log_weights[:, None] + log_comps
    m = jnp.max(a, axis=0)
    return jnp.sum(m + jnp.log(jnp.sum(jnp.exp(a - m[None, :]), axis=0)))


def sq_dist_ref(x, mu):
    """Sum of squared distances of rows of x (N, D) to a vector mu (D,)."""
    d = x - mu[None, :]
    return jnp.sum(d * d)
