"""L1 Pallas kernel: blocked mixture (log-sum-exp) log-likelihood.

Hot spot of the Naive Bayes / LDA benchmarks: for N items and K mixture
components, reduce ``sum_n LSE_k(log_weights[k] + log_comps[k, n])``. Each
grid step loads a (K, block_n) tile of component scores into VMEM and
reduces it; K is small (5-10) so tiles are long and thin.

Backward is the softmax responsibilities, closed-form via custom_vjp.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048


def _mix_kernel(lw_ref, lc_ref, mask_ref, out_ref):
    a = lw_ref[...][:, None] + lc_ref[...]
    m = jnp.max(a, axis=0)
    lse = m + jnp.log(jnp.sum(jnp.exp(a - m[None, :]), axis=0))
    out_ref[0] = jnp.sum(lse * mask_ref[...])


def _mix_partials(log_weights, log_comps, block_n):
    from .. import config

    if not config.use_pallas():
        a = log_weights[:, None] + log_comps
        m = jnp.max(a, axis=0)
        return jnp.sum(m + jnp.log(jnp.sum(jnp.exp(a - m[None, :]), axis=0)))
    k, n = log_comps.shape
    nb = -(-n // block_n)
    pad = nb * block_n - n
    lcp = jnp.pad(log_comps, ((0, 0), (0, pad)))
    mask = (jnp.arange(nb * block_n) < n).astype(log_comps.dtype)
    partials = pl.pallas_call(
        _mix_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), log_comps.dtype),
        interpret=True,
    )(log_weights, lcp, mask)
    return jnp.sum(partials)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_mix(log_weights, log_comps, block_n=DEFAULT_BLOCK_N):
    """``sum_n LSE_k(log_weights[k] + log_comps[k,n])`` via Pallas."""
    return _mix_partials(log_weights, log_comps, block_n)


def _fwd(log_weights, log_comps, block_n):
    s = _mix_partials(log_weights, log_comps, block_n)
    return s, (log_weights, log_comps)


def _bwd(block_n, res, g):
    log_weights, log_comps = res
    a = log_weights[:, None] + log_comps
    r = jax.nn.softmax(a, axis=0)  # responsibilities
    dlw = g * jnp.sum(r, axis=1)
    dlc = g * r
    return dlw, dlc


softmax_mix.defvjp(_fwd, _bwd)
