"""L1 Pallas kernel: fused logistic-regression log-likelihood.

Hot spot of the Logistic Regression benchmark (10,000 x 100): for each row
block, compute ``logits = X_blk @ w`` (MXU-shaped matvec) and reduce the
Bernoulli-logit log-likelihood ``sum log sigmoid((2y-1) * logits)`` without
materializing the logits in HBM. One (block_n, D) tile of X streams through
VMEM per grid step; w stays resident.

TPU mapping (DESIGN.md §Hardware-Adaptation): X tile (512 x 100 f32 = 200 KB)
+ w (400 B) fit comfortably in 16 MB VMEM with double buffering; the matvec
N=1 shape is VPU-bound so the roofline is HBM bandwidth on X.

Backward pass is the closed-form ``X^T (y - sigmoid(logits))``, supplied via
custom_vjp so AOT gradient lowering never differentiates through the kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _loglik_kernel(x_ref, sign_ref, w_ref, out_ref):
    logits = x_ref[...] @ w_ref[...]
    s = sign_ref[...]
    # masked rows have sign 0 -> contribute log sigmoid(0)... avoid that by
    # weighting: contribution = -|s| * log1p(exp(-s*logits)) with |s| in {0,1}
    ll = -jnp.abs(s) * jnp.logaddexp(0.0, -s * logits)
    out_ref[0] = jnp.sum(ll)


def _loglik_partials(xm, w, y, block_n):
    from .. import config

    if not config.use_pallas():
        logits = xm @ w
        sign = 2.0 * y - 1.0
        return jnp.sum(-jnp.logaddexp(0.0, -sign * logits))
    n, d = xm.shape
    nb = -(-n // block_n)
    pad = nb * block_n - n
    xp = jnp.pad(xm, ((0, pad), (0, 0)))
    sign = jnp.pad(2.0 * y - 1.0, (0, pad))
    partials = pl.pallas_call(
        _loglik_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), xm.dtype),
        interpret=True,
    )(xp, sign, w)
    return jnp.sum(partials)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def logreg_loglik(xm, w, y, block_n=DEFAULT_BLOCK_N):
    """Bernoulli-logit log-likelihood with a fused Pallas forward pass."""
    return _loglik_partials(xm, w, y, block_n)


def _fwd(xm, w, y, block_n):
    s = _loglik_partials(xm, w, y, block_n)
    return s, (xm, w, y)


def _bwd(block_n, res, g):
    xm, w, y = res
    logits = xm @ w
    p = jax.nn.sigmoid(logits)
    dw = g * (xm.T @ (y - p))
    # data cotangents unused by the models (data is constant) but must be
    # shaped correctly
    dx = g * jnp.outer(y - p, w)
    dy = g * logits
    return dx, dw, dy


logreg_loglik.defvjp(_fwd, _bwd)
