"""AOT lowering: each benchmark model's ``value_and_grad(logp)`` → HLO text.

This is the build-time half of the architecture: Python/JAX runs ONCE here
(`make artifacts`), emitting one ``<model>.vg.hlo.txt`` per benchmark model
plus a plain-text manifest. The Rust runtime loads the HLO text through the
PJRT CPU client and executes it on the sampling hot path — Python never
runs at inference time.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import config  # noqa: E402
from .models import MODELS  # noqa: E402

# models whose log-joint calls an L1 kernel (get a pallas validation artifact)
KERNEL_MODELS = ["gaussian_10kd", "gauss_unknown", "naive_bayes", "logreg", "lda"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(spec):
    """Lower value_and_grad of the model's log-joint: (theta, *data) ->
    (logp, grad)."""

    def vg(theta, *data):
        return jax.value_and_grad(spec.logp, argnums=0)(theta, *data)

    args = [jax.ShapeDtypeStruct((spec.theta_dim,), jnp.float64)]
    for shape, dtype in spec.data_specs:
        args.append(jax.ShapeDtypeStruct(shape, dtype))
    return jax.jit(vg).lower(*args)


def lower_traj(spec, n_leapfrog: int = 4):
    """Lower a fused static-HMC leapfrog trajectory (identity mass):

        (theta, p, eps, *data) -> (theta_L, p_L, logp_L)

    One PJRT call per HMC iteration instead of n_leapfrog+1 — the §Perf
    optimization that removes host↔runtime round-trips on the hot path.
    """

    def traj(theta, p, eps, g0, *data):
        def vg(t):
            return jax.value_and_grad(spec.logp, argnums=0)(t, *data)

        def step(carry, _):
            th, pp, g = carry
            pp = pp + 0.5 * eps * g
            th = th + eps * pp
            lp, g = vg(th)
            pp = pp + 0.5 * eps * g
            return (th, pp, g), lp

        # the caller threads the gradient across iterations, so a
        # trajectory costs exactly n_leapfrog gradient evaluations
        (theta, p, g), lps = jax.lax.scan(
            step, (theta, p, g0), None, length=n_leapfrog
        )
        return theta, p, lps[-1], g

    args = [
        jax.ShapeDtypeStruct((spec.theta_dim,), jnp.float64),
        jax.ShapeDtypeStruct((spec.theta_dim,), jnp.float64),
        jax.ShapeDtypeStruct((), jnp.float64),
        jax.ShapeDtypeStruct((spec.theta_dim,), jnp.float64),
    ]
    for shape, dtype in spec.data_specs:
        args.append(jax.ShapeDtypeStruct(shape, dtype))
    return jax.jit(traj).lower(*args)


def manifest_line(spec) -> str:
    inputs = [f"theta:float64:{spec.theta_dim}"]
    for i, (shape, dtype) in enumerate(spec.data_specs):
        dims = "x".join(str(s) for s in shape)
        inputs.append(f"data{i}:{dtype}:{dims}")
    return f"model={spec.name} theta_dim={spec.theta_dim} inputs={';'.join(inputs)} outputs=logp,grad"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--models", default="all", help="comma-separated model names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(MODELS) if args.models == "all" else args.models.split(",")
    manifest = []
    for name in names:
        spec = MODELS[name]
        # runtime artifact: fused-jnp kernels (XLA-fused CPU hot path; the
        # role the Pallas kernel plays on real TPU hardware)
        config.set_impl("jnp")
        path = os.path.join(args.out, f"{name}.vg.hlo.txt")
        text = to_hlo_text(lower_model(spec))
        with open(path, "w") as f:
            f.write(text)
        manifest.append(manifest_line(spec))
        print(f"wrote {path} ({len(text)} chars)")
        # fused 4-leapfrog trajectory artifact (perf path)
        tpath = os.path.join(args.out, f"{name}.traj4.hlo.txt")
        ttext = to_hlo_text(lower_traj(spec, 4))
        with open(tpath, "w") as f:
            f.write(ttext)
        print(f"wrote {tpath} ({len(ttext)} chars)")
        # validation artifact: the real Pallas kernels (interpret lowering)
        if name in KERNEL_MODELS:
            config.set_impl("pallas")
            ppath = os.path.join(args.out, f"{name}.pallas.hlo.txt")
            ptext = to_hlo_text(lower_model(spec))
            with open(ppath, "w") as f:
                f.write(ptext)
            print(f"wrote {ppath} ({len(ptext)} chars)")
            config.set_impl("jnp")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} models")


if __name__ == "__main__":
    main()
