//! Minimal, dependency-free subset of the `rand_core` 0.6 API.
//!
//! Vendored so the workspace builds with no network access: the crate
//! exposes exactly the surface this repository uses — the [`RngCore`]
//! trait (with the 0.6-era fallible `try_fill_bytes`) and an opaque
//! [`Error`] type. Generators in `dynamicppl::util::rng` implement
//! `RngCore`, and everything downstream is generic over it, so swapping
//! this for the real crates.io `rand_core` is a one-line manifest change.

use std::fmt;

/// Opaque RNG error (never produced by the in-tree generators, which are
/// infallible; present only to satisfy the 0.6 trait signature).
pub struct Error {
    msg: &'static str,
}

impl Error {
    pub fn new(msg: &'static str) -> Self {
        Self { msg }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rand_core::Error({})", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// The core RNG trait: raw 32/64-bit output plus byte filling.
pub trait RngCore {
    fn next_u32(&mut self) -> u32;
    fn next_u64(&mut self) -> u64;
    fn fill_bytes(&mut self, dest: &mut [u8]);
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error>;
}

impl<R: RngCore + ?Sized> RngCore for &mut R {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (**self).next_u32()
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        (**self).next_u64()
    }

    #[inline]
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        (**self).fill_bytes(dest)
    }

    #[inline]
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
        (**self).try_fill_bytes(dest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter(u64);

    impl RngCore for Counter {
        fn next_u32(&mut self) -> u32 {
            self.next_u64() as u32
        }
        fn next_u64(&mut self) -> u64 {
            self.0 = self.0.wrapping_add(1);
            self.0
        }
        fn fill_bytes(&mut self, dest: &mut [u8]) {
            for b in dest {
                *b = self.next_u64() as u8;
            }
        }
        fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
            self.fill_bytes(dest);
            Ok(())
        }
    }

    #[test]
    fn trait_object_and_reference_impls() {
        let mut c = Counter(0);
        assert_eq!(c.next_u64(), 1);
        let r: &mut dyn RngCore = &mut c;
        assert_eq!(r.next_u64(), 2);
        let mut buf = [0u8; 3];
        (&mut c).fill_bytes(&mut buf);
        assert!((&mut c).try_fill_bytes(&mut buf).is_ok());
        let _ = format!("{:?} {}", Error::new("x"), Error::new("x"));
    }
}
