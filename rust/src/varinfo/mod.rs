//! Execution traces (the paper's §2.2): `UntypedVarInfo` and
//! `TypedVarInfo`.
//!
//! The central performance mechanism of the paper: run the model once with
//! a dynamically-typed trace that can absorb any variable structure
//! ([`UntypedVarInfo`] — boxed values, hash-map addressing), then
//! *specialize* it into a strictly-typed, flat representation
//! ([`TypedVarInfo`]) whose layout the hot loop walks with a cursor — no
//! hashing, no boxing, no dispatch. In Julia the specialization step lets
//! the compiler generate monomorphic machine code; here it additionally
//! fixes the parameter layout that the AOT-compiled XLA log-density
//! artifact (the "generated machine code" of this reproduction) consumes.

pub mod batch;
pub mod typed;
pub mod untyped;

pub use batch::BatchVarInfo;
pub use typed::{Slot, TraceSnapshot, TypedVarInfo};
pub use untyped::{UntypedVarInfo, VarRecord};

/// Per-variable flags (paper: `set_flag!`/`is_flagged`).
pub mod flags {
    /// Value should be re-drawn on the next sampling run ("del" flag).
    pub const RESAMPLE: u8 = 1 << 0;
    /// Value was produced by this run's sampler (vs carried over).
    pub const TRANS: u8 = 1 << 1;
    /// Particle samplers: this record has been scored by an observation
    /// window and is part of the retained trajectory — resampling forks
    /// must never regenerate it. Robust against dynamic models whose
    /// visit order diverges from record insertion order (a prefix
    /// *count* is not; see `crate::particle::exec`).
    pub const LOCKED: u8 = 1 << 2;
}
