//! The dynamically-typed trace: boxed values, hash-map addressing.
//!
//! This is the paper's `UntypedVarInfo` — `Vector{Real}` / abstract
//! element types in Julia, an enum-boxed [`Value`] plus [`AnyDist`] here.
//! It can absorb *any* model structure on first contact (dynamic model
//! dimensionality, type changes between runs), at the price of per-access
//! boxing and hashing. After one successful run it is specialized into
//! [`super::TypedVarInfo`].

use crate::dist::{bijector, AnyDist, Domain};
use crate::util::hash::FnvHashMap;
use crate::value::Value;
use crate::varname::VarName;

/// One traced random variable: value, distribution and support metadata.
#[derive(Clone, Debug)]
pub struct VarRecord {
    pub vn: VarName,
    pub value: Value,
    pub dist: AnyDist,
    pub domain: Domain,
    pub flags: u8,
}

/// Dynamically-typed execution trace.
#[derive(Clone, Debug, Default)]
pub struct UntypedVarInfo {
    records: Vec<VarRecord>,
    /// FNV-1a-keyed: `VarName`s are short program-controlled keys, where
    /// SipHash is pure overhead (see `util::hash`).
    index: FnvHashMap<VarName, usize>,
    /// log-density of the last full evaluation that used this trace
    pub logp: f64,
}

impl UntypedVarInfo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn contains(&self, vn: &VarName) -> bool {
        self.index.contains_key(vn)
    }

    /// Insert a fresh variable; returns its record index. Panics if already
    /// present (model visited the same VarName twice in one run — a model
    /// bug the paper's DSL also rejects).
    pub fn insert(&mut self, vn: VarName, value: Value, dist: AnyDist) -> usize {
        assert!(
            !self.index.contains_key(&vn),
            "duplicate tilde statement for variable {vn}"
        );
        let domain = dist.domain();
        let idx = self.records.len();
        self.index.insert(vn.clone(), idx);
        self.records.push(VarRecord {
            vn,
            value,
            dist,
            domain,
            flags: 0,
        });
        idx
    }

    pub fn get(&self, vn: &VarName) -> Option<&VarRecord> {
        self.index.get(vn).map(|&i| &self.records[i])
    }

    pub fn get_mut(&mut self, vn: &VarName) -> Option<&mut VarRecord> {
        let i = *self.index.get(vn)?;
        Some(&mut self.records[i])
    }

    /// Update value + distribution metadata for an existing variable (the
    /// distribution's parameters may change between runs when they depend
    /// on other parameters).
    pub fn update(&mut self, vn: &VarName, value: Value, dist: AnyDist) {
        let rec = self.get_mut(vn).expect("update of unknown variable");
        rec.domain = dist.domain();
        rec.value = value;
        rec.dist = dist;
    }

    pub fn set_value(&mut self, vn: &VarName, value: Value) {
        let rec = self.get_mut(vn).expect("set_value of unknown variable");
        rec.value = value;
    }

    pub fn set_flag(&mut self, vn: &VarName, flag: u8) {
        if let Some(rec) = self.get_mut(vn) {
            rec.flags |= flag;
        }
    }

    pub fn clear_flag(&mut self, vn: &VarName, flag: u8) {
        if let Some(rec) = self.get_mut(vn) {
            rec.flags &= !flag;
        }
    }

    pub fn is_flagged(&self, vn: &VarName, flag: u8) -> bool {
        self.get(vn).map(|r| r.flags & flag != 0).unwrap_or(false)
    }

    /// Set the resample flag on every record (force fresh draws next run).
    pub fn flag_all_resample(&mut self) {
        for rec in &mut self.records {
            rec.flags |= super::flags::RESAMPLE;
        }
    }

    /// Set `flag` on every record with insertion (visit) index `>= from`
    /// whose name is subsumed by one of `scope` (every record when `scope`
    /// is `None`). This is the particle-sampler "del" sweep: after a
    /// resampling fork, the retained prefix is kept and the suffix is
    /// regenerated on the next replay run.
    pub fn flag_suffix(&mut self, from: usize, scope: Option<&[VarName]>, flag: u8) {
        for rec in self.records.iter_mut().skip(from) {
            let in_scope = match scope {
                None => true,
                Some(vars) => vars.iter().any(|v| rec.vn.subsumed_by(v)),
            };
            if in_scope {
                rec.flags |= flag;
            }
        }
    }

    /// Record by insertion (visit) index.
    pub fn record(&self, i: usize) -> &VarRecord {
        &self.records[i]
    }

    /// Insertion index of a variable, if present.
    pub fn index_of(&self, vn: &VarName) -> Option<usize> {
        self.index.get(vn).copied()
    }

    /// Set `flag` on the record at insertion index `i`.
    pub fn flag_record(&mut self, i: usize, flag: u8) {
        self.records[i].flags |= flag;
    }

    /// Overwrite the whole flag byte of record `i` (particle demotion:
    /// typed per-slot flags are copied back verbatim).
    pub fn set_record_flags(&mut self, i: usize, flags: u8) {
        self.records[i].flags = flags;
    }

    /// Set `flag` on every in-`scope` record that does **not** carry the
    /// `LOCKED` stamp — the particle-fork regeneration sweep: locked
    /// records have been scored and must replay; everything else is fair
    /// game to redraw.
    pub fn flag_unlocked(&mut self, scope: Option<&[VarName]>, flag: u8) {
        for rec in &mut self.records {
            if rec.flags & super::flags::LOCKED != 0 {
                continue;
            }
            let in_scope = match scope {
                None => true,
                Some(vars) => vars.iter().any(|v| rec.vn.subsumed_by(v)),
            };
            if in_scope {
                rec.flags |= flag;
            }
        }
    }

    /// Clear `flag` (a bit mask; may combine flags) on every record.
    pub fn clear_flag_all(&mut self, flag: u8) {
        for rec in &mut self.records {
            rec.flags &= !flag;
        }
    }

    /// Records in insertion (visit) order.
    pub fn records(&self) -> &[VarRecord] {
        &self.records
    }

    /// Number of unconstrained (continuous) coordinates.
    pub fn num_unconstrained(&self) -> usize {
        self.records
            .iter()
            .map(|r| r.domain.unconstrained_dim())
            .sum()
    }

    /// Flatten all continuous variables to unconstrained coordinates in
    /// visit order (the `link` step).
    pub fn to_unconstrained(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.num_unconstrained());
        for rec in &self.records {
            if rec.domain.is_discrete() {
                continue;
            }
            match &rec.value {
                Value::F64(x) => bijector::link(&rec.domain, &[*x], &mut out),
                Value::Vec(v) => bijector::link(&rec.domain, v, &mut out),
                other => panic!("continuous domain with non-continuous value {other:?}"),
            }
        }
        out
    }

    /// Write unconstrained coordinates back into the boxed values (the
    /// `invlink` step); `theta` must have `num_unconstrained()` entries.
    pub fn set_from_unconstrained(&mut self, theta: &[f64]) {
        let mut off = 0;
        let mut buf: Vec<f64> = Vec::new();
        for rec in &mut self.records {
            let d = rec.domain.unconstrained_dim();
            if d == 0 {
                continue;
            }
            buf.clear();
            let _ = bijector::invlink(&rec.domain, &theta[off..off + d], &mut buf);
            off += d;
            rec.value = match &rec.value {
                Value::F64(_) => Value::F64(buf[0]),
                Value::Vec(_) => Value::Vec(buf.clone()),
                other => panic!("continuous domain with non-continuous value {other:?}"),
            };
        }
        assert_eq!(off, theta.len(), "theta length mismatch");
    }

    /// Sum of prior log-densities at the current values (in constrained
    /// space, no Jacobian) — the boxed slow path used by MH and tests.
    pub fn prior_logp(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.dist.logpdf(&r.value))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dirichlet, Gamma, IsoNormal, Normal, ScalarDist, VecDist};
    use crate::varinfo::flags;

    fn demo_vi() -> UntypedVarInfo {
        let mut vi = UntypedVarInfo::new();
        vi.insert(
            VarName::new("s"),
            Value::F64(2.0),
            ScalarDist::Gamma(Gamma::new(2.0, 3.0)).boxed(),
        );
        vi.insert(
            VarName::new("w"),
            Value::Vec(vec![0.1, -0.2, 0.3]),
            VecDist::IsoNormal(IsoNormal::new(0.0, 1.0, 3)).boxed(),
        );
        vi.insert(
            VarName::new("theta"),
            Value::Vec(vec![0.2, 0.3, 0.5]),
            VecDist::Dirichlet(Dirichlet::symmetric(1.0, 3)).boxed(),
        );
        vi
    }

    #[test]
    fn insert_get_update() {
        let mut vi = demo_vi();
        assert_eq!(vi.len(), 3);
        assert!(vi.contains(&VarName::new("s")));
        assert_eq!(vi.get(&VarName::new("s")).unwrap().value, Value::F64(2.0));
        vi.set_value(&VarName::new("s"), Value::F64(5.0));
        assert_eq!(vi.get(&VarName::new("s")).unwrap().value, Value::F64(5.0));
        vi.update(
            &VarName::new("s"),
            Value::F64(1.0),
            ScalarDist::Normal(Normal::std()).boxed(),
        );
        assert_eq!(vi.get(&VarName::new("s")).unwrap().domain, Domain::Real);
    }

    #[test]
    #[should_panic(expected = "duplicate tilde")]
    fn duplicate_insert_panics() {
        let mut vi = demo_vi();
        vi.insert(
            VarName::new("s"),
            Value::F64(0.0),
            ScalarDist::Normal(Normal::std()).boxed(),
        );
    }

    #[test]
    fn unconstrained_dims() {
        let vi = demo_vi();
        // s: Positive → 1; w: RealVec(3) → 3; theta: Simplex(3) → 2
        assert_eq!(vi.num_unconstrained(), 6);
    }

    #[test]
    fn link_invlink_roundtrip() {
        let mut vi = demo_vi();
        let theta = vi.to_unconstrained();
        assert_eq!(theta.len(), 6);
        // s is log-transformed
        assert!((theta[0] - 2.0f64.ln()).abs() < 1e-12);
        // perturb, write back, re-read
        let theta2: Vec<f64> = theta.iter().map(|t| t + 0.1).collect();
        vi.set_from_unconstrained(&theta2);
        let theta3 = vi.to_unconstrained();
        for (a, b) in theta2.iter().zip(&theta3) {
            assert!((a - b).abs() < 1e-10);
        }
        // simplex value still valid
        let th = vi.get(&VarName::new("theta")).unwrap();
        let s: f64 = th.value.as_slice().unwrap().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flags_roundtrip() {
        let mut vi = demo_vi();
        let s = VarName::new("s");
        assert!(!vi.is_flagged(&s, flags::RESAMPLE));
        vi.set_flag(&s, flags::RESAMPLE);
        assert!(vi.is_flagged(&s, flags::RESAMPLE));
        vi.clear_flag(&s, flags::RESAMPLE);
        assert!(!vi.is_flagged(&s, flags::RESAMPLE));
        vi.flag_all_resample();
        assert!(vi.is_flagged(&VarName::new("w"), flags::RESAMPLE));
    }

    #[test]
    fn flag_suffix_respects_index_and_scope() {
        let mut vi = demo_vi(); // records: s, w, theta
        vi.flag_suffix(1, None, flags::RESAMPLE);
        assert!(!vi.is_flagged(&VarName::new("s"), flags::RESAMPLE));
        assert!(vi.is_flagged(&VarName::new("w"), flags::RESAMPLE));
        assert!(vi.is_flagged(&VarName::new("theta"), flags::RESAMPLE));

        let mut vi = demo_vi();
        let scope = [VarName::new("theta")];
        vi.flag_suffix(0, Some(&scope), flags::RESAMPLE);
        assert!(!vi.is_flagged(&VarName::new("s"), flags::RESAMPLE));
        assert!(!vi.is_flagged(&VarName::new("w"), flags::RESAMPLE));
        assert!(vi.is_flagged(&VarName::new("theta"), flags::RESAMPLE));
        assert_eq!(vi.record(0).vn, VarName::new("s"));
    }

    #[test]
    fn prior_logp_sums_records() {
        let vi = demo_vi();
        let expect = Gamma::new(2.0, 3.0).logpdf(2.0)
            + IsoNormal::new(0.0, 1.0, 3).logpdf(&[0.1, -0.2, 0.3])
            + Dirichlet::symmetric(1.0, 3).logpdf(&[0.2f64, 0.3, 0.5]);
        assert!((vi.prior_logp() - expect).abs() < 1e-12);
    }

    use crate::dist::Domain;
}
