//! The specialized trace: flat storage, fixed layout, cursor access.
//!
//! `TypedVarInfo` is produced from a completed [`UntypedVarInfo`] run, once
//! every variable's type, shape and support are known — the paper's type
//! inference step. All continuous state lives in two flat `f64` buffers
//! (unconstrained coordinates and their constrained images) and discrete
//! state in one `i64` buffer; [`Slot`]s record the layout in model visit
//! order so executors walk a cursor instead of hashing `VarName`s.
//!
//! Since the typed-particle fast path landed, a typed trace also carries
//! one **flag byte per slot** (`varinfo::flags` — `RESAMPLE`/`LOCKED`),
//! the flat mirror of `UntypedVarInfo`'s per-record flags: particle
//! samplers regenerate flagged slots in place ([`write_slot_f64`] and
//! friends draw directly into the buffers) instead of replaying through
//! boxed values.
//!
//! [`write_slot_f64`]: TypedVarInfo::write_slot_f64

use crate::dist::{bijector, Domain};
use crate::value::Value;
use crate::varname::VarName;

use super::untyped::UntypedVarInfo;

/// Layout entry for one traced variable, in model visit order.
#[derive(Clone, Debug)]
pub struct Slot {
    pub vn: VarName,
    pub domain: Domain,
    /// Offset/length into the unconstrained vector (0-length for discrete).
    pub unc_offset: usize,
    pub unc_len: usize,
    /// Offset/length into the constrained vector (0-length for discrete).
    pub cons_offset: usize,
    pub cons_len: usize,
    /// Offset into the discrete buffer (only for discrete slots).
    pub disc_offset: usize,
    /// Whether the value is a vector (affects boxing back to `Value`).
    pub is_vec: bool,
}

/// Strictly-typed execution trace with flat storage.
///
/// The layout (`slots`) is behind an [`Arc`]: cloning a `TypedVarInfo`
/// copies only the flat buffers (+ flag bytes) and shares the layout — the
/// cheap trace forking that particle samplers (`crate::particle`) rely on
/// when they duplicate thousands of particles per resampling step.
///
/// [`Arc`]: std::sync::Arc
#[derive(Clone, Debug)]
pub struct TypedVarInfo {
    slots: std::sync::Arc<[Slot]>,
    /// Flat unconstrained parameter vector θ (HMC state).
    pub unconstrained: Vec<f64>,
    /// Constrained images of θ, same layout as `slots[*].cons_*`.
    pub constrained: Vec<f64>,
    /// Discrete values in visit order.
    pub discrete: Vec<i64>,
    /// Per-slot particle flags (`flags::RESAMPLE` / `flags::LOCKED`),
    /// indexed by slot position. Part of the per-particle state, not the
    /// shared layout: forks carry their own copy.
    pub slot_flags: Vec<u8>,
    /// log-density of the last evaluation.
    pub logp: f64,
}

/// A buffers-only snapshot of a [`TypedVarInfo`]: everything that varies
/// between particles sharing one layout (values + flags + logp).
/// Restoring is four `memcpy`s.
#[derive(Clone, Debug, Default)]
pub struct TraceSnapshot {
    pub unconstrained: Vec<f64>,
    pub constrained: Vec<f64>,
    pub discrete: Vec<i64>,
    pub slot_flags: Vec<u8>,
    pub logp: f64,
}

impl TraceSnapshot {
    /// Overwrite this snapshot with `src`'s per-particle state, reusing the
    /// existing allocations — the snapshot-ring primitive of the typed
    /// particle cloud (one ring slot per particle, refreshed every step).
    pub fn copy_from(&mut self, src: &TypedVarInfo) {
        self.unconstrained.clone_from(&src.unconstrained);
        self.constrained.clone_from(&src.constrained);
        self.discrete.clone_from(&src.discrete);
        self.slot_flags.clone_from(&src.slot_flags);
        self.logp = src.logp;
    }
}

impl TypedVarInfo {
    /// Specialize an untyped trace. This is `TypedVarInfo(vi)` in the
    /// paper: called once the initial run has discovered every variable.
    /// Per-record flags carry over to the per-slot flag bytes.
    pub fn from_untyped(vi: &UntypedVarInfo) -> Self {
        let mut slots = Vec::with_capacity(vi.len());
        let mut unconstrained = Vec::new();
        let mut constrained = Vec::new();
        let mut discrete = Vec::new();
        let mut slot_flags = Vec::with_capacity(vi.len());
        for rec in vi.records() {
            let unc_offset = unconstrained.len();
            let cons_offset = constrained.len();
            let disc_offset = discrete.len();
            let mut is_vec = false;
            match (&rec.value, rec.domain.is_discrete()) {
                (Value::F64(x), false) => {
                    bijector::link(&rec.domain, &[*x], &mut unconstrained);
                    constrained.push(*x);
                }
                (Value::Vec(v), false) => {
                    is_vec = true;
                    bijector::link(&rec.domain, v, &mut unconstrained);
                    constrained.extend_from_slice(v);
                }
                (Value::Int(k), true) => {
                    discrete.push(*k);
                }
                (val, disc) => panic!(
                    "cannot specialize record {} (value {val:?}, discrete={disc})",
                    rec.vn
                ),
            }
            slots.push(Slot {
                vn: rec.vn.clone(),
                domain: rec.domain.clone(),
                unc_offset,
                unc_len: unconstrained.len() - unc_offset,
                cons_offset,
                cons_len: constrained.len() - cons_offset,
                disc_offset,
                is_vec,
            });
            slot_flags.push(rec.flags);
        }
        TypedVarInfo {
            slots: slots.into(),
            unconstrained,
            constrained,
            discrete,
            slot_flags,
            logp: vi.logp,
        }
    }

    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    /// Cheap fork: shares the layout `Arc`, copies only the value buffers.
    /// Semantically identical to `clone()`; the name documents intent at
    /// particle-forking call sites.
    pub fn fork(&self) -> TypedVarInfo {
        self.clone()
    }

    /// True if `other` shares this trace's layout allocation (forks do).
    pub fn shares_layout(&self, other: &TypedVarInfo) -> bool {
        std::sync::Arc::ptr_eq(&self.slots, &other.slots)
    }

    /// Fill a fresh trace **sharing this layout `Arc`** with the values and
    /// flags of `vi`. Returns `None` when `vi`'s structure no longer
    /// matches the layout (dynamic model changed shape) — the caller falls
    /// back to the boxed path. This is how a particle cloud promotes every
    /// particle onto one shared layout after its first full run.
    pub fn refill_from_untyped(&self, vi: &UntypedVarInfo) -> Option<TypedVarInfo> {
        if !self.layout_matches(vi) {
            return None;
        }
        let mut out = TypedVarInfo {
            slots: std::sync::Arc::clone(&self.slots),
            unconstrained: Vec::with_capacity(self.unconstrained.len()),
            constrained: Vec::with_capacity(self.constrained.len()),
            discrete: Vec::with_capacity(self.discrete.len()),
            slot_flags: Vec::with_capacity(self.slot_flags.len()),
            logp: vi.logp,
        };
        for rec in vi.records() {
            match (&rec.value, rec.domain.is_discrete()) {
                (Value::F64(x), false) => {
                    bijector::link(&rec.domain, &[*x], &mut out.unconstrained);
                    out.constrained.push(*x);
                }
                (Value::Vec(v), false) => {
                    bijector::link(&rec.domain, v, &mut out.unconstrained);
                    out.constrained.extend_from_slice(v);
                }
                (Value::Int(k), true) => out.discrete.push(*k),
                _ => return None,
            }
            out.slot_flags.push(rec.flags);
        }
        Some(out)
    }

    /// Convert back to the boxed representation: clone `template` (which
    /// supplies names, distributions and record order — it must share this
    /// trace's layout, e.g. the trace the layout was specialized from) and
    /// overwrite its values and flags with this trace's buffers. Used when
    /// a typed particle cloud demotes to the boxed path mid-sweep.
    pub fn to_untyped(&self, template: &UntypedVarInfo) -> UntypedVarInfo {
        assert_eq!(
            template.len(),
            self.slots.len(),
            "demotion template does not match the typed layout"
        );
        let mut vi = template.clone();
        for (i, slot) in self.slots.iter().enumerate() {
            vi.set_value(&slot.vn, self.boxed_value(slot));
            vi.set_record_flags(i, self.slot_flags[i]);
        }
        vi.logp = self.logp;
        vi
    }

    // ------------------------------------------------------- slot flags

    /// Whether slot `i` carries `flag`.
    #[inline]
    pub fn is_slot_flagged(&self, i: usize, flag: u8) -> bool {
        self.slot_flags[i] & flag != 0
    }

    /// Set `flag` on slot `i`.
    #[inline]
    pub fn flag_slot(&mut self, i: usize, flag: u8) {
        self.slot_flags[i] |= flag;
    }

    /// Clear `flag` on slot `i`.
    #[inline]
    pub fn clear_slot_flag(&mut self, i: usize, flag: u8) {
        self.slot_flags[i] &= !flag;
    }

    /// Clear `mask` (may combine flags) on every slot.
    pub fn clear_all_slot_flags(&mut self, mask: u8) {
        for f in &mut self.slot_flags {
            *f &= !mask;
        }
    }

    /// Set `flag` on every slot that does **not** carry `flags::LOCKED`
    /// and is selected by `mask` (all slots when `mask` is `None`) — the
    /// flat mirror of [`UntypedVarInfo::flag_unlocked`], i.e. the
    /// particle-fork regeneration sweep.
    pub fn flag_unlocked_slots(&mut self, mask: Option<&[bool]>, flag: u8) {
        for (i, f) in self.slot_flags.iter_mut().enumerate() {
            if *f & super::flags::LOCKED != 0 {
                continue;
            }
            let selected = match mask {
                None => true,
                Some(m) => m[i],
            };
            if selected {
                *f |= flag;
            }
        }
    }

    /// Copy `reference`'s values into every slot of `self` that is not
    /// `LOCKED` and is selected by `mask` — splicing the reference's
    /// *future* onto this particle's retained prefix (ancestor sampling's
    /// hybrid trajectory). Both traces must share the layout.
    pub fn overlay_unscored_slots_from(&mut self, reference: &TypedVarInfo, mask: Option<&[bool]>) {
        debug_assert!(self.shares_layout(reference));
        for (i, slot) in self.slots.iter().enumerate() {
            if self.slot_flags[i] & super::flags::LOCKED != 0 {
                continue;
            }
            let selected = match mask {
                None => true,
                Some(m) => m[i],
            };
            if !selected {
                continue;
            }
            if slot.domain.is_discrete() {
                self.discrete[slot.disc_offset] = reference.discrete[slot.disc_offset];
            } else {
                let (uo, ul) = (slot.unc_offset, slot.unc_len);
                self.unconstrained[uo..uo + ul]
                    .copy_from_slice(&reference.unconstrained[uo..uo + ul]);
                let (co, cl) = (slot.cons_offset, slot.cons_len);
                self.constrained[co..co + cl]
                    .copy_from_slice(&reference.constrained[co..co + cl]);
            }
        }
    }

    // -------------------------------------------------- in-place writes

    /// Write a freshly drawn scalar into slot `i`: the constrained buffer
    /// gets the raw value, the unconstrained buffer its link image —
    /// written in place, no allocation. `domain` is the distribution's
    /// *current* domain (parameters may depend on other parameters).
    pub fn write_slot_f64(&mut self, i: usize, x: f64, domain: &Domain) {
        let (co, uo, ul) = {
            let s = &self.slots[i];
            (s.cons_offset, s.unc_offset, s.unc_len)
        };
        self.constrained[co] = x;
        bijector::link_slice(domain, &[x], &mut self.unconstrained[uo..uo + ul]);
    }

    /// Vector analogue of [`write_slot_f64`](Self::write_slot_f64).
    pub fn write_slot_vec(&mut self, i: usize, xs: &[f64], domain: &Domain) {
        let (co, cl, uo, ul) = {
            let s = &self.slots[i];
            (s.cons_offset, s.cons_len, s.unc_offset, s.unc_len)
        };
        debug_assert_eq!(xs.len(), cl);
        self.constrained[co..co + cl].copy_from_slice(xs);
        bijector::link_slice(domain, xs, &mut self.unconstrained[uo..uo + ul]);
    }

    /// Discrete analogue of [`write_slot_f64`](Self::write_slot_f64).
    pub fn write_slot_int(&mut self, i: usize, k: i64) {
        let off = self.slots[i].disc_offset;
        self.discrete[off] = k;
    }

    /// Boxed-value form of the in-place write (demotion helpers, tests).
    pub fn write_slot_sample(&mut self, i: usize, value: &Value) {
        let domain = self.slots[i].domain.clone();
        match value {
            Value::F64(x) => self.write_slot_f64(i, *x, &domain),
            Value::Vec(v) => self.write_slot_vec(i, v, &domain),
            Value::Int(k) => self.write_slot_int(i, *k),
        }
    }

    // ------------------------------------------------------- snapshots

    /// Capture the per-particle state (buffers + flags + logp) without the
    /// layout.
    pub fn snapshot(&self) -> TraceSnapshot {
        TraceSnapshot {
            unconstrained: self.unconstrained.clone(),
            constrained: self.constrained.clone(),
            discrete: self.discrete.clone(),
            slot_flags: self.slot_flags.clone(),
            logp: self.logp,
        }
    }

    /// Restore a snapshot taken from a trace with the same layout.
    pub fn restore(&mut self, s: &TraceSnapshot) {
        assert_eq!(s.unconstrained.len(), self.unconstrained.len());
        assert_eq!(s.constrained.len(), self.constrained.len());
        assert_eq!(s.discrete.len(), self.discrete.len());
        assert_eq!(s.slot_flags.len(), self.slot_flags.len());
        self.unconstrained.copy_from_slice(&s.unconstrained);
        self.constrained.copy_from_slice(&s.constrained);
        self.discrete.copy_from_slice(&s.discrete);
        self.slot_flags.copy_from_slice(&s.slot_flags);
        self.logp = s.logp;
    }

    /// Dimension of the unconstrained parameter vector.
    pub fn dim(&self) -> usize {
        self.unconstrained.len()
    }

    /// Overwrite θ and refresh the constrained cache.
    pub fn set_unconstrained(&mut self, theta: &[f64]) {
        assert_eq!(theta.len(), self.unconstrained.len());
        self.unconstrained.copy_from_slice(theta);
        self.refresh_constrained();
    }

    /// Recompute the constrained buffer from θ (invlink per slot), writing
    /// each slot's image directly into the constrained buffer — no
    /// temporary allocation.
    pub fn refresh_constrained(&mut self) {
        for slot in self.slots.iter() {
            if slot.unc_len == 0 {
                continue;
            }
            let y = &self.unconstrained[slot.unc_offset..slot.unc_offset + slot.unc_len];
            let out = &mut self.constrained[slot.cons_offset..slot.cons_offset + slot.cons_len];
            let _ = bijector::invlink_slice(&slot.domain, y, out);
        }
    }

    /// Constrained value of a slot as a boxed [`Value`] (chain recording).
    pub fn boxed_value(&self, slot: &Slot) -> Value {
        if slot.domain.is_discrete() {
            Value::Int(self.discrete[slot.disc_offset])
        } else if slot.is_vec {
            Value::Vec(
                self.constrained[slot.cons_offset..slot.cons_offset + slot.cons_len].to_vec(),
            )
        } else {
            Value::F64(self.constrained[slot.cons_offset])
        }
    }

    /// Column names for chain output: one per constrained scalar element
    /// (`s`, `w[0]`, `w[1]`, …) plus discrete slots.
    pub fn column_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for slot in self.slots.iter() {
            if slot.domain.is_discrete() {
                names.push(slot.vn.to_string());
            } else if slot.is_vec {
                for i in 0..slot.cons_len {
                    names.push(format!("{}[{i}]", slot.vn));
                }
            } else {
                names.push(slot.vn.to_string());
            }
        }
        names
    }

    /// Flatten current constrained + discrete state into one row (chain
    /// recording; same order as `column_names`).
    pub fn row(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.constrained.len() + self.discrete.len());
        for slot in self.slots.iter() {
            if slot.domain.is_discrete() {
                out.push(self.discrete[slot.disc_offset] as f64);
            } else {
                out.extend_from_slice(
                    &self.constrained[slot.cons_offset..slot.cons_offset + slot.cons_len],
                );
            }
        }
        out
    }

    /// Check that this layout is still valid for a trace that just ran:
    /// same variables in the same order with the same domains. Dynamic
    /// models can change structure between iterations; on mismatch the
    /// caller must re-specialize (paper: fall back to UntypedVarInfo).
    pub fn layout_matches(&self, vi: &UntypedVarInfo) -> bool {
        if self.slots.len() != vi.len() {
            return false;
        }
        self.slots
            .iter()
            .zip(vi.records())
            .all(|(s, r)| s.vn == r.vn && s.domain == r.domain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Categorical, Dirichlet, DiscreteDist, Gamma, IsoNormal, ScalarDist, VecDist};
    use crate::varinfo::flags;

    fn demo_untyped() -> UntypedVarInfo {
        let mut vi = UntypedVarInfo::new();
        vi.insert(
            VarName::new("s"),
            Value::F64(2.0),
            ScalarDist::Gamma(Gamma::new(2.0, 3.0)).boxed(),
        );
        vi.insert(
            VarName::new("w"),
            Value::Vec(vec![0.1, -0.2, 0.3]),
            VecDist::IsoNormal(IsoNormal::new(0.0, 1.0, 3)).boxed(),
        );
        vi.insert(
            VarName::new("z"),
            Value::Int(2),
            DiscreteDist::Categorical(Categorical::from_probs(&[0.2, 0.3, 0.5])).boxed(),
        );
        vi.insert(
            VarName::new("theta"),
            Value::Vec(vec![0.2, 0.3, 0.5]),
            VecDist::Dirichlet(Dirichlet::symmetric(1.0, 3)).boxed(),
        );
        vi
    }

    #[test]
    fn specialization_layout() {
        let tvi = TypedVarInfo::from_untyped(&demo_untyped());
        assert_eq!(tvi.slots().len(), 4);
        // dims: s→1, w→3, z→0, theta→2 ⇒ 6 unconstrained
        assert_eq!(tvi.dim(), 6);
        assert_eq!(tvi.constrained.len(), 7); // 1 + 3 + 3
        assert_eq!(tvi.discrete, vec![2]);
        assert_eq!(tvi.slot_flags, vec![0, 0, 0, 0]);
        let s = &tvi.slots()[0];
        assert_eq!((s.unc_offset, s.unc_len), (0, 1));
        let w = &tvi.slots()[1];
        assert_eq!((w.unc_offset, w.unc_len), (1, 3));
        let z = &tvi.slots()[2];
        assert_eq!(z.unc_len, 0);
        let th = &tvi.slots()[3];
        assert_eq!((th.unc_offset, th.unc_len), (4, 2));
    }

    #[test]
    fn set_unconstrained_refreshes_constrained() {
        let mut tvi = TypedVarInfo::from_untyped(&demo_untyped());
        let mut theta = tvi.unconstrained.clone();
        theta[0] = 0.0; // s = exp(0) = 1
        tvi.set_unconstrained(&theta);
        assert!((tvi.constrained[0] - 1.0).abs() < 1e-12);
        // simplex block still sums to 1
        let s: f64 = tvi.constrained[4..7].iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn boxed_values_and_rows() {
        let tvi = TypedVarInfo::from_untyped(&demo_untyped());
        assert_eq!(tvi.boxed_value(&tvi.slots()[0]), Value::F64(2.0));
        assert_eq!(
            tvi.boxed_value(&tvi.slots()[1]),
            Value::Vec(vec![0.1, -0.2, 0.3])
        );
        assert_eq!(tvi.boxed_value(&tvi.slots()[2]), Value::Int(2));
        let names = tvi.column_names();
        assert_eq!(
            names,
            vec!["s", "w[0]", "w[1]", "w[2]", "z", "theta[0]", "theta[1]", "theta[2]"]
        );
        let row = tvi.row();
        assert_eq!(row.len(), names.len());
        assert_eq!(row[4], 2.0);
    }

    #[test]
    fn fork_shares_layout_and_snapshot_restores() {
        let mut tvi = TypedVarInfo::from_untyped(&demo_untyped());
        let snap = tvi.snapshot();
        let fork = tvi.fork();
        assert!(tvi.shares_layout(&fork));
        assert_eq!(fork.unconstrained, tvi.unconstrained);
        // mutate, then restore
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x + 1.0).collect();
        tvi.set_unconstrained(&theta);
        tvi.discrete[0] = 0;
        tvi.flag_slot(1, flags::RESAMPLE);
        tvi.logp = -123.0;
        assert_ne!(tvi.unconstrained, snap.unconstrained);
        tvi.restore(&snap);
        assert_eq!(tvi.unconstrained, snap.unconstrained);
        assert_eq!(tvi.constrained, snap.constrained);
        assert_eq!(tvi.discrete, vec![2]);
        assert_eq!(tvi.slot_flags, vec![0, 0, 0, 0]);
        assert_eq!(tvi.logp, snap.logp);
        // a from-scratch specialization does NOT share the allocation
        let other = TypedVarInfo::from_untyped(&demo_untyped());
        assert!(!tvi.shares_layout(&other));
    }

    #[test]
    fn snapshot_ring_copy_from_reuses_buffers() {
        let tvi = TypedVarInfo::from_untyped(&demo_untyped());
        let mut ring = TraceSnapshot::default();
        ring.copy_from(&tvi);
        assert_eq!(ring.unconstrained, tvi.unconstrained);
        assert_eq!(ring.slot_flags, tvi.slot_flags);
        let mut restored = tvi.clone();
        restored.discrete[0] = 1;
        restored.restore(&ring);
        assert_eq!(restored.discrete, vec![2]);
    }

    #[test]
    fn layout_match_detects_structure_change() {
        let vi = demo_untyped();
        let tvi = TypedVarInfo::from_untyped(&vi);
        assert!(tvi.layout_matches(&vi));
        // a dynamic model that adds a variable invalidates the layout
        let mut vi2 = demo_untyped();
        vi2.insert(
            VarName::new("extra"),
            Value::F64(0.0),
            ScalarDist::Gamma(Gamma::new(1.0, 1.0)).boxed(),
        );
        assert!(!tvi.layout_matches(&vi2));
    }

    #[test]
    fn refill_shares_layout_and_roundtrips_to_untyped() {
        let vi = demo_untyped();
        let tvi = TypedVarInfo::from_untyped(&vi);
        // a second boxed trace with different values, same structure
        let mut vi2 = demo_untyped();
        vi2.set_value(&VarName::new("s"), Value::F64(5.0));
        vi2.set_value(&VarName::new("z"), Value::Int(0));
        vi2.set_record_flags(1, flags::RESAMPLE);
        let t2 = tvi.refill_from_untyped(&vi2).expect("layout holds");
        assert!(t2.shares_layout(&tvi));
        assert_eq!(t2.constrained[0], 5.0);
        assert_eq!(t2.discrete, vec![0]);
        assert!(t2.is_slot_flagged(1, flags::RESAMPLE));
        // demote back: values and flags survive the roundtrip
        let back = t2.to_untyped(&vi);
        assert_eq!(back.get(&VarName::new("s")).unwrap().value, Value::F64(5.0));
        assert_eq!(back.get(&VarName::new("z")).unwrap().value, Value::Int(0));
        assert!(back.is_flagged(&VarName::new("w"), flags::RESAMPLE));
        assert!(!back.is_flagged(&VarName::new("s"), flags::RESAMPLE));
        // structure change → refill refuses
        let mut vi3 = demo_untyped();
        vi3.insert(
            VarName::new("extra"),
            Value::F64(0.0),
            ScalarDist::Gamma(Gamma::new(1.0, 1.0)).boxed(),
        );
        assert!(tvi.refill_from_untyped(&vi3).is_none());
    }

    #[test]
    fn in_place_slot_writes_update_both_buffers() {
        let mut tvi = TypedVarInfo::from_untyped(&demo_untyped());
        // scalar slot 0: s ~ Gamma (Positive domain → log link)
        let domain = tvi.slots()[0].domain.clone();
        tvi.write_slot_f64(0, 4.0, &domain);
        assert_eq!(tvi.constrained[0], 4.0);
        assert!((tvi.unconstrained[0] - 4.0f64.ln()).abs() < 1e-12);
        // vector slot 3: theta ~ Dirichlet (Simplex domain)
        let domain = tvi.slots()[3].domain.clone();
        tvi.write_slot_vec(3, &[0.5, 0.25, 0.25], &domain);
        assert_eq!(&tvi.constrained[4..7], &[0.5, 0.25, 0.25]);
        // the unconstrained image round-trips through refresh
        let theta = tvi.unconstrained.clone();
        tvi.refresh_constrained();
        assert_eq!(tvi.unconstrained, theta);
        let s: f64 = tvi.constrained[4..7].iter().sum();
        assert!((s - 1.0).abs() < 1e-10);
        // discrete slot 2
        tvi.write_slot_int(2, 1);
        assert_eq!(tvi.discrete, vec![1]);
        // boxed-value dispatch form
        tvi.write_slot_sample(2, &Value::Int(2));
        assert_eq!(tvi.discrete, vec![2]);
    }

    #[test]
    fn flag_sweeps_respect_locks_and_masks() {
        let mut tvi = TypedVarInfo::from_untyped(&demo_untyped());
        tvi.flag_slot(0, flags::LOCKED);
        let mask = vec![true, true, false, true];
        tvi.flag_unlocked_slots(Some(&mask), flags::RESAMPLE);
        assert!(!tvi.is_slot_flagged(0, flags::RESAMPLE), "locked slot spared");
        assert!(tvi.is_slot_flagged(1, flags::RESAMPLE));
        assert!(!tvi.is_slot_flagged(2, flags::RESAMPLE), "masked-out slot spared");
        assert!(tvi.is_slot_flagged(3, flags::RESAMPLE));
        tvi.clear_all_slot_flags(flags::RESAMPLE | flags::LOCKED);
        assert_eq!(tvi.slot_flags, vec![0, 0, 0, 0]);
    }

    #[test]
    fn overlay_copies_only_unlocked_in_mask_slots() {
        let base = TypedVarInfo::from_untyped(&demo_untyped());
        let mut reference = base.fork();
        let d0 = reference.slots()[0].domain.clone();
        reference.write_slot_f64(0, 9.0, &d0);
        reference.write_slot_int(2, 0);
        let mut particle = base.fork();
        particle.flag_slot(0, flags::LOCKED);
        particle.overlay_unscored_slots_from(&reference, None);
        // locked slot keeps the particle's own value
        assert_eq!(particle.constrained[0], 2.0);
        // unlocked discrete slot takes the reference's value
        assert_eq!(particle.discrete, vec![0]);
    }
}
