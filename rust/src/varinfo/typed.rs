//! The specialized trace: flat storage, fixed layout, cursor access.
//!
//! `TypedVarInfo` is produced from a completed [`UntypedVarInfo`] run, once
//! every variable's type, shape and support are known — the paper's type
//! inference step. All continuous state lives in two flat `f64` buffers
//! (unconstrained coordinates and their constrained images) and discrete
//! state in one `i64` buffer; [`Slot`]s record the layout in model visit
//! order so executors walk a cursor instead of hashing `VarName`s.

use crate::dist::{bijector, Domain};
use crate::value::Value;
use crate::varname::VarName;

use super::untyped::UntypedVarInfo;

/// Layout entry for one traced variable, in model visit order.
#[derive(Clone, Debug)]
pub struct Slot {
    pub vn: VarName,
    pub domain: Domain,
    /// Offset/length into the unconstrained vector (0-length for discrete).
    pub unc_offset: usize,
    pub unc_len: usize,
    /// Offset/length into the constrained vector (0-length for discrete).
    pub cons_offset: usize,
    pub cons_len: usize,
    /// Offset into the discrete buffer (only for discrete slots).
    pub disc_offset: usize,
    /// Whether the value is a vector (affects boxing back to `Value`).
    pub is_vec: bool,
}

/// Strictly-typed execution trace with flat storage.
///
/// The layout (`slots`) is behind an [`Arc`]: cloning a `TypedVarInfo`
/// copies only the three flat buffers and shares the layout — the cheap
/// trace forking that particle samplers (`crate::particle`) rely on when
/// they duplicate thousands of particles per resampling step.
#[derive(Clone, Debug)]
pub struct TypedVarInfo {
    slots: std::sync::Arc<[Slot]>,
    /// Flat unconstrained parameter vector θ (HMC state).
    pub unconstrained: Vec<f64>,
    /// Constrained images of θ, same layout as `slots[*].cons_*`.
    pub constrained: Vec<f64>,
    /// Discrete values in visit order.
    pub discrete: Vec<i64>,
    /// log-density of the last evaluation.
    pub logp: f64,
}

/// A buffers-only snapshot of a [`TypedVarInfo`]: everything that varies
/// between particles sharing one layout. Restoring is three `memcpy`s.
#[derive(Clone, Debug)]
pub struct TraceSnapshot {
    pub unconstrained: Vec<f64>,
    pub constrained: Vec<f64>,
    pub discrete: Vec<i64>,
    pub logp: f64,
}

impl TypedVarInfo {
    /// Specialize an untyped trace. This is `TypedVarInfo(vi)` in the
    /// paper: called once the initial run has discovered every variable.
    pub fn from_untyped(vi: &UntypedVarInfo) -> Self {
        let mut slots = Vec::with_capacity(vi.len());
        let mut unconstrained = Vec::new();
        let mut constrained = Vec::new();
        let mut discrete = Vec::new();
        for rec in vi.records() {
            let unc_offset = unconstrained.len();
            let cons_offset = constrained.len();
            let disc_offset = discrete.len();
            let mut is_vec = false;
            match (&rec.value, rec.domain.is_discrete()) {
                (Value::F64(x), false) => {
                    bijector::link(&rec.domain, &[*x], &mut unconstrained);
                    constrained.push(*x);
                }
                (Value::Vec(v), false) => {
                    is_vec = true;
                    bijector::link(&rec.domain, v, &mut unconstrained);
                    constrained.extend_from_slice(v);
                }
                (Value::Int(k), true) => {
                    discrete.push(*k);
                }
                (val, disc) => panic!(
                    "cannot specialize record {} (value {val:?}, discrete={disc})",
                    rec.vn
                ),
            }
            slots.push(Slot {
                vn: rec.vn.clone(),
                domain: rec.domain.clone(),
                unc_offset,
                unc_len: unconstrained.len() - unc_offset,
                cons_offset,
                cons_len: constrained.len() - cons_offset,
                disc_offset,
                is_vec,
            });
        }
        TypedVarInfo {
            slots: slots.into(),
            unconstrained,
            constrained,
            discrete,
            logp: vi.logp,
        }
    }

    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    /// Cheap fork: shares the layout `Arc`, copies only the value buffers.
    /// Semantically identical to `clone()`; the name documents intent at
    /// particle-forking call sites.
    pub fn fork(&self) -> TypedVarInfo {
        self.clone()
    }

    /// True if `other` shares this trace's layout allocation (forks do).
    pub fn shares_layout(&self, other: &TypedVarInfo) -> bool {
        std::sync::Arc::ptr_eq(&self.slots, &other.slots)
    }

    /// Capture the per-particle state (buffers + logp) without the layout.
    pub fn snapshot(&self) -> TraceSnapshot {
        TraceSnapshot {
            unconstrained: self.unconstrained.clone(),
            constrained: self.constrained.clone(),
            discrete: self.discrete.clone(),
            logp: self.logp,
        }
    }

    /// Restore a snapshot taken from a trace with the same layout.
    pub fn restore(&mut self, s: &TraceSnapshot) {
        assert_eq!(s.unconstrained.len(), self.unconstrained.len());
        assert_eq!(s.constrained.len(), self.constrained.len());
        assert_eq!(s.discrete.len(), self.discrete.len());
        self.unconstrained.copy_from_slice(&s.unconstrained);
        self.constrained.copy_from_slice(&s.constrained);
        self.discrete.copy_from_slice(&s.discrete);
        self.logp = s.logp;
    }

    /// Dimension of the unconstrained parameter vector.
    pub fn dim(&self) -> usize {
        self.unconstrained.len()
    }

    /// Overwrite θ and refresh the constrained cache.
    pub fn set_unconstrained(&mut self, theta: &[f64]) {
        assert_eq!(theta.len(), self.unconstrained.len());
        self.unconstrained.copy_from_slice(theta);
        self.refresh_constrained();
    }

    /// Recompute the constrained buffer from θ (invlink per slot).
    pub fn refresh_constrained(&mut self) {
        let mut buf: Vec<f64> = Vec::with_capacity(8);
        for slot in &self.slots {
            if slot.unc_len == 0 {
                continue;
            }
            buf.clear();
            let y = &self.unconstrained[slot.unc_offset..slot.unc_offset + slot.unc_len];
            let _ = bijector::invlink(&slot.domain, y, &mut buf);
            self.constrained[slot.cons_offset..slot.cons_offset + slot.cons_len]
                .copy_from_slice(&buf);
        }
    }

    /// Constrained value of a slot as a boxed [`Value`] (chain recording).
    pub fn boxed_value(&self, slot: &Slot) -> Value {
        if slot.domain.is_discrete() {
            Value::Int(self.discrete[slot.disc_offset])
        } else if slot.is_vec {
            Value::Vec(
                self.constrained[slot.cons_offset..slot.cons_offset + slot.cons_len].to_vec(),
            )
        } else {
            Value::F64(self.constrained[slot.cons_offset])
        }
    }

    /// Column names for chain output: one per constrained scalar element
    /// (`s`, `w[0]`, `w[1]`, …) plus discrete slots.
    pub fn column_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for slot in &self.slots {
            if slot.domain.is_discrete() {
                names.push(slot.vn.to_string());
            } else if slot.is_vec {
                for i in 0..slot.cons_len {
                    names.push(format!("{}[{i}]", slot.vn));
                }
            } else {
                names.push(slot.vn.to_string());
            }
        }
        names
    }

    /// Flatten current constrained + discrete state into one row (chain
    /// recording; same order as `column_names`).
    pub fn row(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.constrained.len() + self.discrete.len());
        for slot in &self.slots {
            if slot.domain.is_discrete() {
                out.push(self.discrete[slot.disc_offset] as f64);
            } else {
                out.extend_from_slice(
                    &self.constrained[slot.cons_offset..slot.cons_offset + slot.cons_len],
                );
            }
        }
        out
    }

    /// Check that this layout is still valid for a trace that just ran:
    /// same variables in the same order with the same domains. Dynamic
    /// models can change structure between iterations; on mismatch the
    /// caller must re-specialize (paper: fall back to UntypedVarInfo).
    pub fn layout_matches(&self, vi: &UntypedVarInfo) -> bool {
        if self.slots.len() != vi.len() {
            return false;
        }
        self.slots
            .iter()
            .zip(vi.records())
            .all(|(s, r)| s.vn == r.vn && s.domain == r.domain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Categorical, Dirichlet, DiscreteDist, Gamma, IsoNormal, ScalarDist, VecDist};

    fn demo_untyped() -> UntypedVarInfo {
        let mut vi = UntypedVarInfo::new();
        vi.insert(
            VarName::new("s"),
            Value::F64(2.0),
            ScalarDist::Gamma(Gamma::new(2.0, 3.0)).boxed(),
        );
        vi.insert(
            VarName::new("w"),
            Value::Vec(vec![0.1, -0.2, 0.3]),
            VecDist::IsoNormal(IsoNormal::new(0.0, 1.0, 3)).boxed(),
        );
        vi.insert(
            VarName::new("z"),
            Value::Int(2),
            DiscreteDist::Categorical(Categorical::from_probs(&[0.2, 0.3, 0.5])).boxed(),
        );
        vi.insert(
            VarName::new("theta"),
            Value::Vec(vec![0.2, 0.3, 0.5]),
            VecDist::Dirichlet(Dirichlet::symmetric(1.0, 3)).boxed(),
        );
        vi
    }

    #[test]
    fn specialization_layout() {
        let tvi = TypedVarInfo::from_untyped(&demo_untyped());
        assert_eq!(tvi.slots().len(), 4);
        // dims: s→1, w→3, z→0, theta→2 ⇒ 6 unconstrained
        assert_eq!(tvi.dim(), 6);
        assert_eq!(tvi.constrained.len(), 7); // 1 + 3 + 3
        assert_eq!(tvi.discrete, vec![2]);
        let s = &tvi.slots()[0];
        assert_eq!((s.unc_offset, s.unc_len), (0, 1));
        let w = &tvi.slots()[1];
        assert_eq!((w.unc_offset, w.unc_len), (1, 3));
        let z = &tvi.slots()[2];
        assert_eq!(z.unc_len, 0);
        let th = &tvi.slots()[3];
        assert_eq!((th.unc_offset, th.unc_len), (4, 2));
    }

    #[test]
    fn set_unconstrained_refreshes_constrained() {
        let mut tvi = TypedVarInfo::from_untyped(&demo_untyped());
        let mut theta = tvi.unconstrained.clone();
        theta[0] = 0.0; // s = exp(0) = 1
        tvi.set_unconstrained(&theta);
        assert!((tvi.constrained[0] - 1.0).abs() < 1e-12);
        // simplex block still sums to 1
        let s: f64 = tvi.constrained[4..7].iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn boxed_values_and_rows() {
        let tvi = TypedVarInfo::from_untyped(&demo_untyped());
        assert_eq!(tvi.boxed_value(&tvi.slots()[0]), Value::F64(2.0));
        assert_eq!(
            tvi.boxed_value(&tvi.slots()[1]),
            Value::Vec(vec![0.1, -0.2, 0.3])
        );
        assert_eq!(tvi.boxed_value(&tvi.slots()[2]), Value::Int(2));
        let names = tvi.column_names();
        assert_eq!(
            names,
            vec!["s", "w[0]", "w[1]", "w[2]", "z", "theta[0]", "theta[1]", "theta[2]"]
        );
        let row = tvi.row();
        assert_eq!(row.len(), names.len());
        assert_eq!(row[4], 2.0);
    }

    #[test]
    fn fork_shares_layout_and_snapshot_restores() {
        let mut tvi = TypedVarInfo::from_untyped(&demo_untyped());
        let snap = tvi.snapshot();
        let fork = tvi.fork();
        assert!(tvi.shares_layout(&fork));
        assert_eq!(fork.unconstrained, tvi.unconstrained);
        // mutate, then restore
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x + 1.0).collect();
        tvi.set_unconstrained(&theta);
        tvi.discrete[0] = 0;
        tvi.logp = -123.0;
        assert_ne!(tvi.unconstrained, snap.unconstrained);
        tvi.restore(&snap);
        assert_eq!(tvi.unconstrained, snap.unconstrained);
        assert_eq!(tvi.constrained, snap.constrained);
        assert_eq!(tvi.discrete, vec![2]);
        assert_eq!(tvi.logp, snap.logp);
        // a from-scratch specialization does NOT share the allocation
        let other = TypedVarInfo::from_untyped(&demo_untyped());
        assert!(!tvi.shares_layout(&other));
    }

    #[test]
    fn layout_match_detects_structure_change() {
        let vi = demo_untyped();
        let tvi = TypedVarInfo::from_untyped(&vi);
        assert!(tvi.layout_matches(&vi));
        // a dynamic model that adds a variable invalidates the layout
        let mut vi2 = demo_untyped();
        vi2.insert(
            VarName::new("extra"),
            Value::F64(0.0),
            ScalarDist::Gamma(Gamma::new(1.0, 1.0)).boxed(),
        );
        assert!(!tvi.layout_matches(&vi2));
    }
}
