//! K-lane SoA trace storage: one `TypedVarInfo` layout, K value lanes.
//!
//! [`BatchVarInfo`] holds the per-particle (or per-chain / per-draw) state
//! of K traces that share one typed layout, transposed to
//! **coordinate-major** order: `unconstrained[coord * K + lane]`. A lane-
//! batched executor walking the tilde program then touches each site's K
//! values as one contiguous run — the auto-vectorizable inner loop the
//! lane-batched engine is built around — instead of K strided loads from K
//! separate `TypedVarInfo`s.
//!
//! Gather/scatter between a batch and individual traces is plain copying;
//! it never mutates the sources, so a batched pass that fails mid-walk
//! (dynamic structure change, per-lane rejection) leaves every particle
//! untouched and the caller can redo the step on the sequential path.

use crate::dist::{bijector, Domain};

use super::typed::{Slot, TypedVarInfo};

/// K lanes of per-trace state over one shared typed layout,
/// coordinate-major (SoA across lanes).
#[derive(Clone, Debug)]
pub struct BatchVarInfo {
    template: TypedVarInfo,
    lanes: usize,
    /// `unconstrained[coord * lanes + lane]`.
    pub unconstrained: Vec<f64>,
    /// `constrained[coord * lanes + lane]`.
    pub constrained: Vec<f64>,
    /// `discrete[idx * lanes + lane]`.
    pub discrete: Vec<i64>,
    /// `slot_flags[slot * lanes + lane]`.
    pub slot_flags: Vec<u8>,
    /// Per-lane log-density.
    pub logp: Vec<f64>,
}

impl BatchVarInfo {
    /// Gather `states` (all sharing `template`'s layout) into one batch.
    pub fn gather(template: &TypedVarInfo, states: &[&TypedVarInfo]) -> Self {
        let k = states.len();
        assert!(k > 0, "a batch needs at least one lane");
        let dim = template.unconstrained.len();
        let n_cons = template.constrained.len();
        let n_disc = template.discrete.len();
        let n_slots = template.slots().len();
        let mut out = BatchVarInfo {
            template: template.fork(),
            lanes: k,
            unconstrained: vec![0.0; dim * k],
            constrained: vec![0.0; n_cons * k],
            discrete: vec![0; n_disc * k],
            slot_flags: vec![0; n_slots * k],
            logp: vec![0.0; k],
        };
        for (l, s) in states.iter().enumerate() {
            debug_assert!(s.shares_layout(template), "lane {l} layout mismatch");
            out.load_lane(l, s);
        }
        out
    }

    /// Overwrite lane `l` from one trace (transposing into SoA order).
    pub fn load_lane(&mut self, l: usize, src: &TypedVarInfo) {
        let k = self.lanes;
        for (i, &v) in src.unconstrained.iter().enumerate() {
            self.unconstrained[i * k + l] = v;
        }
        for (i, &v) in src.constrained.iter().enumerate() {
            self.constrained[i * k + l] = v;
        }
        for (i, &v) in src.discrete.iter().enumerate() {
            self.discrete[i * k + l] = v;
        }
        for (i, &v) in src.slot_flags.iter().enumerate() {
            self.slot_flags[i * k + l] = v;
        }
        self.logp[l] = src.logp;
    }

    /// Copy lane `l` back into an individual trace (same layout).
    pub fn scatter_lane(&self, l: usize, dst: &mut TypedVarInfo) {
        let k = self.lanes;
        for (i, v) in dst.unconstrained.iter_mut().enumerate() {
            *v = self.unconstrained[i * k + l];
        }
        for (i, v) in dst.constrained.iter_mut().enumerate() {
            *v = self.constrained[i * k + l];
        }
        for (i, v) in dst.discrete.iter_mut().enumerate() {
            *v = self.discrete[i * k + l];
        }
        for (i, v) in dst.slot_flags.iter_mut().enumerate() {
            *v = self.slot_flags[i * k + l];
        }
        dst.logp = self.logp[l];
    }

    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Unconstrained dimension of one lane.
    #[inline]
    pub fn dim(&self) -> usize {
        self.template.dim()
    }

    #[inline]
    pub fn slots(&self) -> &[Slot] {
        self.template.slots()
    }

    /// The layout template the lanes share.
    #[inline]
    pub fn template(&self) -> &TypedVarInfo {
        &self.template
    }

    /// Constrained value at flat offset `off`, lane `l`.
    #[inline]
    pub fn cons(&self, off: usize, l: usize) -> f64 {
        self.constrained[off * self.lanes + l]
    }

    /// Discrete value at flat offset `off`, lane `l`.
    #[inline]
    pub fn disc(&self, off: usize, l: usize) -> i64 {
        self.discrete[off * self.lanes + l]
    }

    #[inline]
    pub fn is_slot_flagged(&self, slot: usize, l: usize, flag: u8) -> bool {
        self.slot_flags[slot * self.lanes + l] & flag != 0
    }

    #[inline]
    pub fn flag_slot(&mut self, slot: usize, l: usize, flag: u8) {
        self.slot_flags[slot * self.lanes + l] |= flag;
    }

    #[inline]
    pub fn clear_slot_flag(&mut self, slot: usize, l: usize, flag: u8) {
        self.slot_flags[slot * self.lanes + l] &= !flag;
    }

    /// Lane form of [`TypedVarInfo::write_slot_f64`]: write a freshly drawn
    /// scalar into slot `i` of lane `l` (constrained value + link image).
    pub fn write_slot_f64_lane(&mut self, i: usize, l: usize, x: f64, domain: &Domain) {
        let k = self.lanes;
        let (co, uo, ul) = {
            let s = &self.slots()[i];
            (s.cons_offset, s.unc_offset, s.unc_len)
        };
        self.constrained[co * k + l] = x;
        let mut tmp = [0.0f64; 1];
        debug_assert_eq!(ul, 1, "scalar slot");
        bijector::link_slice(domain, &[x], &mut tmp);
        self.unconstrained[uo * k + l] = tmp[0];
    }

    /// Lane form of [`TypedVarInfo::write_slot_vec`].
    pub fn write_slot_vec_lane(&mut self, i: usize, l: usize, xs: &[f64], domain: &Domain) {
        let k = self.lanes;
        let (co, cl, uo, ul) = {
            let s = &self.slots()[i];
            (s.cons_offset, s.cons_len, s.unc_offset, s.unc_len)
        };
        debug_assert_eq!(xs.len(), cl);
        for (j, &x) in xs.iter().enumerate() {
            self.constrained[(co + j) * k + l] = x;
        }
        let mut tmp = vec![0.0f64; ul];
        bijector::link_slice(domain, xs, &mut tmp);
        for (j, &y) in tmp.iter().enumerate() {
            self.unconstrained[(uo + j) * k + l] = y;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Gamma, IsoNormal, ScalarDist, VecDist};
    use crate::value::Value;
    use crate::varinfo::{flags, UntypedVarInfo};
    use crate::varname::VarName;

    fn demo_typed(seed_val: f64) -> TypedVarInfo {
        let mut vi = UntypedVarInfo::new();
        vi.insert(
            VarName::new("s"),
            Value::F64(seed_val),
            ScalarDist::Gamma(Gamma::new(2.0, 3.0)).boxed(),
        );
        vi.insert(
            VarName::new("w"),
            Value::Vec(vec![0.1 * seed_val, -0.2, 0.3]),
            VecDist::IsoNormal(IsoNormal::new(0.0, 1.0, 3)).boxed(),
        );
        TypedVarInfo::from_untyped(&vi)
    }

    #[test]
    fn gather_scatter_roundtrips() {
        let a = demo_typed(2.0);
        let mut b = a.fork();
        let domain = b.slots()[0].domain.clone();
        b.write_slot_f64(0, 5.0, &domain);
        b.flag_slot(1, flags::RESAMPLE);
        b.logp = -7.0;
        let batch = BatchVarInfo::gather(&a, &[&a, &b]);
        assert_eq!(batch.lanes(), 2);
        assert_eq!(batch.cons(0, 0), 2.0);
        assert_eq!(batch.cons(0, 1), 5.0);
        assert!(batch.is_slot_flagged(1, 1, flags::RESAMPLE));
        assert!(!batch.is_slot_flagged(1, 0, flags::RESAMPLE));
        let mut out = a.fork();
        batch.scatter_lane(1, &mut out);
        assert_eq!(out.constrained, b.constrained);
        assert_eq!(out.unconstrained, b.unconstrained);
        assert_eq!(out.slot_flags, b.slot_flags);
        assert_eq!(out.logp, -7.0);
    }

    #[test]
    fn lane_writes_match_typed_writes() {
        let a = demo_typed(2.0);
        let mut batch = BatchVarInfo::gather(&a, &[&a, &a]);
        let mut seq = a.fork();
        let d0 = a.slots()[0].domain.clone();
        let d1 = a.slots()[1].domain.clone();
        seq.write_slot_f64(0, 4.0, &d0);
        seq.write_slot_vec(1, &[1.0, 2.0, -0.5], &d1);
        batch.write_slot_f64_lane(0, 1, 4.0, &d0);
        batch.write_slot_vec_lane(1, 1, &[1.0, 2.0, -0.5], &d1);
        let mut out = a.fork();
        batch.scatter_lane(1, &mut out);
        assert_eq!(out.unconstrained, seq.unconstrained);
        assert_eq!(out.constrained, seq.constrained);
        // lane 0 untouched
        batch.scatter_lane(0, &mut out);
        assert_eq!(out.constrained, a.constrained);
    }
}
