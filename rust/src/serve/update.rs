//! Streaming Bayesian updating: absorb new observations into a cached
//! SMC posterior without refitting from scratch.
//!
//! The cheap path is [`Smc::resume`] — reweight/propagate the existing
//! cloud through only the appended observation steps, so update cost is
//! independent of how much history the posterior already absorbed. Two
//! guard rails keep the cheap path honest:
//!
//! - **resample–move rejuvenation**: when the resumed filter had to
//!   resample (weight degeneracy), the surviving particle set has lost
//!   diversity. A conditional-SMC sweep ([`csmc_sweep`]) re-draws a few
//!   particles from a kernel that leaves the posterior invariant —
//!   the classic resample–move correction (Gilks & Berzuini 2001),
//!   implemented with the Particle-Gibbs machinery the crate already has.
//! - **ESS-collapse fallback**: if the updated cloud's effective sample
//!   size still lands below `refit_ess_frac · N`, the cloud no longer
//!   represents the posterior and the updater falls back to a full
//!   from-scratch refit on the extended record.
//!
//! Everything is deterministic in `(cloud, seed)`: a fixed seed sequence
//! replays bit-identically (the streaming-update tests pin this down).

use std::time::Instant;

use crate::inference::smc::{csmc_sweep, Csmc, Smc, SmcCloud, SmcResult};
use crate::model::Model;
use crate::obs::metrics::{self, Counter};
use crate::particle::particle_seed;
use crate::util::rng::Xoshiro256pp;
use crate::varname::VarName;

/// Which path an update took.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateKind {
    /// Cloud reweighted through the appended steps (cheap path).
    Streamed,
    /// ESS collapsed after the resume; refitted from scratch.
    EssRefit,
}

impl UpdateKind {
    pub fn label(&self) -> &'static str {
        match self {
            UpdateKind::Streamed => "streamed",
            UpdateKind::EssRefit => "ess-refit",
        }
    }
}

/// Outcome of one streaming update.
pub struct UpdateOutcome {
    pub kind: UpdateKind,
    /// The posterior over the extended record (total running evidence).
    pub result: SmcResult,
    /// Evidence contributed by the new batch:
    /// `log Ẑ(y_{1..t+k}) − log Ẑ(y_{1..t})`. Increments across a stream
    /// of updates telescope to the batch-fit evidence.
    pub increment: f64,
    /// Particles re-drawn by the rejuvenation sweep.
    pub rejuvenated: usize,
    pub wall_secs: f64,
}

/// Absorb the appended observations of `model` (whose record extends the
/// one `prev` was fitted on) into the cached cloud. Consumes `prev` —
/// resuming mutates the cloud in place; on the fallback path the old
/// cloud is discarded with the rest of the stale fit.
pub fn streaming_update(
    smc: &Smc,
    model: &dyn Model,
    prev: SmcResult,
    seed: u64,
    refit_ess_frac: f64,
    rejuvenation_moves: usize,
) -> UpdateOutcome {
    let t0 = Instant::now();
    let prev_evidence = prev.log_evidence;
    let mut result = smc.resume(model, prev.cloud, seed);
    let n = result.cloud.len() as f64;
    if result.cloud.ess() < refit_ess_frac * n {
        // the reweighted cloud no longer represents the posterior —
        // refit the extended record from scratch (distinct seed stream
        // so the refit is not a replay of the failed resume)
        metrics::inc(Counter::ServeEssRefits);
        let refit = smc.run(model, seed ^ 0x9E37_79B9_7F4A_7C15);
        return UpdateOutcome {
            kind: UpdateKind::EssRefit,
            increment: refit.log_evidence - prev_evidence,
            result: refit,
            rejuvenated: 0,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
    }
    metrics::inc(Counter::ServeStreamUpdates);
    let rejuvenated = if rejuvenation_moves > 0 && result.resamples > 0 {
        rejuvenate(smc, model, &mut result, seed, rejuvenation_moves)
    } else {
        0
    };
    UpdateOutcome {
        kind: UpdateKind::Streamed,
        increment: result.log_evidence - prev_evidence,
        result,
        rejuvenated,
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

/// Resample–move: equalize the cloud's weights, then re-draw `moves`
/// particles through a conditional-SMC sweep over the full latent scope.
/// Leaves `log_evidence` untouched (the move kernel is posterior-
/// invariant and evidence accumulation happened at propagation time).
/// Returns how many particles were actually replaced.
fn rejuvenate(
    smc: &Smc,
    model: &dyn Model,
    result: &mut SmcResult,
    seed: u64,
    moves: usize,
) -> usize {
    let mut master = Xoshiro256pp::seed_from_u64(particle_seed(seed, usize::MAX / 2, 0x7E01));
    let n_obs = result.cloud.n_obs();
    // a small inner filter is enough for a move kernel; the validity of
    // the sweep does not depend on its particle count
    let csmc = Csmc::new((smc.n_particles / 16).max(8));
    // the move targets the posterior, so it must replace an *unweighted*
    // particle: force one resampling pass first (flag-clean at the final
    // horizon — every site is already scored)
    match &mut result.cloud {
        SmcCloud::Typed { cloud, .. } => cloud.resample(smc.resampler, false, &mut master),
        SmcCloud::Boxed(c) => c.resample(smc.resampler, false, &mut master),
    }
    metrics::inc(Counter::ResampleEvents);
    let mut done = 0;
    for k in 0..moves {
        match &mut result.cloud {
            SmcCloud::Typed { cloud, template } => {
                let i = (master.next_u64() as usize) % cloud.particles.len();
                let state = &cloud.particles[i].state;
                let scope: Vec<VarName> = state.slots().iter().map(|s| s.vn.clone()).collect();
                let reference = state.to_untyped(template);
                let fresh = csmc_sweep(
                    model,
                    &reference,
                    &scope,
                    &csmc,
                    particle_seed(seed, k, 0xC53C),
                    Some(n_obs),
                    Some(state),
                );
                if let Some(new_state) = state.refill_from_untyped(&fresh) {
                    cloud.particles[i].state = new_state;
                    done += 1;
                }
            }
            SmcCloud::Boxed(c) => {
                let i = (master.next_u64() as usize) % c.particles.len();
                let reference = c.particles[i].state.clone();
                let scope: Vec<VarName> =
                    reference.records().iter().map(|r| r.vn.clone()).collect();
                c.particles[i].state = csmc_sweep(
                    model,
                    &reference,
                    &scope,
                    &csmc,
                    particle_seed(seed, k, 0xC53C),
                    Some(n_obs),
                    None,
                );
                done += 1;
            }
        }
    }
    done
}
