//! Query evaluation against a cached artifact: summary statistics and
//! quantiles read straight off the draw matrix; posterior-predictive
//! queries replay the likelihood under each precomputed parameter map
//! ([`crate::query::run_fixed`]) and log-mean-exp the terms.
//!
//! Nothing here re-runs inference — that is the whole point. The
//! expensive grouping work (chain columns → one parameter map per draw)
//! happened once at fit time and lives in [`Artifact::param_maps`], so a
//! summary query is an `O(draws)` fold and a predictive query is one
//! fixed-values model replay per draw.

use crate::context::Context;
use crate::model::Model;
use crate::query::run_fixed;
use crate::util::math::log_sum_exp;

use super::artifact::Artifact;

/// One request against a fitted posterior.
#[derive(Clone, Debug)]
pub enum ServeQuery {
    /// Posterior mean of a chain column (e.g. `"m"`, `"h[4]"`).
    Mean { param: String },
    /// Posterior standard deviation of a column.
    Std { param: String },
    /// Posterior quantile `q ∈ [0, 1]` of a column.
    Quantile { param: String, q: f64 },
    /// The fit's log-evidence estimate (SMC) / ELBO (ADVI).
    Evidence,
    /// Log posterior-predictive of fresh observations (the caller binds
    /// them into a model instance; see `ServeHandle::query`).
    LogPredictive { y: Vec<f64> },
}

impl ServeQuery {
    /// Short label for protocol responses and bench rows.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeQuery::Mean { .. } => "mean",
            ServeQuery::Std { .. } => "std",
            ServeQuery::Quantile { .. } => "quantile",
            ServeQuery::Evidence => "evidence",
            ServeQuery::LogPredictive { .. } => "predictive",
        }
    }
}

/// Answer a summary-statistic query from the artifact's draws.
/// `LogPredictive` is not answerable here — it needs a model instance
/// bound to the query's data; use [`log_predictive`].
pub fn summary(artifact: &Artifact, q: &ServeQuery) -> Result<f64, String> {
    match q {
        ServeQuery::Mean { param } => artifact
            .chain
            .mean(param)
            .ok_or_else(|| format!("unknown parameter {param:?}")),
        ServeQuery::Std { param } => artifact
            .chain
            .std(param)
            .ok_or_else(|| format!("unknown parameter {param:?}")),
        ServeQuery::Quantile { param, q } => {
            if !(0.0..=1.0).contains(q) {
                return Err(format!("quantile {q} outside [0, 1]"));
            }
            artifact
                .chain
                .quantile(param, *q)
                .ok_or_else(|| format!("unknown parameter {param:?}"))
        }
        ServeQuery::Evidence => Ok(artifact.chain.stats.log_evidence),
        ServeQuery::LogPredictive { .. } => {
            Err("predictive queries need a model instance; use log_predictive".into())
        }
    }
}

/// Log posterior-predictive of `model`'s observations under the
/// artifact's draws: `log (1/S) Σ_s p(y_new | θ_s)`.
pub fn log_predictive(artifact: &Artifact, model: &dyn Model) -> Result<f64, String> {
    let mut terms = Vec::with_capacity(artifact.param_maps.len());
    for params in &artifact.param_maps {
        terms.push(run_fixed(model, params, Context::Likelihood)?);
    }
    if terms.is_empty() {
        return Err("artifact has no draws".into());
    }
    Ok(log_sum_exp(&terms) - (terms.len() as f64).ln())
}

/// Batched predictive evaluation: answer every query in one sweep over
/// the draw matrix (outer loop draws, inner loop queries), so each
/// parameter map is touched once however many queries are in flight —
/// the batching the concurrent server path funnels into.
pub fn log_predictive_batch(
    artifact: &Artifact,
    models: &[Box<dyn Model>],
) -> Result<Vec<f64>, String> {
    let s = artifact.param_maps.len();
    if s == 0 {
        return Err("artifact has no draws".into());
    }
    let mut terms: Vec<Vec<f64>> = models.iter().map(|_| Vec::with_capacity(s)).collect();
    for params in &artifact.param_maps {
        for (qi, m) in models.iter().enumerate() {
            terms[qi].push(run_fixed(m.as_ref(), params, Context::Likelihood)?);
        }
    }
    Ok(terms
        .iter()
        .map(|t| log_sum_exp(t) - (s as f64).ln())
        .collect())
}
