//! The wire front end: line-delimited JSON over `std::net::TcpListener`,
//! one request per line, one response line back, many requests per
//! connection. Connections are handled by a fixed worker pool
//! ([`crate::util::threadpool::ThreadPool`]); every worker shares one
//! [`ServeHandle`] behind an `Arc`, so all connections hit the same
//! artifact cache and stream registry.
//!
//! Protocol (all requests are single-line JSON objects with an `"op"`):
//!
//! ```text
//! {"op":"init","model":"kalman","y":[…]}                → {"ok":true,"version":1}
//! {"op":"fit","model":"kalman","sampler":"smc"}         → {"ok":true,"cached":false,…}
//! {"op":"query","model":"kalman","kind":"mean","param":"h[9]"}
//! {"op":"query","model":"kalman","kind":"predictive","y":[…]}
//! {"op":"update","model":"kalman","y":[…]}              → {"ok":true,"kind":"streamed",…}
//! {"op":"invalidate","model":"kalman"}                  → {"ok":true,"removed":2}
//! {"op":"stats"}                                        → cache + counter snapshot
//! {"op":"shutdown"}                                     → {"ok":true} and the server drains
//! ```
//!
//! Errors come back as `{"ok":false,"error":"…"}` — a malformed line
//! never kills the connection, let alone the server.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::json::{escape, Json};
use crate::util::threadpool::ThreadPool;

use super::query::ServeQuery;
use super::{FitSpec, ServeHandle};

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn err_line(msg: &str) -> String {
    format!("{{\"ok\": false, \"error\": \"{}\"}}", escape(msg))
}

/// Pull a [`FitSpec`] out of a request, defaulting every absent field.
fn fit_spec(req: &Json) -> FitSpec {
    let mut spec = FitSpec::default();
    if let Some(s) = req.get("sampler").and_then(Json::as_str) {
        spec.sampler = s.to_string();
    }
    if let Some(n) = req.get("draws").and_then(Json::as_u64) {
        spec.draws = n as usize;
    }
    if let Some(n) = req.get("warmup").and_then(Json::as_u64) {
        spec.warmup = n as usize;
    }
    if let Some(n) = req.get("particles").and_then(Json::as_u64) {
        spec.particles = (n as usize).max(2);
    }
    if let Some(n) = req.get("seed").and_then(Json::as_u64) {
        spec.seed = n;
    }
    spec
}

fn req_model(req: &Json) -> Result<&str, String> {
    req.get("model")
        .and_then(Json::as_str)
        .ok_or_else(|| "request is missing \"model\"".to_string())
}

fn req_obs(req: &Json) -> Result<Vec<f64>, String> {
    req.get("y")
        .and_then(Json::num_vec)
        .ok_or_else(|| "request is missing a numeric \"y\" array".to_string())
}

/// Evaluate one parsed request against the handle. Returns the response
/// line (no trailing newline) and whether this request asked the server
/// to shut down. Public so tests and tools can speak the protocol
/// without a socket.
pub fn dispatch(handle: &ServeHandle, req: &Json) -> (String, bool) {
    let op = match req.get("op").and_then(Json::as_str) {
        Some(op) => op,
        None => return (err_line("request is missing \"op\""), false),
    };
    let resp = match op {
        "init" => req_model(req).and_then(|model| {
            let y = req_obs(req)?;
            let version = handle.init_stream(model, y)?;
            Ok(format!("{{\"ok\": true, \"version\": {version}}}"))
        }),
        "fit" => req_model(req).and_then(|model| {
            let spec = fit_spec(req);
            let (art, cached) = handle.fit(model, &spec)?;
            Ok(format!(
                "{{\"ok\": true, \"cached\": {cached}, \"n_draws\": {}, \
                 \"log_evidence\": {}, \"fit_secs\": {}}}",
                art.chain.len(),
                json_num(art.chain.stats.log_evidence),
                json_num(art.fit_secs),
            ))
        }),
        "query" => req_model(req).and_then(|model| {
            let spec = fit_spec(req);
            let q = parse_query(req)?;
            let value = handle.query(model, &spec, &q)?;
            Ok(format!(
                "{{\"ok\": true, \"kind\": \"{}\", \"value\": {}}}",
                q.kind(),
                json_num(value)
            ))
        }),
        "update" => req_model(req).and_then(|model| {
            let spec = fit_spec(req);
            let y = req_obs(req)?;
            let rep = handle.update_stream(model, &y, &spec)?;
            Ok(format!(
                "{{\"ok\": true, \"kind\": \"{}\", \"version\": {}, \"n_obs\": {}, \
                 \"log_evidence\": {}, \"increment\": {}, \"ess\": {}, \
                 \"rejuvenated\": {}, \"wall_secs\": {}}}",
                rep.kind.label(),
                rep.data_version,
                rep.n_obs,
                json_num(rep.log_evidence),
                json_num(rep.increment),
                json_num(rep.ess),
                rep.rejuvenated,
                json_num(rep.wall_secs),
            ))
        }),
        "invalidate" => req_model(req).map(|model| {
            let removed = handle.invalidate(model);
            format!("{{\"ok\": true, \"removed\": {removed}}}")
        }),
        "stats" => {
            let s = handle.stats();
            Ok(format!(
                "{{\"ok\": true, \"artifacts\": {}, \"queries\": {}, \
                 \"cache_hits\": {}, \"cache_misses\": {}, \"hit_rate\": {}, \
                 \"evictions\": {}, \"stream_updates\": {}, \"ess_refits\": {}, \
                 \"warm_starts\": {}}}",
                s.artifacts,
                s.queries,
                s.cache_hits,
                s.cache_misses,
                json_num(s.hit_rate),
                s.evictions,
                s.stream_updates,
                s.ess_refits,
                s.warm_starts,
            ))
        }
        "shutdown" => return ("{\"ok\": true}".to_string(), true),
        other => Err(format!("unknown op {other:?}")),
    };
    match resp {
        Ok(line) => (line, false),
        Err(e) => (err_line(&e), false),
    }
}

fn parse_query(req: &Json) -> Result<ServeQuery, String> {
    let kind = req
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("query is missing \"kind\"")?;
    let param = || -> Result<String, String> {
        req.get("param")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("{kind} query is missing \"param\""))
    };
    match kind {
        "mean" => Ok(ServeQuery::Mean { param: param()? }),
        "std" => Ok(ServeQuery::Std { param: param()? }),
        "quantile" => Ok(ServeQuery::Quantile {
            param: param()?,
            q: req
                .get("q")
                .and_then(Json::as_f64)
                .ok_or("quantile query is missing \"q\"")?,
        }),
        "evidence" => Ok(ServeQuery::Evidence),
        "predictive" => Ok(ServeQuery::LogPredictive { y: req_obs(req)? }),
        other => Err(format!(
            "unknown query kind {other:?} (mean, std, quantile, evidence, predictive)"
        )),
    }
}

/// Outcome of one bounded line read.
enum LineRead {
    /// A complete line landed in the buffer (without its `\n`).
    Line,
    /// Clean end of stream with nothing buffered.
    Eof,
    /// The line exceeded the configured byte cap.
    TooLong,
    /// The socket read timed out before a newline arrived.
    TimedOut,
    /// Any other I/O failure.
    Err,
}

/// Read one `\n`-terminated line into `buf`, refusing to accumulate more
/// than `max` bytes — the unbounded-`read_line` DoS hole this replaces.
/// A trailing line without a newline at EOF still counts as a line.
fn read_bounded_line<R: BufRead>(r: &mut R, buf: &mut Vec<u8>, max: usize) -> LineRead {
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return LineRead::TimedOut
            }
            Err(_) => return LineRead::Err,
        };
        if chunk.is_empty() {
            return if buf.is_empty() {
                LineRead::Eof
            } else {
                LineRead::Line
            };
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if buf.len() + pos > max {
                    r.consume(pos + 1);
                    return LineRead::TooLong;
                }
                buf.extend_from_slice(&chunk[..pos]);
                r.consume(pos + 1);
                return LineRead::Line;
            }
            None => {
                let n = chunk.len();
                if buf.len() + n > max {
                    r.consume(n);
                    return LineRead::TooLong;
                }
                buf.extend_from_slice(chunk);
                r.consume(n);
            }
        }
    }
}

/// One connection: read request lines until EOF or a shutdown op,
/// answering each on its own line. Reads are bounded in both time
/// (`ServeConfig::request_timeout_ms`) and size
/// (`ServeConfig::max_line_bytes`); a violation gets a structured JSON
/// error line and the connection is closed — a hostile or stalled client
/// cannot pin a worker or its memory.
fn handle_conn(stream: TcpStream, handle: &ServeHandle, stop: &AtomicBool, addr: SocketAddr) {
    let timeout_ms = handle.cfg.request_timeout_ms;
    if timeout_ms > 0 {
        let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(timeout_ms)));
    }
    let max_line = handle.cfg.max_line_bytes.max(1);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        // (resp, shutdown-after-reply, close-after-reply)
        let (resp, shutdown, close) = match read_bounded_line(&mut reader, &mut buf, max_line) {
            LineRead::Eof | LineRead::Err => break,
            LineRead::TimedOut => (
                err_line(&format!("request timed out after {timeout_ms}ms")),
                false,
                true,
            ),
            LineRead::TooLong => (
                err_line(&format!("request line exceeds {max_line} bytes")),
                false,
                true,
            ),
            LineRead::Line => {
                let line = String::from_utf8_lossy(&buf);
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                let (resp, shutdown) = match Json::parse(line) {
                    Ok(req) => dispatch(handle, &req),
                    Err(e) => (err_line(&format!("bad request: {e}")), false),
                };
                (resp, shutdown, false)
            }
        };
        if writer
            .write_all(resp.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush())
            .is_err()
        {
            break;
        }
        if shutdown {
            stop.store(true, Ordering::SeqCst);
            // the accept loop is blocked in accept(); poke it loose
            let _ = TcpStream::connect(addr);
            break;
        }
        if close {
            break;
        }
    }
}

/// The serving daemon: a bound listener plus the worker pool that drains
/// it. `run` blocks until a client sends `{"op":"shutdown"}`.
pub struct Server {
    listener: TcpListener,
    handle: Arc<ServeHandle>,
    workers: usize,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port).
    pub fn bind(addr: &str, handle: Arc<ServeHandle>, workers: usize) -> std::io::Result<Server> {
        Ok(Server {
            listener: TcpListener::bind(addr)?,
            handle,
            workers: workers.max(1),
        })
    }

    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept connections until a shutdown op arrives, then drain the
    /// pool (dropping it joins the workers).
    pub fn run(&self) -> std::io::Result<()> {
        let pool = ThreadPool::new(self.workers);
        let stop = Arc::new(AtomicBool::new(false));
        let addr = self.listener.local_addr()?;
        for conn in self.listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let conn = match conn {
                Ok(c) => c,
                Err(_) => continue,
            };
            let handle = Arc::clone(&self.handle);
            let stop = Arc::clone(&stop);
            pool.execute(move || handle_conn(conn, &handle, &stop, addr));
        }
        Ok(())
    }
}
