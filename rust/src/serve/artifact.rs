//! Fitted-posterior artifacts and the LRU cache that serves them.
//!
//! A fit is expensive (seconds); a query against its draws is cheap
//! (microseconds). The cache turns that asymmetry into a serving story:
//! each artifact is fitted once per `(model, data-version, sampler-config)`
//! key, wrapped in an `Arc`, and every concurrent query thread reads the
//! same immutable draw matrix. Streaming updates insert a new version and
//! invalidate the stale ones; capacity pressure evicts the least recently
//! used artifact.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::chain::Chain;
use crate::inference::smc::SmcResult;
use crate::obs::metrics::{self, Counter};
use crate::value::Value;
use crate::varname::VarName;
use crate::vi::ViFit;

/// What a fitted posterior is cached under. `data_version` advances on
/// every streaming update, so an artifact never silently serves stale
/// data — a new version is a new key.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub model: String,
    pub data_version: u64,
    /// Sampler-config label (`FitSpec::label()`): same model + data under
    /// a different sampler or budget is a different artifact.
    pub sampler: String,
}

/// The sampler-specific state kept alongside the draws — whatever the
/// *next* fit of the same stream can reuse.
pub enum Posterior {
    /// MCMC draws: the chain is the whole story (plus `warm_theta`).
    Draws,
    /// Variational fit: kept for warm-starting the next fit (`mu`, `eta`).
    Vi(ViFit),
    /// SMC cloud: kept for streaming updates. `Mutex<Option<..>>` so an
    /// update can *take* the cloud (resuming consumes it) while queries
    /// keep reading the immutable chain next to it.
    Smc(Mutex<Option<SmcResult>>),
}

/// One fitted posterior, immutable once inserted (the SMC cloud's slot is
/// the deliberate exception). Queries touch `chain` / `param_maps` only.
pub struct Artifact {
    pub key: ArtifactKey,
    /// Equal-weight constrained-space draws.
    pub chain: Chain,
    /// One parameter map per draw, grouped once at fit time
    /// ([`crate::query::chain_param_maps`]) — the reason a
    /// posterior-predictive query is a plain replay per draw instead of a
    /// per-query chain traversal.
    pub param_maps: Vec<HashMap<VarName, Value>>,
    pub posterior: Posterior,
    /// Unconstrained warm-start point for the next fit of this stream
    /// (NUTS: last draw; ADVI: variational mean).
    pub warm_theta: Option<Vec<f64>>,
    /// Wall-clock seconds the fit took — the denominator of every
    /// "serving is N× cheaper" claim.
    pub fit_secs: f64,
}

struct Entry {
    artifact: Arc<Artifact>,
    last_used: u64,
}

struct Inner {
    map: HashMap<ArtifactKey, Entry>,
    tick: u64,
}

/// Thread-safe LRU cache of fitted posteriors. All bookkeeping sits
/// behind one mutex held for map operations only — fits and queries run
/// outside it.
pub struct ArtifactCache {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// Keys whose fit is currently running somewhere (single-flight).
    in_flight: Mutex<HashSet<ArtifactKey>>,
    in_flight_cv: Condvar,
    single_flight_waits: AtomicU64,
}

impl ArtifactCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            in_flight: Mutex::new(HashSet::new()),
            in_flight_cv: Condvar::new(),
            single_flight_waits: AtomicU64::new(0),
        }
    }

    /// Single-flight claim on fitting `key`. Returns `true` when this
    /// caller is the leader — it must call [`end_fit`](Self::end_fit)
    /// when done (success or failure). Returns `false` after blocking
    /// until the current leader releases; the caller should then re-check
    /// [`get`](Self::get) before deciding to fit itself.
    pub fn begin_fit(&self, key: &ArtifactKey) -> bool {
        let mut fl = self.in_flight.lock().expect("in-flight set poisoned");
        if fl.insert(key.clone()) {
            return true;
        }
        self.single_flight_waits.fetch_add(1, Ordering::Relaxed);
        metrics::inc(Counter::ServeSingleFlightWaits);
        while fl.contains(key) {
            fl = self.in_flight_cv.wait(fl).expect("in-flight set poisoned");
        }
        false
    }

    /// Release a [`begin_fit`](Self::begin_fit) claim and wake every
    /// thread waiting on it.
    pub fn end_fit(&self, key: &ArtifactKey) {
        let mut fl = self.in_flight.lock().expect("in-flight set poisoned");
        fl.remove(key);
        self.in_flight_cv.notify_all();
    }

    /// How many fit requests blocked behind an in-flight fit of the same
    /// key instead of fitting redundantly.
    pub fn single_flight_waits(&self) -> u64 {
        self.single_flight_waits.load(Ordering::Relaxed)
    }

    /// Look up an artifact, counting the hit/miss and refreshing LRU age.
    pub fn get(&self, key: &ArtifactKey) -> Option<Arc<Artifact>> {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                metrics::inc(Counter::ServeCacheHits);
                Some(Arc::clone(&e.artifact))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                metrics::inc(Counter::ServeCacheMisses);
                None
            }
        }
    }

    /// Insert (or replace) an artifact, evicting the least recently used
    /// entry while over capacity. Returns the shared handle.
    pub fn insert(&self, artifact: Artifact) -> Arc<Artifact> {
        let key = artifact.key.clone();
        let artifact = Arc::new(artifact);
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(
            key,
            Entry {
                artifact: Arc::clone(&artifact),
                last_used: tick,
            },
        );
        while inner.map.len() > self.capacity {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map has a minimum");
            inner.map.remove(&oldest);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        artifact
    }

    /// Explicitly drop one artifact. Returns whether it existed.
    pub fn invalidate(&self, key: &ArtifactKey) -> bool {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        inner.map.remove(key).is_some()
    }

    /// Drop every artifact of `model` (all versions, all samplers).
    /// Returns how many were removed.
    pub fn invalidate_model(&self, model: &str) -> usize {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        let before = inner.map.len();
        inner.map.retain(|k, _| k.model != model);
        before - inner.map.len()
    }

    /// Drop artifacts of `model` older than `keep_version` — the
    /// streaming updater's cleanup after publishing a new version.
    pub fn invalidate_stale(&self, model: &str, keep_version: u64) -> usize {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        let before = inner.map.len();
        inner
            .map
            .retain(|k, _| k.model != model || k.data_version >= keep_version);
        before - inner.map.len()
    }

    /// The newest artifact of `model` whose sampler label starts with
    /// `sampler_prefix` — the warm-start donor for the next fit. Does not
    /// count as a hit or miss (it is not a serving lookup).
    pub fn latest_for(&self, model: &str, sampler_prefix: &str) -> Option<Arc<Artifact>> {
        let inner = self.inner.lock().expect("artifact cache poisoned");
        inner
            .map
            .iter()
            .filter(|(k, _)| k.model == model && k.sampler.starts_with(sampler_prefix))
            .max_by_key(|(k, _)| k.data_version)
            .map(|(_, e)| Arc::clone(&e.artifact))
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("artifact cache poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// hits / (hits + misses); 1.0 when nothing was looked up yet.
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m == 0.0 {
            1.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(model: &str, version: u64) -> Artifact {
        Artifact {
            key: ArtifactKey {
                model: model.into(),
                data_version: version,
                sampler: "smc-test".into(),
            },
            chain: Chain::new(vec!["m".into()]),
            param_maps: Vec::new(),
            posterior: Posterior::Draws,
            warm_theta: None,
            fit_secs: 0.0,
        }
    }

    #[test]
    fn lru_evicts_oldest_untouched_entry() {
        let cache = ArtifactCache::new(2);
        cache.insert(dummy("a", 1));
        cache.insert(dummy("b", 1));
        // touch `a` so `b` is the LRU victim
        assert!(cache.get(&dummy("a", 1).key).is_some());
        cache.insert(dummy("c", 1));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&dummy("a", 1).key).is_some());
        assert!(cache.get(&dummy("b", 1).key).is_none());
        assert!(cache.get(&dummy("c", 1).key).is_some());
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn invalidation_by_key_model_and_version() {
        let cache = ArtifactCache::new(8);
        cache.insert(dummy("a", 1));
        cache.insert(dummy("a", 2));
        cache.insert(dummy("b", 1));
        assert!(cache.invalidate(&dummy("b", 1).key));
        assert!(!cache.invalidate(&dummy("b", 1).key));
        assert_eq!(cache.invalidate_stale("a", 2), 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.invalidate_model("a"), 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn single_flight_blocks_waiters_until_the_leader_releases() {
        use std::sync::atomic::AtomicBool;
        let cache = Arc::new(ArtifactCache::new(4));
        let key = dummy("a", 1).key;
        assert!(cache.begin_fit(&key), "first claim elects the leader");
        // a second claim on another key is independent
        let other = dummy("b", 1).key;
        assert!(cache.begin_fit(&other));
        cache.end_fit(&other);

        let released = Arc::new(AtomicBool::new(false));
        let entering = Arc::new(AtomicBool::new(false));
        let (c2, k2, r2, e2) = (
            Arc::clone(&cache),
            key.clone(),
            Arc::clone(&released),
            Arc::clone(&entering),
        );
        let waiter = std::thread::spawn(move || {
            e2.store(true, Ordering::SeqCst);
            let leader = c2.begin_fit(&k2);
            // by the time the wait returns, the leader has released
            (leader, r2.load(Ordering::SeqCst), c2.get(&k2).is_some())
        });
        while !entering.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        // give the waiter time to block on the in-flight claim
        std::thread::sleep(std::time::Duration::from_millis(100));
        cache.insert(dummy("a", 1));
        released.store(true, Ordering::SeqCst);
        cache.end_fit(&key);

        let (leader, saw_release, found) = waiter.join().unwrap();
        assert!(!leader, "the waiter must not become a second leader");
        assert!(saw_release, "the waiter woke before the leader released");
        assert!(found, "the leader's artifact is visible after the wait");
        assert_eq!(cache.single_flight_waits(), 1);
    }

    #[test]
    fn latest_for_picks_newest_version() {
        let cache = ArtifactCache::new(8);
        cache.insert(dummy("a", 1));
        cache.insert(dummy("a", 3));
        cache.insert(dummy("a", 2));
        let got = cache.latest_for("a", "smc").expect("artifact");
        assert_eq!(got.key.data_version, 3);
        assert!(cache.latest_for("a", "nuts").is_none());
        // warm-start lookups do not perturb serving hit-rate accounting
        assert_eq!(cache.hits() + cache.misses(), 0);
    }
}
