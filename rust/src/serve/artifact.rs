//! Fitted-posterior artifacts and the LRU cache that serves them.
//!
//! A fit is expensive (seconds); a query against its draws is cheap
//! (microseconds). The cache turns that asymmetry into a serving story:
//! each artifact is fitted once per `(model, data-version, sampler-config)`
//! key, wrapped in an `Arc`, and every concurrent query thread reads the
//! same immutable draw matrix. Streaming updates insert a new version and
//! invalidate the stale ones; capacity pressure evicts the least recently
//! used artifact.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::chain::Chain;
use crate::inference::smc::SmcResult;
use crate::obs::metrics::{self, Counter};
use crate::value::Value;
use crate::varname::VarName;
use crate::vi::ViFit;

/// What a fitted posterior is cached under. `data_version` advances on
/// every streaming update, so an artifact never silently serves stale
/// data — a new version is a new key.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub model: String,
    pub data_version: u64,
    /// Sampler-config label (`FitSpec::label()`): same model + data under
    /// a different sampler or budget is a different artifact.
    pub sampler: String,
}

/// The sampler-specific state kept alongside the draws — whatever the
/// *next* fit of the same stream can reuse.
pub enum Posterior {
    /// MCMC draws: the chain is the whole story (plus `warm_theta`).
    Draws,
    /// Variational fit: kept for warm-starting the next fit (`mu`, `eta`).
    Vi(ViFit),
    /// SMC cloud: kept for streaming updates. `Mutex<Option<..>>` so an
    /// update can *take* the cloud (resuming consumes it) while queries
    /// keep reading the immutable chain next to it.
    Smc(Mutex<Option<SmcResult>>),
}

/// One fitted posterior, immutable once inserted (the SMC cloud's slot is
/// the deliberate exception). Queries touch `chain` / `param_maps` only.
pub struct Artifact {
    pub key: ArtifactKey,
    /// Equal-weight constrained-space draws.
    pub chain: Chain,
    /// One parameter map per draw, grouped once at fit time
    /// ([`crate::query::chain_param_maps`]) — the reason a
    /// posterior-predictive query is a plain replay per draw instead of a
    /// per-query chain traversal.
    pub param_maps: Vec<HashMap<VarName, Value>>,
    pub posterior: Posterior,
    /// Unconstrained warm-start point for the next fit of this stream
    /// (NUTS: last draw; ADVI: variational mean).
    pub warm_theta: Option<Vec<f64>>,
    /// Wall-clock seconds the fit took — the denominator of every
    /// "serving is N× cheaper" claim.
    pub fit_secs: f64,
}

struct Entry {
    artifact: Arc<Artifact>,
    last_used: u64,
}

struct Inner {
    map: HashMap<ArtifactKey, Entry>,
    tick: u64,
}

/// Thread-safe LRU cache of fitted posteriors. All bookkeeping sits
/// behind one mutex held for map operations only — fits and queries run
/// outside it.
pub struct ArtifactCache {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ArtifactCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look up an artifact, counting the hit/miss and refreshing LRU age.
    pub fn get(&self, key: &ArtifactKey) -> Option<Arc<Artifact>> {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                metrics::inc(Counter::ServeCacheHits);
                Some(Arc::clone(&e.artifact))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                metrics::inc(Counter::ServeCacheMisses);
                None
            }
        }
    }

    /// Insert (or replace) an artifact, evicting the least recently used
    /// entry while over capacity. Returns the shared handle.
    pub fn insert(&self, artifact: Artifact) -> Arc<Artifact> {
        let key = artifact.key.clone();
        let artifact = Arc::new(artifact);
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(
            key,
            Entry {
                artifact: Arc::clone(&artifact),
                last_used: tick,
            },
        );
        while inner.map.len() > self.capacity {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map has a minimum");
            inner.map.remove(&oldest);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        artifact
    }

    /// Explicitly drop one artifact. Returns whether it existed.
    pub fn invalidate(&self, key: &ArtifactKey) -> bool {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        inner.map.remove(key).is_some()
    }

    /// Drop every artifact of `model` (all versions, all samplers).
    /// Returns how many were removed.
    pub fn invalidate_model(&self, model: &str) -> usize {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        let before = inner.map.len();
        inner.map.retain(|k, _| k.model != model);
        before - inner.map.len()
    }

    /// Drop artifacts of `model` older than `keep_version` — the
    /// streaming updater's cleanup after publishing a new version.
    pub fn invalidate_stale(&self, model: &str, keep_version: u64) -> usize {
        let mut inner = self.inner.lock().expect("artifact cache poisoned");
        let before = inner.map.len();
        inner
            .map
            .retain(|k, _| k.model != model || k.data_version >= keep_version);
        before - inner.map.len()
    }

    /// The newest artifact of `model` whose sampler label starts with
    /// `sampler_prefix` — the warm-start donor for the next fit. Does not
    /// count as a hit or miss (it is not a serving lookup).
    pub fn latest_for(&self, model: &str, sampler_prefix: &str) -> Option<Arc<Artifact>> {
        let inner = self.inner.lock().expect("artifact cache poisoned");
        inner
            .map
            .iter()
            .filter(|(k, _)| k.model == model && k.sampler.starts_with(sampler_prefix))
            .max_by_key(|(k, _)| k.data_version)
            .map(|(_, e)| Arc::clone(&e.artifact))
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("artifact cache poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// hits / (hits + misses); 1.0 when nothing was looked up yet.
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m == 0.0 {
            1.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(model: &str, version: u64) -> Artifact {
        Artifact {
            key: ArtifactKey {
                model: model.into(),
                data_version: version,
                sampler: "smc-test".into(),
            },
            chain: Chain::new(vec!["m".into()]),
            param_maps: Vec::new(),
            posterior: Posterior::Draws,
            warm_theta: None,
            fit_secs: 0.0,
        }
    }

    #[test]
    fn lru_evicts_oldest_untouched_entry() {
        let cache = ArtifactCache::new(2);
        cache.insert(dummy("a", 1));
        cache.insert(dummy("b", 1));
        // touch `a` so `b` is the LRU victim
        assert!(cache.get(&dummy("a", 1).key).is_some());
        cache.insert(dummy("c", 1));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&dummy("a", 1).key).is_some());
        assert!(cache.get(&dummy("b", 1).key).is_none());
        assert!(cache.get(&dummy("c", 1).key).is_some());
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn invalidation_by_key_model_and_version() {
        let cache = ArtifactCache::new(8);
        cache.insert(dummy("a", 1));
        cache.insert(dummy("a", 2));
        cache.insert(dummy("b", 1));
        assert!(cache.invalidate(&dummy("b", 1).key));
        assert!(!cache.invalidate(&dummy("b", 1).key));
        assert_eq!(cache.invalidate_stale("a", 2), 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.invalidate_model("a"), 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn latest_for_picks_newest_version() {
        let cache = ArtifactCache::new(8);
        cache.insert(dummy("a", 1));
        cache.insert(dummy("a", 3));
        cache.insert(dummy("a", 2));
        let got = cache.latest_for("a", "smc").expect("artifact");
        assert_eq!(got.key.data_version, 3);
        assert!(cache.latest_for("a", "nuts").is_none());
        // warm-start lookups do not perturb serving hit-rate accounting
        assert_eq!(cache.hits() + cache.misses(), 0);
    }
}
