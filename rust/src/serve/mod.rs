//! The posterior-serving runtime: fit once, answer millions of queries,
//! update streams in place.
//!
//! Classic PPL usage is batch-shaped — fit, summarize, exit — which
//! throws the fitted posterior away and pays the full inference cost for
//! every question. This module keeps fitted posteriors *resident*:
//!
//! - [`artifact`] — an LRU cache of fitted posteriors keyed by
//!   `(model, data-version, sampler-config)`, each held behind an `Arc`
//!   so concurrent query threads share one immutable draw matrix.
//! - [`query`] — posterior-predictive / summary / quantile evaluation
//!   against cached draws through the [`crate::query`] fixed-values
//!   executor, with parameter maps precomputed at fit time so a query is
//!   microseconds, not a refit.
//! - [`update`] — streaming Bayesian updating: new observations resume
//!   the cached SMC cloud ([`crate::inference::smc::Smc::resume`]) with a
//!   resample–move rejuvenation sweep, falling back to a full refit when
//!   the ESS collapses; NUTS/ADVI refits warm-start from the cached
//!   posterior instead of a cold init.
//! - [`server`] — a line-delimited JSON protocol over
//!   `std::net::TcpListener` with a worker pool, plus the in-process
//!   [`ServeHandle`] API that tests, the benchmark and the coordinator
//!   drive directly.
//!
//! Every serving event feeds the [`crate::obs::metrics`] counters
//! (`serve_queries`, `serve_cache_hits/misses`, `serve_stream_updates`,
//! `serve_ess_refits`, `serve_warm_starts`), so METRICS.json and the
//! bench report tell the cache story in numbers.

pub mod artifact;
pub mod query;
pub mod server;
pub mod update;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::gradient::NativeDensity;
use crate::inference::smc::Smc;
use crate::inference::{raw_to_chain, Nuts};
use crate::model::macros::c;
use crate::model::{init_typed, Model};
use crate::obs::metrics::{self, Counter};
use crate::util::rng::{Rng as _, Xoshiro256pp};
use crate::vi::Advi;

use artifact::{Artifact, ArtifactCache, ArtifactKey, Posterior};
use query::ServeQuery;
use update::{streaming_update, UpdateKind, UpdateOutcome};

// ---------------------------------------------------------------- models

crate::model! {
    /// Conjugate Normal–Normal stream: `m ~ N(0, 1)`, `y_t ~ N(m, 1)` —
    /// closed-form posterior and evidence, the correctness anchor of the
    /// streaming tests. Its latent set is fixed, so streaming updates
    /// keep the typed fast path.
    pub StreamNormal {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = crate::tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        for &yi in &this.y {
            crate::obs!(api, yi => Normal(m, c(1.0)));
        }
    }
}

crate::model! {
    /// Linear-Gaussian state-space stream (Kalman-solvable):
    /// `h_0 ~ N(0, 1)`, `h_t ~ N(φ h_{t−1}, q)`, `y_t ~ N(h_t, r)`.
    /// Each appended observation introduces a fresh latent `h[t]`, so a
    /// streaming update exercises the dynamic-structure path (typed cloud
    /// demotes to boxed, exactly like a mid-sweep structure change).
    pub StreamKalman {
        y: Vec<f64>,
        phi: f64,
        q: f64,
        r: f64,
    }
    fn body<T>(this, api) {
        let mut h_prev = crate::tilde!(api, h[0] ~ Normal(c(0.0), c(1.0)));
        crate::obs!(api, this.y[0] => Normal(h_prev, c(this.r)));
        for t in 1..this.y.len() {
            let h_t = crate::tilde!(api, h[t] ~ Normal(h_prev * this.phi, c(this.q)));
            crate::obs!(api, this.y[t] => Normal(h_t, c(this.r)));
            h_prev = h_t;
        }
    }
}

/// The serve-side Kalman hyperparameters (shared with the bench oracle).
/// `q` and `r` are standard deviations, matching the model body.
pub const KALMAN_PHI: f64 = 0.8;
pub const KALMAN_Q: f64 = 0.6;
pub const KALMAN_R: f64 = 0.5;

/// Stream-model names the runtime can build from an observation vector.
pub const STREAM_MODELS: [&str; 2] = ["normal_normal", "kalman"];

/// Instantiate a stream model over `y`. Every servable model is a
/// function of its observation record — that is what makes "append
/// observations, rebuild, resume" a well-defined update.
pub fn build_stream_model(name: &str, y: &[f64]) -> Result<Box<dyn Model>, String> {
    if y.is_empty() {
        return Err("stream has no observations".into());
    }
    match name {
        "normal_normal" => Ok(Box::new(StreamNormal { y: y.to_vec() })),
        "kalman" => Ok(Box::new(StreamKalman {
            y: y.to_vec(),
            phi: KALMAN_PHI,
            q: KALMAN_Q,
            r: KALMAN_R,
        })),
        other => Err(format!(
            "unknown stream model {other:?} (known: {})",
            STREAM_MODELS.join(", ")
        )),
    }
}

/// Simulate a ground-truth observation record from the Kalman stream's
/// generative process (bench + test fixture).
pub fn simulate_kalman(t: usize, seed: u64) -> Vec<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut h = rng.normal();
    let mut y = Vec::with_capacity(t);
    y.push(h + KALMAN_R * rng.normal());
    for _ in 1..t {
        h = KALMAN_PHI * h + KALMAN_Q * rng.normal();
        y.push(h + KALMAN_R * rng.normal());
    }
    y
}

/// Exact Kalman filter log-likelihood + RTS smoother means for the
/// [`StreamKalman`] stream — the ground truth its SMC posterior (batch
/// or streamed) is judged against.
pub fn kalman_oracle(y: &[f64]) -> (f64, Vec<f64>) {
    let t_len = y.len();
    let (q2, r2) = (KALMAN_Q * KALMAN_Q, KALMAN_R * KALMAN_R);
    let phi = KALMAN_PHI;
    let mut mf = Vec::with_capacity(t_len); // filtered means
    let mut pf = Vec::with_capacity(t_len); // filtered variances
    let mut mp = Vec::with_capacity(t_len); // predicted means
    let mut pp = Vec::with_capacity(t_len); // predicted variances
    let mut ll = 0.0;
    for t in 0..t_len {
        let (m_pred, p_pred) = if t == 0 {
            (0.0, 1.0)
        } else {
            (phi * mf[t - 1], phi * phi * pf[t - 1] + q2)
        };
        mp.push(m_pred);
        pp.push(p_pred);
        let s = p_pred + r2;
        ll += crate::dist::Normal::new(m_pred, s.sqrt()).logpdf(y[t]);
        let k = p_pred / s;
        mf.push(m_pred + k * (y[t] - m_pred));
        pf.push((1.0 - k) * p_pred);
    }
    // RTS smoother
    let mut ms = vec![0.0; t_len];
    ms[t_len - 1] = mf[t_len - 1];
    for t in (0..t_len - 1).rev() {
        let c = pf[t] * phi / pp[t + 1];
        ms[t] = mf[t] + c * (ms[t + 1] - mp[t + 1]);
    }
    (ll, ms)
}

/// Sequential conjugate log-evidence of the [`StreamNormal`] stream —
/// each term is one prefix's predictive density, so prefix differences
/// are exactly the evidence increments a streaming update reports.
pub fn conjugate_log_evidence(y: &[f64]) -> f64 {
    let (mut mu, mut tau2) = (0.0f64, 1.0f64);
    let mut lz = 0.0;
    for &yt in y {
        let pv = 1.0 + tau2;
        lz += crate::dist::Normal::new(mu, pv.sqrt()).logpdf(yt);
        let k = tau2 / pv;
        mu += k * (yt - mu);
        tau2 *= 1.0 - k;
    }
    lz
}

// ------------------------------------------------------------------ spec

/// A sampler configuration request — part of the artifact cache key, so
/// the same stream fitted under two budgets is two artifacts.
#[derive(Clone, Debug, PartialEq)]
pub struct FitSpec {
    /// `"smc"` (streamable), `"nuts"` or `"advi"` (warm-startable).
    pub sampler: String,
    /// Posterior draws (NUTS/ADVI; SMC draws = `particles`).
    pub draws: usize,
    /// Warmup iterations (NUTS).
    pub warmup: usize,
    /// Particle count (SMC).
    pub particles: usize,
    pub seed: u64,
}

impl Default for FitSpec {
    fn default() -> Self {
        Self {
            sampler: "smc".into(),
            draws: 500,
            warmup: 200,
            particles: 256,
            seed: 42,
        }
    }
}

impl FitSpec {
    pub fn smc(particles: usize, seed: u64) -> Self {
        Self {
            sampler: "smc".into(),
            particles,
            seed,
            ..Self::default()
        }
    }

    /// The cache-key sampler label. Starts with the sampler name, so
    /// warm-start donor lookups can prefix-match across budgets.
    pub fn label(&self) -> String {
        format!(
            "{}-d{}-w{}-p{}-s{}",
            self.sampler, self.draws, self.warmup, self.particles, self.seed
        )
    }
}

// ---------------------------------------------------------------- handle

/// Runtime configuration for a [`ServeHandle`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Artifact-cache capacity (LRU beyond this).
    pub cache_capacity: usize,
    /// SMC propagation threads.
    pub threads: usize,
    /// Streaming updates refit from scratch when the resumed cloud's
    /// ESS lands below `refit_ess_frac · N`.
    pub refit_ess_frac: f64,
    /// Resample–move particles re-drawn per streaming update (0 = off;
    /// only applies when the resumed filter actually resampled).
    pub rejuvenation_moves: usize,
    /// Warm-start NUTS/ADVI refits from the cached posterior.
    pub warm_start: bool,
    /// Per-connection read timeout in milliseconds (0 = none): a stalled
    /// client gets a structured JSON error and its worker back.
    pub request_timeout_ms: u64,
    /// Maximum request-line length in bytes: longer lines are rejected
    /// with a structured JSON error instead of buffering unboundedly.
    pub max_line_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            cache_capacity: 32,
            threads: 1,
            refit_ess_frac: 0.1,
            rejuvenation_moves: 1,
            warm_start: true,
            request_timeout_ms: 30_000,
            max_line_bytes: 1 << 20,
        }
    }
}

struct StreamState {
    y: Vec<f64>,
    version: u64,
}

/// RAII release of a single-flight fit claim ([`ArtifactCache::begin_fit`]).
struct FitClaim<'a> {
    cache: &'a ArtifactCache,
    key: &'a ArtifactKey,
}

impl Drop for FitClaim<'_> {
    fn drop(&mut self) {
        self.cache.end_fit(self.key);
    }
}

/// Aggregate serving statistics (the `stats` protocol op and the bench
/// report read these).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub artifacts: usize,
    pub queries: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub hit_rate: f64,
    pub evictions: u64,
    pub stream_updates: u64,
    pub ess_refits: u64,
    pub warm_starts: u64,
    /// Fit requests that blocked on another thread's in-flight fit of the
    /// same key instead of fitting redundantly.
    pub single_flight_waits: u64,
}

/// One streaming-update report as the handle returns it (protocol and
/// bench serialize from this).
pub struct UpdateReport {
    pub kind: UpdateKind,
    pub data_version: u64,
    pub n_obs: usize,
    pub log_evidence: f64,
    pub increment: f64,
    pub ess: f64,
    pub rejuvenated: usize,
    pub wall_secs: f64,
}

/// The in-process serving runtime: stream data registry + artifact cache
/// + the fit/query/update entry points. `Arc<ServeHandle>` is what the
/// TCP worker pool shares; tests and the bench call it directly.
pub struct ServeHandle {
    pub cfg: ServeConfig,
    pub cache: ArtifactCache,
    streams: Mutex<HashMap<String, StreamState>>,
    queries: AtomicU64,
    stream_updates: AtomicU64,
    ess_refits: AtomicU64,
    warm_starts: AtomicU64,
}

impl ServeHandle {
    pub fn new(cfg: ServeConfig) -> Self {
        let cache = ArtifactCache::new(cfg.cache_capacity);
        Self {
            cfg,
            cache,
            streams: Mutex::new(HashMap::new()),
            queries: AtomicU64::new(0),
            stream_updates: AtomicU64::new(0),
            ess_refits: AtomicU64::new(0),
            warm_starts: AtomicU64::new(0),
        }
    }

    /// Seed (or reset) a stream's observation record. Resetting bumps the
    /// data version and drops every cached artifact of the model.
    pub fn init_stream(&self, model: &str, y: Vec<f64>) -> Result<u64, String> {
        // validate the model name + data before registering anything
        build_stream_model(model, &y)?;
        let mut streams = self.streams.lock().expect("stream registry poisoned");
        let version = match streams.get(model) {
            Some(s) => s.version + 1,
            None => 1,
        };
        streams.insert(model.to_string(), StreamState { y, version });
        drop(streams);
        self.cache.invalidate_model(model);
        Ok(version)
    }

    /// Current observation record + data version of a stream.
    pub fn stream_data(&self, model: &str) -> Result<(Vec<f64>, u64), String> {
        let streams = self.streams.lock().expect("stream registry poisoned");
        streams
            .get(model)
            .map(|s| (s.y.clone(), s.version))
            .ok_or_else(|| format!("stream {model:?} has no data (send an init first)"))
    }

    /// Fit-or-fetch: returns the artifact for the stream's *current* data
    /// under `spec`, fitting only on a cache miss. The bool is
    /// "served from cache".
    pub fn fit(&self, model: &str, spec: &FitSpec) -> Result<(Arc<Artifact>, bool), String> {
        let (y, version) = self.stream_data(model)?;
        let key = ArtifactKey {
            model: model.to_string(),
            data_version: version,
            sampler: spec.label(),
        };
        if let Some(art) = self.cache.get(&key) {
            return Ok((art, true));
        }
        // single-flight: concurrent misses on one key elect a leader to
        // run the fit while everyone else blocks on the claim and then
        // serves the leader's artifact from cache — one fit per key, not
        // one per caller
        loop {
            if self.cache.begin_fit(&key) {
                // claim released on every exit path, panics included —
                // a stuck claim would block all future fits of this key
                let _claim = FitClaim {
                    cache: &self.cache,
                    key: &key,
                };
                let art = self.fit_artifact(key.clone(), &y, spec)?;
                return Ok((self.cache.insert(art), false));
            }
            // the leader finished: its insert (if it succeeded) is
            // visible now; a failed or evicted fit falls through and
            // re-elects
            if let Some(art) = self.cache.get(&key) {
                return Ok((art, true));
            }
        }
    }

    /// Answer one query against the stream's cached posterior (fitting
    /// it first if needed).
    pub fn query(&self, model: &str, spec: &FitSpec, q: &ServeQuery) -> Result<f64, String> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        metrics::inc(Counter::ServeQueries);
        let (art, _) = self.fit(model, spec)?;
        match q {
            ServeQuery::LogPredictive { y } => {
                let m = build_stream_model(model, y)?;
                query::log_predictive(&art, m.as_ref())
            }
            other => query::summary(&art, other),
        }
    }

    /// Batched posterior-predictive: all of `ys` answered in one sweep
    /// over the draw matrix (the concurrent-server batching path).
    pub fn predictive_batch(
        &self,
        model: &str,
        spec: &FitSpec,
        ys: &[Vec<f64>],
    ) -> Result<Vec<f64>, String> {
        self.queries.fetch_add(ys.len() as u64, Ordering::Relaxed);
        metrics::add(Counter::ServeQueries, ys.len() as u64);
        let (art, _) = self.fit(model, spec)?;
        let models = ys
            .iter()
            .map(|y| build_stream_model(model, y))
            .collect::<Result<Vec<_>, _>>()?;
        query::log_predictive_batch(&art, &models)
    }

    /// Append observations to a stream and update its posterior in place:
    /// resume the cached SMC cloud over the new steps (full refit when no
    /// SMC artifact is cached or the ESS collapses), publish the new
    /// artifact under the bumped data version, and drop stale versions.
    pub fn update_stream(
        &self,
        model: &str,
        new_y: &[f64],
        spec: &FitSpec,
    ) -> Result<UpdateReport, String> {
        if new_y.is_empty() {
            return Err("update carries no observations".into());
        }
        if spec.sampler != "smc" {
            return Err(format!(
                "streaming updates need an SMC posterior (got {:?})",
                spec.sampler
            ));
        }
        // bump the record under the lock; fits below run outside it
        let (y, old_version, version) = {
            let mut streams = self.streams.lock().expect("stream registry poisoned");
            let s = streams
                .get_mut(model)
                .ok_or_else(|| format!("stream {model:?} has no data (send an init first)"))?;
            s.y.extend_from_slice(new_y);
            let old_version = s.version;
            s.version += 1;
            (s.y.clone(), old_version, s.version)
        };
        let extended = build_stream_model(model, &y)?;
        let smc = self.smc_config(spec);
        // distinct seed per update batch: fresh RNG streams for the new
        // steps, deterministic for a fixed (seed, version) sequence
        let update_seed = spec.seed ^ version.wrapping_mul(0xA24B_AED4_963E_E407);

        let prev_key = ArtifactKey {
            model: model.to_string(),
            data_version: old_version,
            sampler: spec.label(),
        };
        let prev_cloud = self.cache.get(&prev_key).and_then(|art| {
            match &art.posterior {
                // take() the cloud: queries keep hitting the chain the
                // artifact retains; the cloud itself moves on
                Posterior::Smc(slot) => slot.lock().expect("cloud slot poisoned").take(),
                _ => None,
            }
        });

        let (outcome, fit_secs) = match prev_cloud {
            Some(prev) => {
                let t0 = Instant::now();
                let out = streaming_update(
                    &smc,
                    extended.as_ref(),
                    prev,
                    update_seed,
                    self.cfg.refit_ess_frac,
                    self.cfg.rejuvenation_moves,
                );
                match out.kind {
                    UpdateKind::Streamed => {
                        self.stream_updates.fetch_add(1, Ordering::Relaxed);
                    }
                    UpdateKind::EssRefit => {
                        self.ess_refits.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let secs = t0.elapsed().as_secs_f64();
                (out, secs)
            }
            None => {
                // nothing cached to resume — full fit on the extended
                // record (a miss, but it still counts as a refit: the
                // stream paid batch cost for this update)
                self.ess_refits.fetch_add(1, Ordering::Relaxed);
                metrics::inc(Counter::ServeEssRefits);
                let t0 = Instant::now();
                let result = smc.run(extended.as_ref(), update_seed);
                let increment = result.log_evidence;
                (
                    UpdateOutcome {
                        kind: UpdateKind::EssRefit,
                        increment,
                        result,
                        rejuvenated: 0,
                        wall_secs: t0.elapsed().as_secs_f64(),
                    },
                    t0.elapsed().as_secs_f64(),
                )
            }
        };

        let report = UpdateReport {
            kind: outcome.kind,
            data_version: version,
            n_obs: y.len(),
            log_evidence: outcome.result.log_evidence,
            increment: outcome.increment,
            ess: outcome.result.cloud.ess(),
            rejuvenated: outcome.rejuvenated,
            wall_secs: outcome.wall_secs,
        };

        // publish the updated posterior under the new version…
        let chain = smc.chain_from_result(extended.as_ref(), &outcome.result, update_seed);
        let param_maps = crate::query::chain_param_maps(&chain)?;
        self.cache.insert(Artifact {
            key: ArtifactKey {
                model: model.to_string(),
                data_version: version,
                sampler: spec.label(),
            },
            chain,
            param_maps,
            posterior: Posterior::Smc(Mutex::new(Some(outcome.result))),
            warm_theta: None,
            fit_secs,
        });
        // …and retire every stale version of this stream
        self.cache.invalidate_stale(model, version);
        Ok(report)
    }

    /// Drop every cached artifact of `model`. Returns how many.
    pub fn invalidate(&self, model: &str) -> usize {
        self.cache.invalidate_model(model)
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            artifacts: self.cache.len(),
            queries: self.queries.load(Ordering::Relaxed),
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            hit_rate: self.cache.hit_rate(),
            evictions: self.cache.evictions(),
            stream_updates: self.stream_updates.load(Ordering::Relaxed),
            ess_refits: self.ess_refits.load(Ordering::Relaxed),
            warm_starts: self.warm_starts.load(Ordering::Relaxed),
            single_flight_waits: self.cache.single_flight_waits(),
        }
    }

    fn smc_config(&self, spec: &FitSpec) -> Smc {
        Smc {
            n_particles: spec.particles,
            threads: self.cfg.threads,
            ..Smc::default()
        }
    }

    /// Run the actual fit for a cache miss. NUTS/ADVI warm-start from the
    /// newest cached artifact of the same stream + sampler family.
    fn fit_artifact(&self, key: ArtifactKey, y: &[f64], spec: &FitSpec) -> Result<Artifact, String> {
        let model = build_stream_model(&key.model, y)?;
        let donor = if self.cfg.warm_start {
            self.cache.latest_for(&key.model, &spec.sampler)
        } else {
            None
        };
        let t0 = Instant::now();
        let (chain, posterior, warm_theta) = match spec.sampler.as_str() {
            "smc" => {
                let smc = self.smc_config(spec);
                let result = smc.run(model.as_ref(), spec.seed);
                let chain = smc.chain_from_result(model.as_ref(), &result, spec.seed);
                (chain, Posterior::Smc(Mutex::new(Some(result))), None)
            }
            "nuts" => {
                let mut rng = Xoshiro256pp::seed_from_u64(spec.seed);
                let mut tvi = init_typed(model.as_ref(), &mut rng);
                if let Some(w) = donor.as_ref().and_then(|a| a.warm_theta.clone()) {
                    if w.len() == tvi.dim() {
                        tvi.set_unconstrained(&w);
                        self.warm_starts.fetch_add(1, Ordering::Relaxed);
                        metrics::inc(Counter::ServeWarmStarts);
                    }
                }
                let ld = NativeDensity::fused(model.as_ref(), &tvi);
                let theta0 = tvi.unconstrained.clone();
                let raw =
                    Nuts::default().sample(&ld, &theta0, spec.warmup, spec.draws, &mut rng);
                let warm = raw.thetas.last().cloned();
                (raw_to_chain(&raw, &tvi), Posterior::Draws, warm)
            }
            "advi" => {
                let mut rng = Xoshiro256pp::seed_from_u64(spec.seed);
                let tvi = init_typed(model.as_ref(), &mut rng);
                let ld = NativeDensity::fused(model.as_ref(), &tvi);
                let mut advi = Advi::meanfield();
                let theta0 = match donor.as_ref().map(|a| &a.posterior) {
                    Some(Posterior::Vi(prev)) if prev.approx.mu().len() == tvi.dim() => {
                        // reuse the converged mean *and* step size — skips
                        // the η ladder search entirely
                        advi.eta = Some(prev.eta);
                        self.warm_starts.fetch_add(1, Ordering::Relaxed);
                        metrics::inc(Counter::ServeWarmStarts);
                        prev.approx.mu().to_vec()
                    }
                    _ => tvi.unconstrained.clone(),
                };
                let fit = advi.fit(&ld, &theta0, &mut rng);
                let raw = fit.sample_raw(&ld, spec.draws, &mut rng);
                let warm = Some(fit.approx.mu().to_vec());
                let mut chain = raw_to_chain(&raw, &tvi);
                chain.stats.log_evidence = fit.elbo;
                (chain, Posterior::Vi(fit), warm)
            }
            other => return Err(format!("unknown sampler {other:?} (smc, nuts, advi)")),
        };
        let param_maps = crate::query::chain_param_maps(&chain)?;
        Ok(Artifact {
            key,
            chain,
            param_maps,
            posterior,
            warm_theta,
            fit_secs: t0.elapsed().as_secs_f64(),
        })
    }
}
