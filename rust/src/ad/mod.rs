//! Automatic differentiation substrates.
//!
//! The paper's §3.2 point is that the *trace* must be written so that the
//! value vector's element type can be swapped for an AD number: ForwardDiff
//! dual numbers or Tracker tracked reals in Julia. We reproduce that design
//! with a [`Scalar`] trait that the whole model-evaluation path (trace,
//! distributions, bijectors, log-density accumulation) is generic over:
//!
//! - [`forward::Dual`] — forward-mode dual numbers (ForwardDiff.jl analogue)
//! - [`reverse::TVar`] — tape-based reverse mode with one heap node per op
//!   (Tracker.jl analogue — it *deliberately* carries the dynamic-dispatch /
//!   allocation overhead the paper measures in §4)
//! - [`arena::AVar`] — arena-fused reverse mode: flat SoA tape with
//!   retained capacity, variable-arity fused nodes (one per tilde
//!   statement via analytic `logpdf_adj` kernels) and seed-based density
//!   accumulation — the Stan-style repaired native path
//! - [`batch::BVar`] — the K-lane form of the arena: one shared node
//!   topology, lane-strided values/partials/adjoints, so K chains /
//!   particles / ELBO draws share a single tape walk
//! - `f64` — plain evaluation
//!
//! The AOT alternative (the paper's "Julia compiler specializes the typed
//! trace") is the XLA gradient artifact, which is not an instance of
//! `Scalar` — see `crate::gradient`.

pub mod arena;
pub mod batch;
pub mod forward;
pub mod record;
pub mod reverse;

use crate::util::math;

/// A differentiable scalar: the element type of traced parameter vectors.
///
/// All model code (distributions, bijectors, log-density math) is written
/// against this trait so the same definition executes as plain `f64`,
/// forward dual, or reverse tape variable — the paper's AD-interoperability
/// contribution.
pub trait Scalar:
    Copy
    + Clone
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::Add<f64, Output = Self>
    + std::ops::Sub<f64, Output = Self>
    + std::ops::Mul<f64, Output = Self>
    + std::ops::Div<f64, Output = Self>
    + PartialOrd
{
    /// Lift a constant (no derivative).
    fn constant(x: f64) -> Self;
    /// Primal value.
    fn value(&self) -> f64;

    fn ln(self) -> Self;
    fn exp(self) -> Self;
    fn sqrt(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn powf(self, e: f64) -> Self;
    fn abs(self) -> Self;
    fn ln_1p(self) -> Self;
    fn tanh(self) -> Self;
    fn sin(self) -> Self;
    fn cos(self) -> Self;
    /// log Γ(x) with derivative ψ(x).
    fn lgamma(self) -> Self;

    /// Numerically stable log(1+exp(x)).
    fn log1p_exp(self) -> Self {
        // Branch on the primal; both branches have the right derivative in
        // their region.
        if self.value() > 35.0 {
            self
        } else if self.value() < -35.0 {
            self.exp()
        } else {
            self.exp().ln_1p()
        }
    }

    /// Stable log-sigmoid −log(1+exp(−x)).
    fn log_sigmoid(self) -> Self {
        -((-self).log1p_exp())
    }

    /// Logistic sigmoid with stable branches.
    fn sigmoid(self) -> Self {
        if self.value() >= 0.0 {
            let one_plus = (-self).exp() + 1.0;
            Self::constant(1.0) / one_plus
        } else {
            let e = self.exp();
            e / (e + 1.0)
        }
    }

    /// Pairwise stable log-add-exp.
    fn log_add_exp(self, other: Self) -> Self {
        let (hi, lo) = if self.value() >= other.value() {
            (self, other)
        } else {
            (other, self)
        };
        if hi.value() == f64::NEG_INFINITY {
            return Self::constant(f64::NEG_INFINITY);
        }
        hi + (lo - hi).exp().ln_1p()
    }

    /// Stable log-sum-exp over a slice. Overridable so recording scalars
    /// ([`record::RVar`]) can capture the reduction as one opcode instead
    /// of baking the running maximum in as a constant.
    fn log_sum_exp_slice(xs: &[Self]) -> Self {
        let m = xs
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, |a, b| a.max(b.value()));
        if m == f64::NEG_INFINITY {
            return Self::constant(f64::NEG_INFINITY);
        }
        let mut s = Self::constant(0.0);
        for &x in xs {
            s = s + (x - m).exp();
        }
        s.ln() + m
    }
}

impl Scalar for f64 {
    #[inline]
    fn constant(x: f64) -> Self {
        x
    }
    #[inline]
    fn value(&self) -> f64 {
        *self
    }
    #[inline]
    fn ln(self) -> Self {
        f64::ln(self)
    }
    #[inline]
    fn exp(self) -> Self {
        f64::exp(self)
    }
    #[inline]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        f64::powi(self, n)
    }
    #[inline]
    fn powf(self, e: f64) -> Self {
        f64::powf(self, e)
    }
    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline]
    fn ln_1p(self) -> Self {
        f64::ln_1p(self)
    }
    #[inline]
    fn tanh(self) -> Self {
        f64::tanh(self)
    }
    #[inline]
    fn sin(self) -> Self {
        f64::sin(self)
    }
    #[inline]
    fn cos(self) -> Self {
        f64::cos(self)
    }
    #[inline]
    fn lgamma(self) -> Self {
        math::lgamma(self)
    }
}

/// Stable log-sum-exp over a slice of scalars.
pub fn log_sum_exp_t<T: Scalar>(xs: &[T]) -> T {
    T::log_sum_exp_slice(xs)
}

/// Gradient of `f` at `x` by central finite differences — test oracle only.
pub fn finite_diff_grad<F: FnMut(&[f64]) -> f64>(mut f: F, x: &[f64], h: f64) -> Vec<f64> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let x0 = xp[i];
        xp[i] = x0 + h;
        let fp = f(&xp);
        xp[i] = x0 - h;
        let fm = f(&xp);
        xp[i] = x0;
        g[i] = (fp - fm) / (2.0 * h);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_scalar_ops() {
        let x: f64 = 2.0;
        assert!((Scalar::ln(x) - std::f64::consts::LN_2).abs() < 1e-15);
        assert!((x.log1p_exp() - (1.0 + x.exp()).ln()).abs() < 1e-12);
        assert!((x.sigmoid() - 1.0 / (1.0 + (-2.0f64).exp())).abs() < 1e-15);
        assert!(((-50.0f64).log_sigmoid() + 50.0).abs() < 1e-9);
    }

    #[test]
    fn lse_t_matches_math() {
        let xs = [1.0f64, -2.0, 0.5];
        assert!((log_sum_exp_t(&xs) - math::log_sum_exp(&xs)).abs() < 1e-14);
    }

    #[test]
    fn finite_diff_sane() {
        let g = finite_diff_grad(|x| x[0] * x[0] + 3.0 * x[1], &[2.0, 1.0], 1e-6);
        assert!((g[0] - 4.0).abs() < 1e-5);
        assert!((g[1] - 3.0).abs() < 1e-5);
    }
}
