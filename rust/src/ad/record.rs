//! Structure-recording scalar for the static-model compiler.
//!
//! [`RVar`] is a [`Scalar`] whose arithmetic does no differentiation at
//! all: each operation appends an opcode to a thread-local recording and
//! carries its `f64` primal forward so data-dependent branches (stable
//! log1p_exp regions, bijector domains, rejection checks) resolve exactly
//! as they would under plain `f64` evaluation. Running a model body once
//! with `T = RVar` therefore yields a flat, varname-free program — the
//! [`StaticProgram`](crate::model::compiled::StaticProgram) — that the
//! compiled executor can replay against fresh parameter values without
//! ever re-entering the model body.
//!
//! Two properties matter for bit-identical replay:
//!
//! - **Constant folding mirrors the arena.** An operation whose inputs are
//!   all constants emits no opcode and computes its value through the very
//!   same `f64` expressions the arena scalar would use for a constant
//!   node, so the recorded primal stream matches the dynamic executor's
//!   bit for bit.
//! - **Composites stay composite.** The stable compound kernels
//!   (`log1p_exp`, `sigmoid`, `log_add_exp`, `log_sum_exp_slice`, `abs`)
//!   are captured as single opcodes rather than their expanded branch
//!   bodies, because the branch decisions depend on the primal value:
//!   replay re-takes the branch at the *replayed* value, exactly like the
//!   generic default methods do.

use std::cell::RefCell;

use crate::ad::Scalar;
use crate::util::math;

/// Register id meaning "no register": the value is a compile-time
/// constant of the recording (mirrors `arena::NONE` for tape nodes).
pub const REG_NONE: u32 = u32::MAX;

/// An operand of a recorded operation: either a register written by an
/// earlier opcode or an `f64` constant baked into the program.
#[derive(Clone, Copy, Debug)]
pub enum Src {
    Reg(u32),
    Const(f64),
}

impl PartialEq for Src {
    fn eq(&self, other: &Self) -> bool {
        // bitwise on constants: structural comparison between two
        // recordings must not conflate 0.0/−0.0 or miscompare NaN
        match (self, other) {
            (Src::Reg(a), Src::Reg(b)) => a == b,
            (Src::Const(a), Src::Const(b)) => a.to_bits() == b.to_bits(),
            _ => false,
        }
    }
}

/// A recorded scalar operation. Unary opcodes take a register directly
/// (a constant input would have been folded); binary opcodes take [`Src`]
/// operands so reg⊗const mixes need no materialized constant registers.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Add(Src, Src),
    Sub(Src, Src),
    Mul(Src, Src),
    Div(Src, Src),
    Neg(u32),
    Ln(u32),
    Exp(u32),
    Sqrt(u32),
    Ln1p(u32),
    Tanh(u32),
    Sin(u32),
    Cos(u32),
    Lgamma(u32),
    Powi(u32, i32),
    Powf(u32, f64),
    // composite stable kernels, replayed with value-dependent branches
    Abs(u32),
    Log1pExp(u32),
    LogSigmoid(u32),
    Sigmoid(u32),
    LogAddExp(Src, Src),
    Lse(Vec<Src>),
}

/// One recorded statement: `regs[out] = op(...)`.
#[derive(Clone, Debug, PartialEq)]
pub struct ROp {
    pub out: u32,
    pub op: Op,
}

#[derive(Default)]
struct Recorder {
    ops: Vec<ROp>,
    n_regs: u32,
    active: bool,
}

thread_local! {
    static RECORDER: RefCell<Recorder> = RefCell::new(Recorder::default());
}

/// Start a recording on this thread. Panics if one is already active.
pub fn begin() {
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        assert!(!r.active, "nested RVar recordings are not supported");
        r.ops.clear();
        r.n_regs = 0;
        r.active = true;
    });
}

/// Finish the recording, returning the opcode stream and register count.
pub fn end() -> (Vec<ROp>, u32) {
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        assert!(r.active, "no RVar recording active");
        r.active = false;
        (std::mem::take(&mut r.ops), r.n_regs)
    })
}

/// Number of opcodes recorded so far — the structure recorder marks this
/// before/after each tilde site to delimit the glue-arithmetic runs.
pub fn len() -> usize {
    RECORDER.with(|r| {
        let r = r.borrow();
        assert!(r.active, "no RVar recording active");
        r.ops.len()
    })
}

/// Allocate a fresh register without emitting an opcode — used by the
/// recording executor for assume-site outputs, which the replay writes
/// directly from the fused transform kernels.
pub fn alloc_reg() -> u32 {
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        assert!(r.active, "no RVar recording active");
        let id = r.n_regs;
        r.n_regs += 1;
        id
    })
}

fn push(op: Op) -> u32 {
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        assert!(r.active, "RVar arithmetic outside an active recording");
        let out = r.n_regs;
        r.n_regs += 1;
        r.ops.push(ROp { out, op });
        out
    })
}

/// The recording scalar: a register id (or [`REG_NONE`] for constants)
/// plus the primal value carried forward for branch resolution.
#[derive(Clone, Copy, Debug)]
pub struct RVar {
    reg: u32,
    val: f64,
}

impl RVar {
    /// A value seated in an externally allocated register (assume-site
    /// outputs written by the replay's transform kernels).
    pub fn from_reg(reg: u32, val: f64) -> Self {
        RVar { reg, val }
    }

    pub fn reg(&self) -> u32 {
        self.reg
    }

    /// This value as an operand of a later opcode.
    pub fn src(&self) -> Src {
        if self.reg == REG_NONE {
            Src::Const(self.val)
        } else {
            Src::Reg(self.reg)
        }
    }
}

fn binary(a: RVar, b: RVar, v: f64, mk: impl FnOnce(Src, Src) -> Op) -> RVar {
    if a.reg == REG_NONE && b.reg == REG_NONE {
        return RVar { reg: REG_NONE, val: v };
    }
    RVar {
        reg: push(mk(a.src(), b.src())),
        val: v,
    }
}

fn unary(a: RVar, v: f64, mk: impl FnOnce(u32) -> Op) -> RVar {
    if a.reg == REG_NONE {
        return RVar { reg: REG_NONE, val: v };
    }
    RVar {
        reg: push(mk(a.reg)),
        val: v,
    }
}

macro_rules! rvar_binop {
    ($trait:ident, $method:ident, $op:ident, $fop:tt) => {
        impl std::ops::$trait for RVar {
            type Output = RVar;
            fn $method(self, rhs: RVar) -> RVar {
                binary(self, rhs, self.val $fop rhs.val, Op::$op)
            }
        }
        impl std::ops::$trait<f64> for RVar {
            type Output = RVar;
            fn $method(self, rhs: f64) -> RVar {
                binary(self, RVar::constant(rhs), self.val $fop rhs, Op::$op)
            }
        }
    };
}

rvar_binop!(Add, add, Add, +);
rvar_binop!(Sub, sub, Sub, -);
rvar_binop!(Mul, mul, Mul, *);
rvar_binop!(Div, div, Div, /);

impl std::ops::Neg for RVar {
    type Output = RVar;
    fn neg(self) -> RVar {
        unary(self, -self.val, Op::Neg)
    }
}

impl PartialEq for RVar {
    fn eq(&self, other: &Self) -> bool {
        self.val == other.val
    }
}

impl PartialOrd for RVar {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.val.partial_cmp(&other.val)
    }
}

impl Scalar for RVar {
    fn constant(x: f64) -> Self {
        RVar {
            reg: REG_NONE,
            val: x,
        }
    }
    fn value(&self) -> f64 {
        self.val
    }
    fn ln(self) -> Self {
        unary(self, self.val.ln(), Op::Ln)
    }
    fn exp(self) -> Self {
        unary(self, self.val.exp(), Op::Exp)
    }
    fn sqrt(self) -> Self {
        unary(self, self.val.sqrt(), Op::Sqrt)
    }
    fn powi(self, n: i32) -> Self {
        if self.reg == REG_NONE {
            return Self::constant(self.val.powi(n));
        }
        RVar {
            reg: push(Op::Powi(self.reg, n)),
            val: self.val.powi(n),
        }
    }
    fn powf(self, e: f64) -> Self {
        if self.reg == REG_NONE {
            return Self::constant(self.val.powf(e));
        }
        RVar {
            reg: push(Op::Powf(self.reg, e)),
            val: self.val.powf(e),
        }
    }
    fn abs(self) -> Self {
        unary(self, self.val.abs(), Op::Abs)
    }
    fn ln_1p(self) -> Self {
        unary(self, self.val.ln_1p(), Op::Ln1p)
    }
    fn tanh(self) -> Self {
        unary(self, self.val.tanh(), Op::Tanh)
    }
    fn sin(self) -> Self {
        unary(self, self.val.sin(), Op::Sin)
    }
    fn cos(self) -> Self {
        unary(self, self.val.cos(), Op::Cos)
    }
    fn lgamma(self) -> Self {
        unary(self, math::lgamma(self.val), Op::Lgamma)
    }

    // The stable composites are captured whole (see module docs): the
    // value is computed by the f64 instance of the same default body, so
    // constant folding stays bit-identical to the arena's constant path.
    fn log1p_exp(self) -> Self {
        unary(self, <f64 as Scalar>::log1p_exp(self.val), Op::Log1pExp)
    }
    fn log_sigmoid(self) -> Self {
        unary(self, <f64 as Scalar>::log_sigmoid(self.val), Op::LogSigmoid)
    }
    fn sigmoid(self) -> Self {
        unary(self, <f64 as Scalar>::sigmoid(self.val), Op::Sigmoid)
    }
    fn log_add_exp(self, other: Self) -> Self {
        let v = <f64 as Scalar>::log_add_exp(self.val, other.val);
        binary(self, other, v, Op::LogAddExp)
    }
    fn log_sum_exp_slice(xs: &[Self]) -> Self {
        // value computed exactly like the generic default (same fold, same
        // accumulation order); the reduction itself becomes one opcode so
        // the running maximum is re-resolved at replay values
        let m = xs.iter().fold(f64::NEG_INFINITY, |a, b| a.max(b.val));
        let v = if m == f64::NEG_INFINITY {
            f64::NEG_INFINITY
        } else {
            let mut s = 0.0f64;
            for x in xs {
                s += (x.val - m).exp();
            }
            s.ln() + m
        };
        if xs.iter().all(|x| x.reg == REG_NONE) {
            return Self::constant(v);
        }
        RVar {
            reg: push(Op::Lse(xs.iter().map(|x| x.src()).collect())),
            val: v,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_fold_without_opcodes() {
        begin();
        let a = RVar::constant(2.0);
        let b = RVar::constant(3.0);
        let c = (a * b + 1.0).ln();
        let (ops, n_regs) = end();
        assert!(ops.is_empty());
        assert_eq!(n_regs, 0);
        assert_eq!(c.value().to_bits(), 7.0f64.ln().to_bits());
    }

    #[test]
    fn registers_chain_and_values_track_f64() {
        begin();
        let x = RVar::from_reg(alloc_reg(), 0.5);
        let y = (x * 2.0 + 1.0).exp().ln_1p();
        let (ops, n_regs) = end();
        assert_eq!(ops.len(), 4);
        assert_eq!(n_regs, 5);
        let want = (0.5f64 * 2.0 + 1.0).exp().ln_1p();
        assert_eq!(y.value().to_bits(), want.to_bits());
        assert!(matches!(ops[0].op, Op::Mul(Src::Reg(0), Src::Const(c)) if c == 2.0));
    }

    #[test]
    fn composites_record_one_opcode() {
        begin();
        let x = RVar::from_reg(alloc_reg(), -0.3);
        let s = x.log_sigmoid();
        let l = RVar::log_sum_exp_slice(&[x, s, RVar::constant(0.1)]);
        let (ops, _) = end();
        assert_eq!(ops.len(), 2);
        assert!(matches!(ops[0].op, Op::LogSigmoid(0)));
        assert!(matches!(&ops[1].op, Op::Lse(srcs) if srcs.len() == 3));
        let sf = <f64 as Scalar>::log_sigmoid(-0.3);
        assert_eq!(s.value().to_bits(), sf.to_bits());
        assert_eq!(
            l.value().to_bits(),
            <f64 as Scalar>::log_sum_exp_slice(&[-0.3, sf, 0.1]).to_bits()
        );
    }
}
