//! Arena-fused reverse-mode AD: the Stan-style native gradient engine.
//!
//! [`super::reverse`] deliberately reproduces Tracker.jl's overhead profile
//! (one `RefCell`-guarded heap node per scalar op, a fresh adjoint buffer
//! per backward pass). This module is the *repaired* native path, modeled
//! on what Stan's math library actually does for a `_lpdf` call: one fused
//! vari with analytic adjoints per density statement, on a reusable arena
//! stack.
//!
//! Three mechanisms deliver the speedup:
//!
//! 1. **Flat SoA arena with retained capacity.** Nodes live in three flat
//!    vectors (`bounds`/`parents`/`partials`); resetting clears lengths but
//!    keeps allocations, so steady-state gradient evaluation allocates
//!    nothing.
//! 2. **Variable-arity fused nodes.** A node may have any number of
//!    parents, so one tilde statement's whole density (logpdf + bijector
//!    Jacobian, ~20 scalar ops on the generic tape) collapses into at most
//!    one value node plus a handful of *seeds*.
//! 3. **Seeds instead of sum chains.** The log-density is a plain sum, so
//!    every density term's partials are recorded directly as
//!    `(node, weight)` seed pairs — the `lp = lp + term` chain that
//!    dominates the generic tape vanishes entirely; observe statements
//!    cost **zero** tape nodes.
//!
//! [`AVar`] is the tracked scalar ([`crate::ad::Scalar`] instance) that
//! model-body code between tilde statements runs on; constants carry no
//! node at all. The fused executors in [`crate::model::executors`] push
//! the per-tilde analytic kernels (`logpdf_adj`, `invlink_scalar_adj`).

use std::cell::{Cell, RefCell};

use super::Scalar;
use crate::util::math;

/// Sentinel index for constants (no tape node, adjoint discarded).
pub const NONE: u32 = u32::MAX;

/// The flat SoA tape: node `i` owns `parents[bounds[i]..bounds[i+1]]` and
/// the matching `partials` range. The first `n_inputs` nodes are the input
/// leaves (empty parent ranges).
#[derive(Default)]
pub struct ArenaTape {
    /// `n_nodes + 1` prefix offsets into `parents`/`partials`.
    bounds: Vec<u32>,
    parents: Vec<u32>,
    partials: Vec<f64>,
    /// Direct gradient contributions `(node, weight)` of density terms.
    seeds: Vec<(u32, f64)>,
    /// Reused adjoint buffer for [`ArenaTape::backward_into`].
    adj: Vec<f64>,
    n_inputs: usize,
}

impl ArenaTape {
    #[inline]
    pub fn n_nodes(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }

    /// Fused (non-leaf) nodes pushed since the last reset.
    #[inline]
    pub fn n_fused_nodes(&self) -> usize {
        self.n_nodes() - self.n_inputs
    }

    #[inline]
    pub fn n_seeds(&self) -> usize {
        self.seeds.len()
    }

    /// Clear the tape for a fresh evaluation with `n_inputs` leaves,
    /// retaining every allocation.
    pub fn reset(&mut self, n_inputs: usize) {
        self.bounds.clear();
        self.parents.clear();
        self.partials.clear();
        self.seeds.clear();
        self.bounds.resize(n_inputs + 1, 0);
        self.n_inputs = n_inputs;
    }

    /// Push a fused node with explicit parents and local partials.
    #[inline]
    pub fn push(&mut self, parents: &[u32], partials: &[f64]) -> u32 {
        debug_assert_eq!(parents.len(), partials.len());
        let idx = self.n_nodes() as u32;
        self.parents.extend_from_slice(parents);
        self.partials.extend_from_slice(partials);
        self.bounds.push(self.parents.len() as u32);
        idx
    }

    /// Unary-node fast path.
    #[inline]
    pub fn push1(&mut self, p: u32, d: f64) -> u32 {
        let idx = self.n_nodes() as u32;
        self.parents.push(p);
        self.partials.push(d);
        self.bounds.push(self.parents.len() as u32);
        idx
    }

    /// Binary-node fast path.
    #[inline]
    pub fn push2(&mut self, pa: u32, da: f64, pb: u32, db: f64) -> u32 {
        let idx = self.n_nodes() as u32;
        self.parents.push(pa);
        self.parents.push(pb);
        self.partials.push(da);
        self.partials.push(db);
        self.bounds.push(self.parents.len() as u32);
        idx
    }

    /// Record a direct gradient contribution: `d total / d node += w`.
    /// Seeds on constants ([`NONE`]) or with zero weight are dropped.
    #[inline]
    pub fn seed(&mut self, node: u32, w: f64) {
        if node != NONE && w != 0.0 {
            self.seeds.push((node, w));
        }
    }

    /// Reverse sweep: zero the (reused) adjoint buffer, apply seeds, and
    /// propagate to the leaves, writing `∂total/∂input_i` into `grad`.
    ///
    /// Runs of unary nodes whose parents ascend in lockstep with the node
    /// index ("diagonal links" — the shape per-coordinate vector kernels
    /// emit: node `r+j` reads parent `base+j`) propagate through a
    /// contiguous inner loop with no index indirection, which the
    /// auto-vectorizer can turn into masked SIMD. The fast path requires
    /// every parent to sit *below* the run, so each target is written
    /// exactly once and the result is bit-identical to the scalar sweep;
    /// unary chains (`parents[j] == j−1`) fail that test immediately and
    /// stay on the generic path at no extra scan cost.
    pub fn backward_into(&mut self, grad: &mut [f64]) {
        assert_eq!(grad.len(), self.n_inputs);
        let n = self.n_nodes();
        self.adj.clear();
        self.adj.resize(n, 0.0);
        for &(p, w) in &self.seeds {
            self.adj[p as usize] += w;
        }
        let mut i = n;
        while i > self.n_inputs {
            i -= 1;
            let lo = self.bounds[i] as usize;
            let hi = self.bounds[i + 1] as usize;
            if hi - lo == 1 {
                let p_i = self.parents[lo] as usize;
                // extend the diagonal run downward; `r − 1 > p_i` keeps
                // every parent strictly below the run start
                let mut r = i;
                while r > self.n_inputs && r - 1 > p_i {
                    let j = r - 1;
                    let jl = self.bounds[j] as usize;
                    if (self.bounds[j + 1] as usize) - jl != 1
                        || self.parents[jl] as usize + (i - j) != p_i
                    {
                        break;
                    }
                    r = j;
                }
                let len = i + 1 - r;
                if len >= 4 {
                    let plo = self.bounds[r] as usize;
                    let base = p_i + 1 - len;
                    // base + len == p_i + 1 ≤ r: targets and sources are
                    // disjoint, each target written once
                    let (head, tail) = self.adj.split_at_mut(r);
                    let src = &tail[..len];
                    let dst = &mut head[base..base + len];
                    let par = &self.partials[plo..plo + len];
                    for j in 0..len {
                        let a = src[j];
                        if a != 0.0 {
                            dst[j] += a * par[j];
                        }
                    }
                    i = r;
                    continue;
                }
            }
            let a = self.adj[i];
            if a == 0.0 {
                continue;
            }
            for k in lo..hi {
                self.adj[self.parents[k] as usize] += a * self.partials[k];
            }
        }
        grad.copy_from_slice(&self.adj[..self.n_inputs]);
    }

    /// Total retained capacity in bytes — constant at steady state; probed
    /// by the allocation-regression checks in `bench grad` and the tests.
    pub fn capacity_bytes(&self) -> usize {
        self.bounds.capacity() * 4
            + self.parents.capacity() * 4
            + self.partials.capacity() * 8
            + self.seeds.capacity() * 16
            + self.adj.capacity() * 8
    }
}

thread_local! {
    static TAPE: RefCell<ArenaTape> = RefCell::new(ArenaTape::default());
    /// Statement/node counters of the last completed fused evaluation
    /// (survive the next `begin` so benchmarks can read them).
    static LAST_STATS: Cell<FusedStats> = const { Cell::new(FusedStats::zero()) };
}

/// Diagnostics of one fused evaluation.
#[derive(Clone, Copy, Debug, Default)]
pub struct FusedStats {
    /// Tape nodes beyond the input leaves.
    pub nodes: usize,
    /// Direct seed contributions (≈ analytic partials recorded).
    pub seeds: usize,
    /// Tilde statements (assume + observe + raw logp terms) visited.
    pub tilde_stmts: usize,
}

impl FusedStats {
    const fn zero() -> Self {
        FusedStats {
            nodes: 0,
            seeds: 0,
            tilde_stmts: 0,
        }
    }
}

/// Run `f` with mutable access to the thread-local tape (one borrow for a
/// whole fused kernel — cheaper than a borrow per op).
#[inline]
pub fn with_tape<R>(f: impl FnOnce(&mut ArenaTape) -> R) -> R {
    TAPE.with(|t| f(&mut t.borrow_mut()))
}

/// Start a fresh fused evaluation with `n_inputs` leaf variables.
/// Capacity from previous evaluations is retained.
pub fn begin(n_inputs: usize) {
    with_tape(|t| t.reset(n_inputs));
}

/// Record a direct gradient seed (see [`ArenaTape::seed`]).
#[inline]
pub fn seed(node: u32, w: f64) {
    if node != NONE && w != 0.0 {
        with_tape(|t| t.seeds.push((node, w)));
    }
}

/// Backward pass into a caller-owned gradient buffer, then publish the
/// evaluation's node/seed counts (`tilde_stmts` supplied by the executor).
pub fn backward_into(grad: &mut [f64], tilde_stmts: usize) {
    with_tape(|t| {
        t.backward_into(grad);
        let stats = FusedStats {
            nodes: t.n_fused_nodes(),
            seeds: t.n_seeds(),
            tilde_stmts,
        };
        LAST_STATS.set(stats);
        use crate::obs::metrics::{add, inc, Counter};
        inc(Counter::ArenaEvals);
        add(Counter::ArenaNodes, stats.nodes as u64);
        add(Counter::ArenaSeeds, stats.seeds as u64);
    });
}

/// Diagnostics of the most recent completed fused evaluation.
pub fn last_stats() -> FusedStats {
    LAST_STATS.get()
}

/// Retained tape capacity in bytes (allocation-regression probes).
pub fn capacity_bytes() -> usize {
    with_tape(|t| t.capacity_bytes())
}

/// A tracked real on the arena tape. Constants carry [`NONE`] and cost no
/// node; ops with constant operands collapse to unary (or constant) form.
#[derive(Clone, Copy, Debug)]
pub struct AVar {
    idx: u32,
    v: f64,
}

impl AVar {
    /// The `i`-th input leaf (leaves are the first `n_inputs` tape nodes,
    /// so no storage lookup is needed to reconstruct one).
    #[inline]
    pub fn leaf(i: u32, v: f64) -> Self {
        AVar { idx: i, v }
    }

    /// Attach a value to an existing tape node (fused executors wrap the
    /// value node they just pushed).
    #[inline]
    pub fn from_node(idx: u32, v: f64) -> Self {
        AVar { idx, v }
    }

    /// Node index, [`NONE`] for constants.
    #[inline]
    pub fn idx(&self) -> u32 {
        self.idx
    }

    #[inline]
    fn unary(self, v: f64, dv: f64) -> Self {
        if self.idx == NONE {
            return AVar { idx: NONE, v };
        }
        let idx = with_tape(|t| t.push1(self.idx, dv));
        AVar { idx, v }
    }

    #[inline]
    fn binary(self, rhs: AVar, v: f64, da: f64, db: f64) -> Self {
        let idx = match (self.idx, rhs.idx) {
            (NONE, NONE) => NONE,
            (a, NONE) => with_tape(|t| t.push1(a, da)),
            (NONE, b) => with_tape(|t| t.push1(b, db)),
            (a, b) => with_tape(|t| t.push2(a, da, b, db)),
        };
        AVar { idx, v }
    }
}

macro_rules! impl_avar_binop {
    ($trait:ident, $fn:ident, |$a:ident, $b:ident| $v:expr, $da:expr, $db:expr) => {
        impl std::ops::$trait for AVar {
            type Output = AVar;
            #[inline]
            fn $fn(self, rhs: AVar) -> AVar {
                let ($a, $b) = (self.v, rhs.v);
                let _ = ($a, $b);
                self.binary(rhs, $v, $da, $db)
            }
        }
        impl std::ops::$trait<f64> for AVar {
            type Output = AVar;
            #[inline]
            fn $fn(self, rhs: f64) -> AVar {
                let ($a, $b) = (self.v, rhs);
                let _ = ($a, $b);
                self.unary($v, $da)
            }
        }
        impl std::ops::$trait<AVar> for f64 {
            type Output = AVar;
            #[inline]
            fn $fn(self, rhs: AVar) -> AVar {
                let ($a, $b) = (self, rhs.v);
                let _ = ($a, $b);
                rhs.unary($v, $db)
            }
        }
    };
}

impl_avar_binop!(Add, add, |a, b| a + b, 1.0, 1.0);
impl_avar_binop!(Sub, sub, |a, b| a - b, 1.0, -1.0);
impl_avar_binop!(Mul, mul, |a, b| a * b, b, a);
impl_avar_binop!(Div, div, |a, b| a / b, 1.0 / b, -a / (b * b));

impl std::ops::Neg for AVar {
    type Output = AVar;
    #[inline]
    fn neg(self) -> AVar {
        self.unary(-self.v, -1.0)
    }
}

impl PartialEq for AVar {
    fn eq(&self, other: &Self) -> bool {
        self.v == other.v
    }
}

impl PartialOrd for AVar {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.v.partial_cmp(&other.v)
    }
}

impl Scalar for AVar {
    #[inline]
    fn constant(x: f64) -> Self {
        AVar { idx: NONE, v: x }
    }
    #[inline]
    fn value(&self) -> f64 {
        self.v
    }
    #[inline]
    fn ln(self) -> Self {
        self.unary(self.v.ln(), 1.0 / self.v)
    }
    #[inline]
    fn exp(self) -> Self {
        let e = self.v.exp();
        self.unary(e, e)
    }
    #[inline]
    fn sqrt(self) -> Self {
        let s = self.v.sqrt();
        self.unary(s, 0.5 / s)
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        self.unary(self.v.powi(n), n as f64 * self.v.powi(n - 1))
    }
    #[inline]
    fn powf(self, e: f64) -> Self {
        self.unary(self.v.powf(e), e * self.v.powf(e - 1.0))
    }
    #[inline]
    fn abs(self) -> Self {
        if self.v >= 0.0 {
            self
        } else {
            -self
        }
    }
    #[inline]
    fn ln_1p(self) -> Self {
        self.unary(self.v.ln_1p(), 1.0 / (1.0 + self.v))
    }
    #[inline]
    fn tanh(self) -> Self {
        let t = self.v.tanh();
        self.unary(t, 1.0 - t * t)
    }
    #[inline]
    fn sin(self) -> Self {
        self.unary(self.v.sin(), self.v.cos())
    }
    #[inline]
    fn cos(self) -> Self {
        self.unary(self.v.cos(), -self.v.sin())
    }
    #[inline]
    fn lgamma(self) -> Self {
        self.unary(math::lgamma(self.v), math::digamma(self.v))
    }
}

/// Evaluate a closure over leaf variables and backpropagate the seeds it
/// recorded into `grad` — the arena analogue of
/// [`crate::ad::reverse::grad_reverse`], for tests and custom densities.
/// The closure returns the primal total; its gradient contributions must
/// have been recorded with [`seed`] (or flow through a returned tracked
/// value, which is seeded with weight 1).
pub fn grad_fused_into<F>(f: F, x: &[f64], grad: &mut [f64]) -> f64
where
    F: FnOnce(&[AVar]) -> AVar,
{
    begin(x.len());
    let inputs: Vec<AVar> = x
        .iter()
        .enumerate()
        .map(|(i, &v)| AVar::leaf(i as u32, v))
        .collect();
    let out = f(&inputs);
    seed(out.idx, 1.0);
    backward_into(grad, 0);
    out.v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::finite_diff_grad;

    fn grad_of(f: impl Fn(&[AVar]) -> AVar, x: &[f64]) -> (f64, Vec<f64>) {
        let mut grad = vec![0.0; x.len()];
        let v = grad_fused_into(&f, x, &mut grad);
        (v, grad)
    }

    #[test]
    fn simple_gradient() {
        let (v, g) = grad_of(|x| x[0] * x[0] + x[1] * 3.0, &[2.0, 5.0]);
        assert!((v - 19.0).abs() < 1e-14);
        assert!((g[0] - 4.0).abs() < 1e-14);
        assert!((g[1] - 3.0).abs() < 1e-14);
    }

    #[test]
    fn fan_out_accumulates() {
        let (_, g) = grad_of(|x| x[0] * x[0] + x[0], &[3.0]);
        assert!((g[0] - 7.0).abs() < 1e-14);
    }

    #[test]
    fn matches_finite_differences() {
        let primal = |x: &[f64]| (x[0] * x[1]).sin() + (x[2].exp() + x[0]).ln();
        let fd = finite_diff_grad(primal, &[0.5, 1.5, 0.3], 1e-6);
        let (v, g) = grad_of(
            |x| Scalar::sin(x[0] * x[1]) + Scalar::ln(Scalar::exp(x[2]) + x[0]),
            &[0.5, 1.5, 0.3],
        );
        assert!((v - primal(&[0.5, 1.5, 0.3])).abs() < 1e-13);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn constants_cost_no_nodes() {
        let (_, g) = grad_of(
            |x| {
                let c = AVar::constant(10.0);
                let d = c * 2.0 + 1.0; // pure-constant chain: still no nodes
                x[0] * d
            },
            &[2.0],
        );
        assert!((g[0] - 21.0).abs() < 1e-14);
        // one input leaf + exactly one node (the final multiply)
        assert_eq!(last_stats().nodes, 1);
    }

    #[test]
    fn fused_multi_parent_node_backprops() {
        // y = 2·x0 + 3·x1 + 5·x2 as ONE fused node
        begin(3);
        let y = with_tape(|t| t.push(&[0, 1, 2], &[2.0, 3.0, 5.0]));
        seed(y, 10.0);
        let mut grad = vec![0.0; 3];
        backward_into(&mut grad, 1);
        assert_eq!(grad, vec![20.0, 30.0, 50.0]);
        assert_eq!(last_stats().nodes, 1);
        assert_eq!(last_stats().seeds, 1);
        assert_eq!(last_stats().tilde_stmts, 1);
    }

    #[test]
    fn diagonal_runs_match_generic_sweep() {
        // the per-coordinate vector-kernel shape: a run of unary nodes
        // whose parents are the consecutive leaves below — exercises the
        // contiguous fast path, including the zero-adjoint mask (node 3 is
        // unseeded and its ∞ partial must NOT leak a NaN into the grad)
        begin(8);
        let nodes: Vec<u32> = (0..8u32)
            .map(|j| {
                let d = if j == 3 { f64::INFINITY } else { (j + 1) as f64 * 0.5 };
                with_tape(|t| t.push1(j, d))
            })
            .collect();
        for (j, &nd) in nodes.iter().enumerate() {
            if j != 3 {
                seed(nd, 2.0);
            }
        }
        let mut grad = vec![0.0; 8];
        backward_into(&mut grad, 1);
        for j in 0..8 {
            let want = if j == 3 { 0.0 } else { 2.0 * (j + 1) as f64 * 0.5 };
            assert_eq!(grad[j], want, "grad[{j}]");
        }
    }

    #[test]
    fn seeds_on_leaves_and_capacity_is_stable() {
        // run the same evaluation many times; capacity must stop growing
        let run = || {
            begin(2);
            let x0 = AVar::leaf(0, 1.5);
            let x1 = AVar::leaf(1, -0.5);
            let y = x0 * x1;
            seed(y.idx(), 1.0);
            seed(0, 0.25); // direct leaf seed (ladj-style)
            let mut grad = vec![0.0; 2];
            backward_into(&mut grad, 1);
            grad
        };
        let g = run();
        assert!((g[0] - (-0.5 + 0.25)).abs() < 1e-14);
        assert!((g[1] - 1.5).abs() < 1e-14);
        let cap = capacity_bytes();
        for _ in 0..10 {
            let _ = run();
        }
        assert_eq!(capacity_bytes(), cap, "steady-state arena must not grow");
    }

    #[test]
    fn scalar_trait_ops_match_reverse_tape() {
        let x = [0.8f64, 1.7];
        let f_fused = grad_of(
            |x| {
                Scalar::lgamma(x[0]) + x[1].log1p_exp() + x[0].sigmoid() * x[1]
                    - Scalar::tanh(x[0] / x[1])
            },
            &x,
        );
        let fd = finite_diff_grad(
            |x| {
                math::lgamma(x[0]) + (1.0 + x[1].exp()).ln() + math::sigmoid(x[0]) * x[1]
                    - (x[0] / x[1]).tanh()
            },
            &x,
            1e-6,
        );
        for (a, b) in f_fused.1.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
