//! Lane-batched arena AD: one tape walk, K gradient lanes.
//!
//! [`BatchTape`] is the K-lane generalization of [`super::arena::ArenaTape`]:
//! the node *topology* (bounds/parents) is recorded once — every lane shares
//! the same tilde program and typed layout — while node **values**, edge
//! **partials** and **adjoints** are stored lane-strided
//! (`vals[node * K + lane]`), so both the forward walk and the backward
//! sweep run contiguous K-wide inner loops that the compiler can
//! auto-vectorize. Bookkeeping (node pushes, bounds, dispatch) is paid once
//! per node instead of once per node per lane — that amortization is the
//! whole speedup; the per-lane arithmetic is **exactly** the sequential
//! arena arithmetic, in the same order, so each lane's value and gradient
//! are bit-identical to a sequential [`super::arena::AVar`] evaluation of
//! that lane alone.
//!
//! [`BVar`] is the tracked scalar: like `AVar` it carries a node index
//! (`NONE` for constants) plus a cached primal, but the cached primal is
//! **lane 0's** value — `value()` and comparisons (used by glue-code
//! branches such as `Scalar::sigmoid`) resolve against lane 0. Lanes whose
//! control flow would diverge from lane 0 inside glue-code branches are a
//! documented hazard (the fused executors never branch; the stable-branch
//! cutoffs in the `Scalar` defaults sit far outside normal data), the same
//! class of hazard as a dynamic structure change, and the samplers that
//! feed lanes (chains, particles, ELBO draws) keep lanes near one another
//! only statistically — correctness of each lane's arithmetic never depends
//! on the branch agreeing, only branch *selection* does.
//!
//! Per-lane rejection (−∞ log-density) is handled by masking at the output:
//! a rejected lane's seeds are still recorded (weights of 0 are skipped per
//! lane, mirroring the sequential tape's zero-weight seed drop), and the
//! caller zeroes that lane's gradient exactly as the sequential path does
//! after a non-finite lp.

use std::cell::RefCell;

use super::arena::NONE;
use super::Scalar;
use crate::util::math;

/// K-lane SoA tape. Topology is shared across lanes; values/partials/
/// adjoints are lane-strided.
#[derive(Default)]
pub struct BatchTape {
    /// `n_nodes + 1` prefix offsets into `parents` (edge index space).
    bounds: Vec<u32>,
    parents: Vec<u32>,
    /// Edge partials, lane-strided: `partials[edge * lanes + lane]`.
    partials: Vec<f64>,
    /// Node values, lane-strided: `vals[node * lanes + lane]`.
    vals: Vec<f64>,
    /// Seed nodes (density-term gradient contributions).
    seed_nodes: Vec<u32>,
    /// Seed weights, lane-strided: `seed_w[seed * lanes + lane]`.
    seed_w: Vec<f64>,
    /// Reused lane-strided adjoint buffer.
    adj: Vec<f64>,
    n_inputs: usize,
    lanes: usize,
}

impl BatchTape {
    #[inline]
    pub fn n_nodes(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }

    #[inline]
    pub fn n_fused_nodes(&self) -> usize {
        self.n_nodes() - self.n_inputs
    }

    #[inline]
    pub fn n_seeds(&self) -> usize {
        self.seed_nodes.len()
    }

    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Clear for a fresh K-lane evaluation. `theta_t` holds the input
    /// leaves coordinate-major (`theta_t[i * lanes + lane]`); allocations
    /// are retained across evaluations.
    pub fn reset(&mut self, theta_t: &[f64], n_inputs: usize, lanes: usize) {
        assert!(lanes > 0);
        assert_eq!(theta_t.len(), n_inputs * lanes);
        self.bounds.clear();
        self.parents.clear();
        self.partials.clear();
        self.vals.clear();
        self.seed_nodes.clear();
        self.seed_w.clear();
        self.bounds.resize(n_inputs + 1, 0);
        self.vals.extend_from_slice(theta_t);
        self.n_inputs = n_inputs;
        self.lanes = lanes;
    }

    /// Lane values of node `i`.
    #[inline]
    pub fn node_vals(&self, i: u32) -> &[f64] {
        let k = self.lanes;
        &self.vals[i as usize * k..i as usize * k + k]
    }

    /// Read the lane values of a [`BVar`] (constants broadcast) into `out`.
    #[inline]
    pub fn read_lanes(&self, x: BVar, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.lanes);
        if x.idx == NONE {
            out.fill(x.cv);
        } else {
            out.copy_from_slice(self.node_vals(x.idx));
        }
    }

    /// Push a unary node: `vals`/`ds` are the K per-lane values/partials.
    #[inline]
    pub fn push1_lanes(&mut self, p: u32, vals: &[f64], ds: &[f64]) -> u32 {
        debug_assert_eq!(vals.len(), self.lanes);
        debug_assert_eq!(ds.len(), self.lanes);
        let idx = self.n_nodes() as u32;
        self.parents.push(p);
        self.partials.extend_from_slice(ds);
        self.vals.extend_from_slice(vals);
        self.bounds.push(self.parents.len() as u32);
        idx
    }

    /// Push a value-only node (no parents, no partials). The batched
    /// replay executors use these to carry per-lane sampled values through
    /// model glue arithmetic; the node contributes nothing to a backward
    /// sweep (its edge range is empty).
    #[inline]
    pub fn push0_lanes(&mut self, vals: &[f64]) -> u32 {
        debug_assert_eq!(vals.len(), self.lanes);
        let idx = self.n_nodes() as u32;
        self.vals.extend_from_slice(vals);
        self.bounds.push(self.parents.len() as u32);
        idx
    }

    /// Push a binary node; `da`/`db` are per-lane partials.
    #[inline]
    pub fn push2_lanes(&mut self, pa: u32, da: &[f64], pb: u32, db: &[f64], vals: &[f64]) -> u32 {
        let idx = self.n_nodes() as u32;
        self.parents.push(pa);
        self.parents.push(pb);
        self.partials.extend_from_slice(da);
        self.partials.extend_from_slice(db);
        self.vals.extend_from_slice(vals);
        self.bounds.push(self.parents.len() as u32);
        idx
    }

    /// Record per-lane gradient seeds for `node`. Constants are dropped
    /// whole; zero weights are skipped lane-by-lane at application time,
    /// mirroring the sequential tape's zero-weight drop.
    #[inline]
    pub fn seed_lanes(&mut self, node: u32, ws: &[f64]) {
        debug_assert_eq!(ws.len(), self.lanes);
        if node != NONE && ws.iter().any(|&w| w != 0.0) {
            self.seed_nodes.push(node);
            self.seed_w.extend_from_slice(ws);
        }
    }

    /// K-lane reverse sweep: `grad` is coordinate-major
    /// (`grad[i * lanes + lane]`), length `n_inputs * lanes`. Per lane this
    /// performs exactly the sequential sweep's adds in the sequential
    /// sweep's node order.
    pub fn backward_into(&mut self, grad: &mut [f64]) {
        let k = self.lanes;
        assert_eq!(grad.len(), self.n_inputs * k);
        let n = self.n_nodes();
        self.adj.clear();
        self.adj.resize(n * k, 0.0);
        for (s, &p) in self.seed_nodes.iter().enumerate() {
            let base = p as usize * k;
            for l in 0..k {
                let w = self.seed_w[s * k + l];
                if w != 0.0 {
                    self.adj[base + l] += w;
                }
            }
        }
        for i in (self.n_inputs..n).rev() {
            let abase = i * k;
            if self.adj[abase..abase + k].iter().all(|&a| a == 0.0) {
                continue; // nothing to propagate on any lane
            }
            let lo = self.bounds[i] as usize;
            let hi = self.bounds[i + 1] as usize;
            for e in lo..hi {
                let pbase = self.parents[e] as usize * k;
                let dbase = e * k;
                for l in 0..k {
                    let a = self.adj[abase + l];
                    if a != 0.0 {
                        self.adj[pbase + l] += a * self.partials[dbase + l];
                    }
                }
            }
        }
        grad.copy_from_slice(&self.adj[..self.n_inputs * k]);
    }

    /// Retained capacity in bytes (allocation-regression probes).
    pub fn capacity_bytes(&self) -> usize {
        self.bounds.capacity() * 4
            + self.parents.capacity() * 4
            + self.partials.capacity() * 8
            + self.vals.capacity() * 8
            + self.seed_nodes.capacity() * 4
            + self.seed_w.capacity() * 8
            + self.adj.capacity() * 8
    }
}

thread_local! {
    static BATCH_TAPE: RefCell<BatchTape> = RefCell::new(BatchTape::default());
}

/// Run `f` with mutable access to the thread-local batch tape.
#[inline]
pub fn with_tape<R>(f: impl FnOnce(&mut BatchTape) -> R) -> R {
    BATCH_TAPE.with(|t| f(&mut t.borrow_mut()))
}

/// Start a fresh K-lane evaluation with coordinate-major leaf values.
pub fn begin(theta_t: &[f64], n_inputs: usize, lanes: usize) {
    with_tape(|t| t.reset(theta_t, n_inputs, lanes));
}

/// K-lane backward pass into a coordinate-major gradient buffer.
pub fn backward_into(grad: &mut [f64]) {
    with_tape(|t| t.backward_into(grad));
}

/// A tracked K-lane scalar. `idx == NONE` means a *uniform* constant (every
/// lane holds `cv`); tracked variables cache lane 0's primal in `cv` so
/// `value()`/comparisons need no tape access.
#[derive(Clone, Copy, Debug)]
pub struct BVar {
    idx: u32,
    cv: f64,
}

impl BVar {
    /// The `i`-th input leaf; `cv0` is lane 0's value.
    #[inline]
    pub fn leaf(i: u32, cv0: f64) -> Self {
        BVar { idx: i, cv: cv0 }
    }

    /// Wrap an existing tape node (fused executors).
    #[inline]
    pub fn from_node(idx: u32, cv0: f64) -> Self {
        BVar { idx, cv: cv0 }
    }

    #[inline]
    pub fn idx(&self) -> u32 {
        self.idx
    }
}

/// Scratch buffers for one op: K values + up to 2×K partials. Kept in a
/// thread-local so ops allocate nothing at steady state.
struct OpScratch {
    av: Vec<f64>,
    bv: Vec<f64>,
    v: Vec<f64>,
    da: Vec<f64>,
    db: Vec<f64>,
}

thread_local! {
    static OP_SCRATCH: RefCell<OpScratch> = RefCell::new(OpScratch {
        av: Vec::new(),
        bv: Vec::new(),
        v: Vec::new(),
        da: Vec::new(),
        db: Vec::new(),
    });
}

/// Apply a unary op lane-wise: `f(x) -> (value, dvalue/dx)`. Constant
/// operands collapse to a constant, exactly like `AVar::unary`.
#[inline]
fn bvar_unary(x: BVar, f: impl Fn(f64) -> (f64, f64)) -> BVar {
    if x.idx == NONE {
        return BVar {
            idx: NONE,
            cv: f(x.cv).0,
        };
    }
    OP_SCRATCH.with(|s| {
        let s = &mut *s.borrow_mut();
        with_tape(|t| {
            let k = t.lanes();
            s.v.resize(k, 0.0);
            s.da.resize(k, 0.0);
            {
                let xs = t.node_vals(x.idx);
                for l in 0..k {
                    let (v, d) = f(xs[l]);
                    s.v[l] = v;
                    s.da[l] = d;
                }
            }
            let idx = t.push1_lanes(x.idx, &s.v, &s.da);
            BVar { idx, cv: s.v[0] }
        })
    })
}

/// Apply a binary op lane-wise: `f(a, b) -> (value, dv/da, dv/db)`, with
/// the same constant-collapsing rules as `AVar::binary` (const ∘ const →
/// const; one const operand → unary node on the tracked operand).
#[inline]
fn bvar_binary(a: BVar, b: BVar, f: impl Fn(f64, f64) -> (f64, f64, f64)) -> BVar {
    if a.idx == NONE && b.idx == NONE {
        return BVar {
            idx: NONE,
            cv: f(a.cv, b.cv).0,
        };
    }
    OP_SCRATCH.with(|s| {
        let s = &mut *s.borrow_mut();
        with_tape(|t| {
            let k = t.lanes();
            s.v.resize(k, 0.0);
            s.da.resize(k, 0.0);
            s.db.resize(k, 0.0);
            s.av.resize(k, 0.0);
            s.bv.resize(k, 0.0);
            t.read_lanes(a, &mut s.av);
            t.read_lanes(b, &mut s.bv);
            for l in 0..k {
                let (v, da, db) = f(s.av[l], s.bv[l]);
                s.v[l] = v;
                s.da[l] = da;
                s.db[l] = db;
            }
            let idx = match (a.idx, b.idx) {
                (NONE, bi) => t.push1_lanes(bi, &s.v, &s.db),
                (ai, NONE) => t.push1_lanes(ai, &s.v, &s.da),
                (ai, bi) => t.push2_lanes(ai, &s.da, bi, &s.db, &s.v),
            };
            BVar { idx, cv: s.v[0] }
        })
    })
}

macro_rules! impl_bvar_binop {
    ($trait:ident, $fn:ident, |$a:ident, $b:ident| $v:expr, $da:expr, $db:expr) => {
        impl std::ops::$trait for BVar {
            type Output = BVar;
            #[inline]
            fn $fn(self, rhs: BVar) -> BVar {
                bvar_binary(self, rhs, |$a, $b| {
                    let _ = (&$a, &$b);
                    ($v, $da, $db)
                })
            }
        }
        impl std::ops::$trait<f64> for BVar {
            type Output = BVar;
            #[inline]
            fn $fn(self, rhs: f64) -> BVar {
                bvar_binary(self, BVar::constant(rhs), |$a, $b| {
                    let _ = (&$a, &$b);
                    ($v, $da, $db)
                })
            }
        }
        impl std::ops::$trait<BVar> for f64 {
            type Output = BVar;
            #[inline]
            fn $fn(self, rhs: BVar) -> BVar {
                bvar_binary(BVar::constant(self), rhs, |$a, $b| {
                    let _ = (&$a, &$b);
                    ($v, $da, $db)
                })
            }
        }
    };
}

impl_bvar_binop!(Add, add, |a, b| a + b, 1.0, 1.0);
impl_bvar_binop!(Sub, sub, |a, b| a - b, 1.0, -1.0);
impl_bvar_binop!(Mul, mul, |a, b| a * b, b, a);
impl_bvar_binop!(Div, div, |a, b| a / b, 1.0 / b, -a / (b * b));

impl std::ops::Neg for BVar {
    type Output = BVar;
    #[inline]
    fn neg(self) -> BVar {
        bvar_unary(self, |x| (-x, -1.0))
    }
}

impl PartialEq for BVar {
    fn eq(&self, other: &Self) -> bool {
        self.cv == other.cv
    }
}

impl PartialOrd for BVar {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.cv.partial_cmp(&other.cv)
    }
}

impl Scalar for BVar {
    #[inline]
    fn constant(x: f64) -> Self {
        BVar { idx: NONE, cv: x }
    }
    /// Lane 0's primal (see the module docs for the branch caveat).
    #[inline]
    fn value(&self) -> f64 {
        self.cv
    }
    #[inline]
    fn ln(self) -> Self {
        bvar_unary(self, |x| (x.ln(), 1.0 / x))
    }
    #[inline]
    fn exp(self) -> Self {
        bvar_unary(self, |x| {
            let e = x.exp();
            (e, e)
        })
    }
    #[inline]
    fn sqrt(self) -> Self {
        bvar_unary(self, |x| {
            let s = x.sqrt();
            (s, 0.5 / s)
        })
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        bvar_unary(self, |x| (x.powi(n), n as f64 * x.powi(n - 1)))
    }
    #[inline]
    fn powf(self, e: f64) -> Self {
        bvar_unary(self, |x| (x.powf(e), e * x.powf(e - 1.0)))
    }
    /// Unlike `AVar::abs` (which branches on the sign and returns `self`
    /// untouched when positive), the batched form always pushes one node
    /// with a per-lane ±1 partial so that lanes with different signs stay
    /// individually correct. The ±1 multiply is exact, so per-lane values
    /// and adjoint flow match the sequential result bit-for-bit.
    #[inline]
    fn abs(self) -> Self {
        bvar_unary(self, |x| (x.abs(), if x >= 0.0 { 1.0 } else { -1.0 }))
    }
    #[inline]
    fn ln_1p(self) -> Self {
        bvar_unary(self, |x| (x.ln_1p(), 1.0 / (1.0 + x)))
    }
    #[inline]
    fn tanh(self) -> Self {
        bvar_unary(self, |x| {
            let t = x.tanh();
            (t, 1.0 - t * t)
        })
    }
    #[inline]
    fn sin(self) -> Self {
        bvar_unary(self, |x| (x.sin(), x.cos()))
    }
    #[inline]
    fn cos(self) -> Self {
        bvar_unary(self, |x| (x.cos(), -x.sin()))
    }
    #[inline]
    fn lgamma(self) -> Self {
        bvar_unary(self, |x| (math::lgamma(x), math::digamma(x)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::arena::{self, AVar};

    /// Sequential-arena gradient of `f` at `x` — the bit-identity oracle.
    fn arena_grad(f: impl Fn(&[AVar]) -> AVar, x: &[f64]) -> (f64, Vec<f64>) {
        let mut g = vec![0.0; x.len()];
        let v = arena::grad_fused_into(&f, x, &mut g);
        (v, g)
    }

    /// Batched gradient of `f` across lanes whose inputs are the rows of
    /// `xs`, returned per lane.
    fn batch_grad(f: impl Fn(&[BVar]) -> BVar, xs: &[Vec<f64>]) -> Vec<(f64, Vec<f64>)> {
        let k = xs.len();
        let dim = xs[0].len();
        let mut theta_t = vec![0.0; dim * k];
        for (l, x) in xs.iter().enumerate() {
            for i in 0..dim {
                theta_t[i * k + l] = x[i];
            }
        }
        begin(&theta_t, dim, k);
        let leaves: Vec<BVar> = (0..dim)
            .map(|i| BVar::leaf(i as u32, theta_t[i * k]))
            .collect();
        let out = f(&leaves);
        let ones = vec![1.0; k];
        with_tape(|t| t.seed_lanes(out.idx(), &ones));
        let mut grad_t = vec![0.0; dim * k];
        backward_into(&mut grad_t);
        let mut outv = vec![0.0; k];
        with_tape(|t| t.read_lanes(out, &mut outv));
        (0..k)
            .map(|l| {
                let g = (0..dim).map(|i| grad_t[i * k + l]).collect();
                (outv[l], g)
            })
            .collect()
    }

    #[test]
    fn lanes_are_bit_identical_to_sequential_arena() {
        let lanes: Vec<Vec<f64>> = vec![
            vec![0.5, 1.5, 0.3],
            vec![-0.2, 2.0, 1.1],
            vec![3.0, 0.25, -0.7],
            vec![1.0, 1.0, 1.0],
        ];
        let batched = batch_grad(
            |x| {
                let t = x[0] * x[1] + Scalar::exp(x[2]) * 0.5;
                Scalar::ln(t * t + 1.0) - x[1] / 3.0 + Scalar::tanh(x[0])
            },
            &lanes,
        );
        for (l, x) in lanes.iter().enumerate() {
            let (v, g) = arena_grad(
                |x| {
                    let t = x[0] * x[1] + Scalar::exp(x[2]) * 0.5;
                    Scalar::ln(t * t + 1.0) - x[1] / 3.0 + Scalar::tanh(x[0])
                },
                x,
            );
            assert_eq!(v.to_bits(), batched[l].0.to_bits(), "lane {l} value");
            for i in 0..x.len() {
                assert_eq!(
                    g[i].to_bits(),
                    batched[l].1[i].to_bits(),
                    "lane {l} grad[{i}]"
                );
            }
        }
    }

    #[test]
    fn constant_collapsing_matches_arena() {
        let lanes = vec![vec![2.0], vec![-1.5]];
        let batched = batch_grad(
            |x| {
                let c = BVar::constant(10.0);
                let d = c * 2.0 + 1.0; // pure-constant chain: no nodes
                x[0] * d
            },
            &lanes,
        );
        assert_eq!(batched[0].0, 42.0);
        assert_eq!(batched[1].1[0], 21.0);
        // leaves + exactly one fused node, like the sequential arena
        with_tape(|t| assert_eq!(t.n_fused_nodes(), 1));
    }

    #[test]
    fn zero_weight_seed_lanes_are_skipped() {
        let theta_t = vec![1.0, 2.0]; // 1 input × 2 lanes
        begin(&theta_t, 1, 2);
        let x = BVar::leaf(0, theta_t[0]);
        let y = x * x;
        // lane 1 rejected: weight 0 must not touch its adjoint
        with_tape(|t| t.seed_lanes(y.idx(), &[1.0, 0.0]));
        let mut grad_t = vec![0.0; 2];
        backward_into(&mut grad_t);
        assert_eq!(grad_t, vec![2.0, 0.0]);
    }

    #[test]
    fn capacity_is_stable_across_evaluations() {
        let run = || {
            let theta_t = vec![0.5, 1.5, 2.5, -0.5]; // 2 inputs × 2 lanes
            begin(&theta_t, 2, 2);
            let a = BVar::leaf(0, theta_t[0]);
            let b = BVar::leaf(1, theta_t[2]);
            let y = Scalar::ln(a * a + Scalar::exp(b));
            with_tape(|t| t.seed_lanes(y.idx(), &[1.0, 1.0]));
            let mut grad_t = vec![0.0; 4];
            backward_into(&mut grad_t);
            grad_t
        };
        let _ = run();
        let cap = with_tape(|t| t.capacity_bytes());
        for _ in 0..10 {
            let _ = run();
        }
        assert_eq!(
            with_tape(|t| t.capacity_bytes()),
            cap,
            "steady-state batch tape must not grow"
        );
    }
}
