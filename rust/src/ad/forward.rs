//! Forward-mode AD: dual numbers (ForwardDiff.jl analogue).
//!
//! [`Dual`] carries one directional derivative; a full gradient of an
//! n-parameter density costs n evaluations. That is acceptable for the small
//! models and is exactly how the *vectorized* forward mode of ForwardDiff
//! behaves per chunk; `grad_forward` evaluates in chunks of one.

use super::Scalar;
use crate::util::math;

/// Dual number a + b·ε with ε² = 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dual {
    pub v: f64,
    pub d: f64,
}

impl Dual {
    #[inline]
    pub fn new(v: f64, d: f64) -> Self {
        Self { v, d }
    }

    /// Seed variable: derivative 1.
    #[inline]
    pub fn var(v: f64) -> Self {
        Self { v, d: 1.0 }
    }
}

macro_rules! impl_dual_binop {
    ($trait:ident, $fn:ident, |$a:ident, $b:ident| $v:expr, |$av:ident, $ad:ident, $bv:ident, $bd:ident| $d:expr) => {
        impl std::ops::$trait for Dual {
            type Output = Dual;
            #[inline]
            fn $fn(self, rhs: Dual) -> Dual {
                let ($a, $b) = (self.v, rhs.v);
                let ($av, $ad, $bv, $bd) = (self.v, self.d, rhs.v, rhs.d);
                let _ = ($av, $bv);
                Dual::new($v, $d)
            }
        }
        impl std::ops::$trait<f64> for Dual {
            type Output = Dual;
            #[inline]
            fn $fn(self, rhs: f64) -> Dual {
                std::ops::$trait::$fn(self, Dual::new(rhs, 0.0))
            }
        }
        impl std::ops::$trait<Dual> for f64 {
            type Output = Dual;
            #[inline]
            fn $fn(self, rhs: Dual) -> Dual {
                std::ops::$trait::$fn(Dual::new(self, 0.0), rhs)
            }
        }
    };
}

impl_dual_binop!(Add, add, |a, b| a + b, |av, ad, bv, bd| ad + bd);
impl_dual_binop!(Sub, sub, |a, b| a - b, |av, ad, bv, bd| ad - bd);
impl_dual_binop!(Mul, mul, |a, b| a * b, |av, ad, bv, bd| ad * bv + av * bd);
impl_dual_binop!(Div, div, |a, b| a / b, |av, ad, bv, bd| (ad * bv - av * bd)
    / (bv * bv));

impl std::ops::Neg for Dual {
    type Output = Dual;
    #[inline]
    fn neg(self) -> Dual {
        Dual::new(-self.v, -self.d)
    }
}

impl PartialOrd for Dual {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.v.partial_cmp(&other.v)
    }
}

impl Scalar for Dual {
    #[inline]
    fn constant(x: f64) -> Self {
        Dual::new(x, 0.0)
    }
    #[inline]
    fn value(&self) -> f64 {
        self.v
    }
    #[inline]
    fn ln(self) -> Self {
        Dual::new(self.v.ln(), self.d / self.v)
    }
    #[inline]
    fn exp(self) -> Self {
        let e = self.v.exp();
        Dual::new(e, self.d * e)
    }
    #[inline]
    fn sqrt(self) -> Self {
        let s = self.v.sqrt();
        Dual::new(s, self.d / (2.0 * s))
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        Dual::new(
            self.v.powi(n),
            self.d * n as f64 * self.v.powi(n - 1),
        )
    }
    #[inline]
    fn powf(self, e: f64) -> Self {
        Dual::new(self.v.powf(e), self.d * e * self.v.powf(e - 1.0))
    }
    #[inline]
    fn abs(self) -> Self {
        if self.v >= 0.0 {
            self
        } else {
            -self
        }
    }
    #[inline]
    fn ln_1p(self) -> Self {
        Dual::new(self.v.ln_1p(), self.d / (1.0 + self.v))
    }
    #[inline]
    fn tanh(self) -> Self {
        let t = self.v.tanh();
        Dual::new(t, self.d * (1.0 - t * t))
    }
    #[inline]
    fn sin(self) -> Self {
        Dual::new(self.v.sin(), self.d * self.v.cos())
    }
    #[inline]
    fn cos(self) -> Self {
        Dual::new(self.v.cos(), -self.d * self.v.sin())
    }
    #[inline]
    fn lgamma(self) -> Self {
        Dual::new(math::lgamma(self.v), self.d * math::digamma(self.v))
    }
}

/// Full gradient of `f` at `x` by n forward passes (one seed per input).
/// Returns (f(x), ∇f(x)).
pub fn grad_forward<F>(mut f: F, x: &[f64]) -> (f64, Vec<f64>)
where
    F: FnMut(&[Dual]) -> Dual,
{
    let n = x.len();
    let mut duals: Vec<Dual> = x.iter().map(|&v| Dual::constant(v)).collect();
    let mut grad = vec![0.0; n];
    let mut val = 0.0;
    if n == 0 {
        // Evaluate once for the value.
        return (f(&duals).v, grad);
    }
    for i in 0..n {
        duals[i].d = 1.0;
        let out = f(&duals);
        duals[i].d = 0.0;
        grad[i] = out.d;
        val = out.v;
    }
    (val, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::finite_diff_grad;

    #[test]
    fn arithmetic_rules() {
        let x = Dual::var(3.0);
        let y = x * x + 2.0 * x + 1.0; // d/dx = 2x + 2 = 8
        assert!((y.v - 16.0).abs() < 1e-14);
        assert!((y.d - 8.0).abs() < 1e-14);
        let z = (x * x) / (x + 1.0); // d/dx = (x²+2x)/(x+1)²
        assert!((z.d - (9.0 + 6.0) / 16.0).abs() < 1e-14);
    }

    #[test]
    fn transcendental_rules() {
        let x = Dual::var(0.7);
        assert!((Scalar::ln(x).d - 1.0 / 0.7).abs() < 1e-14);
        assert!((Scalar::exp(x).d - 0.7f64.exp()).abs() < 1e-14);
        assert!((Scalar::sqrt(x).d - 0.5 / 0.7f64.sqrt()).abs() < 1e-14);
        assert!((Scalar::tanh(x).d - (1.0 - 0.7f64.tanh().powi(2))).abs() < 1e-14);
        assert!((Scalar::sin(x).d - 0.7f64.cos()).abs() < 1e-14);
        assert!((x.powf(2.5).d - 2.5 * 0.7f64.powf(1.5)).abs() < 1e-14);
        assert!((x.powi(3).d - 3.0 * 0.49).abs() < 1e-12);
    }

    #[test]
    fn lgamma_derivative_is_digamma() {
        let x = Dual::var(4.2);
        assert!((Scalar::lgamma(x).d - math::digamma(4.2)).abs() < 1e-11);
    }

    #[test]
    fn stable_helpers_differentiate() {
        let x = Dual::var(1.3);
        let s = x.sigmoid();
        let sv = 1.0 / (1.0 + (-1.3f64).exp());
        assert!((s.d - sv * (1.0 - sv)).abs() < 1e-13);
        let l = x.log_sigmoid();
        assert!((l.d - (1.0 - sv)).abs() < 1e-13);
    }

    #[test]
    fn grad_forward_matches_fd() {
        let f_primal = |x: &[f64]| x[0].ln() * x[1] + (x[2] * x[0]).sin();
        let fd = finite_diff_grad(f_primal, &[1.2, 0.8, 2.0], 1e-6);
        let (v, g) = grad_forward(
            |x: &[Dual]| Scalar::ln(x[0]) * x[1] + Scalar::sin(x[2] * x[0]),
            &[1.2, 0.8, 2.0],
        );
        assert!((v - f_primal(&[1.2, 0.8, 2.0])).abs() < 1e-14);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn log_add_exp_dual() {
        let a = Dual::var(2.0);
        let b = Dual::constant(1.0);
        let r = a.log_add_exp(b);
        // d/da log(e^a + e^b) = softmax weight of a
        let w = 2.0f64.exp() / (2.0f64.exp() + 1.0f64.exp());
        assert!((r.d - w).abs() < 1e-13);
    }
}
