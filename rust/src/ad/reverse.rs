//! Reverse-mode AD: a dynamic tape (Tracker.jl analogue).
//!
//! Every scalar operation appends a node (parents + local partials) to a
//! thread-local tape through a `RefCell` — i.e. an indirect, allocating,
//! dynamically-dispatched step per primitive op. This is an intentional
//! reproduction of the overhead profile the paper attributes to Tracker.jl
//! in §4 ("repeated use of Julia's dynamic dispatch leading to a large
//! run-time overhead"), which dominates on scalar-loop time-series models
//! (stochastic volatility, HMM). The AOT/XLA backend is the repaired path.

use std::cell::RefCell;

use super::Scalar;
use crate::util::math;

const NONE: u32 = u32::MAX;

#[derive(Clone, Copy, Debug)]
struct Node {
    parents: [u32; 2],
    partials: [f64; 2],
}

#[derive(Default)]
struct Tape {
    values: Vec<f64>,
    nodes: Vec<Node>,
}

thread_local! {
    static TAPE: RefCell<Tape> = RefCell::new(Tape::default());
    /// Adjoint scratch reused across [`backward`] calls — the buffer is as
    /// long as the whole tape, so reallocating it per gradient evaluation
    /// (the old `vec![0.0; n]`) dominated small-model backward passes.
    static ADJ_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
    /// Tape length of the last completed `grad_reverse` (survives the
    /// reset, for node-count diagnostics in `bench grad`).
    static LAST_TAPE_LEN: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// A tracked real: an index into the thread-local tape.
#[derive(Clone, Copy, Debug)]
pub struct TVar {
    idx: u32,
    v: f64, // cached primal so comparisons don't hit the tape
}

impl TVar {
    /// Push a leaf (input) variable.
    pub fn input(v: f64) -> Self {
        TAPE.with(|t| {
            let mut t = t.borrow_mut();
            let idx = t.values.len() as u32;
            t.values.push(v);
            t.nodes.push(Node {
                parents: [NONE, NONE],
                partials: [0.0, 0.0],
            });
            TVar { idx, v }
        })
    }

    #[inline]
    fn unary(self, v: f64, dv: f64) -> Self {
        TAPE.with(|t| {
            let mut t = t.borrow_mut();
            let idx = t.values.len() as u32;
            t.values.push(v);
            t.nodes.push(Node {
                parents: [self.idx, NONE],
                partials: [dv, 0.0],
            });
            TVar { idx, v }
        })
    }

    #[inline]
    fn binary(self, rhs: TVar, v: f64, da: f64, db: f64) -> Self {
        TAPE.with(|t| {
            let mut t = t.borrow_mut();
            let idx = t.values.len() as u32;
            t.values.push(v);
            t.nodes.push(Node {
                parents: [self.idx, rhs.idx],
                partials: [da, db],
            });
            TVar { idx, v }
        })
    }
}

/// Clear the thread-local tape. Must be called before each fresh gradient
/// evaluation; `grad_reverse` does this for you.
pub fn reset_tape() {
    TAPE.with(|t| {
        let mut t = t.borrow_mut();
        t.values.clear();
        t.nodes.clear();
    });
}

/// Current number of tape nodes (diagnostics / tests).
pub fn tape_len() -> usize {
    TAPE.with(|t| t.borrow().nodes.len())
}

/// Backpropagate from `out`, returning adjoints of the first `n_inputs`
/// tape entries (which must be the leaves created first, in order).
///
/// The full-tape adjoint buffer is a thread-local scratch reused across
/// calls (clear + zero-fill, no steady-state allocation); only the small
/// `n_inputs`-sized result is allocated.
pub fn backward(out: TVar, n_inputs: usize) -> Vec<f64> {
    TAPE.with(|t| {
        ADJ_SCRATCH.with(|s| {
            let t = t.borrow();
            let mut adj = s.borrow_mut();
            let n = t.nodes.len();
            adj.clear();
            adj.resize(n, 0.0);
            if (out.idx as usize) < n {
                adj[out.idx as usize] = 1.0;
            }
            for i in (0..n).rev() {
                let a = adj[i];
                if a == 0.0 {
                    continue;
                }
                let node = &t.nodes[i];
                for k in 0..2 {
                    let p = node.parents[k];
                    if p != NONE {
                        adj[p as usize] += a * node.partials[k];
                    }
                }
            }
            adj[..n_inputs].to_vec()
        })
    })
}

/// Capacity of the reused adjoint scratch — steady across repeated
/// gradient evaluations of the same model (regression probe for
/// `benches/ad.rs`).
pub fn adjoint_scratch_capacity() -> usize {
    ADJ_SCRATCH.with(|s| s.borrow().capacity())
}

/// Tape length (node count) of the last completed [`grad_reverse`].
pub fn last_tape_len() -> usize {
    LAST_TAPE_LEN.get()
}

/// Evaluate `f` on tracked inputs and return (value, gradient).
pub fn grad_reverse<F>(mut f: F, x: &[f64]) -> (f64, Vec<f64>)
where
    F: FnMut(&[TVar]) -> TVar,
{
    reset_tape();
    let inputs: Vec<TVar> = x.iter().map(|&v| TVar::input(v)).collect();
    let out = f(&inputs);
    let g = backward(out, x.len());
    let v = out.v;
    LAST_TAPE_LEN.set(tape_len());
    reset_tape();
    (v, g)
}

macro_rules! impl_tvar_binop {
    ($trait:ident, $fn:ident, |$a:ident, $b:ident| $v:expr, $da:expr, $db:expr) => {
        impl std::ops::$trait for TVar {
            type Output = TVar;
            #[inline]
            fn $fn(self, rhs: TVar) -> TVar {
                let ($a, $b) = (self.v, rhs.v);
                let _ = ($a, $b);
                self.binary(rhs, $v, $da, $db)
            }
        }
        impl std::ops::$trait<f64> for TVar {
            type Output = TVar;
            #[inline]
            fn $fn(self, rhs: f64) -> TVar {
                let ($a, $b) = (self.v, rhs);
                let _ = ($a, $b);
                self.unary($v, $da)
            }
        }
        impl std::ops::$trait<TVar> for f64 {
            type Output = TVar;
            #[inline]
            fn $fn(self, rhs: TVar) -> TVar {
                let ($a, $b) = (self, rhs.v);
                let _ = ($a, $b);
                rhs.unary($v, $db)
            }
        }
    };
}

impl_tvar_binop!(Add, add, |a, b| a + b, 1.0, 1.0);
impl_tvar_binop!(Sub, sub, |a, b| a - b, 1.0, -1.0);
impl_tvar_binop!(Mul, mul, |a, b| a * b, b, a);
impl_tvar_binop!(Div, div, |a, b| a / b, 1.0 / b, -a / (b * b));

impl std::ops::Neg for TVar {
    type Output = TVar;
    #[inline]
    fn neg(self) -> TVar {
        self.unary(-self.v, -1.0)
    }
}

impl PartialEq for TVar {
    fn eq(&self, other: &Self) -> bool {
        self.v == other.v
    }
}

impl PartialOrd for TVar {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.v.partial_cmp(&other.v)
    }
}

impl Scalar for TVar {
    #[inline]
    fn constant(x: f64) -> Self {
        TVar::input(x) // leaf with no seeding; adjoint discarded
    }
    #[inline]
    fn value(&self) -> f64 {
        self.v
    }
    #[inline]
    fn ln(self) -> Self {
        self.unary(self.v.ln(), 1.0 / self.v)
    }
    #[inline]
    fn exp(self) -> Self {
        let e = self.v.exp();
        self.unary(e, e)
    }
    #[inline]
    fn sqrt(self) -> Self {
        let s = self.v.sqrt();
        self.unary(s, 0.5 / s)
    }
    #[inline]
    fn powi(self, n: i32) -> Self {
        self.unary(self.v.powi(n), n as f64 * self.v.powi(n - 1))
    }
    #[inline]
    fn powf(self, e: f64) -> Self {
        self.unary(self.v.powf(e), e * self.v.powf(e - 1.0))
    }
    #[inline]
    fn abs(self) -> Self {
        if self.v >= 0.0 {
            self
        } else {
            -self
        }
    }
    #[inline]
    fn ln_1p(self) -> Self {
        self.unary(self.v.ln_1p(), 1.0 / (1.0 + self.v))
    }
    #[inline]
    fn tanh(self) -> Self {
        let t = self.v.tanh();
        self.unary(t, 1.0 - t * t)
    }
    #[inline]
    fn sin(self) -> Self {
        self.unary(self.v.sin(), self.v.cos())
    }
    #[inline]
    fn cos(self) -> Self {
        self.unary(self.v.cos(), -self.v.sin())
    }
    #[inline]
    fn lgamma(self) -> Self {
        self.unary(math::lgamma(self.v), math::digamma(self.v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::finite_diff_grad;

    #[test]
    fn simple_gradient() {
        let (v, g) = grad_reverse(|x| x[0] * x[0] + x[1] * 3.0, &[2.0, 5.0]);
        assert!((v - 19.0).abs() < 1e-14);
        assert!((g[0] - 4.0).abs() < 1e-14);
        assert!((g[1] - 3.0).abs() < 1e-14);
    }

    #[test]
    fn fan_out_accumulates() {
        // y = x*x + x → dy/dx = 2x + 1
        let (_, g) = grad_reverse(|x| x[0] * x[0] + x[0], &[3.0]);
        assert!((g[0] - 7.0).abs() < 1e-14);
    }

    #[test]
    fn matches_finite_differences() {
        let primal = |x: &[f64]| (x[0] * x[1]).sin() + (x[2].exp() + x[0]).ln();
        let fd = finite_diff_grad(primal, &[0.5, 1.5, 0.3], 1e-6);
        let (v, g) = grad_reverse(
            |x: &[TVar]| Scalar::sin(x[0] * x[1]) + Scalar::ln(Scalar::exp(x[2]) + x[0]),
            &[0.5, 1.5, 0.3],
        );
        assert!((v - primal(&[0.5, 1.5, 0.3])).abs() < 1e-13);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn constants_do_not_leak_gradient() {
        let (_, g) = grad_reverse(
            |x: &[TVar]| {
                let c = TVar::constant(10.0);
                x[0] * c
            },
            &[2.0],
        );
        assert!((g[0] - 10.0).abs() < 1e-14);
    }

    #[test]
    fn scalar_loop_time_series() {
        // AR(1)-like recursion, the workload shape where tape AD is slow.
        let n = 50;
        let obs: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
        let primal = |p: &[f64]| {
            let (phi, mut h) = (p[0], p[1]);
            let mut lp = 0.0;
            for &y in &obs {
                h = phi * h;
                lp += -0.5 * (y - h) * (y - h);
            }
            lp
        };
        let fd = finite_diff_grad(primal, &[0.9, 0.2], 1e-6);
        let (_, g) = grad_reverse(
            |p: &[TVar]| {
                let phi = p[0];
                let mut h = p[1];
                let mut lp = TVar::constant(0.0);
                for &y in &obs {
                    h = phi * h;
                    let r = y - h;
                    lp = lp + -0.5 * (r * r);
                }
                lp
            },
            &[0.9, 0.2],
        );
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn tape_resets() {
        let _ = grad_reverse(|x| x[0] + x[0], &[1.0]);
        assert_eq!(tape_len(), 0);
    }

    #[test]
    fn adjoint_scratch_reused_across_calls() {
        fn quad(v: &[TVar]) -> TVar {
            let mut s = TVar::constant(0.0);
            for &xi in v {
                s = s + xi * xi;
            }
            s
        }
        let x: Vec<f64> = (0..64).map(|i| 0.1 * i as f64 + 0.5).collect();
        let _ = grad_reverse(quad, &x);
        let cap = adjoint_scratch_capacity();
        assert!(cap > 0);
        for _ in 0..5 {
            let _ = grad_reverse(quad, &x);
        }
        assert_eq!(
            adjoint_scratch_capacity(),
            cap,
            "backward must reuse its adjoint scratch, not reallocate"
        );
        assert!(last_tape_len() >= x.len());
    }

    #[test]
    fn lgamma_reverse() {
        let (_, g) = grad_reverse(|x| Scalar::lgamma(x[0]), &[3.7]);
        assert!((g[0] - math::digamma(3.7)).abs() < 1e-11);
    }
}
