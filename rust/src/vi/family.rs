//! Variational families over the unconstrained space.
//!
//! Both families are Gaussians in the unconstrained coordinates of a
//! [`TypedVarInfo`](crate::varinfo::TypedVarInfo) layout — exactly Stan's
//! ADVI design (Kucukelbir et al. 2017): the constraint bijectors already
//! map ℝⁿ to the model's support, so a Gaussian q(θ) plus the existing
//! `invlink` machinery yields a valid approximation of any continuous
//! posterior, with the log-Jacobian terms accounted for by the model's
//! own log-density evaluation (the fused executors add them to logp).
//!
//! - **Mean-field**: q = N(μ, diag(σ²)), σ_i = exp(ω_i). 2n parameters.
//! - **Full-rank**: q = N(μ, LLᵀ) with L lower-triangular, diagonal
//!   parameterized as L_ii = exp(ω_i) (always positive — unlike Stan's raw
//!   Cholesky this keeps the entropy term well-defined for every parameter
//!   vector). n + n + n(n−1)/2 parameters.
//!
//! The entropy is analytic for both: H = Σ ω_i + ½·n·ln(2πe), because
//! ln|det L| = Σ ω_i under the log-diagonal parameterization.

use rand_core::RngCore;

use crate::util::rng::Rng;

/// ln(2πe) — the per-dimension entropy constant of a unit Gaussian.
const LN_2PI_E: f64 = 2.837_877_066_409_345_3;

/// Which Gaussian family an [`Advi`](super::Advi) run fits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ViFamily {
    /// Diagonal covariance: cheap, exact marginal means on Gaussian
    /// targets, underestimates correlated variances.
    #[default]
    MeanField,
    /// Dense lower-triangular Cholesky factor: captures posterior
    /// correlations at O(n²) parameter cost.
    FullRank,
}

impl ViFamily {
    pub fn label(&self) -> &'static str {
        match self {
            ViFamily::MeanField => "meanfield",
            ViFamily::FullRank => "fullrank",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "meanfield" | "mean-field" | "mf" => ViFamily::MeanField,
            "fullrank" | "full-rank" | "fr" => ViFamily::FullRank,
            _ => return None,
        })
    }
}

/// Index of the strictly-lower-triangular entry (i, j), i > j, in the
/// row-major packed `off_diag` vector.
#[inline]
fn tri_index(i: usize, j: usize) -> usize {
    debug_assert!(i > j);
    i * (i - 1) / 2 + j
}

/// A Gaussian variational approximation with its parameters flattened as
/// `[μ…, ω…, off_diag…]` — one contiguous vector so a single optimizer
/// instance steps every parameter.
#[derive(Clone, Debug)]
pub struct VarApprox {
    pub family: ViFamily,
    pub dim: usize,
    /// Flat parameter vector: μ (dim), ω = log-diagonal (dim), then the
    /// strictly-lower-triangular entries of L row-major (full-rank only).
    pub params: Vec<f64>,
}

impl VarApprox {
    /// Fresh approximation centered at `mu0` with isotropic scale
    /// `init_scale` (L = init_scale · I).
    pub fn new(family: ViFamily, mu0: &[f64], init_scale: f64) -> Self {
        let dim = mu0.len();
        let n_off = match family {
            ViFamily::MeanField => 0,
            ViFamily::FullRank => dim * (dim - 1) / 2,
        };
        let mut params = Vec::with_capacity(2 * dim + n_off);
        params.extend_from_slice(mu0);
        params.resize(2 * dim, init_scale.ln());
        params.resize(2 * dim + n_off, 0.0);
        Self { family, dim, params }
    }

    /// Total number of variational parameters.
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn mu(&self) -> &[f64] {
        &self.params[..self.dim]
    }

    /// ω = log of the scale diagonal (mean-field: log σ; full-rank: log L_ii).
    pub fn omega(&self) -> &[f64] {
        &self.params[self.dim..2 * self.dim]
    }

    fn off_diag(&self) -> &[f64] {
        &self.params[2 * self.dim..]
    }

    /// Marginal standard deviations of q (mean-field: exp ω; full-rank:
    /// row norms of L).
    pub fn stddevs(&self) -> Vec<f64> {
        let omega = self.omega();
        match self.family {
            ViFamily::MeanField => omega.iter().map(|w| w.exp()).collect(),
            ViFamily::FullRank => {
                let off = self.off_diag();
                (0..self.dim)
                    .map(|i| {
                        let mut s = omega[i].exp().powi(2);
                        for j in 0..i {
                            s += off[tri_index(i, j)].powi(2);
                        }
                        s.sqrt()
                    })
                    .collect()
            }
        }
    }

    /// Analytic entropy H[q] = Σ ω_i + ½·n·ln(2πe).
    pub fn entropy(&self) -> f64 {
        self.omega().iter().sum::<f64>() + 0.5 * self.dim as f64 * LN_2PI_E
    }

    /// Fill `eta` with a standard-normal base draw.
    pub fn sample_eta<R: RngCore>(&self, rng: &mut R, eta: &mut [f64]) {
        debug_assert_eq!(eta.len(), self.dim);
        for e in eta.iter_mut() {
            *e = rng.normal();
        }
    }

    /// Reparameterization z = μ + L·η into `z`.
    pub fn transform(&self, eta: &[f64], z: &mut [f64]) {
        debug_assert_eq!(eta.len(), self.dim);
        debug_assert_eq!(z.len(), self.dim);
        let (mu, omega) = (self.mu(), self.omega());
        match self.family {
            ViFamily::MeanField => {
                for i in 0..self.dim {
                    z[i] = mu[i] + omega[i].exp() * eta[i];
                }
            }
            ViFamily::FullRank => {
                let off = self.off_diag();
                for i in 0..self.dim {
                    let mut acc = mu[i] + omega[i].exp() * eta[i];
                    for j in 0..i {
                        acc += off[tri_index(i, j)] * eta[j];
                    }
                    z[i] = acc;
                }
            }
        }
    }

    /// One posterior draw z ~ q into `z` (scratch `eta` reused).
    pub fn draw<R: RngCore>(&self, rng: &mut R, eta: &mut [f64], z: &mut [f64]) {
        self.sample_eta(rng, eta);
        self.transform(eta, z);
    }

    /// log q(z) for the draw produced from base noise `eta` (cheap form:
    /// −½‖η‖² − Σ ω − ½·n·ln 2π).
    pub fn logq_of_eta(&self, eta: &[f64]) -> f64 {
        let sq: f64 = eta.iter().map(|e| e * e).sum();
        -0.5 * sq - self.omega().iter().sum::<f64>()
            - 0.5 * self.dim as f64 * crate::util::math::LN_2PI
    }

    /// Accumulate one Monte-Carlo term of the reparameterized ELBO
    /// gradient into `grad` (same layout as `params`).
    ///
    /// `grad_logp` is ∇_z log p(z) at z = μ + L·η. With `stl` false this
    /// is the standard ADVI estimator — the analytic entropy gradient
    /// (+1 on every ω, once per *step*) is added by the caller via
    /// [`add_entropy_grad`](Self::add_entropy_grad). With `stl` true
    /// (sticking the landing, Roeder et al. 2017) the path derivative of
    /// −log q with the variational parameters held fixed replaces the
    /// analytic entropy: the estimator gains ∇_z log q(z) = −L⁻ᵀη inside
    /// the bracket and the caller must *not* add the entropy gradient.
    /// `scratch` must have length `dim` (used by the full-rank STL solve).
    pub fn accumulate_grad(
        &self,
        eta: &[f64],
        grad_logp: &[f64],
        stl: bool,
        scratch: &mut [f64],
        grad: &mut [f64],
    ) {
        debug_assert_eq!(grad.len(), self.n_params());
        debug_assert_eq!(scratch.len(), self.dim);
        let omega_off = self.dim;
        let tri_off = 2 * self.dim;
        let omega = self.omega();

        // bracket[i] = ∇_z log p(z)_i, plus the STL path term +L⁻ᵀη|_i
        // (= −∇_z log q(z)_i) when sticking the landing.
        // scratch holds the bracket.
        match (self.family, stl) {
            (_, false) => scratch.copy_from_slice(grad_logp),
            (ViFamily::MeanField, true) => {
                for i in 0..self.dim {
                    scratch[i] = grad_logp[i] + eta[i] / omega[i].exp();
                }
            }
            (ViFamily::FullRank, true) => {
                // solve Lᵀ x = η by back substitution: x = L⁻ᵀη
                let off = self.off_diag();
                for i in (0..self.dim).rev() {
                    let mut acc = eta[i];
                    for k in i + 1..self.dim {
                        acc -= off[tri_index(k, i)] * scratch[k];
                    }
                    scratch[i] = acc / omega[i].exp();
                }
                for i in 0..self.dim {
                    scratch[i] += grad_logp[i];
                }
            }
        }

        for i in 0..self.dim {
            grad[i] += scratch[i];
            // dz_i/dω_i = exp(ω_i)·η_i
            grad[omega_off + i] += scratch[i] * omega[i].exp() * eta[i];
        }
        if self.family == ViFamily::FullRank {
            for i in 1..self.dim {
                for j in 0..i {
                    // dz_i/dL_ij = η_j
                    grad[tri_off + tri_index(i, j)] += scratch[i] * eta[j];
                }
            }
        }
    }

    /// Add the analytic entropy gradient (∂H/∂ω_i = 1) — call once per
    /// optimization step for the standard (non-STL) estimator.
    pub fn add_entropy_grad(&self, grad: &mut [f64]) {
        for g in grad[self.dim..2 * self.dim].iter_mut() {
            *g += 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats;

    #[test]
    fn meanfield_transform_and_entropy() {
        let q = VarApprox::new(ViFamily::MeanField, &[1.0, -2.0], 0.5);
        assert_eq!(q.n_params(), 4);
        let mut z = [0.0; 2];
        q.transform(&[2.0, -1.0], &mut z);
        assert!((z[0] - (1.0 + 0.5 * 2.0)).abs() < 1e-12);
        assert!((z[1] - (-2.0 - 0.5)).abs() < 1e-12);
        // H = Σ ln σ + ½·n·ln(2πe)
        let want = 2.0 * 0.5f64.ln() + LN_2PI_E;
        assert!((q.entropy() - want).abs() < 1e-12);
        assert_eq!(q.stddevs(), vec![0.5, 0.5]);
    }

    #[test]
    fn fullrank_transform_matches_manual_cholesky() {
        let mut q = VarApprox::new(ViFamily::FullRank, &[0.0, 0.0, 0.0], 1.0);
        assert_eq!(q.n_params(), 3 + 3 + 3);
        // L = [[1,0,0],[0.5,2,0],[−0.3,0.7,0.25]]
        q.params[3] = 1.0f64.ln();
        q.params[4] = 2.0f64.ln();
        q.params[5] = 0.25f64.ln();
        q.params[6] = 0.5; // (1,0)
        q.params[7] = -0.3; // (2,0)
        q.params[8] = 0.7; // (2,1)
        let eta = [1.0, -1.0, 2.0];
        let mut z = [0.0; 3];
        q.transform(&eta, &mut z);
        assert!((z[0] - 1.0).abs() < 1e-12);
        assert!((z[1] - (0.5 - 2.0)).abs() < 1e-12);
        assert!((z[2] - (-0.3 - 0.7 + 0.5)).abs() < 1e-12);
        // marginal sds are the L row norms
        let sd = q.stddevs();
        assert!((sd[0] - 1.0).abs() < 1e-12);
        assert!((sd[1] - (0.25f64 + 4.0).sqrt()).abs() < 1e-12);
        assert!((sd[2] - (0.09f64 + 0.49 + 0.0625).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn logq_matches_density_of_draws() {
        // For q = N(μ, σ²) in 1D, logq(η) must equal the Normal logpdf at z.
        let q = VarApprox::new(ViFamily::MeanField, &[0.7], 0.3);
        let eta = [1.4];
        let mut z = [0.0];
        q.transform(&eta, &mut z);
        let want = crate::dist::Normal::new(0.7, 0.3).logpdf(z[0]);
        assert!((q.logq_of_eta(&eta) - want).abs() < 1e-12);
    }

    #[test]
    fn draw_moments_match_parameters() {
        let mut q = VarApprox::new(ViFamily::FullRank, &[1.0, -1.0], 1.0);
        // L = [[0.5, 0], [0.8, 0.6]] → var(z0)=0.25, var(z1)=1.0, cov=0.4
        q.params[2] = 0.5f64.ln();
        q.params[3] = 0.6f64.ln();
        q.params[4] = 0.8;
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let (mut eta, mut z) = (vec![0.0; 2], vec![0.0; 2]);
        let mut z0 = Vec::new();
        let mut z1 = Vec::new();
        for _ in 0..40_000 {
            q.draw(&mut rng, &mut eta, &mut z);
            z0.push(z[0]);
            z1.push(z[1]);
        }
        assert!((stats::mean(&z0) - 1.0).abs() < 0.02);
        assert!((stats::mean(&z1) + 1.0).abs() < 0.02);
        assert!((stats::variance(&z0) - 0.25).abs() < 0.01);
        assert!((stats::variance(&z1) - 1.0).abs() < 0.04);
        let cov = z0
            .iter()
            .zip(&z1)
            .map(|(a, b)| (a - stats::mean(&z0)) * (b - stats::mean(&z1)))
            .sum::<f64>()
            / (z0.len() - 1) as f64;
        assert!((cov - 0.4).abs() < 0.03, "{cov}");
    }

    /// Finite-difference check of the full ELBO gradient on a quadratic
    /// target where E_q[log p] is available in closed form.
    #[test]
    fn elbo_gradient_matches_finite_difference_quadratic() {
        // target: log p(z) = −½ Σ a_i (z_i − c_i)², a = (1, 4), c = (0.3, −0.6)
        let a = [1.0, 4.0];
        let c = [0.3, -0.6];
        // closed-form ELBO: −½ Σ a_i ((μ_i−c_i)² + Var_i) + H(q)
        let elbo = |q: &VarApprox| -> f64 {
            let sd = q.stddevs();
            let mu = q.mu();
            let mut e = q.entropy();
            for i in 0..2 {
                e -= 0.5 * a[i] * ((mu[i] - c[i]).powi(2) + sd[i] * sd[i]);
            }
            e
        };
        for family in [ViFamily::MeanField, ViFamily::FullRank] {
            let mut q = VarApprox::new(family, &[0.9, -0.1], 0.7);
            if family == ViFamily::FullRank {
                q.params[4] = 0.4; // non-trivial off-diagonal
            }
            // Monte-Carlo gradient with common random numbers, many samples
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            let n = 60_000;
            let mut grad = vec![0.0; q.n_params()];
            let (mut eta, mut z, mut scratch) = (vec![0.0; 2], vec![0.0; 2], vec![0.0; 2]);
            for _ in 0..n {
                q.draw(&mut rng, &mut eta, &mut z);
                let glp: Vec<f64> = (0..2).map(|i| -a[i] * (z[i] - c[i])).collect();
                q.accumulate_grad(&eta, &glp, false, &mut scratch, &mut grad);
            }
            for g in grad.iter_mut() {
                *g /= n as f64;
            }
            q.add_entropy_grad(&mut grad);
            // finite differences of the closed-form ELBO
            for k in 0..q.n_params() {
                let h = 1e-5;
                let mut qp = q.clone();
                qp.params[k] += h;
                let mut qm = q.clone();
                qm.params[k] -= h;
                let fd = (elbo(&qp) - elbo(&qm)) / (2.0 * h);
                assert!(
                    (grad[k] - fd).abs() < 0.05 * (1.0 + fd.abs()),
                    "{family:?} param {k}: MC {} vs FD {fd}",
                    grad[k]
                );
            }
        }
    }

    /// STL and standard estimators agree in expectation (same target).
    #[test]
    fn stl_estimator_agrees_in_expectation() {
        let a = [2.0, 0.5];
        for family in [ViFamily::MeanField, ViFamily::FullRank] {
            let mut q = VarApprox::new(family, &[0.2, 0.4], 0.8);
            if family == ViFamily::FullRank {
                q.params[4] = -0.3;
            }
            let n = 80_000;
            let run = |stl: bool, seed: u64| -> Vec<f64> {
                let mut rng = Xoshiro256pp::seed_from_u64(seed);
                let mut grad = vec![0.0; q.n_params()];
                let (mut eta, mut z, mut scratch) =
                    (vec![0.0; 2], vec![0.0; 2], vec![0.0; 2]);
                for _ in 0..n {
                    q.draw(&mut rng, &mut eta, &mut z);
                    let glp: Vec<f64> = (0..2).map(|i| -a[i] * z[i]).collect();
                    q.accumulate_grad(&eta, &glp, stl, &mut scratch, &mut grad);
                }
                for g in grad.iter_mut() {
                    *g /= n as f64;
                }
                if !stl {
                    q.add_entropy_grad(&mut grad);
                }
                grad
            };
            let std_grad = run(false, 11);
            let stl_grad = run(true, 11);
            for k in 0..std_grad.len() {
                assert!(
                    (std_grad[k] - stl_grad[k]).abs() < 0.06 * (1.0 + std_grad[k].abs()),
                    "{family:?} param {k}: std {} vs stl {}",
                    std_grad[k],
                    stl_grad[k]
                );
            }
        }
    }
}
