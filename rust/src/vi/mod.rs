//! Automatic Differentiation Variational Inference (ADVI) — the third
//! inference family next to MCMC and SMC.
//!
//! ADVI (Kucukelbir et al. 2017, Stan's `variational` mode) fits a
//! Gaussian approximation q(θ) over the **unconstrained** space by
//! stochastic ascent on the reparameterized ELBO
//!
//! ```text
//! ELBO(φ) = E_{η∼N(0,I)}[ log p(μ + L·η) ] + H[q_φ]
//! ```
//!
//! where `log p` is the model's unconstrained log-joint *including* the
//! bijector log-Jacobians — exactly what every [`LogDensity`] backend
//! already computes. Each gradient step is therefore `grad_samples` calls
//! to the allocation-free [`LogDensity::logp_grad_into`] fast path (the
//! arena-fused engine of PR 3): the per-iteration cost of ADVI is a small
//! constant multiple of one HMC leapfrog step, with none of the
//! trajectory rejection — which is where the ≥10× wall-clock win over
//! NUTS comes from.
//!
//! Submodules: [`family`] (mean-field / full-rank Gaussians, analytic
//! entropy, sticking-the-landing estimator), [`optimizer`] (Stan's
//! decayed RMSProp, Adam, the η search ladder). The [`Advi`] driver adds
//! ELBO-SE convergence monitoring and draws posterior samples from the
//! fitted approximation into the ordinary [`RawDraws`]/`Chain` pipeline,
//! so diagnostics, `query` posterior predictives and `stanlike`
//! comparisons run unchanged over a VI fit.
//!
//! **Minibatching (tall data).** [`Advi::fit_minibatch`] runs the same
//! driver over a [`MinibatchTarget`]: the model's observation sites are
//! partitioned into `⌈N/B⌉` blocks, each gradient step re-windows the
//! native density with [`Context::Subsample`] onto one seeded-uniform
//! block (priors at weight 1, block likelihood scaled by the block
//! count), and the fused executors skip out-of-window observations
//! before their kernels run — so a step costs O(B), not O(N), while the
//! gradient stays exactly unbiased (the block average over all blocks
//! *is* the full-data gradient). The η ladder scores candidates with the
//! same subsampling-corrected ELBO estimator; convergence and
//! best-params tracking use periodic **full-data** ELBO checks.

pub mod family;
pub mod optimizer;

pub use family::{ViFamily, VarApprox};
pub use optimizer::{Optimizer, OptimizerKind, ETA_CANDIDATES};

use rand_core::RngCore;

use crate::chain::SamplerStats;
use crate::context::{Context, SubsetId};
use crate::gradient::{Backend, LogDensity, NativeDensity};
use crate::inference::RawDraws;
use crate::model::Model;
use crate::obs::metrics::{self, Counter};
use crate::varinfo::TypedVarInfo;

/// ADVI configuration. Defaults mirror Stan's `variational` mode scaled
/// for the fused gradient path (more MC samples per step, fewer, denser
/// evaluations).
#[derive(Clone, Debug)]
pub struct Advi {
    pub family: ViFamily,
    /// Monte-Carlo samples per gradient step (Stan: `grad_samples`).
    pub grad_samples: usize,
    /// Lane count for batched gradient evaluation: each step's
    /// `grad_samples` draws are evaluated in chunks of `lanes` through one
    /// [`LogDensity::logp_grad_batch_into`] call (one K-lane tape walk on
    /// the fused engine). 1 = sequential. All base noise is drawn before
    /// the evaluations, and the batched engine is bit-identical per lane,
    /// so the fit does not depend on this knob — only wall-clock does.
    pub lanes: usize,
    /// Monte-Carlo samples per ELBO evaluation (Stan: `elbo_samples`).
    pub elbo_samples: usize,
    /// Maximum optimizer iterations.
    pub max_iters: usize,
    /// Evaluate the ELBO (and test convergence) every this many iterations.
    pub eval_every: usize,
    /// Relative-change convergence tolerance (Stan: `tol_rel_obj`).
    pub tol_rel: f64,
    pub optimizer: OptimizerKind,
    /// Base step size; `None` runs Stan's η ladder search
    /// ([`ETA_CANDIDATES`]) before the main fit.
    pub eta: Option<f64>,
    /// Trial iterations per η candidate during the search.
    pub adapt_iters: usize,
    /// Sticking-the-landing (path-derivative) gradient estimator
    /// (Roeder et al. 2017): lower-variance near the optimum, one extra
    /// triangular solve per sample for the full-rank family.
    pub stl: bool,
    /// Initial scale of q (σ and L diagonal).
    pub init_scale: f64,
}

impl Default for Advi {
    fn default() -> Self {
        Self {
            family: ViFamily::MeanField,
            grad_samples: 4,
            lanes: 4,
            elbo_samples: 100,
            max_iters: 2000,
            eval_every: 50,
            tol_rel: 0.01,
            optimizer: OptimizerKind::RmsProp,
            eta: None,
            adapt_iters: 30,
            stl: false,
            init_scale: 0.1,
        }
    }
}

/// A minibatch-able VI target: model + typed layout + native engine, from
/// which per-block subsampled densities are built each step.
///
/// Two block shapes:
///
/// - **Windowed** ([`MinibatchTarget::new`]): the `n_obs` observation
///   sites (model visit order) are partitioned into `⌈N/B⌉` contiguous
///   windows served through [`Context::Subsample`].
/// - **Index sets** ([`MinibatchTarget::with_index_sets`]): caller-chosen,
///   possibly non-contiguous site sets (strided, clustered,
///   importance-grouped) served through [`Context::SubsampleIdx`].
///
/// Either way, sampling a block uniformly and scaling its likelihood by
/// the block count is an exactly unbiased estimator of the full-data
/// log-joint gradient — for index sets, provided the sets partition the
/// observation sites.
pub struct MinibatchTarget<'a> {
    pub model: &'a dyn Model,
    pub tvi: &'a TypedVarInfo,
    pub backend: Backend,
    /// Total observation sites (N), counted by one model evaluation.
    pub n_obs: usize,
    /// Batch size (B), clamped to `[1, n_obs]`. In index-set mode: the
    /// largest set size (reporting only).
    pub batch: usize,
    /// Pre-registered index sets — `Some` switches [`Self::block`] to
    /// [`Context::SubsampleIdx`] mode.
    idx_sets: Option<Vec<SubsetId>>,
}

impl<'a> MinibatchTarget<'a> {
    pub fn new(
        model: &'a dyn Model,
        tvi: &'a TypedVarInfo,
        batch: usize,
        backend: Backend,
    ) -> Self {
        let n_obs = crate::model::count_obs_sites(model, tvi);
        Self {
            model,
            tvi,
            backend,
            n_obs,
            batch: batch.clamp(1, n_obs.max(1)),
            idx_sets: None,
        }
    }

    /// Non-contiguous minibatching: each `sets[k]` is a set of observation
    /// visit indices (sorted/deduplicated on registration). The sets
    /// should partition `[0, n_obs)` for an unbiased gradient estimator;
    /// out-of-range indices never match a site and contribute nothing.
    pub fn with_index_sets(
        model: &'a dyn Model,
        tvi: &'a TypedVarInfo,
        sets: Vec<Vec<u32>>,
        backend: Backend,
    ) -> Self {
        assert!(!sets.is_empty(), "index-set minibatching needs ≥ 1 set");
        let n_obs = crate::model::count_obs_sites(model, tvi);
        let batch = sets.iter().map(Vec::len).max().unwrap_or(0).max(1);
        let ids = sets.into_iter().map(crate::context::register_subset).collect();
        Self {
            model,
            tvi,
            backend,
            n_obs,
            batch,
            idx_sets: Some(ids),
        }
    }

    /// Number of minibatch blocks: the set count in index-set mode, else
    /// ⌈N/B⌉ (≥ 1).
    pub fn n_blocks(&self) -> usize {
        match &self.idx_sets {
            Some(ids) => ids.len(),
            None => self.n_obs.div_ceil(self.batch).max(1),
        }
    }

    /// The full-data density (used for posterior draws and the periodic
    /// full ELBO checks).
    pub fn full(&self) -> NativeDensity<'a> {
        NativeDensity::new(self.model, self.tvi, self.backend)
    }

    /// The subsampled density of block `k`: priors at weight 1, the
    /// block's observations scaled by the block count.
    pub fn block(&self, k: usize) -> NativeDensity<'a> {
        let n_blocks = self.n_blocks();
        debug_assert!(k < n_blocks);
        let mut ld = NativeDensity::new(self.model, self.tvi, self.backend);
        ld.ctx = match &self.idx_sets {
            Some(ids) => Context::SubsampleIdx {
                set: ids[k],
                scale: n_blocks as f64,
            },
            None => {
                let lo = k * self.batch;
                let hi = (lo + self.batch).min(self.n_obs);
                Context::Subsample {
                    lo,
                    hi,
                    scale: n_blocks as f64,
                }
            }
        };
        ld
    }
}

/// Seeded-uniform block index in `[0, k)`.
#[inline]
fn draw_block<R: RngCore>(rng: &mut R, k: usize) -> usize {
    (rng.next_u64() % k.max(1) as u64) as usize
}

/// A fitted variational approximation plus its optimization telemetry.
#[derive(Clone, Debug)]
pub struct ViFit {
    pub approx: VarApprox,
    /// (iteration, ELBO) at every evaluation point.
    pub elbo_trace: Vec<(usize, f64)>,
    /// Best evaluated ELBO (the returned `approx` is the parameters at
    /// this evaluation, not necessarily the last step).
    pub elbo: f64,
    /// Monte-Carlo standard error of the best ELBO estimate.
    pub elbo_se: f64,
    pub converged: bool,
    /// Optimizer iterations actually run.
    pub iters: usize,
    /// η chosen (configured or found by the ladder search).
    pub eta: f64,
    /// The η ladder search failed outright: every candidate diverged or
    /// produced a non-finite trial ELBO, and the fit fell back to the
    /// smallest candidate rate. A fit that starts this way deserves
    /// scrutiny (bad initialization, unstable model) — surfaced here
    /// instead of silently fitting at an arbitrary rate.
    pub eta_search_failed: bool,
    /// Minibatch size the fit ran with (`None` = full-data gradients).
    pub minibatch: Option<usize>,
    /// Gradient evaluations spent (fit only; excludes ELBO evaluations).
    pub n_grad_evals: u64,
    /// Plain log-density evaluations spent on ELBO monitoring.
    pub n_logp_evals: u64,
    /// Gradient steps skipped because every MC draw landed outside the
    /// target's support (all `logp = −∞`).
    pub rejected_steps: usize,
    /// Total fit wall time, η ladder search included.
    pub wall_secs: f64,
    /// Main optimization loop only (η search excluded; ELBO monitoring
    /// included, as it is part of the steady per-iteration cost) — the
    /// honest numerator for a seconds-per-iteration figure.
    pub opt_wall_secs: f64,
}

impl ViFit {
    /// Draw `n` posterior samples from the approximation as [`RawDraws`],
    /// scoring each draw under `ld` so the chain's `logp` column is the
    /// target (not the variational) log-density. `stats.log_evidence`
    /// carries the ELBO — a lower bound on the log marginal likelihood.
    pub fn sample_raw<R: RngCore>(&self, ld: &dyn LogDensity, n: usize, rng: &mut R) -> RawDraws {
        let dim = self.approx.dim;
        let mut eta = vec![0.0; dim];
        let mut thetas = Vec::with_capacity(n);
        let mut logps = Vec::with_capacity(n);
        for _ in 0..n {
            let mut z = vec![0.0; dim];
            self.approx.draw(rng, &mut eta, &mut z);
            logps.push(ld.logp(&z));
            thetas.push(z);
        }
        RawDraws {
            thetas,
            logps,
            stats: SamplerStats {
                accept_rate: 1.0,
                step_size: self.eta,
                n_grad_evals: self.n_grad_evals,
                wall_secs: self.wall_secs,
                // the optimization *is* ADVI's warmup; posterior draws
                // from the fitted q are effectively free and untimed
                warmup_secs: self.wall_secs,
                eta_search_failed: self.eta_search_failed,
                log_evidence: self.elbo,
                ..SamplerStats::default()
            },
        }
    }
}

/// Scratch buffers shared by the fit and ELBO loops (all sized `dim` or
/// `n_params`, allocated once per fit).
struct FitScratch {
    eta: Vec<f64>,
    z: Vec<f64>,
    glp: Vec<f64>,
    bracket: Vec<f64>,
    grad: Vec<f64>,
    /// Lane-major buffers for batched gradient steps (`lanes > 1`): all
    /// `grad_samples` base draws, states, log-densities and gradients of
    /// one step, sized once per fit.
    betas: Vec<f64>,
    bzs: Vec<f64>,
    blps: Vec<f64>,
    bglps: Vec<f64>,
}

impl Advi {
    /// Mean-field with defaults.
    pub fn meanfield() -> Self {
        Self::default()
    }

    /// Full-rank with defaults.
    pub fn fullrank() -> Self {
        Self {
            family: ViFamily::FullRank,
            ..Self::default()
        }
    }

    /// Fit q to `ld` starting from `theta0` (the approximation is
    /// initialized at μ = θ₀, scale = `init_scale`). The RNG must be
    /// `Clone` so the η ladder search can replay the same noise stream
    /// for every candidate (common random numbers).
    pub fn fit<R: RngCore + Clone>(&self, ld: &dyn LogDensity, theta0: &[f64], rng: &mut R) -> ViFit {
        self.fit_impl(ld, None, theta0, rng)
    }

    /// Minibatched fit over a [`MinibatchTarget`]: every gradient step
    /// resamples one observation block (seeded) and steps on the
    /// [`Context::Subsample`]-scaled reparameterized gradient; the η
    /// ladder scores candidates with the subsampling-corrected ELBO and
    /// the convergence monitor keeps its periodic full-data checks.
    pub fn fit_minibatch<R: RngCore + Clone>(
        &self,
        target: &MinibatchTarget,
        theta0: &[f64],
        rng: &mut R,
    ) -> ViFit {
        let full = target.full();
        self.fit_impl(&full, Some(target), theta0, rng)
    }

    fn fit_impl<R: RngCore + Clone>(
        &self,
        ld: &dyn LogDensity,
        mb: Option<&MinibatchTarget>,
        theta0: &[f64],
        rng: &mut R,
    ) -> ViFit {
        let dim = ld.dim();
        assert_eq!(theta0.len(), dim, "theta0 does not match the density dimension");
        let t_start = std::time::Instant::now();
        let mut n_grad: u64 = 0;
        let mut n_logp: u64 = 0;

        let q0 = VarApprox::new(self.family, theta0, self.init_scale);
        let gs = self.grad_samples.max(1);
        let mut scratch = FitScratch {
            eta: vec![0.0; dim],
            z: vec![0.0; dim],
            glp: vec![0.0; dim],
            bracket: vec![0.0; dim],
            grad: vec![0.0; q0.n_params()],
            betas: vec![0.0; gs * dim],
            bzs: vec![0.0; gs * dim],
            blps: vec![0.0; gs],
            bglps: vec![0.0; gs * dim],
        };

        // ---------------------------------------------------- η search
        let mut eta_search_failed = false;
        let eta = match self.eta {
            Some(e) => e,
            None => {
                let fallback = ETA_CANDIDATES.iter().copied().fold(f64::INFINITY, f64::min);
                let mut best: Option<(f64, f64)> = None; // (elbo, eta)
                for &cand in &ETA_CANDIDATES {
                    metrics::inc(Counter::EtaTrials);
                    // common random numbers: every candidate replays the
                    // same stream from the search entry point
                    let mut probe_rng = rng.clone();
                    let mut q = q0.clone();
                    let mut opt = Optimizer::new(self.optimizer, cand, q.n_params());
                    let mut diverged = false;
                    for _ in 0..self.adapt_iters {
                        let stepped = self.grad_step(
                            ld,
                            mb,
                            &mut q,
                            &mut opt,
                            &mut probe_rng,
                            &mut scratch,
                            &mut n_grad,
                        );
                        if !stepped || q.params.iter().any(|p| !p.is_finite()) {
                            diverged = true;
                            break;
                        }
                    }
                    if diverged {
                        continue;
                    }
                    // trial score: the subsampling-corrected ELBO when
                    // minibatching (cheap), the plain estimator otherwise
                    let trial_samples = self.elbo_samples / 2 + 1;
                    let (elbo, _se) = self.estimate_elbo(
                        ld,
                        mb,
                        &q,
                        trial_samples,
                        &mut probe_rng,
                        &mut scratch,
                        &mut n_logp,
                    );
                    let improves = match best {
                        Some((b, _)) => elbo > b,
                        None => true,
                    };
                    if elbo.is_finite() && improves {
                        best = Some((elbo, cand));
                    }
                }
                match best {
                    Some((_, eta)) => eta,
                    None => {
                        // every candidate diverged or scored non-finite:
                        // fall back to the *smallest* (safest) rate and
                        // say so in the fit diagnostics
                        eta_search_failed = true;
                        fallback
                    }
                }
            }
        };

        // ---------------------------------------------------- main fit
        let mut q = q0;
        let init_params = q.params.clone();
        let t_opt = std::time::Instant::now();
        let mut opt = Optimizer::new(self.optimizer, eta, q.n_params());
        let mut trace: Vec<(usize, f64)> = Vec::new();
        let mut rejected_steps = 0usize;
        let mut prev: Option<(f64, f64)> = None; // (elbo, se)
        let mut best_params: Option<Vec<f64>> = None;
        let mut best = (f64::NEG_INFINITY, f64::NAN); // (elbo, se)
        let mut converged = false;
        let mut hits = 0usize;
        let mut iters_run = 0usize;

        for it in 1..=self.max_iters {
            iters_run = it;
            if !self.grad_step(ld, mb, &mut q, &mut opt, rng, &mut scratch, &mut n_grad) {
                rejected_steps += 1;
            }
            if q.params.iter().any(|p| !p.is_finite()) {
                // diverged (a fixed η skips the ladder's own guard): roll
                // back to the best evaluated parameters — never hand the
                // caller a non-finite approximation
                q.params
                    .clone_from(best_params.as_ref().unwrap_or(&init_params));
                break;
            }
            if it % self.eval_every == 0 || it == self.max_iters {
                // convergence + best-params tracking always run on the
                // full-data ELBO (mb = None), so a minibatched fit cannot
                // converge onto subsampling noise
                let (elbo, se) = self.estimate_elbo(
                    ld,
                    None,
                    &q,
                    self.elbo_samples,
                    rng,
                    &mut scratch,
                    &mut n_logp,
                );
                trace.push((it, elbo));
                if elbo.is_finite() && elbo > best.0 {
                    best = (elbo, se);
                    best_params = Some(q.params.clone());
                }
                if let Some((pe, pse)) = prev {
                    let delta = elbo - pe;
                    let rel = delta.abs() / pe.abs().max(elbo.abs()).max(1.0);
                    // converged when the ELBO change is either small
                    // relative to its level or indistinguishable from the
                    // Monte-Carlo noise of the two estimates
                    let noise = (se * se + pse * pse).sqrt();
                    if elbo.is_finite() && (rel < self.tol_rel || delta.abs() <= noise) {
                        hits += 1;
                    } else {
                        hits = 0;
                    }
                    if hits >= 2 {
                        converged = true;
                    }
                }
                prev = Some((elbo, se));
                if converged {
                    break;
                }
            }
        }

        if let Some(p) = best_params {
            q.params = p;
        }
        ViFit {
            approx: q,
            elbo_trace: trace,
            elbo: best.0,
            elbo_se: best.1,
            converged,
            iters: iters_run,
            eta,
            eta_search_failed,
            minibatch: mb.map(|t| t.batch),
            n_grad_evals: n_grad,
            n_logp_evals: n_logp,
            rejected_steps,
            wall_secs: t_start.elapsed().as_secs_f64(),
            opt_wall_secs: t_opt.elapsed().as_secs_f64(),
        }
    }

    /// One stochastic-ascent step. With a minibatch target, one seeded
    /// block is drawn for the whole step and every MC sample differentiates
    /// the block's [`Context::Subsample`] density. Returns `false` when
    /// every MC draw was rejected (non-finite logp or gradient) and no
    /// update was applied.
    #[allow(clippy::too_many_arguments)]
    fn grad_step<R: RngCore>(
        &self,
        full: &dyn LogDensity,
        mb: Option<&MinibatchTarget>,
        q: &mut VarApprox,
        opt: &mut Optimizer,
        rng: &mut R,
        s: &mut FitScratch,
        n_grad: &mut u64,
    ) -> bool {
        let block_ld = mb.map(|t| {
            metrics::inc(Counter::MinibatchWindows);
            t.block(draw_block(rng, t.n_blocks()))
        });
        let ld: &dyn LogDensity = match &block_ld {
            Some(b) => b,
            None => full,
        };
        s.grad.fill(0.0);
        let mut used = 0usize;
        let samples = self.grad_samples.max(1);
        let k = self.lanes.clamp(1, samples);
        if k > 1 {
            // batched: draw all base noise first (gradient evaluations
            // consume no randomness, so the η stream matches the
            // sequential loop exactly), evaluate in K-lane chunks, then
            // accumulate in draw order — bit-identical to the loop below
            let dim = q.dim;
            for i in 0..samples {
                let (eta, z) = (
                    &mut s.betas[i * dim..(i + 1) * dim],
                    &mut s.bzs[i * dim..(i + 1) * dim],
                );
                q.draw(rng, eta, z);
            }
            let mut lo = 0usize;
            while lo < samples {
                let hi = (lo + k).min(samples);
                ld.logp_grad_batch_into(
                    &s.bzs[lo * dim..hi * dim],
                    &mut s.blps[lo..hi],
                    &mut s.bglps[lo * dim..hi * dim],
                );
                *n_grad += (hi - lo) as u64;
                lo = hi;
            }
            for i in 0..samples {
                let glp = &s.bglps[i * dim..(i + 1) * dim];
                if !s.blps[i].is_finite() || glp.iter().any(|g| !g.is_finite()) {
                    continue;
                }
                q.accumulate_grad(
                    &s.betas[i * dim..(i + 1) * dim],
                    glp,
                    self.stl,
                    &mut s.bracket,
                    &mut s.grad,
                );
                used += 1;
            }
        } else {
            for _ in 0..samples {
                q.draw(rng, &mut s.eta, &mut s.z);
                let lp = ld.logp_grad_into(&s.z, &mut s.glp);
                *n_grad += 1;
                if !lp.is_finite() || s.glp.iter().any(|g| !g.is_finite()) {
                    continue;
                }
                q.accumulate_grad(&s.eta, &s.glp, self.stl, &mut s.bracket, &mut s.grad);
                used += 1;
            }
        }
        if used == 0 {
            return false;
        }
        let inv = 1.0 / used as f64;
        s.grad.iter_mut().for_each(|g| *g *= inv);
        if !self.stl {
            q.add_entropy_grad(&mut s.grad);
        }
        opt.step(&mut q.params, &s.grad);
        true
    }

    /// Monte-Carlo ELBO estimate with its standard error: the entropy is
    /// analytic, so only E_q[log p] is sampled. Draws go through the
    /// fit's scratch buffers — monitoring stays allocation-free too.
    /// With a minibatch target the estimator is subsampling-corrected:
    /// each MC sample scores one seeded block's `Subsample` density, an
    /// unbiased (over z *and* block) estimate of E_q[log p] whose extra
    /// variance shows up honestly in the reported SE.
    #[allow(clippy::too_many_arguments)]
    fn estimate_elbo<R: RngCore>(
        &self,
        ld: &dyn LogDensity,
        mb: Option<&MinibatchTarget>,
        q: &VarApprox,
        n_samples: usize,
        rng: &mut R,
        s: &mut FitScratch,
        n_logp: &mut u64,
    ) -> (f64, f64) {
        let n = n_samples.max(2);
        let mut acc = crate::util::stats::RunningStats::new();
        for _ in 0..n {
            q.draw(rng, &mut s.eta, &mut s.z);
            let lp = match mb {
                Some(t) => t.block(draw_block(rng, t.n_blocks())).logp(&s.z),
                None => ld.logp(&s.z),
            };
            acc.push(lp);
            *n_logp += 1;
        }
        let mean = acc.mean();
        let se = (acc.variance() / n as f64).sqrt();
        (mean + q.entropy(), se)
    }

    /// Fit, then draw `iters` posterior samples — the [`RawDraws`]-shaped
    /// entry point [`SamplerKind::Advi`](crate::inference::SamplerKind)
    /// dispatches to. `warmup` is ignored: ADVI's "warmup" is the
    /// optimization itself, budgeted by `max_iters`.
    pub fn sample<R: RngCore + Clone>(
        &self,
        ld: &dyn LogDensity,
        theta0: &[f64],
        _warmup: usize,
        iters: usize,
        rng: &mut R,
    ) -> RawDraws {
        let fit = self.fit(ld, theta0, rng);
        fit.sample_raw(ld, iters, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gradient::{std_normal_density, FnDensity};
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats;

    #[test]
    fn meanfield_fits_standard_normal() {
        let ld = std_normal_density(3);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let fit = Advi::default().fit(&ld, &[0.5, -0.5, 0.2], &mut rng);
        assert!(fit.elbo.is_finite());
        for i in 0..3 {
            assert!(fit.approx.mu()[i].abs() < 0.08, "mu[{i}] = {}", fit.approx.mu()[i]);
            let sd = fit.approx.stddevs()[i];
            assert!((sd - 1.0).abs() < 0.12, "sd[{i}] = {sd}");
        }
        // the ELBO of the exact family at the optimum is the exact log
        // evidence of a normalized density: 0 here
        assert!(fit.elbo.abs() < 0.1, "elbo = {}", fit.elbo);
    }

    #[test]
    fn fullrank_recovers_correlation() {
        // N(0, Σ) with ρ = 0.8: logp = −½ zᵀΣ⁻¹z
        let rho: f64 = 0.8;
        let det = 1.0 - rho * rho;
        let ld = FnDensity {
            dim: 2,
            f: move |t: &[f64]| {
                -0.5 * (t[0] * t[0] - 2.0 * rho * t[0] * t[1] + t[1] * t[1]) / det
                    - 0.5 * det.ln()
                    - crate::util::math::LN_2PI
            },
            g: move |t: &[f64]| {
                (
                    -0.5 * (t[0] * t[0] - 2.0 * rho * t[0] * t[1] + t[1] * t[1]) / det
                        - 0.5 * det.ln()
                        - crate::util::math::LN_2PI,
                    vec![
                        -(t[0] - rho * t[1]) / det,
                        -(t[1] - rho * t[0]) / det,
                    ],
                )
            },
        };
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let advi = Advi {
            max_iters: 4000,
            ..Advi::fullrank()
        };
        let fit = advi.fit(&ld, &[0.3, 0.3], &mut rng);
        // marginal sds ≈ 1, implied correlation ≈ ρ
        let sd = fit.approx.stddevs();
        assert!((sd[0] - 1.0).abs() < 0.15, "{sd:?}");
        assert!((sd[1] - 1.0).abs() < 0.15, "{sd:?}");
        let l10 = fit.approx.params[4];
        let l00 = fit.approx.omega()[0].exp();
        let corr = l10 * l00 / (sd[0] * sd[1]);
        assert!((corr - rho).abs() < 0.12, "corr = {corr}");
        // exact family, normalized target → ELBO ≈ 0
        assert!(fit.elbo.abs() < 0.15, "elbo = {}", fit.elbo);
    }

    #[test]
    fn stl_fits_standard_normal_too() {
        let ld = std_normal_density(2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let advi = Advi {
            stl: true,
            ..Advi::default()
        };
        let fit = advi.fit(&ld, &[1.0, -1.0], &mut rng);
        for i in 0..2 {
            assert!(fit.approx.mu()[i].abs() < 0.1);
            assert!((fit.approx.stddevs()[i] - 1.0).abs() < 0.15);
        }
    }

    #[test]
    fn fit_is_bit_deterministic() {
        let ld = std_normal_density(2);
        let advi = Advi::default();
        let run = || {
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            advi.fit(&ld, &[0.2, 0.2], &mut rng)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.eta, b.eta);
        for (x, y) in a.approx.params.iter().zip(&b.approx.params) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.elbo.to_bits(), b.elbo.to_bits());
        assert_eq!(a.elbo_trace.len(), b.elbo_trace.len());
    }

    #[test]
    fn lane_batched_fit_is_bitwise_equal_to_sequential() {
        // batching the per-step MC gradient draws must not change the fit:
        // same η stream, same accumulation order, bit-equal parameters
        let ld = std_normal_density(3);
        let run = |lanes: usize| {
            let advi = Advi {
                grad_samples: 8,
                lanes,
                max_iters: 200,
                ..Advi::default()
            };
            let mut rng = Xoshiro256pp::seed_from_u64(11);
            advi.fit(&ld, &[0.4, -0.2, 0.1], &mut rng)
        };
        let seq = run(1);
        for lanes in [3, 8] {
            let bat = run(lanes);
            assert_eq!(seq.eta, bat.eta);
            assert_eq!(seq.elbo.to_bits(), bat.elbo.to_bits());
            for (x, y) in seq.approx.params.iter().zip(&bat.approx.params) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn sample_raw_draws_match_fit_moments() {
        let ld = std_normal_density(2);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let fit = Advi::default().fit(&ld, &[0.0, 0.0], &mut rng);
        let raw = fit.sample_raw(&ld, 8000, &mut rng);
        assert_eq!(raw.thetas.len(), 8000);
        assert_eq!(raw.stats.log_evidence.to_bits(), fit.elbo.to_bits());
        let x0: Vec<f64> = raw.thetas.iter().map(|t| t[0]).collect();
        assert!(stats::mean(&x0).abs() < 0.1);
        assert!((stats::variance(&x0) - 1.0).abs() < 0.2);
        assert!(raw.logps.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn eta_ladder_failure_falls_back_to_smallest_and_is_surfaced() {
        // a target that is −∞ everywhere: every ladder candidate rejects
        // every draw, so the search cannot score any candidate
        let ld = FnDensity {
            dim: 1,
            f: |_: &[f64]| f64::NEG_INFINITY,
            g: |_: &[f64]| (f64::NEG_INFINITY, vec![0.0]),
        };
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let advi = Advi {
            max_iters: 10,
            ..Advi::default()
        };
        let fit = advi.fit(&ld, &[0.0], &mut rng);
        assert!(fit.eta_search_failed, "failed search must be surfaced");
        let smallest = ETA_CANDIDATES.iter().copied().fold(f64::INFINITY, f64::min);
        assert_eq!(fit.eta, smallest, "fallback must be the smallest η");
        assert!(fit.approx.params.iter().all(|p| p.is_finite()));
        // a healthy fit does not set the flag
        let ok = Advi::default().fit(&std_normal_density(1), &[0.0], &mut rng);
        assert!(!ok.eta_search_failed);
        assert!(ok.minibatch.is_none());
    }

    #[test]
    fn rejected_draws_do_not_poison_the_fit() {
        // half-line target: logp = −∞ for x ≤ 0 (a hard support edge the
        // MC estimator must skip over, not propagate)
        let ld = FnDensity {
            dim: 1,
            f: |t: &[f64]| {
                if t[0] > 0.0 {
                    -(t[0] - 1.0) * (t[0] - 1.0)
                } else {
                    f64::NEG_INFINITY
                }
            },
            g: |t: &[f64]| {
                if t[0] > 0.0 {
                    (-(t[0] - 1.0) * (t[0] - 1.0), vec![-2.0 * (t[0] - 1.0)])
                } else {
                    (f64::NEG_INFINITY, vec![0.0])
                }
            },
        };
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let fit = Advi::default().fit(&ld, &[1.0], &mut rng);
        assert!(fit.approx.params.iter().all(|p| p.is_finite()));
        assert!((fit.approx.mu()[0] - 1.0).abs() < 0.2, "{}", fit.approx.mu()[0]);
    }
}
