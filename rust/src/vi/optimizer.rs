//! Stochastic-ascent optimizers for the ELBO, plus Stan's step-size
//! (η) search.
//!
//! Stan's ADVI uses a decayed-RMSProp schedule
//! ρ_k = η · k^(−½+ε) / (τ + √s_k) with s_k an exponential moving average
//! of squared gradients ([`OptimizerKind::RmsProp`], the default). Adam
//! is offered as the fixed-step alternative that modern deep-PPL stacks
//! default to. Both maximize (gradient *ascent*): callers hand in ∇ELBO.

/// Which update rule an [`Optimizer`] applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OptimizerKind {
    /// Stan's windowed-decay RMSProp (`eta` is Stan's η).
    #[default]
    RmsProp,
    /// Adam (Kingma & Ba 2015) with β₁ = 0.9, β₂ = 0.999.
    Adam,
}

impl OptimizerKind {
    pub fn label(&self) -> &'static str {
        match self {
            OptimizerKind::RmsProp => "rmsprop",
            OptimizerKind::Adam => "adam",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rmsprop" => OptimizerKind::RmsProp,
            "adam" => OptimizerKind::Adam,
            _ => return None,
        })
    }
}

/// Per-parameter optimizer state (first/second moment buffers reused
/// across steps — no steady-state allocation in the fit loop).
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub kind: OptimizerKind,
    /// Base step size (Stan's η for RMSProp, α for Adam).
    pub eta: f64,
    t: u64,
    /// Adam first moment / unused for RMSProp.
    m: Vec<f64>,
    /// Second-moment accumulator (Adam v / RMSProp s).
    v: Vec<f64>,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind, eta: f64, n_params: usize) -> Self {
        Self {
            kind,
            eta,
            t: 0,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
        }
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// One ascent step: `params += ρ_t ⊙ update(grad)`.
    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(params.len(), self.v.len());
        self.t += 1;
        let t = self.t as f64;
        match self.kind {
            OptimizerKind::RmsProp => {
                // Stan: s_1 = g², s_k = 0.1·g² + 0.9·s_{k−1};
                // ρ_k = η · k^(−½+ε) / (τ + √s_k), τ = 1.
                const ALPHA: f64 = 0.1;
                const TAU: f64 = 1.0;
                let decay = self.eta * t.powf(-0.5 + 1e-16);
                for i in 0..params.len() {
                    let g = grad[i];
                    self.v[i] = if self.t == 1 {
                        g * g
                    } else {
                        ALPHA * g * g + (1.0 - ALPHA) * self.v[i]
                    };
                    params[i] += decay * g / (TAU + self.v[i].sqrt());
                }
            }
            OptimizerKind::Adam => {
                const B1: f64 = 0.9;
                const B2: f64 = 0.999;
                const EPS: f64 = 1e-8;
                let bc1 = 1.0 - B1.powf(t);
                let bc2 = 1.0 - B2.powf(t);
                for i in 0..params.len() {
                    let g = grad[i];
                    self.m[i] = B1 * self.m[i] + (1.0 - B1) * g;
                    self.v[i] = B2 * self.v[i] + (1.0 - B2) * g * g;
                    let mhat = self.m[i] / bc1;
                    let vhat = self.v[i] / bc2;
                    params[i] += self.eta * mhat / (vhat.sqrt() + EPS);
                }
            }
        }
    }
}

/// Stan's η search ladder, largest first: each candidate is trialed for a
/// few iterations and the best-ELBO survivor wins.
pub const ETA_CANDIDATES: [f64; 5] = [100.0, 10.0, 1.0, 0.1, 0.01];

#[cfg(test)]
mod tests {
    use super::*;

    /// Both rules must climb a deterministic concave objective
    /// f(x) = −Σ (x_i − c_i)² from a cold start.
    #[test]
    fn optimizers_climb_quadratic() {
        let c = [3.0, -2.0];
        for kind in [OptimizerKind::RmsProp, OptimizerKind::Adam] {
            let mut opt = Optimizer::new(kind, 0.5, 2);
            let mut x = [0.0, 0.0];
            for _ in 0..4000 {
                let g = [-2.0 * (x[0] - c[0]), -2.0 * (x[1] - c[1])];
                opt.step(&mut x, &g);
            }
            assert!(
                (x[0] - c[0]).abs() < 0.05 && (x[1] - c[1]).abs() < 0.05,
                "{kind:?}: {x:?}"
            );
            assert_eq!(opt.steps(), 4000);
        }
    }

    #[test]
    fn rmsprop_decays_step_size() {
        // With a constant gradient the RMSProp step shrinks like k^{-1/2}.
        let mut opt = Optimizer::new(OptimizerKind::RmsProp, 1.0, 1);
        let mut x = [0.0];
        opt.step(&mut x, &[1.0]);
        let first = x[0];
        let mut prev = x[0];
        let mut last_delta = f64::INFINITY;
        for _ in 0..99 {
            opt.step(&mut x, &[1.0]);
            last_delta = x[0] - prev;
            prev = x[0];
        }
        assert!(last_delta > 0.0 && last_delta < first, "{last_delta} vs {first}");
    }

    #[test]
    fn kind_labels_roundtrip() {
        for k in [OptimizerKind::RmsProp, OptimizerKind::Adam] {
            assert_eq!(OptimizerKind::parse(k.label()), Some(k));
        }
        assert_eq!(OptimizerKind::parse("sgd"), None);
    }
}
