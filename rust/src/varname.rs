//! Run-time addressing of random variables (the paper's `VarName`).
//!
//! Each tilde statement creates a `VarName` holding the user-visible symbol
//! (e.g. `"w"`) plus optional indexing (e.g. `w[3]`, `theta[2][1]`). Symbols
//! are interned to small integers so hot-path comparisons and hashing are a
//! single integer op rather than a string hash — the Rust analogue of
//! Julia's `Symbol` type used by DynamicPPL.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Mutex, OnceLock};

/// Global symbol interner (std `OnceLock`; no external lazy-init crate).
static INTERNER: OnceLock<Mutex<Interner>> = OnceLock::new();

fn interner() -> &'static Mutex<Interner> {
    INTERNER.get_or_init(|| Mutex::new(Interner::default()))
}

#[derive(Default)]
struct Interner {
    map: HashMap<String, u32>,
    names: Vec<String>,
}

/// An interned symbol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Sym(u32);

impl Sym {
    /// Intern a string.
    pub fn new(s: &str) -> Sym {
        let mut int = interner().lock().unwrap();
        if let Some(&id) = int.map.get(s) {
            return Sym(id);
        }
        let id = int.names.len() as u32;
        int.names.push(s.to_string());
        int.map.insert(s.to_string(), id);
        Sym(id)
    }

    /// Resolve back to the string.
    pub fn as_str(&self) -> String {
        interner().lock().unwrap().names[self.0 as usize].clone()
    }

    pub fn id(&self) -> u32 {
        self.0
    }
}

impl fmt::Display for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.as_str())
    }
}

/// One indexing step applied to a symbol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Index {
    /// `x[i]` (0-based internally; display is 0-based too, unlike Julia).
    At(usize),
    /// `x[i, j]` for matrices.
    At2(usize, usize),
}

/// The address of a random variable: symbol + index path.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarName {
    sym: Sym,
    indices: Vec<Index>,
}

impl VarName {
    /// Plain variable `x`.
    pub fn new(sym: &str) -> Self {
        VarName {
            sym: Sym::new(sym),
            indices: Vec::new(),
        }
    }

    /// From an already-interned symbol (hot path: avoids the interner lock).
    pub fn from_sym(sym: Sym) -> Self {
        VarName {
            sym,
            indices: Vec::new(),
        }
    }

    /// Indexed variable `x[i]`.
    pub fn indexed(sym: &str, i: usize) -> Self {
        VarName {
            sym: Sym::new(sym),
            indices: vec![Index::At(i)],
        }
    }

    /// From interned symbol + index (hot path).
    pub fn from_sym_indexed(sym: Sym, i: usize) -> Self {
        VarName {
            sym,
            indices: vec![Index::At(i)],
        }
    }

    /// Append an index step, consuming self: `vn.index(3)` ⇒ `x[3]`.
    pub fn index(mut self, i: usize) -> Self {
        self.indices.push(Index::At(i));
        self
    }

    /// Append a 2-D index step: `x[i, j]`.
    pub fn index2(mut self, i: usize, j: usize) -> Self {
        self.indices.push(Index::At2(i, j));
        self
    }

    pub fn sym(&self) -> Sym {
        self.sym
    }

    pub fn indices(&self) -> &[Index] {
        &self.indices
    }

    /// True if `self` is `other` or an element of `other` (same symbol and
    /// `other` has no indices, or index path prefix match). Used by Gibbs to
    /// select which variables a sub-sampler owns.
    pub fn subsumed_by(&self, other: &VarName) -> bool {
        if self.sym != other.sym {
            return false;
        }
        if other.indices.is_empty() {
            return true;
        }
        self.indices.len() >= other.indices.len()
            && self.indices[..other.indices.len()] == other.indices[..]
    }

    /// Parse from display syntax: `w`, `w[3]`, `m[1,2]`, `z[2][0]`.
    pub fn parse(s: &str) -> Result<VarName, String> {
        let s = s.trim();
        let open = s.find('[');
        let (base, rest) = match open {
            None => (s, ""),
            Some(i) => (&s[..i], &s[i..]),
        };
        if base.is_empty()
            || !base
                .chars()
                .all(|c| c.is_alphanumeric() || c == '_' || c == '.')
            || base.chars().next().unwrap().is_numeric()
        {
            return Err(format!("invalid variable name: {s:?}"));
        }
        let mut vn = VarName::new(base);
        let mut rest = rest;
        while !rest.is_empty() {
            if !rest.starts_with('[') {
                return Err(format!("expected '[' in {s:?}"));
            }
            let close = rest
                .find(']')
                .ok_or_else(|| format!("unclosed '[' in {s:?}"))?;
            let inner = &rest[1..close];
            let parts: Vec<&str> = inner.split(',').map(|p| p.trim()).collect();
            match parts.len() {
                1 => {
                    let i: usize = parts[0]
                        .parse()
                        .map_err(|_| format!("bad index {:?} in {s:?}", parts[0]))?;
                    vn = vn.index(i);
                }
                2 => {
                    let i: usize = parts[0]
                        .parse()
                        .map_err(|_| format!("bad index {:?} in {s:?}", parts[0]))?;
                    let j: usize = parts[1]
                        .parse()
                        .map_err(|_| format!("bad index {:?} in {s:?}", parts[1]))?;
                    vn = vn.index2(i, j);
                }
                _ => return Err(format!("too many indices in {s:?}")),
            }
            rest = &rest[close + 1..];
        }
        Ok(vn)
    }
}

impl fmt::Display for VarName {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.sym)?;
        for idx in &self.indices {
            match idx {
                Index::At(i) => write!(f, "[{i}]")?,
                Index::At2(i, j) => write!(f, "[{i},{j}]")?,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_stable() {
        let a = Sym::new("w");
        let b = Sym::new("w");
        let c = Sym::new("s");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.as_str(), "w");
    }

    #[test]
    fn display_roundtrip() {
        for s in ["w", "w[3]", "m[1,2]", "z[2][0]", "theta_k[0]"] {
            let vn = VarName::parse(s).unwrap();
            assert_eq!(vn.to_string(), s.replace(" ", ""));
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(VarName::parse("").is_err());
        assert!(VarName::parse("1abc").is_err());
        assert!(VarName::parse("x[").is_err());
        assert!(VarName::parse("x[a]").is_err());
        assert!(VarName::parse("x[1,2,3]").is_err());
    }

    #[test]
    fn equality_and_hash() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(VarName::indexed("w", 0));
        set.insert(VarName::indexed("w", 1));
        set.insert(VarName::indexed("w", 0)); // duplicate
        assert_eq!(set.len(), 2);
        assert!(set.contains(&VarName::parse("w[1]").unwrap()));
    }

    #[test]
    fn subsumption() {
        let w = VarName::new("w");
        let w0 = VarName::indexed("w", 0);
        let s = VarName::new("s");
        assert!(w0.subsumed_by(&w));
        assert!(w.subsumed_by(&w));
        assert!(!w.subsumed_by(&w0));
        assert!(!w0.subsumed_by(&s));
        let m01 = VarName::new("m").index2(0, 1);
        assert!(m01.subsumed_by(&VarName::new("m")));
    }

    #[test]
    fn from_sym_fast_path() {
        let sym = Sym::new("h");
        let a = VarName::from_sym_indexed(sym, 4);
        let b = VarName::indexed("h", 4);
        assert_eq!(a, b);
    }
}
