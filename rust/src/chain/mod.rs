//! MCMC chain storage and diagnostics (the MCMCChains.jl analogue).
//!
//! A [`Chain`] holds constrained-space draws as rows (one column per scalar
//! parameter element, named like `w[3]`), the per-draw log-density, and
//! sampler statistics. [`MultiChain`] aggregates several chains for split-R̂.

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::util::stats;

/// Sampler-level statistics for one run.
#[derive(Clone, Debug)]
pub struct SamplerStats {
    pub accept_rate: f64,
    pub divergences: usize,
    pub step_size: f64,
    pub n_grad_evals: u64,
    pub wall_secs: f64,
    /// Wall-clock spent in warmup/adaptation iterations.
    pub warmup_secs: f64,
    /// Wall-clock spent in post-warmup sampling iterations.
    pub sampling_secs: f64,
    /// NUTS trajectories stopped by the max tree depth (post-warmup).
    pub max_treedepth_hits: usize,
    /// The ADVI η ladder found no finite candidate (fit may be bad).
    pub eta_search_failed: bool,
    /// Per-iteration Hamiltonian energies (post-warmup, HMC/NUTS only;
    /// recorded only while telemetry is enabled) — the E-BFMI input.
    pub energies: Vec<f64>,
    /// Telemetry counters drained from the chain's worker thread.
    pub metrics: crate::obs::metrics::MetricsSnapshot,
    /// log-marginal-likelihood estimate: particle samplers store their
    /// unbiased SMC estimate, VI chains the converged ELBO (a lower
    /// bound); `NaN` for samplers that do not estimate evidence.
    pub log_evidence: f64,
}

impl Default for SamplerStats {
    fn default() -> Self {
        Self {
            accept_rate: 0.0,
            divergences: 0,
            step_size: 0.0,
            n_grad_evals: 0,
            wall_secs: 0.0,
            warmup_secs: 0.0,
            sampling_secs: 0.0,
            max_treedepth_hits: 0,
            eta_search_failed: false,
            energies: Vec::new(),
            metrics: crate::obs::metrics::MetricsSnapshot::default(),
            log_evidence: f64::NAN,
        }
    }
}

/// One MCMC chain in constrained space.
#[derive(Clone, Debug)]
pub struct Chain {
    names: Vec<String>,
    index: HashMap<String, usize>,
    /// draws[i] is one row over all columns.
    draws: Vec<Vec<f64>>,
    /// log-density per draw.
    pub logp: Vec<f64>,
    pub stats: SamplerStats,
}

impl Chain {
    pub fn new(names: Vec<String>) -> Self {
        let index = names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), i))
            .collect();
        Self {
            names,
            index,
            draws: Vec::new(),
            logp: Vec::new(),
            stats: SamplerStats::default(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>, logp: f64) {
        debug_assert_eq!(row.len(), self.names.len());
        self.draws.push(row);
        self.logp.push(logp);
    }

    pub fn len(&self) -> usize {
        self.draws.len()
    }

    pub fn is_empty(&self) -> bool {
        self.draws.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn rows(&self) -> &[Vec<f64>] {
        &self.draws
    }

    /// Column by name (e.g. `"w[0]"`), as a fresh vector.
    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let &i = self.index.get(name)?;
        Some(self.draws.iter().map(|r| r[i]).collect())
    }

    /// All columns whose name starts with `sym` (`"w"` matches `w[0]`, `w[1]`, …).
    pub fn columns_of(&self, sym: &str) -> Vec<(String, Vec<f64>)> {
        let prefix_bracket = format!("{sym}[");
        self.names
            .iter()
            .enumerate()
            .filter(|(_, n)| n.as_str() == sym || n.starts_with(&prefix_bracket))
            .map(|(i, n)| (n.clone(), self.draws.iter().map(|r| r[i]).collect()))
            .collect()
    }

    pub fn mean(&self, name: &str) -> Option<f64> {
        self.column(name).map(|c| stats::mean(&c))
    }

    pub fn std(&self, name: &str) -> Option<f64> {
        self.column(name).map(|c| stats::std(&c))
    }

    pub fn quantile(&self, name: &str, q: f64) -> Option<f64> {
        self.column(name).map(|c| stats::quantile(&c, q))
    }

    pub fn ess(&self, name: &str) -> Option<f64> {
        self.column(name).map(|c| stats::ess(&c))
    }

    /// Drop the first `n` draws (warmup).
    pub fn discard_warmup(&mut self, n: usize) {
        let n = n.min(self.draws.len());
        self.draws.drain(..n);
        self.logp.drain(..n);
    }

    /// Keep every `k`-th draw.
    pub fn thin(&mut self, k: usize) {
        assert!(k >= 1);
        if k == 1 {
            return;
        }
        self.draws = self
            .draws
            .iter()
            .step_by(k)
            .cloned()
            .collect();
        self.logp = self.logp.iter().step_by(k).cloned().collect();
    }

    /// Formatted summary table: mean, std, 2.5%/50%/97.5% quantiles, ESS.
    /// Degenerate columns render finite numbers: a single-draw or
    /// constant column has sd 0 and ESS = draw count, not `NaN`.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        let w = self.names.iter().map(|n| n.len()).max().unwrap_or(5).max(5);
        let _ = writeln!(
            out,
            "{:<w$} {:>10} {:>10} {:>10} {:>10} {:>10} {:>8}",
            "param", "mean", "std", "2.5%", "50%", "97.5%", "ess"
        );
        for name in &self.names {
            let c = self.column(name).unwrap();
            // sample sd of a single draw is undefined (NaN); the spread
            // of the summarized draws is genuinely 0
            let sd = stats::std(&c);
            let sd = if sd.is_finite() { sd } else { 0.0 };
            let _ = writeln!(
                out,
                "{:<w$} {:>10.4} {:>10.4} {:>10.4} {:>10.4} {:>10.4} {:>8.1}",
                name,
                stats::mean(&c),
                sd,
                stats::quantile(&c, 0.025),
                stats::quantile(&c, 0.5),
                stats::quantile(&c, 0.975),
                stats::ess(&c),
            );
        }
        if self.stats.wall_secs > 0.0 {
            let _ = writeln!(
                out,
                "wall: {:.2}s (warmup {:.2}s + sampling {:.2}s)",
                self.stats.wall_secs, self.stats.warmup_secs, self.stats.sampling_secs
            );
        }
        out
    }
}

/// Several chains of the same model (for split-R̂ and pooled estimates).
#[derive(Clone, Debug)]
pub struct MultiChain {
    pub chains: Vec<Chain>,
}

impl MultiChain {
    pub fn new(chains: Vec<Chain>) -> Self {
        assert!(!chains.is_empty());
        let names = chains[0].names().to_vec();
        for c in &chains[1..] {
            assert_eq!(c.names(), &names[..], "chains disagree on columns");
        }
        Self { chains }
    }

    /// Split-R̂ with rank normalization (Vehtari et al. 2021): the default
    /// diagnostic. Rank-normalizing before the Gelman–Rubin computation
    /// makes the statistic robust to heavy tails and sensitive to
    /// single-chain non-stationarity (trends split across halves).
    pub fn rhat(&self, name: &str) -> Option<f64> {
        self.rhat_with(name, true)
    }

    /// Classic (non-rank-normalized) split-R̂ — the pre-2021 behavior,
    /// kept for comparisons and regression baselines.
    pub fn rhat_classic(&self, name: &str) -> Option<f64> {
        self.rhat_with(name, false)
    }

    /// Split-R̂ with rank normalization toggled by `rank_normalized`.
    pub fn rhat_with(&self, name: &str, rank_normalized: bool) -> Option<f64> {
        let cols: Vec<Vec<f64>> = self
            .chains
            .iter()
            .map(|c| c.column(name))
            .collect::<Option<_>>()?;
        let refs: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
        Some(if rank_normalized {
            stats::rank_normalized_split_rhat(&refs)
        } else {
            stats::split_rhat(&refs)
        })
    }

    /// Pooled log-evidence across chains: the log-mean-exp of the
    /// per-chain estimates (each chain's particle run is an independent
    /// unbiased estimator of the marginal likelihood, so averaging in
    /// probability space is the right aggregation). `None` when no chain
    /// carries an estimate.
    pub fn log_evidence(&self) -> Option<f64> {
        let finite: Vec<f64> = self
            .chains
            .iter()
            .map(|c| c.stats.log_evidence)
            .filter(|l| !l.is_nan())
            .collect();
        if finite.is_empty() {
            return None;
        }
        Some(crate::util::math::log_sum_exp(&finite) - (finite.len() as f64).ln())
    }

    /// Pooled posterior mean across chains.
    pub fn mean(&self, name: &str) -> Option<f64> {
        let mut acc = 0.0;
        let mut n = 0usize;
        for c in &self.chains {
            let col = c.column(name)?;
            acc += col.iter().sum::<f64>();
            n += col.len();
        }
        Some(acc / n as f64)
    }

    /// Total ESS (sum over chains).
    pub fn ess(&self, name: &str) -> Option<f64> {
        let mut acc = 0.0;
        for c in &self.chains {
            acc += c.ess(name)?;
        }
        Some(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn demo_chain(seed: u64, shift: f64) -> Chain {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut c = Chain::new(vec!["a".into(), "b[0]".into(), "b[1]".into()]);
        for _ in 0..2000 {
            let a = rng.normal() + shift;
            c.push(vec![a, rng.normal() * 2.0, rng.normal() - 1.0], -a * a);
        }
        c
    }

    #[test]
    fn column_access_and_moments() {
        let c = demo_chain(1, 0.0);
        assert_eq!(c.len(), 2000);
        assert!(c.mean("a").unwrap().abs() < 0.1);
        assert!((c.std("b[0]").unwrap() - 2.0).abs() < 0.15);
        assert!((c.mean("b[1]").unwrap() + 1.0).abs() < 0.1);
        assert!(c.column("nope").is_none());
    }

    #[test]
    fn columns_of_groups_elements() {
        let c = demo_chain(2, 0.0);
        let cols = c.columns_of("b");
        assert_eq!(cols.len(), 2);
        assert_eq!(cols[0].0, "b[0]");
        let cols = c.columns_of("a");
        assert_eq!(cols.len(), 1);
    }

    #[test]
    fn warmup_and_thin() {
        let mut c = demo_chain(3, 0.0);
        c.discard_warmup(500);
        assert_eq!(c.len(), 1500);
        c.thin(3);
        assert_eq!(c.len(), 500);
        assert_eq!(c.logp.len(), 500);
    }

    #[test]
    fn quantiles_are_ordered() {
        let c = demo_chain(4, 0.0);
        let lo = c.quantile("a", 0.025).unwrap();
        let mid = c.quantile("a", 0.5).unwrap();
        let hi = c.quantile("a", 0.975).unwrap();
        assert!(lo < mid && mid < hi);
        // standard normal quantiles approximately
        assert!((lo + 1.96).abs() < 0.2, "{lo}");
        assert!((hi - 1.96).abs() < 0.2, "{hi}");
    }

    #[test]
    fn multichain_rhat() {
        let good = MultiChain::new(vec![demo_chain(5, 0.0), demo_chain(6, 0.0)]);
        assert!((good.rhat("a").unwrap() - 1.0).abs() < 0.02);
        let bad = MultiChain::new(vec![demo_chain(7, 0.0), demo_chain(8, 4.0)]);
        assert!(bad.rhat("a").unwrap() > 1.5);
        // classic flag preserved for baselines; also flags separation
        assert!(bad.rhat_classic("a").unwrap() > 1.5);
        assert!((good.rhat_classic("a").unwrap() - 1.0).abs() < 0.02);
        assert!((good.rhat_with("a", false).unwrap()
            - good.rhat_classic("a").unwrap())
        .abs()
            < 1e-12);
    }

    #[test]
    fn multichain_pools_log_evidence() {
        let mut a = demo_chain(10, 0.0);
        let mut b = demo_chain(11, 0.0);
        // no chain has an estimate → None
        let mc = MultiChain::new(vec![a.clone(), b.clone()]);
        assert!(mc.log_evidence().is_none());
        // log-mean-exp of per-chain estimates
        a.stats.log_evidence = -10.0;
        b.stats.log_evidence = -12.0;
        let mc = MultiChain::new(vec![a.clone(), b.clone()]);
        let expect = crate::util::math::log_sum_exp(&[-10.0, -12.0]) - 2f64.ln();
        assert!((mc.log_evidence().unwrap() - expect).abs() < 1e-12);
        // NaN chains are ignored, not propagated
        b.stats.log_evidence = f64::NAN;
        let mc = MultiChain::new(vec![a, b]);
        assert!((mc.log_evidence().unwrap() + 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_all_params() {
        let c = demo_chain(9, 0.0);
        let s = c.summary();
        assert!(s.contains("b[0]") && s.contains("b[1]") && s.contains("ess"));
    }

    #[test]
    fn summary_includes_wall_clock_split() {
        let mut c = demo_chain(12, 0.0);
        let s = c.summary();
        assert!(!s.contains("wall:"), "no timing line without wall_secs");
        c.stats.wall_secs = 2.0;
        c.stats.warmup_secs = 0.5;
        c.stats.sampling_secs = 1.5;
        let s = c.summary();
        assert!(s.contains("wall: 2.00s (warmup 0.50s + sampling 1.50s)"), "{s}");
    }

    #[test]
    fn summary_of_degenerate_columns_is_finite() {
        // a constant column and a single-draw chain both used to render
        // NaN cells (std / ESS); summaries must stay finite
        let mut c = Chain::new(vec!["a".into(), "k".into()]);
        for _ in 0..50 {
            c.push(vec![1.25, 0.1], -1.0);
        }
        let s = c.summary();
        assert!(!s.contains("NaN"), "degenerate summary has NaN:\n{s}");
        assert_eq!(c.ess("k").unwrap(), 50.0);
        let mut single = Chain::new(vec!["x".into()]);
        single.push(vec![2.0], -0.5);
        let s = single.summary();
        assert!(!s.contains("NaN"), "single-draw summary has NaN:\n{s}");
    }

    #[test]
    fn rhat_of_degenerate_multichain_is_one() {
        let mk = || {
            let mut c = Chain::new(vec!["k".into()]);
            for _ in 0..100 {
                c.push(vec![0.1], 0.0);
            }
            c
        };
        let mc = MultiChain::new(vec![mk(), mk()]);
        assert_eq!(mc.rhat("k").unwrap(), 1.0);
        assert_eq!(mc.rhat_classic("k").unwrap(), 1.0);
    }

    #[test]
    #[should_panic(expected = "disagree")]
    fn multichain_rejects_mismatched_columns() {
        let a = Chain::new(vec!["x".into()]);
        let b = Chain::new(vec!["y".into()]);
        let _ = MultiChain::new(vec![a, b]);
    }
}
