//! `dppl` — the leader binary: CLI over the coordinator.
//!
//! The binary is self-contained at run time: it loads AOT artifacts from
//! `artifacts/` (built once by `make artifacts`) and never invokes Python.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(dynamicppl::coordinator::run(argv));
}
