//! # dynamicppl — Stan-like speed for dynamic probabilistic models
//!
//! A reproduction of *DynamicPPL: Stan-like Speed for Dynamic Probabilistic
//! Models* (Tarek, Xu, Trapp, Ge, Ghahramani, 2020) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the probabilistic-programming runtime: tilde-DSL
//!   models, `VarName` addressing, untyped→typed trace specialization
//!   (`varinfo`), execution contexts, three inference families (MCMC:
//!   MH/HMC/NUTS/Gibbs; SMC: particle filters + Particle-Gibbs; VI: ADVI
//!   over the fused gradient path), chains and probability queries, plus
//!   the benchmark coordinator.
//! - **L2 (python/compile, build-time)** — each benchmark model's
//!   unconstrained log-joint and gradient written in JAX, AOT-lowered to
//!   HLO text artifacts.
//! - **L1 (python/compile/kernels, build-time)** — Pallas kernels for the
//!   density hot-spots, validated against pure-jnp oracles.
//!
//! At run time the Rust binary is self-contained: artifacts are loaded and
//! executed through the PJRT CPU client (`runtime`); Python never runs on
//! the sampling path.

pub mod ad;
pub mod analysis;
pub mod bench;
pub mod chain;
pub mod context;
pub mod coordinator;
pub mod dist;
pub mod gradient;
pub mod inference;
#[macro_use]
pub mod model;
pub mod models;
pub mod obs;
pub mod particle;
pub mod query;
pub mod runtime;
pub mod serve;
pub mod stanlike;
pub mod util;
pub mod value;
pub mod varinfo;
pub mod varname;
pub mod vi;

pub use value::Value;
pub use varname::{Sym, VarName};

/// Convenience re-exports for model authors and examples.
pub mod prelude {
    pub use crate::ad::arena::AVar;
    pub use crate::ad::forward::Dual;
    pub use crate::ad::reverse::TVar;
    pub use crate::ad::Scalar;
    pub use crate::context::Context;
    pub use crate::dist::*;
    pub use crate::model::macros::c;
    pub use crate::model::{
        init_trace, init_typed, sample_run, typed_grad_forward, typed_grad_fused,
        typed_grad_fused_into, typed_grad_reverse, typed_logp, untyped_grad_forward,
        untyped_grad_fused, untyped_grad_fused_into, untyped_grad_reverse, untyped_logp, Model,
        TildeApi,
    };
    pub use crate::util::rng::{Rng, Xoshiro256pp};
    pub use crate::value::Value;
    pub use crate::vi::{Advi, ViFamily};
    pub use crate::varinfo::{TypedVarInfo, UntypedVarInfo};
    pub use crate::varname::{Sym, VarName};
    pub use crate::{
        check_reject, model, obs, obs_iid, obs_int, obs_int_iid, obs_vec, tilde, tilde_int,
        tilde_vec,
    };
}
