//! Particle-inference substrate: weighted trace clouds with cheap forking.
//!
//! This is the subsystem the paper's trace machinery was built to enable
//! (§3.3: the `del`/`RESAMPLE` flag exists for particle samplers): a
//! [`ParticleCloud`] holds N execution traces ([`UntypedVarInfo`]) with
//! normalized log-weights and advances them one *observe statement* at a
//! time by whole-body re-execution under [`Context::ObsWindow`] — the
//! replay-with-regenerate mode implemented by [`exec::ReplayExecutor`].
//!
//! Per step the cloud:
//! 1. **propagates** every particle in parallel ([`parallel_for_each_mut`];
//!    bitwise-deterministic for a fixed seed regardless of thread count,
//!    because each particle's RNG is derived from `(seed, step, index)`
//!    and all weight reductions run serially on the caller thread);
//! 2. **reweights** by the window's incremental log-likelihood and folds
//!    the normalizer into a running log-marginal-likelihood (evidence)
//!    estimate `log Ẑ = Σ_t log Σ_i W_i·w_i^{(t)}`;
//! 3. optionally **resamples** (ESS-triggered) by forking ancestor traces
//!    and flagging each fork's unscored suffix for regeneration, which
//!    restores particle diversity exactly the way Turing's `Trace` copy +
//!    `del` flag does.
//!
//! A cloud can be *scoped* to a subset of variables (Particle-Gibbs /
//! conditional SMC): out-of-scope variables are never flagged, so every
//! replay reproduces them bit-for-bit and the cloud targets their full
//! conditional.

pub mod exec;
pub mod resample;

pub use exec::{ReplayExecutor, ReplayReport};
pub use resample::{ess, normalize_log_weights, Resampler};

use rand_core::RngCore;

use crate::context::Context;
use crate::model::Model;
use crate::util::math;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_for_each_mut;
use crate::varinfo::{flags, UntypedVarInfo};
use crate::varname::VarName;

/// One weighted execution trace.
#[derive(Clone, Debug)]
pub struct Particle {
    /// The trace (complete model execution; replayed/regenerated per step).
    pub trace: UntypedVarInfo,
    /// Normalized log-weight (log-sum-exp over the cloud ≈ 0).
    pub log_weight: f64,
    /// Last step's incremental log-likelihood.
    pub delta: f64,
    /// Retained-prefix record count after the last advance: records at
    /// index ≥ `prefix` have not been scored and may be regenerated.
    pub prefix: usize,
}

/// Count the observe statements `model` visits when replaying `trace`
/// (one scratch whole-body replay; the trace must be complete).
pub fn count_observes(model: &dyn Model, trace: &UntypedVarInfo) -> usize {
    let mut probe = trace.clone();
    let mut rng = Xoshiro256pp::seed_from_u64(0);
    ReplayExecutor::run(
        model,
        &mut rng,
        &mut probe,
        Context::ObsWindow { lo: 0, hi: 0 },
        None,
    )
    .obs_total
}

/// Derive a particle-local RNG seed from `(run seed, step, index)`.
/// Stable across thread counts — the basis of deterministic parallelism.
pub fn particle_seed(seed: u64, step: usize, index: usize) -> u64 {
    let mut x = seed ^ 0xA5A5_5A5A_DEAD_BEEF;
    x = x.wrapping_add((step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    x = x.wrapping_add((index as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// A cloud of weighted particles stepping through a model's observations.
#[derive(Clone, Debug)]
pub struct ParticleCloud {
    pub particles: Vec<Particle>,
    /// Running log-marginal-likelihood (evidence) estimate.
    pub log_evidence: f64,
    /// Next observe index to score (completed steps so far).
    pub step: usize,
    /// Total observe statements of the model (SMC step count).
    pub n_obs: usize,
    /// Restrict regeneration to these variables (Particle-Gibbs scope);
    /// `None` = every variable participates (plain SMC).
    pub scope: Option<Vec<VarName>>,
}

impl ParticleCloud {
    /// Bootstrap initialization: N empty traces, each populated by one
    /// prior run (window `[0,0)` scores nothing). Deterministic in `seed`.
    pub fn from_prior(model: &dyn Model, n: usize, seed: u64, threads: usize) -> Self {
        assert!(n >= 2, "a particle cloud needs at least 2 particles");
        let mut particles: Vec<Particle> = (0..n)
            .map(|_| Particle {
                trace: UntypedVarInfo::new(),
                log_weight: -(n as f64).ln(),
                delta: 0.0,
                prefix: 0,
            })
            .collect();
        let mut n_obs_per: Vec<usize> = vec![0; n];
        {
            let n_obs_slots = std::sync::Mutex::new(&mut n_obs_per);
            parallel_for_each_mut(threads, &mut particles, |i, p| {
                let mut rng = Xoshiro256pp::seed_from_u64(particle_seed(seed, 0, i));
                let rep = ReplayExecutor::run(
                    model,
                    &mut rng,
                    &mut p.trace,
                    Context::ObsWindow { lo: 0, hi: 0 },
                    None,
                );
                p.prefix = rep.prefix_records;
                n_obs_slots.lock().unwrap()[i] = rep.obs_total;
            });
        }
        let n_obs = n_obs_per.into_iter().max().unwrap_or(0);
        ParticleCloud {
            particles,
            log_evidence: 0.0,
            step: 0,
            n_obs,
            scope: None,
        }
    }

    /// Conditional (CSMC) initialization for Particle-Gibbs: particle 0 is
    /// the retained reference trajectory; particles 1..n fork it with all
    /// `scope` variables flagged, so the first advance regenerates them
    /// from the prior while out-of-scope variables replay exactly.
    ///
    /// `n_obs` is the model's observe-statement count; pass `None` to
    /// probe it with one scratch replay, or `Some` (from
    /// [`count_observes`], computed once) when sweeping repeatedly.
    pub fn conditional(
        model: &dyn Model,
        reference: &UntypedVarInfo,
        scope: &[VarName],
        n: usize,
        seed: u64,
        n_obs: Option<usize>,
    ) -> Self {
        assert!(n >= 2, "conditional SMC needs at least 2 particles");
        assert!(!scope.is_empty(), "conditional SMC needs a variable scope");
        let _ = seed;
        let log_w0 = -(n as f64).ln();
        let mut particles = Vec::with_capacity(n);
        for j in 0..n {
            let mut trace = reference.clone();
            // fresh sweep: no record is scored yet, and the reference must
            // replay exactly — scrub stale particle flags either way
            trace.clear_flag_all(flags::RESAMPLE | flags::LOCKED);
            if j > 0 {
                trace.flag_suffix(0, Some(scope), flags::RESAMPLE);
            }
            particles.push(Particle {
                trace,
                log_weight: log_w0,
                delta: 0.0,
                prefix: 0,
            });
        }
        let n_obs = n_obs.unwrap_or_else(|| count_observes(model, reference));
        ParticleCloud {
            particles,
            log_evidence: 0.0,
            step: 0,
            n_obs,
            scope: Some(scope.to_vec()),
        }
    }

    pub fn len(&self) -> usize {
        self.particles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.particles.is_empty()
    }

    /// Normalized weights (probabilities).
    pub fn weights(&self) -> Vec<f64> {
        let logw: Vec<f64> = self.particles.iter().map(|p| p.log_weight).collect();
        normalize_log_weights(&logw).0
    }

    /// Effective sample size of the current weights.
    pub fn ess(&self) -> f64 {
        ess(&self.weights())
    }

    /// Propagate every particle through the next observe window, update
    /// weights and the running evidence estimate. Returns the step's
    /// log-normalizer `log Σ_i W_i·w_i`.
    pub fn advance(&mut self, model: &dyn Model, seed: u64, threads: usize) -> f64 {
        assert!(self.step < self.n_obs, "cloud already consumed all observations");
        let (lo, hi) = (self.step, self.step + 1);
        let step_for_seed = self.step + 1; // 0 is the init run
        let scope = self.scope.clone();
        parallel_for_each_mut(threads, &mut self.particles, |i, p| {
            let mut rng =
                Xoshiro256pp::seed_from_u64(particle_seed(seed, step_for_seed, i));
            let rep = ReplayExecutor::run(
                model,
                &mut rng,
                &mut p.trace,
                Context::ObsWindow { lo, hi },
                scope.as_deref(),
            );
            p.delta = rep.delta_logw;
            p.prefix = rep.prefix_records;
        });
        // serial reduction (index order → deterministic)
        let logw_new: Vec<f64> = self
            .particles
            .iter()
            .map(|p| p.log_weight + p.delta)
            .collect();
        let lz_step = math::log_sum_exp(&logw_new);
        self.log_evidence += lz_step;
        if lz_step == f64::NEG_INFINITY {
            // every particle died: reset to uniform (evidence is −∞ now)
            let lw = -(self.len() as f64).ln();
            for p in &mut self.particles {
                p.log_weight = lw;
            }
        } else {
            for (p, lw) in self.particles.iter_mut().zip(logw_new) {
                p.log_weight = lw - lz_step;
            }
        }
        self.step += 1;
        lz_step
    }

    /// Fork a new generation from ancestors drawn by `resampler`; children
    /// get uniform weights and their unscored suffix flagged for
    /// regeneration (scope-restricted when the cloud is conditional).
    /// With `conditional`, particle 0's ancestor is pinned to the
    /// reference (index 0) and its trace is forked unflagged.
    pub fn resample<R: RngCore>(&mut self, resampler: Resampler, conditional: bool, rng: &mut R) {
        let n = self.len();
        let weights = self.weights();
        let mut ancestors = resampler.ancestors(&weights, n, rng);
        if conditional {
            ancestors[0] = 0;
        }
        let scope = self.scope.clone();
        let log_w0 = -(n as f64).ln();
        let new: Vec<Particle> = ancestors
            .iter()
            .enumerate()
            .map(|(j, &a)| {
                let src = &self.particles[a];
                let mut trace = src.trace.clone();
                if !(conditional && j == 0) {
                    // regenerate everything not yet scored (scope-bounded)
                    trace.flag_unlocked(scope.as_deref(), flags::RESAMPLE);
                }
                Particle {
                    trace,
                    log_weight: log_w0,
                    delta: src.delta,
                    prefix: src.prefix,
                }
            })
            .collect();
        self.particles = new;
    }

    /// Resample only when ESS drops below `threshold_frac · N`. Returns
    /// whether a resampling pass happened.
    pub fn maybe_resample<R: RngCore>(
        &mut self,
        resampler: Resampler,
        threshold_frac: f64,
        conditional: bool,
        rng: &mut R,
    ) -> bool {
        if self.ess() < threshold_frac * self.len() as f64 {
            self.resample(resampler, conditional, rng);
            true
        } else {
            false
        }
    }

    /// Draw one trace index from the final weights (the Particle-Gibbs
    /// selection step).
    pub fn select<R: RngCore>(&self, rng: &mut R) -> usize {
        use crate::util::rng::Rng as _;
        rng.categorical(&self.weights())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prelude::*;

    model! {
        /// m ~ N(0,1); y_t ~ N(m, 1) — one observe statement per data
        /// point, the canonical SMC stepping structure.
        pub IidNormal {
            y: Vec<f64>,
        }
        fn body<T>(this, api) {
            let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
            for &yi in &this.y {
                obs!(api, yi => Normal(m, c(1.0)));
            }
        }
    }

    #[test]
    fn from_prior_counts_observations() {
        let m = IidNormal { y: vec![0.1, -0.2, 0.3] };
        let cloud = ParticleCloud::from_prior(&m, 8, 11, 1);
        assert_eq!(cloud.n_obs, 3);
        assert_eq!(cloud.len(), 8);
        assert_eq!(cloud.step, 0);
        let w = cloud.weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((cloud.ess() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn advance_accumulates_evidence_and_reweights() {
        let m = IidNormal { y: vec![0.5, -0.5] };
        let mut cloud = ParticleCloud::from_prior(&m, 64, 3, 1);
        let lz0 = cloud.advance(&m, 3, 1);
        assert!(lz0.is_finite() && lz0 < 0.0);
        assert_eq!(cloud.step, 1);
        assert!((cloud.log_evidence - lz0).abs() < 1e-12);
        // weights renormalized
        let w = cloud.weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-10);
        let _ = cloud.advance(&m, 3, 1);
        assert_eq!(cloud.step, 2);
        assert!(cloud.log_evidence < lz0);
    }

    #[test]
    fn resample_forks_and_uniformizes() {
        let m = IidNormal { y: vec![2.0, 2.0, 2.0] };
        let mut cloud = ParticleCloud::from_prior(&m, 32, 5, 1);
        let _ = cloud.advance(&m, 5, 1);
        let ess_before = cloud.ess();
        assert!(ess_before < 32.0);
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        cloud.resample(Resampler::Systematic, false, &mut rng);
        assert!((cloud.ess() - 32.0).abs() < 1e-9, "uniform after resample");
        // maybe_resample: ESS is maximal now → no-op
        assert!(!cloud.maybe_resample(Resampler::Systematic, 0.5, false, &mut rng));
    }

    #[test]
    fn particle_seed_is_stable_and_index_sensitive() {
        assert_eq!(particle_seed(1, 2, 3), particle_seed(1, 2, 3));
        assert_ne!(particle_seed(1, 2, 3), particle_seed(1, 2, 4));
        assert_ne!(particle_seed(1, 2, 3), particle_seed(1, 3, 3));
        assert_ne!(particle_seed(1, 2, 3), particle_seed(2, 2, 3));
    }

    #[test]
    fn conditional_cloud_keeps_reference_trajectory() {
        let m = IidNormal { y: vec![0.3, 0.7] };
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let reference = crate::model::init_trace(&m, &mut rng);
        let m_ref = reference
            .get(&VarName::new("m"))
            .unwrap()
            .value
            .as_f64()
            .unwrap();
        let scope = [VarName::new("m")];
        assert_eq!(count_observes(&m, &reference), 2);
        let mut cloud = ParticleCloud::conditional(&m, &reference, &scope, 16, 77, None);
        assert_eq!(cloud.n_obs, 2);
        let m_of = |cloud: &ParticleCloud, j: usize| -> f64 {
            cloud.particles[j]
                .trace
                .get(&VarName::new("m"))
                .unwrap()
                .value
                .as_f64()
                .unwrap()
        };

        // step 0: non-reference particles regenerate m from the prior
        let _ = cloud.advance(&m, 77, 1);
        assert_eq!(m_of(&cloud, 0), m_ref, "reference must replay exactly");
        assert!(
            cloud.particles[1..]
                .iter()
                .enumerate()
                .any(|(j, _)| m_of(&cloud, j + 1) != m_ref),
            "non-reference particles must regenerate their scoped variable"
        );

        // conditional resampling pins the reference at index 0
        let mut r = Xoshiro256pp::seed_from_u64(123);
        cloud.resample(Resampler::Systematic, true, &mut r);
        assert_eq!(m_of(&cloud, 0), m_ref);

        // and it survives the next advance untouched
        let _ = cloud.advance(&m, 77, 1);
        assert_eq!(m_of(&cloud, 0), m_ref);
    }
}
