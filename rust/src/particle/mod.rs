//! Particle-inference substrate: weighted trace clouds with cheap forking.
//!
//! This is the subsystem the paper's trace machinery was built to enable
//! (§3.3: the `del`/`RESAMPLE` flag exists for particle samplers): a
//! [`ParticleCloud`] holds N execution traces with normalized log-weights
//! and advances them one *observe statement* at a time by whole-body
//! re-execution under [`Context::ObsWindow`].
//!
//! The cloud is **generic over its particle representation** via
//! [`ParticleState`], with two implementations:
//!
//! - [`TypedVarInfo`] — the **typed fast path**: every particle is a fork
//!   of one `Arc`-shared layout (three flat buffers + a flag byte per
//!   slot), propagation is a cursor walk
//!   ([`crate::model::executors::TypedReplayExecutor`]), and resampling
//!   copies buffers through a reusable snapshot ring — no hashing, no
//!   boxed values, no per-visit allocation. A dynamic structure change is
//!   detected per particle (`layout_ok`), the pre-step snapshots are
//!   restored, and the caller demotes the cloud to…
//! - [`UntypedVarInfo`] — the **boxed fallback**: replay through the
//!   hash-addressed dynamic trace ([`exec::ReplayExecutor`]), which
//!   absorbs any structure change. This is the only representation that
//!   can *discover* a model's shape, so every sweep starts here and
//!   promotes ([`ParticleCloud::promote`]) once the first full run shows a
//!   stable layout.
//!
//! Both representations are **bitwise equivalent** for a fixed seed: they
//! read and write the same `f64` values in the same order and share the
//! `(seed, step, index)` RNG stream discipline, so log-evidence, weights
//! and particle values agree to the last bit — the typed path is purely a
//! mechanical specialization, exactly the paper's §2.2 argument.
//!
//! Per step the cloud:
//! 1. **propagates** every particle in parallel ([`parallel_for_each_mut`];
//!    bitwise-deterministic for a fixed seed regardless of thread count,
//!    because each particle's RNG is derived from `(seed, step, index)`
//!    and all weight reductions run serially on the caller thread);
//! 2. **reweights** by the window's incremental log-likelihood and folds
//!    the normalizer into a running log-marginal-likelihood (evidence)
//!    estimate `log Ẑ = Σ_t log Σ_i W_i·w_i^{(t)}`;
//! 3. optionally **resamples** (ESS-triggered) by forking ancestor states
//!    and flagging each fork's unscored suffix for regeneration, which
//!    restores particle diversity exactly the way Turing's `Trace` copy +
//!    `del` flag does.
//!
//! A cloud can be *scoped* to a subset of variables (Particle-Gibbs /
//! conditional SMC): out-of-scope variables are never flagged, so every
//! replay reproduces them bit-for-bit and the cloud targets their full
//! conditional. For ancestor sampling (PGAS),
//! [`ParticleCloud::ancestor_sample_reference`] splices the reference's
//! unscored future onto each particle's retained prefix and scores it
//! with a pure evaluation replay.

pub mod exec;
pub mod resample;

pub use exec::{ReplayExecutor, ReplayReport};
pub use resample::{ess, normalize_log_weights, Resampler};

use rand_core::RngCore;

use crate::context::Context;
use crate::model::executors::{ReplayScope, TypedReplayExecutor};
use crate::model::Model;
use crate::util::math;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_for_each_mut;
use crate::varinfo::{flags, TraceSnapshot, TypedVarInfo, UntypedVarInfo};
use crate::varname::VarName;

/// Outcome of one particle propagation.
#[derive(Clone, Copy, Debug)]
pub struct StepReport {
    /// Incremental log-weight of this particle for the step.
    pub delta_logw: f64,
    /// Total observe statements the model visited.
    pub obs_total: usize,
    /// `false` when the particle's structure diverged from its frozen
    /// layout (typed path only) — the cloud must demote.
    pub layout_ok: bool,
}

/// Marker error: a typed cloud hit a dynamic structure change mid-sweep.
/// The cloud restored its pre-step state; demote and retry the step.
#[derive(Clone, Copy, Debug)]
pub struct LayoutMismatch;

/// One representation of a particle's execution trace. The cloud drives
/// propagation, forking and flag sweeps exclusively through this trait, so
/// the SMC/CSMC algorithms are written once for both the typed fast path
/// and the boxed fallback.
pub trait ParticleState: Clone + Send + std::fmt::Debug {
    /// Scope restriction for conditional clouds: variable names for the
    /// boxed path, a per-slot bitmask for the typed path.
    type Scope: Clone + Send + Sync + std::fmt::Debug;
    /// Buffers-only copy of the per-particle state (the snapshot-ring
    /// element used for resampling copies and mismatch rollback).
    type Snapshot: Default + Clone + Send + std::fmt::Debug;
    /// Whether propagation can fail on a dynamic structure change (typed
    /// path). When `false`, `advance` skips the pre-step snapshot pass.
    const CAN_MISMATCH: bool;

    /// Re-run the model over observation window `[lo, hi)`: replay stored
    /// values, regenerate `RESAMPLE`-flagged ones, lock the scored prefix.
    fn propagate(
        &mut self,
        model: &dyn Model,
        rng: &mut Xoshiro256pp,
        lo: usize,
        hi: usize,
        scope: Option<&Self::Scope>,
    ) -> StepReport;

    /// Save the per-particle state into a ring slot (reuses allocations).
    fn save_into(&self, snap: &mut Self::Snapshot);

    /// Restore the per-particle state from a ring slot.
    fn load_from(&mut self, snap: &Self::Snapshot);

    /// `RESAMPLE`-flag every unscored (non-`LOCKED`) in-scope variable —
    /// the regeneration sweep applied to resampling forks.
    fn flag_unscored(&mut self, scope: Option<&Self::Scope>);

    /// Clear all particle flags (`RESAMPLE | LOCKED`) — fresh-sweep reset.
    fn clear_particle_flags(&mut self);

    /// Copy `reference`'s values into every unscored in-scope variable of
    /// `self`: the ancestor-sampling hybrid (my prefix + their future).
    fn overlay_unscored_from(&mut self, reference: &Self, scope: Option<&Self::Scope>);

    /// `log p(future latents, future observations | prefix)`: pure
    /// evaluation of window `[lo, n_obs)` with in-window assume priors
    /// scored. Mutates replay bookkeeping — call on a scratch clone.
    fn future_logp(&mut self, model: &dyn Model, lo: usize, n_obs: usize) -> f64;
}

impl ParticleState for UntypedVarInfo {
    type Scope = Vec<VarName>;
    type Snapshot = UntypedVarInfo;
    const CAN_MISMATCH: bool = false;

    fn propagate(
        &mut self,
        model: &dyn Model,
        rng: &mut Xoshiro256pp,
        lo: usize,
        hi: usize,
        scope: Option<&Self::Scope>,
    ) -> StepReport {
        let rep = ReplayExecutor::run(
            model,
            rng,
            self,
            Context::ObsWindow { lo, hi },
            scope.map(|s| s.as_slice()),
        );
        StepReport {
            delta_logw: rep.delta_logw,
            obs_total: rep.obs_total,
            layout_ok: true,
        }
    }

    fn save_into(&self, snap: &mut Self::Snapshot) {
        snap.clone_from(self);
    }

    fn load_from(&mut self, snap: &Self::Snapshot) {
        self.clone_from(snap);
    }

    fn flag_unscored(&mut self, scope: Option<&Self::Scope>) {
        self.flag_unlocked(scope.map(|s| s.as_slice()), flags::RESAMPLE);
    }

    fn clear_particle_flags(&mut self) {
        self.clear_flag_all(flags::RESAMPLE | flags::LOCKED);
    }

    fn overlay_unscored_from(&mut self, reference: &Self, scope: Option<&Self::Scope>) {
        for i in 0..reference.len() {
            let rec = reference.record(i);
            let in_scope = match scope {
                None => true,
                Some(vars) => vars.iter().any(|v| rec.vn.subsumed_by(v)),
            };
            if !in_scope {
                continue;
            }
            let unlocked = self
                .get(&rec.vn)
                .map(|mine| mine.flags & flags::LOCKED == 0);
            if unlocked == Some(true) {
                self.set_value(&rec.vn, rec.value.clone());
            }
        }
    }

    fn future_logp(&mut self, model: &dyn Model, lo: usize, n_obs: usize) -> f64 {
        // An empty scope means *nothing* counts as a proposal, so every
        // in-window assume's prior is scored: pure evaluation. Nothing is
        // flagged, so the RNG is never consumed (seed is arbitrary).
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let empty: &[VarName] = &[];
        ReplayExecutor::run(
            model,
            &mut rng,
            self,
            Context::ObsWindow { lo, hi: n_obs },
            Some(empty),
        )
        .delta_logw
    }
}

impl ParticleState for TypedVarInfo {
    type Scope = std::sync::Arc<[bool]>;
    type Snapshot = TraceSnapshot;
    const CAN_MISMATCH: bool = true;

    fn propagate(
        &mut self,
        model: &dyn Model,
        rng: &mut Xoshiro256pp,
        lo: usize,
        hi: usize,
        scope: Option<&Self::Scope>,
    ) -> StepReport {
        let replay_scope = match scope {
            Some(mask) => ReplayScope::Mask(&mask[..]),
            None => ReplayScope::Unscoped,
        };
        let rep = TypedReplayExecutor::run(
            model,
            rng,
            self,
            Context::ObsWindow { lo, hi },
            replay_scope,
        );
        StepReport {
            delta_logw: rep.delta_logw,
            obs_total: rep.obs_total,
            layout_ok: rep.layout_ok,
        }
    }

    fn save_into(&self, snap: &mut Self::Snapshot) {
        snap.copy_from(self);
    }

    fn load_from(&mut self, snap: &Self::Snapshot) {
        self.restore(snap);
    }

    fn flag_unscored(&mut self, scope: Option<&Self::Scope>) {
        self.flag_unlocked_slots(scope.map(|m| &m[..]), flags::RESAMPLE);
    }

    fn clear_particle_flags(&mut self) {
        self.clear_all_slot_flags(flags::RESAMPLE | flags::LOCKED);
    }

    fn overlay_unscored_from(&mut self, reference: &Self, scope: Option<&Self::Scope>) {
        self.overlay_unscored_slots_from(reference, scope.map(|m| &m[..]));
    }

    fn future_logp(&mut self, model: &dyn Model, lo: usize, n_obs: usize) -> f64 {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        TypedReplayExecutor::run(
            model,
            &mut rng,
            self,
            Context::ObsWindow { lo, hi: n_obs },
            ReplayScope::Eval,
        )
        .delta_logw
    }
}

/// One weighted particle.
#[derive(Clone, Debug)]
pub struct Particle<S: ParticleState> {
    /// The execution-trace state (typed buffers or boxed trace).
    pub state: S,
    /// Normalized log-weight (log-sum-exp over the cloud ≈ 0).
    pub log_weight: f64,
    /// Last step's incremental log-likelihood.
    pub delta: f64,
    /// Scratch written by `advance`: whether the last propagation kept the
    /// frozen layout (always `true` on the boxed path).
    pub layout_ok: bool,
}

/// Count the observe statements `model` visits when replaying `trace`
/// (one scratch whole-body replay; the trace must be complete).
pub fn count_observes(model: &dyn Model, trace: &UntypedVarInfo) -> usize {
    let mut probe = trace.clone();
    let mut rng = Xoshiro256pp::seed_from_u64(0);
    ReplayExecutor::run(
        model,
        &mut rng,
        &mut probe,
        Context::ObsWindow { lo: 0, hi: 0 },
        None,
    )
    .obs_total
}

/// Derive a particle-local RNG seed from `(run seed, step, index)`.
/// Stable across thread counts — the basis of deterministic parallelism.
pub fn particle_seed(seed: u64, step: usize, index: usize) -> u64 {
    let mut x = seed ^ 0xA5A5_5A5A_DEAD_BEEF;
    x = x.wrapping_add((step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    x = x.wrapping_add((index as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Per-slot scope bitmask for a typed layout: `mask[i]` ⇔ slot `i`'s
/// variable is subsumed by one of `scope` — computed once per cloud so the
/// hot cursor walk does a single indexed load instead of name subsumption.
pub fn scope_mask(tvi: &TypedVarInfo, scope: &[VarName]) -> std::sync::Arc<[bool]> {
    tvi.slots()
        .iter()
        .map(|s| scope.iter().any(|v| s.vn.subsumed_by(v)))
        .collect::<Vec<bool>>()
        .into()
}

/// A cloud of weighted particles stepping through a model's observations,
/// generic over the particle representation (see module docs).
#[derive(Clone, Debug)]
pub struct ParticleCloud<S: ParticleState> {
    pub particles: Vec<Particle<S>>,
    /// Running log-marginal-likelihood (evidence) estimate.
    pub log_evidence: f64,
    /// Next observe index to score (completed steps so far).
    pub step: usize,
    /// Total observe statements of the model (SMC step count).
    pub n_obs: usize,
    /// Restrict regeneration to these variables (Particle-Gibbs scope);
    /// `None` = every variable participates (plain SMC).
    pub scope: Option<S::Scope>,
    /// Snapshot ring: one buffers-only copy per particle, reused by
    /// resampling forks and (typed path) mismatch rollback.
    snapshots: Vec<S::Snapshot>,
}

/// The boxed-fallback cloud (hash-addressed traces; absorbs any model).
pub type BoxedCloud = ParticleCloud<UntypedVarInfo>;
/// The typed fast-path cloud (forked flat-buffer traces, shared layout).
pub type TypedCloud = ParticleCloud<TypedVarInfo>;

impl<S: ParticleState> ParticleCloud<S> {
    pub fn len(&self) -> usize {
        self.particles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.particles.is_empty()
    }

    /// Normalized weights (probabilities).
    pub fn weights(&self) -> Vec<f64> {
        let logw: Vec<f64> = self.particles.iter().map(|p| p.log_weight).collect();
        normalize_log_weights(&logw).0
    }

    /// Effective sample size of the current weights.
    pub fn ess(&self) -> f64 {
        ess(&self.weights())
    }

    fn ensure_ring(&mut self) {
        if self.snapshots.len() != self.particles.len() {
            self.snapshots
                .resize_with(self.particles.len(), Default::default);
        }
    }

    /// Save every particle's state into the snapshot ring.
    fn save_all(&mut self) {
        self.ensure_ring();
        for (p, snap) in self.particles.iter().zip(self.snapshots.iter_mut()) {
            p.state.save_into(snap);
        }
    }

    /// Propagate every particle through the next observe window, update
    /// weights and the running evidence estimate. Returns the step's
    /// log-normalizer `log Σ_i W_i·w_i`.
    ///
    /// On the typed path a dynamic structure change in *any* particle
    /// aborts the step: every particle is rolled back to its pre-step
    /// snapshot, weights/evidence/step are untouched, and
    /// [`LayoutMismatch`] tells the caller to demote to the boxed path and
    /// retry the same step (whose per-particle RNG streams are derived
    /// from `(seed, step, index)`, so the retry is exactly the run a
    /// boxed-only sweep would have made). The boxed path never fails.
    pub fn advance(
        &mut self,
        model: &dyn Model,
        seed: u64,
        threads: usize,
    ) -> Result<f64, LayoutMismatch> {
        assert!(self.step < self.n_obs, "cloud already consumed all observations");
        if S::CAN_MISMATCH {
            self.save_all();
        }
        let (lo, hi) = (self.step, self.step + 1);
        let step_for_seed = self.step + 1; // 0 is the init run
        let scope = self.scope.as_ref();
        parallel_for_each_mut(threads, &mut self.particles, |i, p| {
            let mut rng =
                Xoshiro256pp::seed_from_u64(particle_seed(seed, step_for_seed, i));
            let rep = p.state.propagate(model, &mut rng, lo, hi, scope);
            p.delta = rep.delta_logw;
            p.layout_ok = rep.layout_ok;
        });
        if S::CAN_MISMATCH && self.particles.iter().any(|p| !p.layout_ok) {
            for (p, snap) in self.particles.iter_mut().zip(self.snapshots.iter()) {
                p.state.load_from(snap);
                p.layout_ok = true;
            }
            return Err(LayoutMismatch);
        }
        Ok(self.reduce_step())
    }

    /// Serial post-propagation reduction (index order → deterministic):
    /// fold each particle's `delta` into its weight, renormalize, update
    /// the evidence estimate and advance the step counter. Shared between
    /// [`ParticleCloud::advance`] and the lane-batched advance so both
    /// produce bit-identical weights from identical deltas.
    fn reduce_step(&mut self) -> f64 {
        let logw_new: Vec<f64> = self
            .particles
            .iter()
            .map(|p| p.log_weight + p.delta)
            .collect();
        let lz_step = math::log_sum_exp(&logw_new);
        self.log_evidence += lz_step;
        if lz_step == f64::NEG_INFINITY {
            // every particle died: reset to uniform (evidence is −∞ now)
            let lw = -(self.len() as f64).ln();
            for p in &mut self.particles {
                p.log_weight = lw;
            }
        } else {
            for (p, lw) in self.particles.iter_mut().zip(logw_new) {
                p.log_weight = lw - lz_step;
            }
        }
        self.step += 1;
        lz_step
    }

    /// Fork a new generation from ancestors drawn by `resampler`; children
    /// get uniform weights and their unscored suffix flagged for
    /// regeneration (scope-restricted when the cloud is conditional).
    /// With `conditional`, particle 0's ancestor is pinned to the
    /// reference (index 0) and its state is forked unflagged.
    ///
    /// Forks are buffers-only copies through the snapshot ring: no new
    /// allocations on the typed path once the ring exists.
    pub fn resample<R: RngCore>(&mut self, resampler: Resampler, conditional: bool, rng: &mut R) {
        let n = self.len();
        let weights = self.weights();
        let mut ancestors = resampler.ancestors(&weights, n, rng);
        if conditional {
            ancestors[0] = 0;
        }
        self.fork_generation(&ancestors, conditional);
    }

    /// Replace the generation by forks of `ancestors[j]` (see `resample`).
    pub fn fork_generation(&mut self, ancestors: &[usize], conditional: bool) {
        assert_eq!(ancestors.len(), self.len());
        self.save_all();
        let n = self.len();
        let deltas: Vec<f64> = self.particles.iter().map(|p| p.delta).collect();
        let log_w0 = -(n as f64).ln();
        let scope = self.scope.as_ref();
        let snaps = &self.snapshots;
        for (j, p) in self.particles.iter_mut().enumerate() {
            let a = ancestors[j];
            p.state.load_from(&snaps[a]);
            if !(conditional && j == 0) {
                // regenerate everything not yet scored (scope-bounded)
                p.state.flag_unscored(scope);
            }
            p.log_weight = log_w0;
            p.delta = deltas[a];
        }
    }

    /// Resample only when ESS drops below `threshold_frac · N`. Returns
    /// whether a resampling pass happened.
    pub fn maybe_resample<R: RngCore>(
        &mut self,
        resampler: Resampler,
        threshold_frac: f64,
        conditional: bool,
        rng: &mut R,
    ) -> bool {
        if self.ess() < threshold_frac * self.len() as f64 {
            self.resample(resampler, conditional, rng);
            true
        } else {
            false
        }
    }

    /// Draw one trace index from the final weights (the Particle-Gibbs
    /// selection step).
    pub fn select<R: RngCore>(&self, rng: &mut R) -> usize {
        use crate::util::rng::Rng as _;
        rng.categorical(&self.weights())
    }

    /// Ancestor sampling (PGAS; Lindsten, Jordan & Schön 2014): for each
    /// particle, splice the reference's unscored future onto its retained
    /// prefix, weight by `W_i · p(future | prefix_i)`, and draw the
    /// retained path's new ancestry. Returns the new reference state
    /// (ancestor prefix + reference future, unflagged); assign it to
    /// particle 0 **after** the ordinary conditional resampling pass, so
    /// the other children still fork from the original generation.
    ///
    /// Costs one pure-evaluation replay per particle; serial by design so
    /// results stay deterministic.
    pub fn ancestor_sample_reference<R: RngCore>(
        &self,
        model: &dyn Model,
        rng: &mut R,
    ) -> S {
        let scope = self.scope.as_ref();
        let reference = &self.particles[0].state;
        let mut logw = Vec::with_capacity(self.len());
        for p in &self.particles {
            let mut hybrid = p.state.clone();
            hybrid.overlay_unscored_from(reference, scope);
            let future = hybrid.future_logp(model, self.step, self.n_obs);
            logw.push(p.log_weight + future);
        }
        let (probs, lse) = normalize_log_weights(&logw);
        let a0 = if lse == f64::NEG_INFINITY {
            0 // fully degenerate: keep the current ancestry
        } else {
            use crate::util::rng::Rng as _;
            rng.categorical(&probs)
        };
        let mut new_reference = self.particles[a0].state.clone();
        new_reference.overlay_unscored_from(reference, scope);
        new_reference
    }
}

impl BoxedCloud {
    /// Bootstrap initialization: N empty traces, each populated by one
    /// prior run (window `[0,0)` scores nothing). Deterministic in `seed`.
    /// Always boxed — the first run is what *discovers* the layout; call
    /// [`TypedCloud::promote`] afterwards to move onto the fast path.
    pub fn from_prior(model: &dyn Model, n: usize, seed: u64, threads: usize) -> Self {
        assert!(n >= 2, "a particle cloud needs at least 2 particles");
        let mut particles: Vec<Particle<UntypedVarInfo>> = (0..n)
            .map(|_| Particle {
                state: UntypedVarInfo::new(),
                log_weight: -(n as f64).ln(),
                delta: 0.0,
                layout_ok: true,
            })
            .collect();
        let mut n_obs_per: Vec<usize> = vec![0; n];
        {
            let n_obs_slots = std::sync::Mutex::new(&mut n_obs_per);
            parallel_for_each_mut(threads, &mut particles, |i, p| {
                let mut rng = Xoshiro256pp::seed_from_u64(particle_seed(seed, 0, i));
                let rep = ReplayExecutor::run(
                    model,
                    &mut rng,
                    &mut p.state,
                    Context::ObsWindow { lo: 0, hi: 0 },
                    None,
                );
                n_obs_slots.lock().unwrap()[i] = rep.obs_total;
            });
        }
        let n_obs = n_obs_per.into_iter().max().unwrap_or(0);
        ParticleCloud {
            particles,
            log_evidence: 0.0,
            step: 0,
            n_obs,
            scope: None,
            snapshots: Vec::new(),
        }
    }

    /// Conditional (CSMC) initialization for Particle-Gibbs: particle 0 is
    /// the retained reference trajectory; particles 1..n fork it with all
    /// `scope` variables flagged, so the first advance regenerates them
    /// from the prior while out-of-scope variables replay exactly.
    ///
    /// `n_obs` is the model's observe-statement count (see
    /// [`count_observes`], computed once when sweeping repeatedly).
    pub fn conditional(
        reference: &UntypedVarInfo,
        scope: &[VarName],
        n: usize,
        n_obs: usize,
    ) -> Self {
        assert!(n >= 2, "conditional SMC needs at least 2 particles");
        assert!(!scope.is_empty(), "conditional SMC needs a variable scope");
        let log_w0 = -(n as f64).ln();
        let mut particles = Vec::with_capacity(n);
        for j in 0..n {
            let mut state = reference.clone();
            // fresh sweep: no record is scored yet, and the reference must
            // replay exactly — scrub stale particle flags either way
            state.clear_flag_all(flags::RESAMPLE | flags::LOCKED);
            if j > 0 {
                state.flag_suffix(0, Some(scope), flags::RESAMPLE);
            }
            particles.push(Particle {
                state,
                log_weight: log_w0,
                delta: 0.0,
                layout_ok: true,
            });
        }
        ParticleCloud {
            particles,
            log_evidence: 0.0,
            step: 0,
            n_obs,
            scope: Some(scope.to_vec()),
            snapshots: Vec::new(),
        }
    }
}

impl TypedCloud {
    /// Specialize a boxed cloud onto the typed fast path after its first
    /// full run: freeze particle 0's structure into a shared layout and
    /// refill every particle's buffers from its boxed trace. Returns
    /// `None` when any particle's structure disagrees (the model is
    /// dynamic *across particles* — stay boxed). Also returns the boxed
    /// template kept for demotion/conversion.
    pub fn promote(boxed: &BoxedCloud) -> Option<(TypedCloud, UntypedVarInfo)> {
        let template = boxed.particles.first()?.state.clone();
        if template.is_empty() {
            return None; // nothing traced: nothing to specialize
        }
        let layout = TypedVarInfo::from_untyped(&template);
        let mask = boxed.scope.as_ref().map(|vars| scope_mask(&layout, vars));
        let mut particles = Vec::with_capacity(boxed.len());
        for p in &boxed.particles {
            let state = layout.refill_from_untyped(&p.state)?;
            particles.push(Particle {
                state,
                log_weight: p.log_weight,
                delta: p.delta,
                layout_ok: true,
            });
        }
        Some((
            ParticleCloud {
                particles,
                log_evidence: boxed.log_evidence,
                step: boxed.step,
                n_obs: boxed.n_obs,
                scope: mask,
                snapshots: Vec::new(),
            },
            template,
        ))
    }

    /// Typed conditional (CSMC) cloud: refill `template`'s layout from the
    /// boxed `reference` trajectory, then fork it N times with all
    /// in-scope slots flagged on particles 1..n (particle 0 replays the
    /// reference exactly). `None` when the reference no longer fits the
    /// layout — fall back to [`BoxedCloud::conditional`].
    pub fn conditional_typed(
        template: &TypedVarInfo,
        reference: &UntypedVarInfo,
        scope: &[VarName],
        n: usize,
        n_obs: usize,
    ) -> Option<TypedCloud> {
        assert!(n >= 2, "conditional SMC needs at least 2 particles");
        assert!(!scope.is_empty(), "conditional SMC needs a variable scope");
        let mut ref_state = template.refill_from_untyped(reference)?;
        ref_state.clear_all_slot_flags(flags::RESAMPLE | flags::LOCKED);
        let mask = scope_mask(template, scope);
        let log_w0 = -(n as f64).ln();
        let mut particles = Vec::with_capacity(n);
        for j in 0..n {
            let mut state = ref_state.clone();
            if j > 0 {
                state.flag_unlocked_slots(Some(&mask), flags::RESAMPLE);
            }
            particles.push(Particle {
                state,
                log_weight: log_w0,
                delta: 0.0,
                layout_ok: true,
            });
        }
        Some(ParticleCloud {
            particles,
            log_evidence: 0.0,
            step: 0,
            n_obs,
            scope: Some(mask),
            snapshots: Vec::new(),
        })
    }

    /// Lane-batched advance: gather the whole cloud into one
    /// [`crate::varinfo::BatchVarInfo`] and replay every particle in a
    /// single tilde walk
    /// ([`crate::model::batched::BatchedReplayExecutor`]), paying the
    /// per-statement bookkeeping once for all N particles. Each lane's RNG
    /// is seeded from the same `(seed, step, index)` stream as
    /// [`ParticleCloud::advance`] and the reduction is shared, so a
    /// batched step is bit-identical to a sequential one.
    ///
    /// Returns `None` when the walk cannot be expressed batched (layout
    /// mismatch, a discrete assume, or any particle rejecting mid-step) —
    /// the gathered buffers are discarded, the cloud is **untouched**, and
    /// the caller redoes the same step with [`ParticleCloud::advance`]
    /// (same seed ⇒ same result; a true structure change then surfaces as
    /// [`LayoutMismatch`] there).
    pub fn advance_batched(&mut self, model: &dyn Model, seed: u64) -> Option<f64> {
        assert!(self.step < self.n_obs, "cloud already consumed all observations");
        let step_for_seed = self.step + 1; // 0 is the init run
        let mut rngs: Vec<Xoshiro256pp> = (0..self.len())
            .map(|i| Xoshiro256pp::seed_from_u64(particle_seed(seed, step_for_seed, i)))
            .collect();
        let states: Vec<&TypedVarInfo> = self.particles.iter().map(|p| &p.state).collect();
        let mut bvi = crate::varinfo::BatchVarInfo::gather(&self.particles[0].state, &states);
        drop(states);
        let replay_scope = match self.scope.as_ref() {
            Some(mask) => ReplayScope::Mask(&mask[..]),
            None => ReplayScope::Unscoped,
        };
        let report = crate::model::batched::BatchedReplayExecutor::run(
            model,
            &mut rngs,
            &mut bvi,
            Context::ObsWindow { lo: self.step, hi: self.step + 1 },
            replay_scope,
        )?;
        crate::obs::metrics::inc(crate::obs::metrics::Counter::BatchedEvals);
        crate::obs::metrics::add(
            crate::obs::metrics::Counter::BatchedLanes,
            self.len() as u64,
        );
        for (l, p) in self.particles.iter_mut().enumerate() {
            bvi.scatter_lane(l, &mut p.state);
            p.delta = report.deltas[l];
            p.layout_ok = true;
        }
        Some(self.reduce_step())
    }

    /// Demote to the boxed representation mid-sweep (dynamic structure
    /// change): every particle's buffers and flags are written back into a
    /// clone of `template`, and weights/step/evidence carry over, so the
    /// boxed cloud resumes exactly where the typed one stopped.
    pub fn demote(
        &self,
        template: &UntypedVarInfo,
        scope: Option<Vec<VarName>>,
    ) -> BoxedCloud {
        ParticleCloud {
            particles: self
                .particles
                .iter()
                .map(|p| Particle {
                    state: p.state.to_untyped(template),
                    log_weight: p.log_weight,
                    delta: p.delta,
                    layout_ok: true,
                })
                .collect(),
            log_evidence: self.log_evidence,
            step: self.step,
            n_obs: self.n_obs,
            scope,
            snapshots: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prelude::*;

    model! {
        /// m ~ N(0,1); y_t ~ N(m, 1) — one observe statement per data
        /// point, the canonical SMC stepping structure.
        pub IidNormal {
            y: Vec<f64>,
        }
        fn body<T>(this, api) {
            let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
            for &yi in &this.y {
                obs!(api, yi => Normal(m, c(1.0)));
            }
        }
    }

    fn m_of<S: ParticleState>(cloud: &ParticleCloud<S>, j: usize, get: impl Fn(&S) -> f64) -> f64 {
        get(&cloud.particles[j].state)
    }

    fn boxed_m(state: &UntypedVarInfo) -> f64 {
        state.get(&VarName::new("m")).unwrap().value.as_f64().unwrap()
    }

    #[test]
    fn from_prior_counts_observations() {
        let m = IidNormal { y: vec![0.1, -0.2, 0.3] };
        let cloud = BoxedCloud::from_prior(&m, 8, 11, 1);
        assert_eq!(cloud.n_obs, 3);
        assert_eq!(cloud.len(), 8);
        assert_eq!(cloud.step, 0);
        let w = cloud.weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((cloud.ess() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn advance_accumulates_evidence_and_reweights() {
        let m = IidNormal { y: vec![0.5, -0.5] };
        let mut cloud = BoxedCloud::from_prior(&m, 64, 3, 1);
        let lz0 = cloud.advance(&m, 3, 1).unwrap();
        assert!(lz0.is_finite() && lz0 < 0.0);
        assert_eq!(cloud.step, 1);
        assert!((cloud.log_evidence - lz0).abs() < 1e-12);
        // weights renormalized
        let w = cloud.weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-10);
        let _ = cloud.advance(&m, 3, 1).unwrap();
        assert_eq!(cloud.step, 2);
        assert!(cloud.log_evidence < lz0);
    }

    #[test]
    fn resample_forks_and_uniformizes() {
        let m = IidNormal { y: vec![2.0, 2.0, 2.0] };
        let mut cloud = BoxedCloud::from_prior(&m, 32, 5, 1);
        let _ = cloud.advance(&m, 5, 1).unwrap();
        let ess_before = cloud.ess();
        assert!(ess_before < 32.0);
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        cloud.resample(Resampler::Systematic, false, &mut rng);
        assert!((cloud.ess() - 32.0).abs() < 1e-9, "uniform after resample");
        // maybe_resample: ESS is maximal now → no-op
        assert!(!cloud.maybe_resample(Resampler::Systematic, 0.5, false, &mut rng));
    }

    #[test]
    fn particle_seed_is_stable_and_index_sensitive() {
        assert_eq!(particle_seed(1, 2, 3), particle_seed(1, 2, 3));
        assert_ne!(particle_seed(1, 2, 3), particle_seed(1, 2, 4));
        assert_ne!(particle_seed(1, 2, 3), particle_seed(1, 3, 3));
        assert_ne!(particle_seed(1, 2, 3), particle_seed(2, 2, 3));
    }

    #[test]
    fn conditional_cloud_keeps_reference_trajectory() {
        let m = IidNormal { y: vec![0.3, 0.7] };
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let reference = crate::model::init_trace(&m, &mut rng);
        let m_ref = boxed_m(&reference);
        let scope = [VarName::new("m")];
        assert_eq!(count_observes(&m, &reference), 2);
        let mut cloud = BoxedCloud::conditional(&reference, &scope, 16, 2);
        assert_eq!(cloud.n_obs, 2);

        // step 0: non-reference particles regenerate m from the prior
        let _ = cloud.advance(&m, 77, 1).unwrap();
        assert_eq!(m_of(&cloud, 0, boxed_m), m_ref, "reference must replay exactly");
        assert!(
            (1..cloud.len()).any(|j| m_of(&cloud, j, boxed_m) != m_ref),
            "non-reference particles must regenerate their scoped variable"
        );

        // conditional resampling pins the reference at index 0
        let mut r = Xoshiro256pp::seed_from_u64(123);
        cloud.resample(Resampler::Systematic, true, &mut r);
        assert_eq!(m_of(&cloud, 0, boxed_m), m_ref);

        // and it survives the next advance untouched
        let _ = cloud.advance(&m, 77, 1).unwrap();
        assert_eq!(m_of(&cloud, 0, boxed_m), m_ref);
    }

    #[test]
    fn promoted_cloud_is_bitwise_equal_to_boxed() {
        // The central fast-path claim at the cloud level: a promoted typed
        // cloud advances/resamples/regenerates exactly like its boxed
        // source for the same seeds.
        let m = IidNormal { y: vec![0.4, -0.1, 0.6] };
        let mut boxed = BoxedCloud::from_prior(&m, 16, 9, 1);
        let (mut typed, _template) = TypedCloud::promote(&boxed).expect("static layout");
        let typed_m = |s: &TypedVarInfo| s.constrained[s.slots()[0].cons_offset];
        for j in 0..16 {
            assert_eq!(m_of(&typed, j, typed_m).to_bits(), m_of(&boxed, j, boxed_m).to_bits());
        }
        for t in 0..3 {
            let lz_b = boxed.advance(&m, 9, 1).unwrap();
            let lz_t = typed.advance(&m, 9, 1).unwrap();
            assert_eq!(lz_b.to_bits(), lz_t.to_bits(), "step {t}");
            if t == 1 {
                let mut rb = Xoshiro256pp::seed_from_u64(31);
                let mut rt = Xoshiro256pp::seed_from_u64(31);
                boxed.resample(Resampler::Systematic, false, &mut rb);
                typed.resample(Resampler::Systematic, false, &mut rt);
            }
        }
        assert_eq!(boxed.log_evidence.to_bits(), typed.log_evidence.to_bits());
        for j in 0..16 {
            assert_eq!(
                typed.particles[j].log_weight.to_bits(),
                boxed.particles[j].log_weight.to_bits()
            );
            assert_eq!(m_of(&typed, j, typed_m).to_bits(), m_of(&boxed, j, boxed_m).to_bits());
        }
    }

    #[test]
    fn typed_conditional_cloud_demotes_cleanly() {
        let m = IidNormal { y: vec![0.3, 0.7] };
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let reference = crate::model::init_trace(&m, &mut rng);
        let template = TypedVarInfo::from_untyped(&reference);
        let scope = [VarName::new("m")];
        let mut cloud =
            TypedCloud::conditional_typed(&template, &reference, &scope, 8, 2).expect("layout");
        let _ = cloud.advance(&m, 5, 1).unwrap();
        let demoted = cloud.demote(&reference, Some(scope.to_vec()));
        assert_eq!(demoted.step, 1);
        assert_eq!(demoted.n_obs, 2);
        assert_eq!(m_of(&demoted, 0, boxed_m), boxed_m(&reference));
        for j in 0..8 {
            assert_eq!(
                demoted.particles[j].log_weight.to_bits(),
                cloud.particles[j].log_weight.to_bits()
            );
        }
    }
}
