//! The replay-with-regenerate executor: how a particle re-runs the model.
//!
//! SMC without continuations re-executes the whole model body once per
//! observation step (the CuPPL strategy). A [`ReplayExecutor`] run under
//! [`Context::ObsWindow`] does three things at once:
//!
//! 1. **Replay** — variables already in the trace (and not flagged
//!    `RESAMPLE`) keep their stored values, so the retained prefix of the
//!    trajectory is reproduced exactly;
//! 2. **Regenerate** — flagged or missing variables are drawn fresh from
//!    their priors (the bootstrap proposal), clearing the flag;
//! 3. **Windowed scoring** — only observe statements whose visit index
//!    falls in `[lo, hi)` contribute to the accumulated weight. Because
//!    the proposal is the prior, prior terms cancel in the importance
//!    weight and the window's likelihood *is* the incremental weight.
//!
//! The executor also stamps every record visited up to the window end
//! with [`flags::LOCKED`]: those records have been scored, so a
//! resampling fork regenerates exactly the *unlocked* remainder
//! ([`UntypedVarInfo::flag_unlocked`]) without invalidating accumulated
//! weights — the paper's "del" flag machinery (§3.3) driving diversity
//! after resampling. Stamping actual record indices (rather than a
//! visit-count prefix) stays correct for dynamic models whose
//! regeneration changes control flow and hence the visit/insertion
//! correspondence.
//!
//! **Scoped (conditional) clouds.** When a `scope` restricts the filter
//! to a subset of variables (Particle-Gibbs), out-of-scope variables are
//! replayed verbatim — but their *prior* densities may depend on scoped
//! values (e.g. `m ~ Normal(0, √(2·var))` while the filter updates
//! `var`), so those terms vary across particles and belong to the
//! importance weight. The rule: an assume visited inside the window
//! (i.e. being locked in at this step) contributes its prior term to the
//! weight iff it is *out of scope*; scoped assumes are bootstrap
//! proposals whose prior cancels. With no scope (plain SMC) every assume
//! is a proposal and no prior term is ever weighted.

use rand_core::RngCore;

use crate::context::{Accumulator, Context};
use crate::dist::{DiscreteDist, ScalarDist, VecDist};
use crate::model::{Model, TildeApi};
use crate::value::Value;
use crate::varinfo::{flags, UntypedVarInfo};
use crate::varname::VarName;

/// Outcome of one replay run.
#[derive(Clone, Copy, Debug)]
pub struct ReplayReport {
    /// Sum of in-window observation log-likelihoods (the incremental
    /// log-weight of this particle for the step).
    pub delta_logw: f64,
    /// Total observe statements the model visited (the SMC step count).
    pub obs_total: usize,
    /// Number of trace records in the retained prefix: records `>= this`
    /// may be flagged for regeneration after a resampling fork.
    pub prefix_records: usize,
}

/// [`TildeApi`] implementation for particle replay (f64 only — particles
/// never differentiate).
pub struct ReplayExecutor<'a, R: RngCore> {
    rng: &'a mut R,
    vi: &'a mut UntypedVarInfo,
    acc: Accumulator<f64>,
    ctx: Context,
    /// Conditional-cloud scope; `None` = plain SMC (everything proposed).
    scope: Option<&'a [VarName]>,
    lo: usize,
    hi: usize,
    obs_seen: usize,
    assumes_seen: usize,
    prefix_records: Option<usize>,
    /// Record indices visited this run, collected until the window end is
    /// reached and the prefix is stamped `LOCKED`.
    visited: Vec<usize>,
    locking_done: bool,
}

impl<'a, R: RngCore> ReplayExecutor<'a, R> {
    pub fn new(
        rng: &'a mut R,
        vi: &'a mut UntypedVarInfo,
        ctx: Context,
        scope: Option<&'a [VarName]>,
    ) -> Self {
        let (lo, hi) = ctx.obs_window();
        Self {
            rng,
            vi,
            acc: Accumulator::new(ctx),
            ctx,
            scope,
            lo,
            hi,
            obs_seen: 0,
            assumes_seen: 0,
            // hi = 0: nothing scored yet → the whole trace is regenerable
            prefix_records: if hi == 0 { Some(0) } else { None },
            visited: Vec::new(),
            locking_done: hi == 0,
        }
    }

    /// Run `model` once and report.
    pub fn run(
        model: &dyn Model,
        rng: &'a mut R,
        vi: &'a mut UntypedVarInfo,
        ctx: Context,
        scope: Option<&'a [VarName]>,
    ) -> ReplayReport {
        let mut exec = ReplayExecutor::new(rng, vi, ctx, scope);
        model.eval_f64(&mut exec);
        exec.finalize()
    }

    /// Stamp the scored prefix and produce the report. When the observe
    /// counter never reached `hi`, every record visited this run was
    /// scored by the window: lock them all.
    fn finalize(mut self) -> ReplayReport {
        if !self.locking_done {
            for &i in &self.visited {
                self.vi.flag_record(i, flags::LOCKED);
            }
        }
        ReplayReport {
            delta_logw: self.acc.total(),
            obs_total: self.obs_seen,
            prefix_records: self.prefix_records.unwrap_or(self.assumes_seen),
        }
    }

    /// Replay a stored value or draw a fresh one (flagged/missing).
    fn fetch_or_draw(&mut self, vn: VarName, dist: crate::dist::AnyDist) -> Value {
        self.assumes_seen += 1;
        let (idx, val) = if self.vi.contains(&vn) && !self.vi.is_flagged(&vn, flags::RESAMPLE) {
            let val = self.vi.get(&vn).unwrap().value.clone();
            self.vi.update(&vn, val.clone(), dist);
            (self.vi.index_of(&vn).unwrap(), val)
        } else {
            let val = dist.sample(self.rng);
            if self.vi.contains(&vn) {
                self.vi.update(&vn, val.clone(), dist);
                self.vi.clear_flag(&vn, flags::RESAMPLE);
                (self.vi.index_of(&vn).unwrap(), val)
            } else {
                (self.vi.insert(vn, val.clone(), dist), val)
            }
        };
        if !self.locking_done {
            self.visited.push(idx);
        }
        val
    }

    /// Count an observe statement; true if it falls inside the window.
    #[inline]
    fn note_obs(&mut self) -> bool {
        let i = self.obs_seen;
        self.obs_seen += 1;
        if self.obs_seen == self.hi && self.prefix_records.is_none() {
            self.prefix_records = Some(self.assumes_seen);
            // everything visited so far is now scored: lock it
            for &idx in &self.visited {
                self.vi.flag_record(idx, flags::LOCKED);
            }
            self.locking_done = true;
        }
        i >= self.lo && i < self.hi
    }

    /// Score an assume's prior term. Out-of-scope assumes being locked in
    /// by this window add it to the weight (their prior can depend on
    /// scoped values); everything else is a proposal draw whose prior
    /// cancels (routed to the zero-weighted prior side, which still
    /// triggers early rejection on −∞).
    #[inline]
    fn score_assume(&mut self, vn: &VarName, lp: f64) {
        let in_window = self.obs_seen >= self.lo && self.obs_seen < self.hi;
        let proposed = match self.scope {
            None => true,
            Some(vars) => vars.iter().any(|v| vn.subsumed_by(v)),
        };
        if in_window && !proposed {
            self.acc.add_lik(lp);
        } else {
            self.acc.add_prior(lp);
        }
    }
}

impl<'a, R: RngCore> TildeApi<f64> for ReplayExecutor<'a, R> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<f64>) -> f64 {
        let val = self.fetch_or_draw(vn.clone(), dist.boxed());
        let x = val.as_f64().expect("scalar assume got non-scalar value");
        self.score_assume(&vn, dist.logpdf(x));
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<f64>) -> Vec<f64> {
        let val = self.fetch_or_draw(vn.clone(), dist.boxed());
        let x = val
            .as_slice()
            .expect("vector assume got non-vector value")
            .to_vec();
        self.score_assume(&vn, dist.logpdf(&x));
        x
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<f64>) -> i64 {
        let val = self.fetch_or_draw(vn.clone(), dist.boxed());
        let k = val.as_int().expect("discrete assume got non-integer value");
        self.score_assume(&vn, dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<f64>, obs: f64) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpdf(obs));
        }
    }

    fn observe_int(&mut self, dist: &DiscreteDist<f64>, obs: i64) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpmf(obs));
        }
    }

    fn observe_vec(&mut self, dist: &VecDist<f64>, obs: &[f64]) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpdf(obs));
        }
    }

    fn add_obs_logp(&mut self, lp: f64) {
        if self.note_obs() {
            self.acc.add_lik(lp);
        }
    }

    fn add_prior_logp(&mut self, lp: f64) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        // advance through note_obs so crossing the window end still stamps
        // the scored prefix LOCKED
        for _ in 0..n {
            let _ = self.note_obs();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prelude::*;

    model! {
        /// Two observations interleaved with latent draws:
        /// a ~ N(0,1); obs y0 ~ N(a,1); b ~ N(a,1); obs y1 ~ N(b,1).
        pub TwoStep {
            y0: f64,
            y1: f64,
        }
        fn body<T>(this, api) {
            let a = tilde!(api, a ~ Normal(c(0.0), c(1.0)));
            obs!(api, this.y0 => Normal(a, c(1.0)));
            let b = tilde!(api, b ~ Normal(a, c(1.0)));
            obs!(api, this.y1 => Normal(b, c(1.0)));
        }
    }

    fn demo() -> TwoStep {
        TwoStep { y0: 0.5, y1: -0.3 }
    }

    #[test]
    fn initial_run_draws_everything_and_scores_nothing() {
        let m = demo();
        let mut vi = UntypedVarInfo::new();
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let rep = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 0, hi: 0 }, None);
        assert_eq!(rep.obs_total, 2);
        assert_eq!(rep.delta_logw, 0.0);
        assert_eq!(vi.len(), 2);
        // hi = 0 → nothing scored yet, everything regenerable: prefix 0
        assert_eq!(rep.prefix_records, 0);
    }

    #[test]
    fn windowed_weight_is_single_observation_likelihood() {
        let m = demo();
        let mut vi = UntypedVarInfo::new();
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let _ = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 0, hi: 0 }, None);
        let a = vi.get(&VarName::new("a")).unwrap().value.as_f64().unwrap();
        let b = vi.get(&VarName::new("b")).unwrap().value.as_f64().unwrap();

        let rep0 = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 0, hi: 1 }, None);
        assert!((rep0.delta_logw - Normal::new(a, 1.0).logpdf(0.5)).abs() < 1e-12);
        // after scoring obs 0, only `a` is in the retained prefix
        assert_eq!(rep0.prefix_records, 1);

        let rep1 = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 1, hi: 2 }, None);
        assert!((rep1.delta_logw - Normal::new(b, 1.0).logpdf(-0.3)).abs() < 1e-12);
        assert_eq!(rep1.prefix_records, 2);
        // replay is exact: values unchanged
        assert_eq!(
            vi.get(&VarName::new("a")).unwrap().value.as_f64().unwrap(),
            a
        );
    }

    #[test]
    fn scored_records_are_locked_and_flag_unlocked_spares_them() {
        let m = demo();
        let mut vi = UntypedVarInfo::new();
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let _ = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 0, hi: 0 }, None);
        use crate::varinfo::flags;
        let a = VarName::new("a");
        let b = VarName::new("b");
        // nothing scored yet → nothing locked
        assert!(!vi.is_flagged(&a, flags::LOCKED));
        // score obs 0: `a` is locked, `b` (after the window) is not
        let _ = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 0, hi: 1 }, None);
        assert!(vi.is_flagged(&a, flags::LOCKED));
        assert!(!vi.is_flagged(&b, flags::LOCKED));
        // the fork sweep regenerates exactly the unlocked remainder
        vi.flag_unlocked(None, flags::RESAMPLE);
        assert!(!vi.is_flagged(&a, flags::RESAMPLE));
        assert!(vi.is_flagged(&b, flags::RESAMPLE));
        // score obs 1: `b` becomes locked too (after regeneration)
        let _ = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 1, hi: 2 }, None);
        assert!(vi.is_flagged(&b, flags::LOCKED));
        vi.flag_unlocked(None, flags::RESAMPLE);
        assert!(!vi.is_flagged(&b, flags::RESAMPLE));
    }

    #[test]
    fn flagged_suffix_regenerates_only_the_suffix() {
        let m = demo();
        let mut vi = UntypedVarInfo::new();
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let _ = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 0, hi: 0 }, None);
        let a = vi.get(&VarName::new("a")).unwrap().value.as_f64().unwrap();
        let b = vi.get(&VarName::new("b")).unwrap().value.as_f64().unwrap();
        // fork-style: keep prefix (a), regenerate suffix (b)
        vi.flag_suffix(1, None, crate::varinfo::flags::RESAMPLE);
        let _ = ReplayExecutor::run(&m, &mut rng, &mut vi, Context::ObsWindow { lo: 1, hi: 2 }, None);
        let a2 = vi.get(&VarName::new("a")).unwrap().value.as_f64().unwrap();
        let b2 = vi.get(&VarName::new("b")).unwrap().value.as_f64().unwrap();
        assert_eq!(a2, a, "prefix must replay");
        assert_ne!(b2, b, "flagged suffix must regenerate");
        assert!(!vi.is_flagged(&VarName::new("b"), crate::varinfo::flags::RESAMPLE));
    }
}
