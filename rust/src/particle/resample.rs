//! Resampling schemes for particle clouds.
//!
//! All three draw `n_out` ancestor indices from a normalized weight vector;
//! they differ in variance. Systematic (one uniform, evenly spaced CDF
//! probes) has the lowest variance and is the SMC default; stratified (one
//! uniform per probe, each confined to its stratum) sits between it and
//! plain multinomial.

use rand_core::RngCore;

use crate::util::rng::Rng as _;

/// Which resampling scheme a particle sampler uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resampler {
    /// iid categorical draws (highest variance; the textbook baseline).
    Multinomial,
    /// One shared uniform offset, probes at `(u + k)/n` (lowest variance).
    Systematic,
    /// Independent uniform per stratum `[k/n, (k+1)/n)`.
    Stratified,
}

impl Resampler {
    pub fn label(&self) -> &'static str {
        match self {
            Resampler::Multinomial => "multinomial",
            Resampler::Systematic => "systematic",
            Resampler::Stratified => "stratified",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "multinomial" => Resampler::Multinomial,
            "systematic" => Resampler::Systematic,
            "stratified" => Resampler::Stratified,
            _ => return None,
        })
    }

    /// Draw `n_out` ancestor indices from normalized `weights` (sum ≈ 1).
    /// Systematic/stratified outputs are sorted by construction.
    pub fn ancestors<R: RngCore>(
        &self,
        weights: &[f64],
        n_out: usize,
        rng: &mut R,
    ) -> Vec<usize> {
        assert!(!weights.is_empty());
        debug_assert!(
            (weights.iter().sum::<f64>() - 1.0).abs() < 1e-6,
            "weights must be normalized"
        );
        match self {
            Resampler::Multinomial => (0..n_out).map(|_| rng.categorical(weights)).collect(),
            Resampler::Systematic => {
                let u0 = rng.uniform() / n_out as f64;
                cdf_probes(weights, (0..n_out).map(|k| u0 + k as f64 / n_out as f64))
            }
            Resampler::Stratified => {
                let probes: Vec<f64> = (0..n_out)
                    .map(|k| (k as f64 + rng.uniform()) / n_out as f64)
                    .collect();
                cdf_probes(weights, probes.into_iter())
            }
        }
    }
}

/// Walk the weight CDF once over an ascending probe sequence.
fn cdf_probes<I: Iterator<Item = f64>>(weights: &[f64], probes: I) -> Vec<usize> {
    let mut out = Vec::new();
    let mut acc = weights[0];
    let mut idx = 0usize;
    for p in probes {
        while p > acc && idx + 1 < weights.len() {
            idx += 1;
            acc += weights[idx];
        }
        out.push(idx);
    }
    out
}

/// Effective sample size of normalized weights: `1 / Σ wᵢ²`.
pub fn ess(weights: &[f64]) -> f64 {
    let s2: f64 = weights.iter().map(|w| w * w).sum();
    if s2 <= 0.0 {
        0.0
    } else {
        1.0 / s2
    }
}

/// Normalize log-weights in place to probabilities; returns their
/// log-sum-exp (the normalizer).
pub fn normalize_log_weights(logw: &[f64]) -> (Vec<f64>, f64) {
    let lse = crate::util::math::log_sum_exp(logw);
    if lse == f64::NEG_INFINITY {
        // fully degenerate cloud: fall back to uniform
        let n = logw.len() as f64;
        return (vec![1.0 / n; logw.len()], lse);
    }
    (logw.iter().map(|&l| (l - lse).exp()).collect(), lse)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn ess_bounds() {
        assert!((ess(&[0.25; 4]) - 4.0).abs() < 1e-12);
        assert!((ess(&[1.0, 0.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_log_weights_sums_to_one() {
        let (w, lse) = normalize_log_weights(&[-1.0, -2.0, -3.0]);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let expect = crate::util::math::log_sum_exp(&[-1.0, -2.0, -3.0]);
        assert!((lse - expect).abs() < 1e-12);
        // degenerate
        let (w, _) = normalize_log_weights(&[f64::NEG_INFINITY; 3]);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_schemes_track_expected_counts() {
        let weights = [0.1, 0.2, 0.3, 0.4];
        for scheme in [
            Resampler::Multinomial,
            Resampler::Systematic,
            Resampler::Stratified,
        ] {
            let mut rng = Xoshiro256pp::seed_from_u64(31);
            let mut counts = [0usize; 4];
            let reps = 2000;
            let n = 16;
            for _ in 0..reps {
                for a in scheme.ancestors(&weights, n, &mut rng) {
                    counts[a] += 1;
                }
            }
            let total = (reps * n) as f64;
            for (c, w) in counts.iter().zip(&weights) {
                let f = *c as f64 / total;
                assert!(
                    (f - w).abs() < 0.02,
                    "{}: freq {f} vs weight {w}",
                    scheme.label()
                );
            }
        }
    }

    #[test]
    fn systematic_counts_are_near_deterministic() {
        // systematic resampling gives each index either ⌊nw⌋ or ⌈nw⌉ copies
        let weights = [0.5, 0.25, 0.25];
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        for _ in 0..50 {
            let a = Resampler::Systematic.ancestors(&weights, 8, &mut rng);
            let c0 = a.iter().filter(|&&x| x == 0).count();
            assert_eq!(c0, 4, "{a:?}");
            assert_eq!(a.iter().filter(|&&x| x == 1).count(), 2);
        }
    }

    #[test]
    fn labels_roundtrip() {
        for r in [
            Resampler::Multinomial,
            Resampler::Systematic,
            Resampler::Stratified,
        ] {
            assert_eq!(Resampler::parse(r.label()), Some(r));
        }
        assert_eq!(Resampler::parse("bogus"), None);
    }
}
