//! The leader process: model registry, CLI command dispatch, multi-chain
//! orchestration. This is what `dppl` (rust/src/main.rs) drives.

use std::sync::Arc;

use crate::bench::{
    append_history, batch_rows_to_json, check_conjugate_speedups, check_serve_gates,
    check_static_speedups, conjugate_rows_to_json, grad_rows_to_json, history_line,
    render_batch_table, render_conjugate_table, render_grad_table, render_serve_table,
    render_smc_table, render_static_table, render_table1, render_vi_table, run_batch_bench,
    run_conjugate_bench, run_grad_bench, run_serve_bench, run_smc_bench, run_static_bench,
    run_table1, run_vi_bench, serve_rows_to_json, smc_rows_to_json, static_rows_to_json,
    table1_cells_to_json, vi_rows_to_json, BatchBenchConfig, BenchBackend, ConjugateBenchConfig,
    GradBenchConfig, HistoryEntry, ServeBenchConfig, SmcBenchConfig, SmcPath, StaticBenchConfig,
    Table1Config, ViBenchConfig,
};
use crate::chain::{Chain, MultiChain};
use crate::gradient::{Backend, LogDensity, NativeDensity};
use crate::inference::{
    sample_chain, sample_chains_batched, sample_smc_chain, Hmc, Nuts, RwMh, SamplerKind, Smc,
};
use crate::model::init_typed;
use crate::models::{build, ALL_MODELS};
use crate::obs::report::RunReport;
use crate::query::{eval_query, Bindings, ModelRegistry, Query};
use crate::runtime::{artifact_exists, artifacts_dir, XlaDensity};
use crate::stanlike::stanlike_density;
use crate::util::cli::{Args, Usage};
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::{default_threads, parallel_map};
use crate::value::Value;
use crate::vi::{Advi, ViFamily};

/// CLI usage text.
pub fn usage() -> String {
    Usage {
        program: "dppl",
        about: "DynamicPPL reproduction — Stan-like speed for dynamic probabilistic models",
        commands: vec![
            ("list", "list benchmark models"),
            ("info", "show runtime/platform information"),
            (
                "sample",
                "run inference: --model NAME [--sampler hmc|nuts|mh|smc|advi|advi-fullrank] [--backend fused|xla|tape|forward|stan] [--iters N] [--warmup N] [--chains C] [--lanes K] [--seed S] [--minibatch B] [--profile] [--quiet] [--json] [--metrics-out FILE]  (smc: iters = particles; advi: iters = posterior draws, --minibatch B fits on Subsample-windowed minibatch gradients; --lanes K replaces --chains with K lane-batched HMC/NUTS chains driven through one fused logp∇ pass per rendezvous; default backend: fused; diagnostics always land in METRICS.json, --json echoes them to stdout, --profile adds per-tilde-site timing rows)",
            ),
            (
                "bench",
                "bench table1 [--models a,b] [--backends x,y] [--iters N] [--reps R] [--out FILE.json] | bench smc [--models a,b] [--particles N] [--threads T] [--path typed|boxed|both] [--full] [--out FILE.json] | bench grad [--models a,b] [--engines fused,tape,forward] [--full] [--out FILE.json] | bench vi [--models a,b] [--families meanfield,fullrank] [--draws N] [--max-iters N] [--minibatch B] [--stl] [--full] [--out FILE.json] | bench batch [--models a,b] [--lanes 1,4,16,64] [--assert-speedup R] [--full] [--out FILE.json] | bench static [--models a,b] [--assert-speedup R] [--full] [--out FILE.json] | bench serve [--queries N] [--particles N] [--seed S] [--assert-cached R] [--assert-stream R] [--out FILE.json] | bench conjugate [--models a,b] [--warmup N] [--iters N] [--assert-speedup R] [--full] [--out FILE.json]  (static: compiled structure replay vs the dynamic fused walk; --assert-speedup R requires >= Rx on logreg_tall and break-even on every other promoted model; serve: cached posterior queries vs fit-per-query + streaming SMC update vs from-scratch refit, --assert-cached/--assert-stream gate the two speedups; conjugate: analyzer-collapsed exact Gibbs draws vs MH-within-Gibbs, --assert-speedup R gates the ESS/sec ratio; any target: --history appends one JSONL row to BENCH_HISTORY.jsonl)",
            ),
            (
                "lint",
                "static-analysis pedantic pass (Stan's `pedantic` mode analogue): --model NAME or --all [--full] [--seed S] [--json] [--out FILE.json]  (dependency-graph lints: dead parameters, domain/support mismatches, centered funnels with a non-centering hint, constant-data observation plates, never-resampled discrete sites; exit 1 when any finding is an error)",
            ),
            ("query", "evaluate a probability query string (paper §3.5)"),
            (
                "serve",
                "run the posterior-serving daemon: --addr HOST:PORT (default 127.0.0.1:8787) [--workers N] [--cache N] [--threads T]  (line-delimited JSON requests: init, fit, query, update, invalidate, stats, shutdown; see rust/src/serve/server.rs for the protocol)",
            ),
        ],
    }
    .render()
}

/// Entry point used by main.rs; returns process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    let mut it = argv.into_iter();
    let cmd = match it.next() {
        Some(c) => c,
        None => {
            print!("{}", usage());
            return 2;
        }
    };
    let args = match Args::parse(it) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match cmd.as_str() {
        "list" => cmd_list(),
        "info" => cmd_info(),
        "sample" => cmd_sample(&args),
        "bench" => cmd_bench(&args),
        "lint" => cmd_lint(&args),
        "query" => cmd_query(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n{}", usage());
            2
        }
    }
}

fn cmd_list() -> i32 {
    println!("Table-1 benchmark models:");
    for name in ALL_MODELS {
        let bm = build(name, 0);
        println!(
            "  {name:<16} dim={:<6} artifact={}",
            bm.theta_dim,
            if artifact_exists(name) { "yes" } else { "NO (make artifacts)" }
        );
    }
    println!("extra workload models:");
    for name in crate::models::EXTRA_MODELS {
        // the reduced build: listing should not generate a 100k-row workload
        let bm = crate::models::build_small(name, 0);
        println!(
            "  {name:<16} dim={:<6} (tall data; --sampler advi --minibatch)",
            bm.theta_dim
        );
    }
    0
}

fn cmd_info() -> i32 {
    match crate::runtime::Runtime::cpu() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts dir: {}", artifacts_dir().display());
            println!("threads:       {}", default_threads());
            0
        }
        Err(e) => {
            eprintln!("PJRT unavailable: {e:?}");
            1
        }
    }
}

fn cmd_sample(args: &Args) -> i32 {
    let model_name = match args.get("model") {
        Some(m) => m.to_string(),
        None => {
            eprintln!("--model required (see `dppl list`)");
            return 2;
        }
    };
    let sampler = args.get_or("sampler", "nuts").to_string();
    // the arena-fused native engine is the default — it needs no AOT
    // artifacts and is the fastest in-process gradient path
    let backend = args.get_or("backend", "fused").to_string();
    let iters = args.get_parse_or("iters", 1000usize).unwrap_or(1000);
    let warmup = args.get_parse_or("warmup", 500usize).unwrap_or(500);
    let n_chains = args.get_parse_or("chains", 2usize).unwrap_or(2);
    let lanes = args.get_parse_or("lanes", 1usize).unwrap_or(1);
    let seed = args.get_parse_or("seed", 42u64).unwrap_or(42);
    let minibatch = match args.get_parse::<usize>("minibatch") {
        Ok(b) => b,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };

    let mc = match sample_model(
        &model_name, &sampler, &backend, iters, warmup, n_chains, seed, minibatch, lanes,
    ) {
        Ok(mc) => mc,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };

    // optional per-tilde-site profile: one instrumented Context::Profile
    // pass through each of the four flat executor monomorphizations
    let profile = if args.flag("profile") && crate::models::is_known(&model_name) {
        let bm = build(&model_name, seed);
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta = tvi.unconstrained.clone();
        crate::obs::profile::profile_model(bm.model.as_ref(), &tvi, &theta, seed)
    } else {
        Vec::new()
    };

    // one reporting path for humans and machines: the same RunReport
    // renders the console summary, the --json echo and METRICS.json
    let mut report = RunReport::from_chains(&model_name, &sampler, &mc, profile);

    // the pedantic static-analysis pass rides along on every run: lint
    // findings land in the same warnings array as the convergence
    // diagnostics (small build — structure is what the linter reads)
    {
        let bm = crate::models::build_small(&model_name, seed);
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        if let Some(lint) = crate::analysis::lint_model(bm.model.as_ref(), &tvi) {
            for f in &lint.findings {
                report.warnings.push(crate::obs::report::Warning::Lint {
                    code: f.code.to_string(),
                    site: f.site.clone(),
                    message: f.message.clone(),
                });
            }
        }
    }
    let quiet = args.flag("quiet");
    if !quiet {
        println!("{}", report.render_human(&mc));
    }
    let payload = report.to_json();
    if args.flag("json") {
        println!("{payload}");
    }
    let metrics_path = args.get_or("metrics-out", "METRICS.json").to_string();
    match std::fs::write(&metrics_path, &payload) {
        Ok(()) => {
            if !quiet {
                println!("wrote {metrics_path}");
            }
            0
        }
        Err(e) => {
            eprintln!("failed to write {metrics_path}: {e}");
            1
        }
    }
}

/// `dppl lint`: the static-analysis pedantic pass over one model or the
/// whole Table-1 zoo. Exit code 1 when any finding is an error (or a
/// model's structure cannot be recorded), 2 on usage problems, 0
/// otherwise — warnings alone do not fail the lint.
fn cmd_lint(args: &Args) -> i32 {
    let models: Vec<String> = if args.flag("all") {
        ALL_MODELS.iter().map(|s| s.to_string()).collect()
    } else {
        match args.get("model") {
            Some(m) => vec![m.to_string()],
            None => {
                eprintln!("--model NAME or --all required (see `dppl list`)");
                return 2;
            }
        }
    };
    let seed = args.get_parse_or("seed", 42u64).unwrap_or(42);
    let full = args.flag("full");
    let json = args.flag("json");
    let mut any_errors = false;
    let mut payloads: Vec<String> = Vec::with_capacity(models.len());
    for name in &models {
        if !crate::models::is_known(name) {
            eprintln!("unknown model {name:?} (see `dppl list`)");
            return 2;
        }
        // the linter reads structure, not data scale: the small build
        // is the default, --full lints the Table-1 workload as-is
        let bm = if full {
            build(name, seed)
        } else {
            crate::models::build_small(name, seed)
        };
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        match crate::analysis::lint_model(bm.model.as_ref(), &tvi) {
            Some(report) => {
                if !json {
                    println!("== {name} ==");
                    print!("{}", report.render());
                }
                any_errors |= report.has_errors();
                payloads.push(format!("\"{name}\": {}", report.to_json()));
            }
            None => {
                eprintln!("{name}: structure recording failed — nothing to lint");
                any_errors = true;
            }
        }
    }
    let payload = format!("{{{}}}\n", payloads.join(", "));
    if json {
        print!("{payload}");
    }
    if let Some(out) = args.get("out") {
        let out = out.to_string();
        if let Err(e) = std::fs::write(&out, &payload) {
            eprintln!("failed to write {out}: {e}");
            return 1;
        }
        if !json {
            println!("wrote {out}");
        }
    }
    if any_errors {
        1
    } else {
        0
    }
}

/// How a CLI `--backend` string maps to a [`LogDensity`] implementation.
/// Native-engine names resolve through the one [`Backend`] `FromStr`
/// table; only the XLA and Stan comparators are coordinator-specific.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DensityKind {
    Native(Backend),
    Xla,
    Stan,
}

fn parse_density(s: &str) -> Result<DensityKind, String> {
    if let Ok(b) = s.parse::<Backend>() {
        return Ok(DensityKind::Native(b));
    }
    match s {
        "xla" => Ok(DensityKind::Xla),
        "stan" | "stanlike" => Ok(DensityKind::Stan),
        other => Err(format!(
            "unknown backend {other:?} (fused|tape|forward|xla|stan)"
        )),
    }
}

/// Build the requested density and sample `n_chains` chains in parallel.
/// `minibatch = Some(B)` is ADVI-only: the fit runs on seeded
/// `Context::Subsample` minibatch gradients (B observations per step,
/// scaled N/B) over a native backend. `lanes > 1` is HMC/NUTS-only:
/// it replaces `n_chains` with `lanes` lane-batched chains advanced
/// through one batched fused logp∇ pass per gang rendezvous.
#[allow(clippy::too_many_arguments)]
pub fn sample_model(
    model_name: &str,
    sampler: &str,
    backend: &str,
    iters: usize,
    warmup: usize,
    n_chains: usize,
    seed: u64,
    minibatch: Option<usize>,
    lanes: usize,
) -> Result<MultiChain, String> {
    if !crate::models::is_known(model_name) {
        return Err(format!("unknown model {model_name:?}"));
    }
    let bm = Arc::new(build(model_name, seed));

    // SMC is model-space (no density backend): one particle-filter pass
    // per chain; `iters` is interpreted as the particle count and the
    // per-chain evidence lands in `stats.log_evidence`.
    if sampler == "smc" {
        if minibatch.is_some() {
            return Err("--minibatch only applies to the advi samplers".into());
        }
        if lanes > 1 {
            return Err("--lanes only applies to the hmc/nuts samplers".into());
        }
        let n_particles = iters.max(2);
        let bmc = Arc::clone(&bm);
        let chains: Vec<Chain> = parallel_map(
            default_threads().min(n_chains),
            n_chains,
            move |i| {
                let smc = Smc {
                    n_particles,
                    ..Smc::default()
                };
                sample_smc_chain(bmc.model.as_ref(), &smc, seed + 1000 * i as u64)
            },
        );
        return Ok(MultiChain::new(chains));
    }

    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let tvi = Arc::new(init_typed(bm.model.as_ref(), &mut rng));
    let kind = match sampler {
        "hmc" => SamplerKind::Hmc(Hmc {
            step_size: bm.step_size,
            ..Hmc::default()
        }),
        "nuts" => SamplerKind::Nuts(Nuts {
            step_size: bm.step_size,
            ..Nuts::default()
        }),
        "mh" => SamplerKind::RwMh(RwMh::default()),
        // `iters` = posterior draws from the fitted approximation; the
        // optimization budget lives in the Advi defaults
        "advi" => SamplerKind::Advi(Advi::meanfield()),
        "advi-fullrank" | "advi-fr" => SamplerKind::Advi(Advi::fullrank()),
        other => return Err(format!("unknown sampler {other:?}")),
    };
    let density = parse_density(backend)?;

    // lane-batched chain gang: `lanes` chains advance in lockstep, one
    // batched fused logp∇ pass per rendezvous (lanes retire
    // independently, so finished chains never block the gang)
    if lanes > 1 {
        if !matches!(kind, SamplerKind::Hmc(_) | SamplerKind::Nuts(_)) {
            return Err("--lanes only applies to the hmc/nuts samplers".into());
        }
        if minibatch.is_some() {
            return Err("--minibatch only applies to the advi samplers".into());
        }
        let b = match density {
            DensityKind::Native(b) => b,
            _ => return Err("--lanes needs a native backend (fused|tape|forward)".into()),
        };
        let ld = NativeDensity::new(bm.model.as_ref(), &tvi, b);
        return Ok(sample_chains_batched(&ld, &tvi, &kind, warmup, iters, seed, lanes));
    }

    // ADVI minibatch mode: fit on Subsample-windowed gradients (needs the
    // model, not just a density, to re-window per step), then draw the
    // chain from the fitted approximation against the full-data density.
    if let Some(b) = minibatch {
        let advi = match &kind {
            SamplerKind::Advi(a) => a.clone(),
            _ => return Err("--minibatch only applies to the advi samplers".into()),
        };
        let native = match density {
            DensityKind::Native(be) => be,
            _ => return Err("--minibatch needs a native backend (fused|tape|forward)".into()),
        };
        let bmc = Arc::clone(&bm);
        let tvic = Arc::clone(&tvi);
        let chains: Vec<Chain> = parallel_map(
            default_threads().min(n_chains),
            n_chains,
            move |i| -> Chain {
                let target =
                    crate::vi::MinibatchTarget::new(bmc.model.as_ref(), &tvic, b, native);
                let mut rng = Xoshiro256pp::seed_from_u64(seed + 1000 * i as u64);
                let theta0 = tvic.unconstrained.clone();
                // scope the telemetry shard to this chain's fit (an η
                // search failure is surfaced through stats.eta_search_failed
                // and becomes a RunReport warning — no ad-hoc stderr line)
                let _ = crate::obs::metrics::take_local();
                let fit = advi.fit_minibatch(&target, &theta0, &mut rng);
                let full = target.full();
                let raw = fit.sample_raw(&full, iters, &mut rng);
                let mut chain = crate::inference::raw_to_chain(&raw, &tvic);
                chain.stats.metrics = crate::obs::metrics::take_local();
                chain
            },
        );
        return Ok(MultiChain::new(chains));
    }

    let chains: Vec<Chain> = parallel_map(
        default_threads().min(n_chains),
        n_chains,
        move |i| -> Chain {
            let ld: Box<dyn LogDensity> = match density {
                DensityKind::Xla => Box::new(
                    XlaDensity::load(&artifacts_dir(), bm.name, bm.theta_dim, &bm.data)
                        .expect("artifact load failed (run `make artifacts`)"),
                ),
                DensityKind::Native(b) => Box::new(NativeDensity::new(bm.model.as_ref(), &tvi, b)),
                DensityKind::Stan => stanlike_density(&bm) as Box<dyn LogDensity>,
            };
            sample_chain(ld.as_ref(), &tvi, &kind, warmup, iters, seed + 1000 * i as u64)
        },
    );
    Ok(MultiChain::new(chains))
}

/// `bench --history` tail: append one timestamped JSONL row to
/// `BENCH_HISTORY.jsonl` so successive bench runs accumulate a
/// machine-readable performance trail.
fn bench_history(bench: &str, seed: u64, entries: Vec<HistoryEntry>) -> i32 {
    let line = history_line(bench, seed, &entries);
    match append_history("BENCH_HISTORY.jsonl", &line) {
        Ok(()) => {
            println!("appended BENCH_HISTORY.jsonl");
            0
        }
        Err(e) => {
            eprintln!("failed to append BENCH_HISTORY.jsonl: {e}");
            1
        }
    }
}

fn cmd_bench(args: &Args) -> i32 {
    let what = args.positional.first().map(|s| s.as_str()).unwrap_or("table1");
    match what {
        "table1" => {
            let mut cfg = Table1Config::default();
            if let Some(models) = args.get("models") {
                cfg.models = models.split(',').map(|s| s.trim().to_string()).collect();
            }
            if let Some(backends) = args.get("backends") {
                cfg.backends = backends
                    .split(',')
                    .map(|s| {
                        BenchBackend::parse(s.trim())
                            .unwrap_or_else(|| panic!("unknown backend {s:?}"))
                    })
                    .collect();
            }
            cfg.iters = args.get_parse_or("iters", cfg.iters).unwrap_or(cfg.iters);
            cfg.reps = args.get_parse_or("reps", cfg.reps).unwrap_or(cfg.reps);
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.max_run_iters = args.get_parse::<usize>("max-run").ok().flatten();
            let cells = run_table1(&cfg);
            println!("{}", render_table1(&cells, &cfg));
            if args.flag("history") {
                let entries = cells
                    .iter()
                    .map(|c| HistoryEntry {
                        model: c.model.clone(),
                        label: c.backend.label().to_string(),
                        secs: c.mean,
                    })
                    .collect();
                let rc = bench_history("table1", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            // machine-readable Table-1 cells alongside the console table
            let out_path = args.get_or("out", "BENCH_TABLE1.json").to_string();
            let json = table1_cells_to_json(&cells, &cfg);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        "smc" => {
            let mut cfg = SmcBenchConfig::default();
            if let Some(models) = args.get("models") {
                cfg.models = models.split(',').map(|s| s.trim().to_string()).collect();
            }
            cfg.n_particles = args
                .get_parse_or("particles", cfg.n_particles)
                .unwrap_or(cfg.n_particles);
            cfg.threads = args
                .get_parse_or("threads", cfg.threads)
                .unwrap_or(cfg.threads);
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.small = !args.flag("full");
            match args.get_or("path", "both") {
                "both" => {}
                p => match SmcPath::parse(p) {
                    Some(path) => cfg.paths = vec![path],
                    None => {
                        eprintln!("unknown path {p:?} (typed|boxed|both)");
                        return 2;
                    }
                },
            }
            let rows = run_smc_bench(&cfg);
            println!("{}", render_smc_table(&rows));
            if args.flag("history") {
                let entries = rows
                    .iter()
                    .map(|r| HistoryEntry {
                        model: r.model.clone(),
                        label: r.path.label().to_string(),
                        secs: r.wall_secs,
                    })
                    .collect();
                let rc = bench_history("smc", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            let out_path = args.get_or("out", "BENCH_SMC.json").to_string();
            let json = smc_rows_to_json(&rows);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        "grad" => {
            let mut cfg = GradBenchConfig::default();
            if let Some(models) = args.get("models") {
                cfg.models = models.split(',').map(|s| s.trim().to_string()).collect();
            }
            if let Some(engines) = args.get("engines") {
                cfg.engines = engines
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<Backend>()
                            .unwrap_or_else(|e| panic!("{e}"))
                    })
                    .collect();
            }
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.reps = args.get_parse_or("reps", cfg.reps).unwrap_or(cfg.reps);
            cfg.small = !args.flag("full");
            let rows = run_grad_bench(&cfg);
            println!("{}", render_grad_table(&rows));
            if args.flag("history") {
                let entries = rows
                    .iter()
                    .map(|r| HistoryEntry {
                        model: r.model.clone(),
                        label: r.engine.label().to_string(),
                        secs: r.secs_per_grad,
                    })
                    .collect();
                let rc = bench_history("grad", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            let out_path = args.get_or("out", "BENCH_GRAD.json").to_string();
            let json = grad_rows_to_json(&rows, &cfg);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        "batch" => {
            let mut cfg = BatchBenchConfig::default();
            if let Some(models) = args.get("models") {
                cfg.models = models.split(',').map(|s| s.trim().to_string()).collect();
            }
            if let Some(lanes) = args.get("lanes") {
                cfg.lane_counts = lanes
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<usize>()
                            .unwrap_or_else(|e| panic!("bad lane count {s:?}: {e}"))
                    })
                    .collect();
            }
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.reps = args.get_parse_or("reps", cfg.reps).unwrap_or(cfg.reps);
            cfg.small = !args.flag("full");
            let min_speedup = match args.get_parse::<f64>("assert-speedup") {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let rows = run_batch_bench(&cfg);
            println!("{}", render_batch_table(&rows));
            // CI tripwire: the lane sweep must actually pay off — every
            // model's best K > 1 row must beat the K = 1 row by ≥ R×
            if let Some(min) = min_speedup {
                for model in &cfg.models {
                    let best = rows
                        .iter()
                        .filter(|r| r.model == *model && r.lanes > 1)
                        .map(|r| r.speedup_vs_k1)
                        .fold(f64::NAN, f64::max);
                    if best.is_nan() || best < min {
                        eprintln!("assert-speedup: {model}: best vs-K1 {best:.2}x < {min:.2}x");
                        return 1;
                    }
                    println!("assert-speedup: {model}: best vs-K1 {best:.2}x >= {min:.2}x");
                }
            }
            if args.flag("history") {
                let entries = rows
                    .iter()
                    .map(|r| HistoryEntry {
                        model: r.model.clone(),
                        label: format!("K{}", r.lanes),
                        secs: r.secs_per_grad,
                    })
                    .collect();
                let rc = bench_history("batch", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            let out_path = args.get_or("out", "BENCH_BATCH.json").to_string();
            let json = batch_rows_to_json(&rows, &cfg);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        "vi" => {
            let mut cfg = ViBenchConfig::default();
            if let Some(models) = args.get("models") {
                cfg.models = models.split(',').map(|s| s.trim().to_string()).collect();
            }
            if let Some(families) = args.get("families") {
                cfg.families = families
                    .split(',')
                    .map(|s| {
                        ViFamily::parse(s.trim())
                            .unwrap_or_else(|| panic!("unknown family {s:?} (meanfield|fullrank)"))
                    })
                    .collect();
            }
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.draws = args.get_parse_or("draws", cfg.draws).unwrap_or(cfg.draws);
            cfg.advi.max_iters = args
                .get_parse_or("max-iters", cfg.advi.max_iters)
                .unwrap_or(cfg.advi.max_iters);
            cfg.minibatch = match args.get_parse::<usize>("minibatch") {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            cfg.advi.stl = args.flag("stl");
            cfg.small = !args.flag("full");
            let rows = run_vi_bench(&cfg);
            println!("{}", render_vi_table(&rows));
            if args.flag("history") {
                let entries = rows
                    .iter()
                    .map(|r| HistoryEntry {
                        model: r.model.clone(),
                        label: if r.minibatch > 0 {
                            format!("{}-mb{}", r.family.label(), r.minibatch)
                        } else {
                            r.family.label().to_string()
                        },
                        secs: r.secs_per_iter,
                    })
                    .collect();
                let rc = bench_history("vi", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            let out_path = args.get_or("out", "BENCH_VI.json").to_string();
            let json = vi_rows_to_json(&rows, &cfg);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        "static" => {
            let mut cfg = StaticBenchConfig::default();
            if let Some(models) = args.get("models") {
                cfg.models = models.split(',').map(|s| s.trim().to_string()).collect();
            }
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.reps = args.get_parse_or("reps", cfg.reps).unwrap_or(cfg.reps);
            cfg.small = !args.flag("full");
            let min_speedup = match args.get_parse::<f64>("assert-speedup") {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let rows = run_static_bench(&cfg);
            println!("{}", render_static_table(&rows));
            // CI tripwire: the compiled replay must pay for itself —
            // ≥ R× on the tall flagship, break-even on every other
            // model that promoted
            if let Some(min) = min_speedup {
                let bad = check_static_speedups(&rows, min);
                for msg in &bad {
                    eprintln!("assert-speedup: {msg}");
                }
                if !bad.is_empty() {
                    return 1;
                }
                println!("assert-speedup: compiled replay meets the gate (tall >= {min:.2}x, rest >= 1.00x)");
            }
            if args.flag("history") {
                let mut entries = Vec::with_capacity(rows.len() * 2);
                for r in &rows {
                    entries.push(HistoryEntry {
                        model: r.model.clone(),
                        label: "dynamic".into(),
                        secs: r.secs_dynamic,
                    });
                    entries.push(HistoryEntry {
                        model: r.model.clone(),
                        label: "compiled".into(),
                        secs: r.secs_compiled,
                    });
                }
                let rc = bench_history("static", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            let out_path = args.get_or("out", "BENCH_STATIC.json").to_string();
            let json = static_rows_to_json(&rows, &cfg);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        "serve" => {
            let mut cfg = ServeBenchConfig::default();
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.n_queries = args
                .get_parse_or("queries", cfg.n_queries)
                .unwrap_or(cfg.n_queries);
            cfg.particles = args
                .get_parse_or("particles", cfg.particles)
                .unwrap_or(cfg.particles);
            cfg.threads = args
                .get_parse_or("threads", cfg.threads)
                .unwrap_or(cfg.threads);
            let min_cached = match args.get_parse::<f64>("assert-cached") {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let min_stream = match args.get_parse::<f64>("assert-stream") {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let rows = run_serve_bench(&cfg);
            println!("{}", render_serve_table(&rows));
            // CI tripwire: serving must beat refitting — cached queries
            // ≥ R× faster than fit-per-query, streaming update ≥ R×
            // faster than a from-scratch refit, and both still accurate
            if min_cached.is_some() || min_stream.is_some() {
                let bad = check_serve_gates(
                    &rows,
                    min_cached.unwrap_or(1.0),
                    min_stream.unwrap_or(1.0),
                );
                for msg in &bad {
                    eprintln!("assert-serve: {msg}");
                }
                if !bad.is_empty() {
                    return 1;
                }
                println!(
                    "assert-serve: gates met (cached >= {:.1}x, stream >= {:.1}x)",
                    min_cached.unwrap_or(1.0),
                    min_stream.unwrap_or(1.0)
                );
            }
            if args.flag("history") {
                let wanted = [
                    ("fit_per_query", "normal_normal"),
                    ("cached_query_mean", "normal_normal"),
                    ("stream_update_secs", "kalman"),
                    ("refit_secs", "kalman"),
                ];
                let mut entries = Vec::new();
                for (metric, model) in wanted {
                    if let Some(r) = rows.iter().find(|r| r.metric == metric) {
                        // microsecond rows go into history in seconds,
                        // like every other bench target
                        let secs = if r.unit == "us" {
                            r.value * 1e-6
                        } else {
                            r.value
                        };
                        entries.push(HistoryEntry {
                            model: model.to_string(),
                            label: metric.to_string(),
                            secs,
                        });
                    }
                }
                let rc = bench_history("serve", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            let out_path = args.get_or("out", "BENCH_SERVE.json").to_string();
            let json = serve_rows_to_json(&rows, &cfg);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        "conjugate" => {
            let mut cfg = ConjugateBenchConfig::default();
            if let Some(models) = args.get("models") {
                cfg.models = models.split(',').map(|s| s.trim().to_string()).collect();
            }
            cfg.seed = args.get_parse_or("seed", cfg.seed).unwrap_or(cfg.seed);
            cfg.warmup = args.get_parse_or("warmup", cfg.warmup).unwrap_or(cfg.warmup);
            cfg.iters = args.get_parse_or("iters", cfg.iters).unwrap_or(cfg.iters);
            cfg.small = !args.flag("full");
            let min_speedup = match args.get_parse::<f64>("assert-speedup") {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let rows = run_conjugate_bench(&cfg);
            println!("{}", render_conjugate_table(&rows));
            // CI tripwire: Rao-Blackwellization must pay off — every
            // model must certify and the collapsed arm's ESS/sec must
            // beat MH-within-Gibbs by ≥ R×
            if let Some(min) = min_speedup {
                let bad = check_conjugate_speedups(&rows, min);
                for msg in &bad {
                    eprintln!("assert-speedup: {msg}");
                }
                if !bad.is_empty() {
                    return 1;
                }
                println!("assert-speedup: collapsed Gibbs meets the gate (>= {min:.2}x ESS/sec)");
            }
            if args.flag("history") {
                let mut entries = Vec::with_capacity(rows.len() * 2);
                for r in &rows {
                    entries.push(HistoryEntry {
                        model: r.model.clone(),
                        label: "mh".into(),
                        secs: r.secs_mh,
                    });
                    entries.push(HistoryEntry {
                        model: r.model.clone(),
                        label: "collapsed".into(),
                        secs: r.secs_collapsed,
                    });
                }
                let rc = bench_history("conjugate", cfg.seed, entries);
                if rc != 0 {
                    return rc;
                }
            }
            let out_path = args.get_or("out", "BENCH_CONJUGATE.json").to_string();
            let json = conjugate_rows_to_json(&rows, &cfg);
            match std::fs::write(&out_path, &json) {
                Ok(()) => {
                    println!("wrote {out_path}");
                    0
                }
                Err(e) => {
                    eprintln!("failed to write {out_path}: {e}");
                    1
                }
            }
        }
        other => {
            eprintln!(
                "unknown bench target {other:?} (try: table1, smc, grad, vi, batch, static, serve, conjugate)"
            );
            2
        }
    }
}

/// Query-command registry: the paper's linreg example model plus
/// gauss_unknown, built from query data bindings.
pub fn query_registry() -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    reg.register("linreg", |data: &Bindings| {
        let get = |n: &str| data.iter().find(|(k, _)| k == n).map(|(_, v)| v.clone());
        let dim = match get("dim") {
            Some(Value::F64(d)) => d as usize,
            _ => 2,
        };
        let x: Vec<Vec<f64>> = match get("X") {
            Some(Value::Vec(flat)) => flat.chunks(dim).map(|c| c.to_vec()).collect(),
            _ => vec![],
        };
        let y: Vec<f64> = match get("y") {
            Some(Value::Vec(v)) => v,
            Some(Value::F64(v)) => vec![v],
            _ => vec![],
        };
        Box::new(QueryLinReg { x, y, dim })
    });
    reg.register("gauss_unknown", |data: &Bindings| {
        let y: Vec<f64> = match data.iter().find(|(k, _)| k == "y").map(|(_, v)| v) {
            Some(Value::Vec(v)) => v.clone(),
            Some(Value::F64(v)) => vec![*v],
            _ => vec![],
        };
        Box::new(crate::models::gauss::GaussUnknown { y })
    });
    reg
}

crate::model! {
    /// The paper's linreg example, data-parameterized for queries.
    pub QueryLinReg {
        x: Vec<Vec<f64>>,
        y: Vec<f64>,
        dim: usize,
    }
    fn body<T>(this, api) {
        let s = crate::tilde!(api, s ~ InverseGamma(crate::model::macros::c(2.0), crate::model::macros::c(3.0)));
        let sd = s.sqrt();
        let w = crate::tilde_vec!(api, w ~ IsoNormal(crate::model::macros::c(0.0), sd, this.dim));
        for i in 0..this.y.len() {
            let mut mu = crate::model::macros::c::<T>(0.0);
            for j in 0..this.dim {
                mu = mu + w[j] * this.x[i][j];
            }
            crate::obs!(api, this.y[i] => Normal(mu, sd));
        }
    }
}

fn cmd_query(args: &Args) -> i32 {
    let qs = match args.positional.first() {
        Some(q) => q.clone(),
        None => {
            eprintln!("usage: dppl query \"w = [1.0, 0.0], s = 1.0 | model = linreg\"");
            return 2;
        }
    };
    let q = match Query::parse(&qs) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("parse error: {e}");
            return 2;
        }
    };
    match eval_query(&q, &query_registry(), None) {
        Ok(r) => {
            println!("log-probability = {:.6}", r.log_prob);
            println!("probability     = {:.6e}", r.prob());
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let addr = args.get_or("addr", "127.0.0.1:8787").to_string();
    let workers = args.get_parse_or("workers", 4usize).unwrap_or(4);
    let mut cfg = crate::serve::ServeConfig::default();
    cfg.cache_capacity = args
        .get_parse_or("cache", cfg.cache_capacity)
        .unwrap_or(cfg.cache_capacity);
    cfg.threads = args
        .get_parse_or("threads", cfg.threads)
        .unwrap_or(cfg.threads);
    let handle = std::sync::Arc::new(crate::serve::ServeHandle::new(cfg));
    let server = match crate::serve::server::Server::bind(&addr, handle, workers) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to bind {addr}: {e}");
            return 1;
        }
    };
    match server.local_addr() {
        Ok(a) => println!(
            "serving on {a} ({workers} workers; line-delimited JSON, \
             {{\"op\":\"shutdown\"}} to stop)"
        ),
        Err(e) => {
            eprintln!("failed to read bound address: {e}");
            return 1;
        }
    }
    match server.run() {
        Ok(()) => {
            println!("server drained");
            0
        }
        Err(e) => {
            eprintln!("server error: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_mentions_all_commands() {
        let u = usage();
        for c in ["list", "sample", "bench", "query", "info", "serve", "lint"] {
            assert!(u.contains(c), "{c}");
        }
        // the bench usage names every target, including the new one
        assert!(u.contains("bench conjugate"));
    }

    #[test]
    fn query_registry_evaluates_paper_example() {
        let q = Query::parse("w = [1.0, 1.0], s = 1.0 | model = linreg").unwrap();
        let r = eval_query(&q, &query_registry(), None).unwrap();
        assert!(r.log_prob.is_finite());
    }

    #[test]
    fn sample_model_smc_carries_evidence() {
        // iters = particle count for the SMC sampler
        let mc = sample_model("hier_poisson", "smc", "stan", 64, 0, 2, 11, None, 1).unwrap();
        assert_eq!(mc.chains.len(), 2);
        assert_eq!(mc.chains[0].len(), 64);
        assert!(mc.chains[0].stats.log_evidence.is_finite());
        assert!(mc.log_evidence().unwrap().is_finite());
        // distinct seeds → distinct evidence estimates
        assert_ne!(
            mc.chains[0].stats.log_evidence,
            mc.chains[1].stats.log_evidence
        );
    }

    #[test]
    fn sample_model_fused_backend_runs() {
        // the default native backend: arena-fused reverse AD
        let mc = sample_model("hier_poisson", "hmc", "fused", 50, 50, 1, 9, None, 1).unwrap();
        assert_eq!(mc.chains.len(), 1);
        assert_eq!(mc.chains[0].len(), 50);
        assert!(mc.chains[0].stats.n_grad_evals > 0);
    }

    #[test]
    fn sample_model_lane_batched_gang() {
        // --lanes K: the chain count comes from the lane count
        let mc = sample_model("gauss_unknown", "nuts", "fused", 40, 40, 1, 17, None, 4).unwrap();
        assert_eq!(mc.chains.len(), 4);
        assert!(mc.chains.iter().all(|c| c.len() == 40));
        // lanes > 1 is an hmc/nuts-over-native-backend mode
        assert!(sample_model("gauss_unknown", "mh", "fused", 10, 10, 1, 1, None, 4).is_err());
        assert!(sample_model("gauss_unknown", "nuts", "stan", 10, 10, 1, 1, None, 4).is_err());
        assert!(sample_model("hier_poisson", "smc", "stan", 16, 0, 1, 1, None, 4).is_err());
    }

    #[test]
    fn sample_model_advi_draws_from_fitted_approximation() {
        // iters = posterior-draw count; stats.log_evidence carries the ELBO
        let mc = sample_model("gauss_unknown", "advi", "fused", 500, 0, 1, 21, None, 1).unwrap();
        assert_eq!(mc.chains.len(), 1);
        assert_eq!(mc.chains[0].len(), 500);
        assert!(mc.chains[0].stats.log_evidence.is_finite());
        // ground truth of the small-workload generator is m ≈ 1.5
        let m = mc.mean("m").unwrap();
        assert!((m - 1.5).abs() < 0.25, "m = {m}");
    }

    #[test]
    fn sample_model_rejects_unknown_backend_and_sampler() {
        assert!(sample_model("gauss_unknown", "hmc", "frobnicate", 10, 10, 1, 1, None, 1).is_err());
        assert!(sample_model("gauss_unknown", "slice", "fused", 10, 10, 1, 1, None, 1).is_err());
        // minibatch is an ADVI-only, native-backend-only mode
        assert!(sample_model("gauss_unknown", "hmc", "fused", 10, 10, 1, 1, Some(64), 1).is_err());
        assert!(sample_model("hier_poisson", "smc", "stan", 16, 0, 1, 1, Some(64), 1).is_err());
        assert!(sample_model("gauss_unknown", "advi", "stan", 10, 0, 1, 1, Some(64), 1).is_err());
    }

    #[test]
    fn sample_model_advi_minibatch_runs_on_the_tall_model() {
        // logreg_tall (full build: N=100k) with B=512: every step is a
        // genuine ~0.5% subsample; the chain comes back in constrained
        // space with the full-data ELBO in stats.log_evidence
        let mc =
            sample_model("logreg_tall", "advi", "fused", 200, 0, 1, 23, Some(512), 1).unwrap();
        assert_eq!(mc.chains.len(), 1);
        assert_eq!(mc.chains[0].len(), 200);
        assert!(mc.chains[0].stats.log_evidence.is_finite());
        assert!(mc.chains[0].logp.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn sample_model_small_run() {
        let mc = sample_model("hier_poisson", "hmc", "stan", 100, 100, 2, 9, None, 1).unwrap();
        assert_eq!(mc.chains.len(), 2);
        assert_eq!(mc.chains[0].len(), 100);
        // a0 should be near 1 (ground truth) — loose check
        let a0 = mc.mean("a0").unwrap();
        assert!(a0.is_finite());
    }

    #[test]
    fn run_dispatches_unknown_command() {
        assert_eq!(run(vec!["frobnicate".into()]), 2);
        assert_eq!(run(vec!["help".into()]), 0);
    }
}
