//! Conjugacy detection over the recorded tilde program.
//!
//! A parent site is *certified conjugate* when every recorded use of its
//! value is a recognized child position of one conjugate family and the
//! glue between the parent's output register and that position is affine
//! (or identity / pure-scale, family-dependent). The certificate is purely
//! structural; the actual coefficients (the `a`, `b` of `a·x + b` glue,
//! the prior parameters, and every child's other-position value) are
//! extracted *numerically* at draw time by replaying the recording's
//! register file at two probe values of the parent — so hyperparameters
//! that are themselves functions of other sites stay exact under Gibbs.
//!
//! Recognized families:
//!
//! | parent prior        | child                             | glue on parent      |
//! |---------------------|-----------------------------------|---------------------|
//! | `Normal`            | `Normal` mean                     | affine `a·x + b`    |
//! | `InverseGamma`      | `Normal` sd                       | `sqrt(a·x)` (pure)  |
//! | `Gamma`             | `Poisson` rate                    | pure scale `a·x`    |
//! | `Beta`              | `Bernoulli` p                     | identity            |
//! | `Dirichlet`         | `add_obs_logp(w[k].ln())` terms   | `ln(w[k])` only     |
//!
//! Children may be observations (scalar, plate, int) *or* latent assume
//! sites — a latent child contributes its current trace value to the
//! conditional, which is exactly Gibbs. Any unrecognized dependent use
//! (non-affine glue, a dependent `ObsLogp`, a dependent position of the
//! wrong kind) kills the certificate and the site stays on the generic
//! samplers.

use std::collections::BTreeSet;

use crate::ad::record::{Op, Src};
use crate::dist::{bijector, DiscreteDist, Normal, ScalarDist, VecDist};
use crate::model::compiled::{visit_item_srcs, visit_op_srcs, Item, Recording};
use crate::obs::metrics::{self, Counter};
use crate::util::rng::Rng;
use crate::varinfo::TypedVarInfo;

use super::graph::{DepMap, SiteGraph};

/// The five recognized conjugate parent/child families.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ConjugateFamily {
    NormalNormal,
    NormalInverseGamma,
    GammaPoisson,
    BetaBernoulli,
    DirichletCategorical,
}

impl ConjugateFamily {
    pub fn key(&self) -> &'static str {
        match self {
            ConjugateFamily::NormalNormal => "normal-normal",
            ConjugateFamily::NormalInverseGamma => "normal-inverse-gamma",
            ConjugateFamily::GammaPoisson => "gamma-poisson",
            ConjugateFamily::BetaBernoulli => "beta-bernoulli",
            ConjugateFamily::DirichletCategorical => "dirichlet-categorical",
        }
    }
}

/// One recognized child term of a certificate.
#[derive(Clone, Debug)]
pub(crate) enum Child {
    /// A recording item (observe / plate / latent assume). For latent
    /// children the value is read from the live trace at draw time.
    Item {
        item: usize,
        latent_slot: Option<usize>,
    },
    /// Dirichlet only: one observed draw of category `k` recorded as
    /// `add_obs_logp(w[k].ln())`.
    Category { k: usize },
}

/// A certified conjugate site: the proof that its full conditional is
/// available in closed form given the current values of every other site.
#[derive(Clone, Debug)]
pub struct ConjugacyCert {
    /// Site index into the [`SiteGraph`].
    pub site: usize,
    /// Slot index into `TypedVarInfo::slots()`.
    pub slot: usize,
    /// Recording item index of the parent's assume.
    pub(crate) item: usize,
    /// Full varname of the parent site.
    pub name: String,
    pub family: ConjugateFamily,
    /// Number of recognized child terms (plate rows count individually).
    pub n_children: usize,
    pub(crate) children: Vec<Child>,
}

// ------------------------------------------------------- classification

/// Affinity of a register's value in the parent's output `x`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Aff {
    /// Does not depend on `x`.
    Indep,
    /// `a·x + b`; `pure` means `b = 0` (built via `Mul`/`Div`/`Neg` only).
    Lin { pure: bool },
    /// `c·sqrt(a·x)` with pure inner scale — the `sd = sqrt(a·v)` shape.
    SqrtLin,
    /// Any other dependent shape.
    Bad,
}

fn src_cls(cls: &[Aff], dep: &DepMap, site: usize, s: &Src) -> Aff {
    match s {
        Src::Const(_) => Aff::Indep,
        Src::Reg(r) => {
            if dep.reg_depends(*r, site) {
                cls[*r as usize]
            } else {
                Aff::Indep
            }
        }
    }
}

/// One pass over the opcode stream classifying every register's shape in
/// the parent's output. Registers independent of the parent stay `Indep`;
/// SSA ordering guarantees inputs are classified before use.
fn classify(rec: &Recording, dep: &DepMap, site: usize, x_reg: u32) -> Vec<Aff> {
    let mut cls = vec![Aff::Indep; rec.n_regs as usize];
    cls[x_reg as usize] = Aff::Lin { pure: true };
    for rop in &rec.ops {
        if !dep.reg_depends(rop.out, site) {
            continue;
        }
        let c = |s: &Src| src_cls(&cls, dep, site, s);
        let out_cls = match &rop.op {
            Op::Add(a, b) | Op::Sub(a, b) => match (c(a), c(b)) {
                (Aff::Lin { pure: p1 }, Aff::Lin { pure: p2 }) => Aff::Lin { pure: p1 && p2 },
                (Aff::Lin { .. }, Aff::Indep) | (Aff::Indep, Aff::Lin { .. }) => {
                    Aff::Lin { pure: false }
                }
                _ => Aff::Bad,
            },
            Op::Mul(a, b) => match (c(a), c(b)) {
                (Aff::Lin { pure }, Aff::Indep) | (Aff::Indep, Aff::Lin { pure }) => {
                    Aff::Lin { pure }
                }
                (Aff::SqrtLin, Aff::Indep) | (Aff::Indep, Aff::SqrtLin) => Aff::SqrtLin,
                _ => Aff::Bad,
            },
            Op::Div(a, b) => match (c(a), c(b)) {
                (Aff::Lin { pure }, Aff::Indep) => Aff::Lin { pure },
                (Aff::SqrtLin, Aff::Indep) => Aff::SqrtLin,
                _ => Aff::Bad,
            },
            Op::Neg(r) => match src_cls(&cls, dep, site, &Src::Reg(*r)) {
                Aff::Lin { pure } => Aff::Lin { pure },
                _ => Aff::Bad,
            },
            Op::Sqrt(r) => match src_cls(&cls, dep, site, &Src::Reg(*r)) {
                Aff::Lin { pure: true } => Aff::SqrtLin,
                _ => Aff::Bad,
            },
            _ => Aff::Bad,
        };
        cls[rop.out as usize] = out_cls;
    }
    cls
}

// ----------------------------------------------------------- detection

/// Scan every site for a certifiable conjugate pattern.
pub(crate) fn detect(rec: &Recording, dep: &DepMap, graph: &SiteGraph) -> Vec<ConjugacyCert> {
    let mut certs = Vec::new();
    for (si, site) in graph.sites.iter().enumerate() {
        let cert = match &rec.items[site.item].item {
            Item::AssumeScalar { out, dist, .. } => {
                let family = match dist {
                    ScalarDist::Normal(_) => Some(ConjugateFamily::NormalNormal),
                    ScalarDist::InverseGamma(_) => Some(ConjugateFamily::NormalInverseGamma),
                    ScalarDist::Gamma(_) => Some(ConjugateFamily::GammaPoisson),
                    ScalarDist::Beta(_) => Some(ConjugateFamily::BetaBernoulli),
                    _ => None,
                };
                family.and_then(|f| scalar_cert(rec, dep, si, site.item, site.slot, *out, f))
                    .map(|mut c| {
                        c.name = site.name.clone();
                        c
                    })
            }
            Item::AssumeVec {
                out,
                dist: VecDist::Dirichlet(_),
                ..
            } => dirichlet_cert(rec, dep, si, site.item, site.slot, out).map(|mut c| {
                c.name = site.name.clone();
                c
            }),
            _ => None,
        };
        if let Some(c) = cert {
            certs.push(c);
        }
    }
    certs
}

fn rows_of(item: &Item) -> usize {
    match item {
        Item::PlateScalar { obs, .. } => obs.len(),
        Item::PlateInt { obs, .. } => obs.len(),
        _ => 1,
    }
}

fn scalar_cert(
    rec: &Recording,
    dep: &DepMap,
    si: usize,
    parent_item: usize,
    slot: usize,
    x_reg: u32,
    family: ConjugateFamily,
) -> Option<ConjugacyCert> {
    let cls = classify(rec, dep, si, x_reg);
    let c = |s: &Src| src_cls(&cls, dep, si, s);
    let mut children: Vec<Child> = Vec::new();
    let mut n_children = 0usize;
    for (ii, ri) in rec.items.iter().enumerate() {
        if ii == parent_item {
            continue;
        }
        let mut involved = false;
        visit_item_srcs(&ri.item, &mut |s| involved |= dep.src_depends(s, si));
        if !involved {
            continue;
        }
        let child = match (family, &ri.item) {
            // Normal parent feeding a Normal child's mean (affine), sd free
            (
                ConjugateFamily::NormalNormal,
                Item::Observe {
                    dist: ScalarDist::Normal(_),
                    ps,
                    ..
                },
            )
            | (
                ConjugateFamily::NormalNormal,
                Item::PlateScalar {
                    dist: ScalarDist::Normal(_),
                    ps,
                    ..
                },
            ) if matches!(c(&ps[0]), Aff::Lin { .. }) && c(&ps[1]) == Aff::Indep => Some(Child::Item {
                item: ii,
                latent_slot: None,
            }),
            (
                ConjugateFamily::NormalNormal,
                Item::AssumeScalar {
                    dist: ScalarDist::Normal(_),
                    ps,
                    slot: cslot,
                    ..
                },
            ) if matches!(c(&ps[0]), Aff::Lin { .. }) && c(&ps[1]) == Aff::Indep => Some(Child::Item {
                item: ii,
                latent_slot: Some(*cslot),
            }),
            // InverseGamma parent feeding a Normal child's sd as sqrt(a·x)
            (
                ConjugateFamily::NormalInverseGamma,
                Item::Observe {
                    dist: ScalarDist::Normal(_),
                    ps,
                    ..
                },
            )
            | (
                ConjugateFamily::NormalInverseGamma,
                Item::PlateScalar {
                    dist: ScalarDist::Normal(_),
                    ps,
                    ..
                },
            ) if c(&ps[1]) == Aff::SqrtLin && c(&ps[0]) == Aff::Indep => Some(Child::Item {
                item: ii,
                latent_slot: None,
            }),
            (
                ConjugateFamily::NormalInverseGamma,
                Item::AssumeScalar {
                    dist: ScalarDist::Normal(_),
                    ps,
                    slot: cslot,
                    ..
                },
            ) if c(&ps[1]) == Aff::SqrtLin && c(&ps[0]) == Aff::Indep => Some(Child::Item {
                item: ii,
                latent_slot: Some(*cslot),
            }),
            // Gamma parent feeding a Poisson rate as a pure scale a·x
            (
                ConjugateFamily::GammaPoisson,
                Item::ObserveInt {
                    dist: DiscreteDist::Poisson(_),
                    p,
                    ..
                },
            )
            | (
                ConjugateFamily::GammaPoisson,
                Item::PlateInt {
                    dist: DiscreteDist::Poisson(_),
                    p,
                    ..
                },
            ) if c(p) == (Aff::Lin { pure: true }) => Some(Child::Item {
                item: ii,
                latent_slot: None,
            }),
            (
                ConjugateFamily::GammaPoisson,
                Item::AssumeInt {
                    dist: DiscreteDist::Poisson(_),
                    p,
                    slot: cslot,
                },
            ) if c(p) == (Aff::Lin { pure: true }) => Some(Child::Item {
                item: ii,
                latent_slot: Some(*cslot),
            }),
            // Beta parent feeding a Bernoulli p — identity only
            (
                ConjugateFamily::BetaBernoulli,
                Item::ObserveInt {
                    dist: DiscreteDist::Bernoulli(_),
                    p: Src::Reg(r),
                    ..
                },
            )
            | (
                ConjugateFamily::BetaBernoulli,
                Item::PlateInt {
                    dist: DiscreteDist::Bernoulli(_),
                    p: Src::Reg(r),
                    ..
                },
            ) if *r == x_reg => Some(Child::Item {
                item: ii,
                latent_slot: None,
            }),
            (
                ConjugateFamily::BetaBernoulli,
                Item::AssumeInt {
                    dist: DiscreteDist::Bernoulli(_),
                    p: Src::Reg(r),
                    slot: cslot,
                },
            ) if *r == x_reg => Some(Child::Item {
                item: ii,
                latent_slot: Some(*cslot),
            }),
            _ => None,
        };
        match child {
            Some(ch) => {
                n_children += rows_of(&ri.item);
                children.push(ch);
            }
            // an unrecognized dependent use — no certificate
            None => return None,
        }
    }
    if children.is_empty() {
        return None;
    }
    Some(ConjugacyCert {
        site: si,
        slot,
        item: parent_item,
        name: String::new(),
        family,
        n_children,
        children,
    })
}

fn dirichlet_cert(
    rec: &Recording,
    dep: &DepMap,
    si: usize,
    parent_item: usize,
    slot: usize,
    out: &[u32],
) -> Option<ConjugacyCert> {
    // Every dependent opcode must be `Ln(w[k])`; record which category
    // each such register logs.
    let mut ln_of: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    for rop in &rec.ops {
        let mut involved = false;
        visit_op_srcs(&rop.op, &mut |s| involved |= dep.src_depends(s, si));
        if !involved {
            continue;
        }
        match &rop.op {
            Op::Ln(r) => match out.iter().position(|&w| w == *r) {
                Some(k) => {
                    ln_of.insert(rop.out, k);
                }
                None => return None,
            },
            _ => return None,
        }
    }
    let mut children = Vec::new();
    for (ii, ri) in rec.items.iter().enumerate() {
        if ii == parent_item {
            continue;
        }
        let mut involved = false;
        visit_item_srcs(&ri.item, &mut |s| involved |= dep.src_depends(s, si));
        if !involved {
            continue;
        }
        match &ri.item {
            Item::ObsLogp { lp: Src::Reg(r) } => match ln_of.get(r) {
                Some(&k) => children.push(Child::Category { k }),
                None => return None,
            },
            _ => return None,
        }
    }
    if children.is_empty() {
        return None;
    }
    Some(ConjugacyCert {
        site: si,
        slot,
        item: parent_item,
        name: String::new(),
        family: ConjugateFamily::DirichletCategorical,
        n_children: children.len(),
        children,
    })
}

// ------------------------------------------------------- replay / draw

fn src_val(regs: &[f64], s: &Src) -> f64 {
    match s {
        Src::Reg(r) => regs[*r as usize],
        Src::Const(c) => *c,
    }
}

fn eval_op(regs: &[f64], op: &Op) -> f64 {
    use crate::util::math;
    match op {
        Op::Add(a, b) => src_val(regs, a) + src_val(regs, b),
        Op::Sub(a, b) => src_val(regs, a) - src_val(regs, b),
        Op::Mul(a, b) => src_val(regs, a) * src_val(regs, b),
        Op::Div(a, b) => src_val(regs, a) / src_val(regs, b),
        Op::Neg(r) => -regs[*r as usize],
        Op::Ln(r) => regs[*r as usize].ln(),
        Op::Exp(r) => regs[*r as usize].exp(),
        Op::Sqrt(r) => regs[*r as usize].sqrt(),
        Op::Ln1p(r) => regs[*r as usize].ln_1p(),
        Op::Tanh(r) => regs[*r as usize].tanh(),
        Op::Sin(r) => regs[*r as usize].sin(),
        Op::Cos(r) => regs[*r as usize].cos(),
        Op::Lgamma(r) => math::lgamma(regs[*r as usize]),
        Op::Powi(r, i) => regs[*r as usize].powi(*i),
        Op::Powf(r, p) => regs[*r as usize].powf(*p),
        Op::Abs(r) => regs[*r as usize].abs(),
        Op::Log1pExp(r) => math::log1p_exp(regs[*r as usize]),
        Op::LogSigmoid(r) => math::log_sigmoid(regs[*r as usize]),
        Op::Sigmoid(r) => math::sigmoid(regs[*r as usize]),
        Op::LogAddExp(a, b) => math::log_add_exp(src_val(regs, a), src_val(regs, b)),
        Op::Lse(xs) => {
            let vals: Vec<f64> = xs.iter().map(|s| src_val(regs, s)).collect();
            math::log_sum_exp(&vals)
        }
    }
}

/// Replay the recording's register file at `theta`, optionally overriding
/// one scalar slot's *constrained* value (the conjugacy probe). Assume
/// registers are seeded through the slot bijectors — the same primal
/// arithmetic the recorder itself ran — and glue opcodes are interpreted
/// in order.
pub(crate) fn eval_regs(
    rec: &Recording,
    tvi: &TypedVarInfo,
    theta: &[f64],
    override_slot: Option<(usize, f64)>,
    regs: &mut Vec<f64>,
) {
    regs.clear();
    regs.resize(rec.n_regs as usize, 0.0);
    let slots = tvi.slots();
    let mut cursor = 0usize;
    let mut buf: Vec<f64> = Vec::new();
    for ri in &rec.items {
        while cursor < ri.glue_end {
            let rop = &rec.ops[cursor];
            regs[rop.out as usize] = eval_op(regs, &rop.op);
            cursor += 1;
        }
        match &ri.item {
            Item::AssumeScalar { slot, out, .. } => {
                let s = &slots[*slot];
                let x = match override_slot {
                    Some((os, v)) if os == *slot => v,
                    _ => bijector::invlink_scalar_adj(&s.domain, theta[s.unc_offset]).x,
                };
                regs[*out as usize] = x;
            }
            Item::AssumeVec { slot, out, .. } => {
                let s = &slots[*slot];
                buf.clear();
                buf.resize(s.cons_len, 0.0);
                bijector::invlink_slice(
                    &s.domain,
                    &theta[s.unc_offset..s.unc_offset + s.unc_len],
                    &mut buf,
                );
                for (&r, &x) in out.iter().zip(buf.iter()) {
                    regs[r as usize] = x;
                }
            }
            _ => {}
        }
    }
    while cursor < rec.ops.len() {
        let rop = &rec.ops[cursor];
        regs[rop.out as usize] = eval_op(regs, &rop.op);
        cursor += 1;
    }
}

/// A child term's value rows plus its parameter sources, resolved for the
/// accumulation loops below.
fn child_rows<'a>(
    rec: &'a Recording,
    tvi: &TypedVarInfo,
    theta: &[f64],
    ch: &Child,
) -> (Vec<f64>, &'a [Src; crate::dist::MAX_DIST_PARAMS], Option<&'a Src>) {
    static ZERO_PS: [Src; crate::dist::MAX_DIST_PARAMS] =
        [Src::Const(0.0), Src::Const(0.0)];
    let slots = tvi.slots();
    match ch {
        Child::Item { item, latent_slot } => match &rec.items[*item].item {
            Item::Observe { ps, obs, .. } => (vec![*obs], ps, None),
            Item::PlateScalar { ps, obs, .. } => (obs.clone(), ps, None),
            Item::AssumeScalar { ps, .. } => {
                let s = &slots[latent_slot.expect("latent scalar child without slot")];
                let x = bijector::invlink_scalar_adj(&s.domain, theta[s.unc_offset]).x;
                (vec![x], ps, None)
            }
            Item::ObserveInt { p, obs, .. } => (vec![*obs as f64], &ZERO_PS, Some(p)),
            Item::PlateInt { p, obs, .. } => {
                (obs.iter().map(|&k| k as f64).collect(), &ZERO_PS, Some(p))
            }
            Item::AssumeInt { p, .. } => {
                let s = &slots[latent_slot.expect("latent int child without slot")];
                (vec![tvi.discrete[s.disc_offset] as f64], &ZERO_PS, Some(p))
            }
            other => unreachable!("unexpected conjugate child item {:?}", std::mem::discriminant(other)),
        },
        Child::Category { .. } => unreachable!("category child has no rows"),
    }
}

/// Closed-form posterior parameters of a certified scalar site given the
/// current trace, extracted by two-point probing of the register file.
/// Returned as `(p1, p2)` with family-dependent meaning: Normal →
/// `(mean, sd)`, InverseGamma → `(shape, scale)`, Gamma → `(shape, rate)`,
/// Beta → `(a, b)`.
pub(crate) fn scalar_posterior(
    rec: &Recording,
    cert: &ConjugacyCert,
    tvi: &TypedVarInfo,
    theta: &[f64],
) -> (f64, f64) {
    let (x0, x1) = match cert.family {
        ConjugateFamily::NormalNormal => (0.0, 1.0),
        ConjugateFamily::NormalInverseGamma | ConjugateFamily::GammaPoisson => (1.0, 2.0),
        ConjugateFamily::BetaBernoulli => (0.25, 0.5),
        ConjugateFamily::DirichletCategorical => unreachable!("scalar posterior on Dirichlet"),
    };
    let mut r0 = Vec::new();
    let mut r1 = Vec::new();
    eval_regs(rec, tvi, theta, Some((cert.slot, x0)), &mut r0);
    eval_regs(rec, tvi, theta, Some((cert.slot, x1)), &mut r1);
    let Item::AssumeScalar { ps, .. } = &rec.items[cert.item].item else {
        unreachable!("scalar cert over non-scalar parent")
    };
    let h0 = src_val(&r0, &ps[0]);
    let h1 = src_val(&r0, &ps[1]);
    match cert.family {
        ConjugateFamily::NormalNormal => {
            let (mu0, sd0) = (h0, h1);
            let mut prec = 1.0 / (sd0 * sd0);
            let mut num = mu0 * prec;
            for ch in &cert.children {
                let (rows, ps, _) = child_rows(rec, tvi, theta, ch);
                let m0 = src_val(&r0, &ps[0]);
                let m1 = src_val(&r1, &ps[0]);
                let a = (m1 - m0) / (x1 - x0);
                let b = m0 - a * x0;
                let sd = src_val(&r0, &ps[1]);
                let w = a / (sd * sd);
                for y in rows {
                    prec += a * w;
                    num += w * (y - b);
                }
            }
            let var = 1.0 / prec;
            (num * var, var.sqrt())
        }
        ConjugateFamily::NormalInverseGamma => {
            let (mut shape, mut scale) = (h0, h1);
            for ch in &cert.children {
                let (rows, ps, _) = child_rows(rec, tvi, theta, ch);
                let s_probe = src_val(&r0, &ps[1]);
                // sd(x) = sqrt(a·x)  ⇒  a = sd(x0)² / x0
                let a = s_probe * s_probe / x0;
                let mu = src_val(&r0, &ps[0]);
                for y in rows {
                    shape += 0.5;
                    scale += (y - mu) * (y - mu) / (2.0 * a);
                }
            }
            (shape, scale)
        }
        ConjugateFamily::GammaPoisson => {
            let (mut shape, mut rate) = (h0, h1);
            for ch in &cert.children {
                let (rows, _, p) = child_rows(rec, tvi, theta, ch);
                let p = p.expect("Poisson child without rate src");
                // rate(x) = a·x (pure)  ⇒  a = rate(x0) / x0
                let a = src_val(&r0, p) / x0;
                for k in rows {
                    shape += k;
                    rate += a;
                }
            }
            (shape, rate)
        }
        ConjugateFamily::BetaBernoulli => {
            let (mut a, mut b) = (h0, h1);
            for ch in &cert.children {
                let (rows, _, _) = child_rows(rec, tvi, theta, ch);
                for k in rows {
                    if k >= 0.5 {
                        a += 1.0;
                    } else {
                        b += 1.0;
                    }
                }
            }
            (a, b)
        }
        ConjugateFamily::DirichletCategorical => unreachable!(),
    }
}

/// Draw the certified site from its exact full conditional and write the
/// new value back into `theta` (through the slot's link bijector).
pub(crate) fn draw(
    rec: &Recording,
    cert: &ConjugacyCert,
    tvi: &TypedVarInfo,
    theta: &mut [f64],
    rng: &mut dyn Rng,
) {
    let slots = tvi.slots();
    let pslot = &slots[cert.slot];
    let mut buf: Vec<f64> = Vec::new();
    if cert.family == ConjugateFamily::DirichletCategorical {
        let Item::AssumeVec {
            dist: VecDist::Dirichlet(d),
            ..
        } = &rec.items[cert.item].item
        else {
            unreachable!("Dirichlet cert over non-Dirichlet parent")
        };
        let mut alpha = d.alpha.clone();
        for ch in &cert.children {
            if let Child::Category { k } = ch {
                alpha[*k] += 1.0;
            }
        }
        let mut xs = vec![0.0; alpha.len()];
        rng.dirichlet_into(&alpha, &mut xs);
        // keep the draw strictly interior so the link stays finite
        let mut total = 0.0;
        for x in xs.iter_mut() {
            *x = x.max(1e-12);
            total += *x;
        }
        for x in xs.iter_mut() {
            *x /= total;
        }
        bijector::link(&pslot.domain, &xs, &mut buf);
        theta[pslot.unc_offset..pslot.unc_offset + pslot.unc_len].copy_from_slice(&buf);
        metrics::inc(Counter::ConjugateDraws);
        return;
    }
    let (p1, p2) = scalar_posterior(rec, cert, tvi, theta);
    let x_new = match cert.family {
        ConjugateFamily::NormalNormal => p1 + p2 * rng.normal(),
        ConjugateFamily::NormalInverseGamma => (p2 / rng.gamma(p1)).max(1e-300),
        ConjugateFamily::GammaPoisson => (rng.gamma(p1) / p2).max(1e-300),
        ConjugateFamily::BetaBernoulli => rng.beta(p1, p2).clamp(1e-12, 1.0 - 1e-12),
        ConjugateFamily::DirichletCategorical => unreachable!(),
    };
    bijector::link(&pslot.domain, &[x_new], &mut buf);
    theta[pslot.unc_offset..pslot.unc_offset + pslot.unc_len].copy_from_slice(&buf);
    metrics::inc(Counter::ConjugateDraws);
}

/// Exact per-observation collapsed log-weights `log p(y_t | y_{1:t-1})`
/// for a single-site Normal–Normal model: the parent is marginalized in
/// closed form by sequential conjugate updating. Only certified when the
/// parent is the model's *only* site and every observation term is one of
/// its recognized children — then the sum of the returned weights is the
/// model's exact log-evidence (the Rao-Blackwellized, zero-variance form
/// of the SMC estimate).
pub(crate) fn collapsed_logweights(
    rec: &Recording,
    cert: &ConjugacyCert,
    tvi: &TypedVarInfo,
    graph: &SiteGraph,
) -> Option<Vec<f64>> {
    if cert.family != ConjugateFamily::NormalNormal || graph.sites.len() != 1 {
        return None;
    }
    let mut child_items = BTreeSet::new();
    for ch in &cert.children {
        match ch {
            Child::Item {
                item,
                latent_slot: None,
            } => {
                child_items.insert(*item);
            }
            _ => return None,
        }
    }
    for (ii, ri) in rec.items.iter().enumerate() {
        if super::graph::is_obs_item(&ri.item) && !child_items.contains(&ii) {
            return None;
        }
    }
    let theta = &tvi.unconstrained;
    let (x0, x1) = (0.0, 1.0);
    let mut r0 = Vec::new();
    let mut r1 = Vec::new();
    eval_regs(rec, tvi, theta, Some((cert.slot, x0)), &mut r0);
    eval_regs(rec, tvi, theta, Some((cert.slot, x1)), &mut r1);
    let Item::AssumeScalar { ps, .. } = &rec.items[cert.item].item else {
        return None;
    };
    let mut mu = src_val(&r0, &ps[0]);
    let sd0 = src_val(&r0, &ps[1]);
    let mut var = sd0 * sd0;
    let mut out = Vec::with_capacity(cert.n_children);
    for ch in &cert.children {
        let (rows, cps, _) = child_rows(rec, tvi, theta, ch);
        let m0 = src_val(&r0, &cps[0]);
        let m1 = src_val(&r1, &cps[0]);
        let a = (m1 - m0) / (x1 - x0);
        let b = m0 - a * x0;
        let sd = src_val(&r0, &cps[1]);
        let s2 = sd * sd;
        for y in rows {
            // predictive: y ~ N(a·mu + b, a²·var + sd²)
            let pvar = a * a * var + s2;
            out.push(Normal::new(a * mu + b, pvar.sqrt()).logpdf(y));
            // posterior update
            let prec = 1.0 / var + a * a / s2;
            let num = mu / var + a * (y - b) / s2;
            var = 1.0 / prec;
            mu = num * var;
        }
    }
    Some(out)
}
