//! Static model analysis over the recorded tilde program.
//!
//! The SlicStan half that PR 8's structure compiler left open: given the
//! slot-resolved recording of one model walk, build the
//! [site-dependency graph](graph::SiteGraph), certify
//! [conjugate parent/child pairs](conjugacy::ConjugacyCert) for
//! Rao-Blackwellized Gibbs/SMC, and run the
//! [Stan-pedantic-parity lints](lint) behind `dppl lint`. Everything here
//! is a pure function of the recording — no sampler runs, no model
//! re-execution beyond the (verified) recording passes themselves.

pub mod conjugacy;
pub mod graph;
pub mod lint;

pub use conjugacy::{ConjugacyCert, ConjugateFamily};
pub use graph::{PlateInfo, SiteGraph, SiteInfo};
pub use lint::{lint_model, LintFinding, LintReport, Severity};

use crate::model::compiled::{self, Recording};
use crate::model::Model;
use crate::util::rng::Rng;
use crate::varinfo::TypedVarInfo;

use graph::DepMap;

/// The full static analysis of one model: dependency graph + conjugacy
/// certificates, plus the private recording the draw path replays.
pub struct ModelAnalysis {
    pub graph: SiteGraph,
    pub certs: Vec<ConjugacyCert>,
    rec: Recording,
    #[allow(dead_code)]
    dep: DepMap,
}

/// Analyze a model against its typed trace.
///
/// Uses the *strict* double-record gate
/// ([`compiled::record_verified`]): the walk is recorded at θ and at a
/// perturbed θ ± 0.125, and analysis proceeds only when both recordings
/// are structurally identical — a conjugacy certificate must never be
/// issued against a θ-dependent walk. Models with discrete sites keep
/// their graph but receive no certificates: a Gibbs move on a discrete
/// site can change the walk in ways the continuous perturbation gate
/// cannot see.
pub fn analyze(model: &dyn Model, tvi: &TypedVarInfo) -> Option<ModelAnalysis> {
    let rec = compiled::record_verified(model, tvi)?;
    let (g, dep) = graph::build(&rec, tvi);
    let certs = if g.sites.iter().any(|s| s.is_discrete) {
        Vec::new()
    } else {
        conjugacy::detect(&rec, &dep, &g)
    };
    Some(ModelAnalysis {
        graph: g,
        certs,
        rec,
        dep,
    })
}

impl ModelAnalysis {
    /// The certificate covering `slot`, if one was issued.
    pub fn cert_for_slot(&self, slot: usize) -> Option<&ConjugacyCert> {
        self.certs.iter().find(|c| c.slot == slot)
    }

    /// Draw `cert`'s site from its exact closed-form full conditional
    /// given the current `theta` / discrete trace, writing the new value
    /// back into `theta` through the slot's link bijector. Bitwise
    /// deterministic for a fixed rng stream.
    pub fn draw_conjugate(
        &self,
        cert: &ConjugacyCert,
        tvi: &TypedVarInfo,
        theta: &mut [f64],
        rng: &mut dyn Rng,
    ) {
        conjugacy::draw(&self.rec, cert, tvi, theta, rng);
    }

    /// Exact per-observation collapsed log-weights for a single-site
    /// Normal–Normal model (see [`conjugacy`] module docs); `None` when
    /// the model does not qualify. The sum is the model's exact
    /// log-evidence.
    pub fn collapsed_logweights(&self, tvi: &TypedVarInfo) -> Option<Vec<f64>> {
        let cert = self.certs.first()?;
        conjugacy::collapsed_logweights(&self.rec, cert, tvi, &self.graph)
    }
}
