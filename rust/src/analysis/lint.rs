//! Stan-pedantic-parity model lints over the site-dependency graph.
//!
//! Every lint is structural — it reads the recorded tilde program and the
//! dependency graph, never sampler output — so `dppl lint` runs in one
//! model walk. The recording pass is *lenient*
//! ([`crate::model::compiled::record_for_analysis`]): a model whose
//! density is non-finite at the init point is precisely the kind of
//! defect the linter exists to surface, so only a rejected (truncated)
//! walk refuses analysis.
//!
//! | code                   | severity | fires when                                      |
//! |------------------------|----------|--------------------------------------------------|
//! | `domain-mismatch`      | error    | a parameter feeds a distribution position whose  |
//! |                        |          | support its declared domain does not guarantee   |
//! | `dead-parameter`       | warning  | a continuous parameter has no dataflow path to   |
//! |                        |          | any observation (posterior = prior)              |
//! | `centered-funnel`      | warning  | a Normal/IsoNormal site's scale depends on       |
//! |                        |          | another parameter (centered hierarchical prior)  |
//! | `constant-data-plate`  | warning  | an observation plate's values are all identical  |
//! | `discrete-no-gradient` | warning  | a discrete site exists (invisible to HMC/NUTS)   |

use std::collections::BTreeMap;

use crate::ad::record::Src;
use crate::dist::{DiscreteDist, Domain, ScalarDist, VecDist};
use crate::model::compiled::{self, visit_item_srcs, Item, Recording};
use crate::model::Model;
use crate::obs::metrics::{self, Counter};
use crate::util::json::escape;
use crate::varinfo::TypedVarInfo;

use super::graph::{self, DepMap, SiteGraph};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn key(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One deduplicated lint finding. Sites that differ only by index (e.g.
/// `h[0]` … `h[499]`) collapse to one finding on the base symbol with
/// `count` occurrences.
#[derive(Clone, Debug)]
pub struct LintFinding {
    pub code: &'static str,
    pub severity: Severity,
    /// Base site symbol (or `plate[i]` for plate-level findings).
    pub site: String,
    pub message: String,
    pub hint: Option<String>,
    /// Number of concrete sites/rows collapsed into this finding.
    pub count: usize,
}

#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub findings: Vec<LintFinding>,
    pub n_sites: usize,
    pub n_obs_items: usize,
}

impl LintReport {
    pub fn n_errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn n_warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    pub fn has_errors(&self) -> bool {
        self.n_errors() > 0
    }

    pub fn has_code(&self, code: &str) -> bool {
        self.findings.iter().any(|f| f.code == code)
    }

    /// Machine-readable report, same hand-rolled JSON style as
    /// `obs::report`.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        s.push_str(&format!("\"n_sites\":{},", self.n_sites));
        s.push_str(&format!("\"n_obs_items\":{},", self.n_obs_items));
        s.push_str(&format!("\"errors\":{},", self.n_errors()));
        s.push_str(&format!("\"warnings\":{},", self.n_warnings()));
        s.push_str("\"findings\":[");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"code\":\"{}\",\"severity\":\"{}\",\"site\":\"{}\",\"count\":{},\"message\":\"{}\"",
                f.code,
                f.severity.key(),
                escape(&f.site),
                f.count,
                escape(&f.message)
            ));
            match &f.hint {
                Some(h) => s.push_str(&format!(",\"hint\":\"{}\"}}", escape(h))),
                None => s.push_str(",\"hint\":null}"),
            }
        }
        s.push_str("]}");
        s
    }

    /// Human-readable one-line-per-finding rendering for the CLI.
    pub fn render(&self) -> String {
        if self.findings.is_empty() {
            return format!(
                "no findings ({} sites, {} observation terms)\n",
                self.n_sites, self.n_obs_items
            );
        }
        let mut s = String::new();
        for f in &self.findings {
            let mult = if f.count > 1 {
                format!(" (x{})", f.count)
            } else {
                String::new()
            };
            s.push_str(&format!(
                "{}: [{}] {}{}: {}\n",
                f.severity.key(),
                f.code,
                f.site,
                mult,
                f.message
            ));
            if let Some(h) = &f.hint {
                s.push_str(&format!("    hint: {h}\n"));
            }
        }
        s
    }
}

/// What a distribution position requires of the value it is fed.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Req {
    Positive,
    UnitInterval,
}

impl Req {
    fn describe(&self) -> &'static str {
        match self {
            Req::Positive => "a positive value",
            Req::UnitInterval => "a value in [0, 1]",
        }
    }
}

/// Per-position support requirements of an item's distribution. Positions
/// without constraints (means, logits, bounds) are `None`.
fn item_reqs(item: &Item) -> [(Option<Req>, &'static str); 2] {
    let none = (None, "");
    match item {
        Item::AssumeScalar { dist, .. }
        | Item::Observe { dist, .. }
        | Item::PlateScalar { dist, .. } => match dist {
            ScalarDist::Normal(_) => [none, (Some(Req::Positive), "sd")],
            ScalarDist::InverseGamma(_) => {
                [(Some(Req::Positive), "shape"), (Some(Req::Positive), "scale")]
            }
            ScalarDist::Gamma(_) => [(Some(Req::Positive), "shape"), (Some(Req::Positive), "rate")],
            ScalarDist::Beta(_) => [(Some(Req::Positive), "a"), (Some(Req::Positive), "b")],
            ScalarDist::Exponential(_) => [(Some(Req::Positive), "rate"), none],
            ScalarDist::Uniform(_) => [none, none],
            ScalarDist::Cauchy(_) => [none, (Some(Req::Positive), "scale")],
            ScalarDist::HalfCauchy(_) => [(Some(Req::Positive), "scale"), none],
        },
        Item::AssumeVec { dist, .. } | Item::ObserveVec { dist, .. } => match dist {
            VecDist::IsoNormal(_) => [none, (Some(Req::Positive), "sd")],
            VecDist::Dirichlet(_) => [none, none],
        },
        Item::AssumeInt { dist, .. } | Item::ObserveInt { dist, .. } | Item::PlateInt { dist, .. } => {
            match dist {
                DiscreteDist::Bernoulli(_) => [(Some(Req::UnitInterval), "p"), none],
                DiscreteDist::Poisson(_) => [(Some(Req::Positive), "rate"), none],
                DiscreteDist::BernoulliLogit(_) | DiscreteDist::Categorical(_) => [none, none],
            }
        }
        _ => [none, none],
    }
}

/// Scalar-component domain of a register seeded directly by an assume.
#[derive(Clone, Copy)]
enum RegDomain {
    Real,
    Positive,
    Interval(f64, f64),
    SimplexComp,
}

impl RegDomain {
    fn guarantees(&self, req: Req) -> bool {
        match req {
            Req::Positive => match self {
                RegDomain::Positive | RegDomain::SimplexComp => true,
                RegDomain::Interval(lo, _) => *lo >= 0.0,
                RegDomain::Real => false,
            },
            Req::UnitInterval => match self {
                RegDomain::SimplexComp => true,
                RegDomain::Interval(lo, hi) => *lo >= 0.0 && *hi <= 1.0,
                RegDomain::Positive | RegDomain::Real => false,
            },
        }
    }

    fn describe(&self) -> String {
        match self {
            RegDomain::Real => "unconstrained (Real)".into(),
            RegDomain::Positive => "positive".into(),
            RegDomain::Interval(lo, hi) => format!("in [{lo}, {hi}]"),
            RegDomain::SimplexComp => "a simplex component".into(),
        }
    }
}

/// Lint a model: lenient-record the walk, build the graph, run the rules.
/// `None` when the walk rejected (nothing to analyze).
pub fn lint_model(model: &dyn Model, tvi: &TypedVarInfo) -> Option<LintReport> {
    let rec = compiled::record_for_analysis(model, tvi)?;
    let (g, dep) = graph::build(&rec, tvi);
    Some(lint_recording(&rec, tvi, &g, &dep))
}

pub(crate) fn lint_recording(
    rec: &Recording,
    tvi: &TypedVarInfo,
    g: &SiteGraph,
    dep: &DepMap,
) -> LintReport {
    let slots = tvi.slots();
    // dedup accumulator: (code, key) → finding
    let mut acc: BTreeMap<(&'static str, String), LintFinding> = BTreeMap::new();
    let mut push = |code: &'static str,
                    severity: Severity,
                    key: String,
                    message: String,
                    hint: Option<String>| {
        acc.entry((code, key.clone()))
            .and_modify(|f| f.count += 1)
            .or_insert(LintFinding {
                code,
                severity,
                site: key,
                message,
                hint,
                count: 1,
            });
    };

    // ---- dead-parameter: continuous sites with no path to an observation
    if g.n_obs_items > 0 {
        for site in &g.sites {
            if site.is_discrete || site.observed_reachable {
                continue;
            }
            push(
                "dead-parameter",
                Severity::Warning,
                site.sym.clone(),
                format!(
                    "parameter `{}` has no dataflow path to any observation; its posterior \
                     equals its prior (unidentifiable or dead code)",
                    site.name
                ),
                Some("remove the parameter or connect it to the likelihood".into()),
            );
        }
    }

    // ---- discrete-no-gradient
    for site in &g.sites {
        if site.is_discrete {
            push(
                "discrete-no-gradient",
                Severity::Warning,
                site.sym.clone(),
                format!(
                    "discrete parameter `{}` is invisible to gradient-based samplers \
                     (HMC/NUTS never resample it)",
                    site.name
                ),
                Some(
                    "sample it with a Gibbs `enumerate` block or Particle Gibbs, or \
                     marginalize it out"
                        .into(),
                ),
            );
        }
    }

    // ---- per-register origin domains (identity feeds only)
    let mut origin: Vec<Option<RegDomain>> = vec![None; rec.n_regs as usize];
    for ri in &rec.items {
        match &ri.item {
            Item::AssumeScalar { slot, out, .. } => {
                let d = match &slots[*slot].domain {
                    Domain::Real => RegDomain::Real,
                    Domain::Positive => RegDomain::Positive,
                    Domain::Interval(lo, hi) => RegDomain::Interval(*lo, *hi),
                    _ => continue,
                };
                origin[*out as usize] = Some(d);
            }
            Item::AssumeVec { slot, out, .. } => {
                let d = match &slots[*slot].domain {
                    Domain::RealVec(_) => RegDomain::Real,
                    Domain::PositiveVec(_) => RegDomain::Positive,
                    Domain::Simplex(_) => RegDomain::SimplexComp,
                    _ => continue,
                };
                for &r in out {
                    origin[r as usize] = Some(d);
                }
            }
            _ => {}
        }
    }
    // reg → owning site name, for messages
    let site_of_reg = |r: u32| -> Option<&str> {
        for site in &g.sites {
            match &rec.items[site.item].item {
                Item::AssumeScalar { out, .. } if *out == r => return Some(&site.name),
                Item::AssumeVec { out, .. } if out.contains(&r) => return Some(&site.name),
                _ => {}
            }
        }
        None
    };

    // ---- domain-mismatch: a parameter's register fed *directly* (identity
    // glue) into a position whose support its domain does not guarantee.
    // Restricting to identity feeds keeps this rule exact: transformed
    // feeds (exp(x), x².. ) change support and are not flagged.
    for ri in &rec.items {
        let reqs = item_reqs(&ri.item);
        let mut pos = 0usize;
        visit_item_srcs(&ri.item, &mut |s| {
            if let (Src::Reg(r), (Some(req), pname)) = (s, &reqs[pos.min(1)]) {
                if let Some(d) = origin[*r as usize] {
                    if !d.guarantees(*req) {
                        let owner = site_of_reg(*r).unwrap_or("<glue>").to_string();
                        push(
                            "domain-mismatch",
                            Severity::Error,
                            owner.clone(),
                            format!(
                                "parameter `{}` is {} but feeds the {} of a {} — requires {}",
                                owner,
                                d.describe(),
                                pname,
                                graph_item_family(&ri.item),
                                req.describe()
                            ),
                            Some(format!(
                                "declare `{owner}` with a prior matching the required support \
                                 (or transform it explicitly)"
                            )),
                        );
                    }
                }
            }
            pos += 1;
        });
    }

    // ---- centered-funnel: Normal/IsoNormal site whose scale depends on
    // another parameter — the classic centered hierarchical geometry.
    for site in &g.sites {
        let ri = &rec.items[site.item];
        let scale_src = match &ri.item {
            Item::AssumeScalar {
                dist: ScalarDist::Normal(_),
                ps,
                ..
            } => Some(&ps[1]),
            Item::AssumeVec {
                dist: VecDist::IsoNormal(_),
                ps,
                ..
            } => Some(&ps[1]),
            _ => None,
        };
        let Some(src) = scale_src else { continue };
        let mut dep_sites = std::collections::BTreeSet::new();
        dep.src_sites_into(src, &mut dep_sites);
        dep_sites.retain(|&s| !g.sites[s].is_discrete);
        if dep_sites.is_empty() {
            continue;
        }
        let parent = &g.sites[*dep_sites.iter().next().unwrap()];
        push(
            "centered-funnel",
            Severity::Warning,
            site.sym.clone(),
            format!(
                "`{}` is centered on parameter-dependent scale (depends on `{}`): \
                 the funnel geometry this creates is hard for HMC/NUTS",
                site.name, parent.name
            ),
            Some(format!(
                "non-center it: `{0}_raw ~ Normal(0, 1); {0} = loc + scale * {0}_raw`",
                site.sym
            )),
        );
    }

    // ---- constant-data-plate
    for (pi, plate) in g.plates.iter().enumerate() {
        if plate.rows >= 2 && plate.constant_data {
            push(
                "constant-data-plate",
                Severity::Warning,
                format!("plate[{pi}]"),
                format!(
                    "observation plate of {} {} rows holds bitwise-identical values — \
                     likely a data-loading bug",
                    plate.rows, plate.family
                ),
                Some("check the observed data column actually varies".into()),
            );
        }
    }

    let mut findings: Vec<LintFinding> = acc.into_values().collect();
    findings.sort_by_key(|f| (f.severity != Severity::Error, f.code, f.site.clone()));
    metrics::add(Counter::LintWarnings, findings.len() as u64);
    LintReport {
        findings,
        n_sites: g.sites.len(),
        n_obs_items: g.n_obs_items,
    }
}

fn graph_item_family(item: &Item) -> &'static str {
    match item {
        Item::AssumeScalar { dist, .. }
        | Item::Observe { dist, .. }
        | Item::PlateScalar { dist, .. } => graph::sdist_name(dist),
        Item::AssumeVec { dist, .. } | Item::ObserveVec { dist, .. } => graph::vdist_name(dist),
        Item::AssumeInt { dist, .. } | Item::ObserveInt { dist, .. } | Item::PlateInt { dist, .. } => {
            graph::ddist_name(dist)
        }
        _ => "term",
    }
}
