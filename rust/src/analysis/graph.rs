//! Site-dependency graph over the recorded tilde program.
//!
//! The PR-8 recorder ([`crate::model::compiled`]) already resolves every
//! tilde site to a slot and every scalar of glue arithmetic to a register
//! opcode. This module runs one forward dataflow pass over that IR and
//! produces, per parameter site: its **parent sites** (sites whose value
//! flows into one of its distribution parameters), its **children**, its
//! **Markov blanket** (parents ∪ children ∪ co-parents), whether it has
//! any dataflow path to an observation, and which observation **plates**
//! it feeds. No model re-execution happens — the analysis is purely over
//! the recording.

use crate::ad::record::Src;
use crate::model::compiled::{visit_item_srcs, visit_op_srcs, Item, Recording};
use crate::dist::{DiscreteDist, ScalarDist, VecDist};
use crate::varinfo::TypedVarInfo;

use std::collections::BTreeSet;

/// Human-readable family tag for a scalar distribution template.
pub(crate) fn sdist_name(d: &ScalarDist<f64>) -> &'static str {
    match d {
        ScalarDist::Normal(_) => "Normal",
        ScalarDist::InverseGamma(_) => "InverseGamma",
        ScalarDist::Gamma(_) => "Gamma",
        ScalarDist::Beta(_) => "Beta",
        ScalarDist::Exponential(_) => "Exponential",
        ScalarDist::Uniform(_) => "Uniform",
        ScalarDist::Cauchy(_) => "Cauchy",
        ScalarDist::HalfCauchy(_) => "HalfCauchy",
    }
}

pub(crate) fn vdist_name(d: &VecDist<f64>) -> &'static str {
    match d {
        VecDist::IsoNormal(_) => "IsoNormal",
        VecDist::Dirichlet(_) => "Dirichlet",
    }
}

pub(crate) fn ddist_name(d: &DiscreteDist<f64>) -> &'static str {
    match d {
        DiscreteDist::Bernoulli(_) => "Bernoulli",
        DiscreteDist::BernoulliLogit(_) => "BernoulliLogit",
        DiscreteDist::Poisson(_) => "Poisson",
        DiscreteDist::Categorical(_) => "Categorical",
    }
}

fn item_family(item: &Item) -> &'static str {
    match item {
        Item::AssumeScalar { dist, .. } | Item::Observe { dist, .. } | Item::PlateScalar { dist, .. } => {
            sdist_name(dist)
        }
        Item::AssumeVec { dist, .. } | Item::ObserveVec { dist, .. } => vdist_name(dist),
        Item::AssumeInt { dist, .. } | Item::ObserveInt { dist, .. } | Item::PlateInt { dist, .. } => {
            ddist_name(dist)
        }
        Item::ObsLogp { .. } => "logp",
        Item::PriorLogp { .. } => "logp",
        Item::SkipObs { .. } => "skip",
    }
}

/// Per-register parameter-site dependence, as a flat bitset (one row of
/// `words` × `u64` per register). Registers are SSA — each is written
/// exactly once, and only by opcodes/items that precede its uses — so a
/// single in-order pass computes the full transitive dependence.
pub(crate) struct DepMap {
    pub(crate) n_sites: usize,
    words: usize,
    bits: Vec<u64>,
    /// Item index → site index, for assume items.
    pub(crate) site_of_item: Vec<Option<usize>>,
}

impl DepMap {
    fn row(&self, r: u32) -> &[u64] {
        let w = self.words;
        &self.bits[r as usize * w..r as usize * w + w]
    }

    pub(crate) fn reg_depends(&self, r: u32, site: usize) -> bool {
        self.row(r)[site / 64] >> (site % 64) & 1 == 1
    }

    pub(crate) fn src_depends(&self, s: &Src, site: usize) -> bool {
        match s {
            Src::Reg(r) => self.reg_depends(*r, site),
            Src::Const(_) => false,
        }
    }

    /// Append every site the register depends on to `out`.
    fn reg_sites_into(&self, r: u32, out: &mut BTreeSet<usize>) {
        for (wi, &w) in self.row(r).iter().enumerate() {
            let mut bits = w;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out.insert(wi * 64 + b);
                bits &= bits - 1;
            }
        }
    }

    pub(crate) fn src_sites_into(&self, s: &Src, out: &mut BTreeSet<usize>) {
        if let Src::Reg(r) = s {
            self.reg_sites_into(*r, out);
        }
    }

    /// All sites any of the item's parameter sources depends on.
    pub(crate) fn item_sites(&self, item: &Item) -> BTreeSet<usize> {
        let mut set = BTreeSet::new();
        visit_item_srcs(item, &mut |s| self.src_sites_into(s, &mut set));
        set
    }
}

/// One parameter site (a recorded assume), with its graph neighborhood.
#[derive(Clone, Debug)]
pub struct SiteInfo {
    /// Full varname (e.g. `h[3]`).
    pub name: String,
    /// Base symbol (e.g. `h`) — the dedup key for per-plate site families.
    pub sym: String,
    /// Index into `TypedVarInfo::slots()`.
    pub slot: usize,
    /// Index of the recording item that declared this site.
    pub item: usize,
    pub is_discrete: bool,
    pub is_vec: bool,
    /// Prior distribution family name.
    pub family: &'static str,
    /// Sites whose value feeds this site's distribution parameters.
    pub parents: Vec<usize>,
    /// Sites whose distribution parameters this site feeds.
    pub children: Vec<usize>,
    /// Markov blanket: parents ∪ children ∪ co-parents of shared terms.
    pub blanket: Vec<usize>,
    /// Whether any directed dataflow path reaches an observation term.
    pub observed_reachable: bool,
    /// Number of observation terms (items) this site feeds directly.
    pub n_obs_terms: usize,
    /// Observation plates (indices into [`SiteGraph::plates`]) fed.
    pub plates: Vec<usize>,
}

/// A run of ≥ 2 consecutive observation rows sharing one distribution
/// family and parameter sources — the same grouping rule the compiler's
/// plate vectorizer uses.
#[derive(Clone, Debug)]
pub struct PlateInfo {
    pub rows: usize,
    pub family: &'static str,
    /// Parameter sites feeding the plate's distribution parameters.
    pub sites: Vec<usize>,
    /// Whether every observed value in the plate is bitwise identical.
    pub constant_data: bool,
}

/// The model's site-dependency graph.
#[derive(Clone, Debug)]
pub struct SiteGraph {
    pub sites: Vec<SiteInfo>,
    pub plates: Vec<PlateInfo>,
    /// Observation-carrying items in the recording (plates count as one).
    pub n_obs_items: usize,
}

impl SiteGraph {
    pub fn site_by_name(&self, name: &str) -> Option<&SiteInfo> {
        self.sites.iter().find(|s| s.name == name)
    }
}

pub(crate) fn is_obs_item(item: &Item) -> bool {
    matches!(
        item,
        Item::Observe { .. }
            | Item::ObserveInt { .. }
            | Item::ObserveVec { .. }
            | Item::ObsLogp { .. }
            | Item::PlateScalar { .. }
            | Item::PlateInt { .. }
    )
}

/// Build the site graph plus the internal register dependence map.
pub(crate) fn build(rec: &Recording, tvi: &TypedVarInfo) -> (SiteGraph, DepMap) {
    let slots = tvi.slots();

    // 1. Enumerate sites (assume items) in walk order.
    let mut sites: Vec<SiteInfo> = Vec::new();
    let mut site_of_item: Vec<Option<usize>> = vec![None; rec.items.len()];
    for (ii, ri) in rec.items.iter().enumerate() {
        let (slot, is_discrete, is_vec) = match &ri.item {
            Item::AssumeScalar { slot, .. } => (*slot, false, false),
            Item::AssumeVec { slot, .. } => (*slot, false, true),
            Item::AssumeInt { slot, .. } => (*slot, true, false),
            _ => continue,
        };
        site_of_item[ii] = Some(sites.len());
        let s = &slots[slot];
        sites.push(SiteInfo {
            name: format!("{}", s.vn),
            sym: s.vn.sym().as_str(),
            slot,
            item: ii,
            is_discrete,
            is_vec,
            family: item_family(&ri.item),
            parents: Vec::new(),
            children: Vec::new(),
            blanket: Vec::new(),
            observed_reachable: false,
            n_obs_terms: 0,
            plates: Vec::new(),
        });
    }
    let n_sites = sites.len();
    let words = (n_sites + 63) / 64;
    let words = words.max(1);

    // 2. Forward dataflow: seed assume output registers with their site
    //    bit, then fold opcode inputs in recording order (SSA order).
    let mut bits = vec![0u64; rec.n_regs as usize * words];
    let set_bit = |bits: &mut [u64], r: u32, site: usize| {
        bits[r as usize * words + site / 64] |= 1u64 << (site % 64);
    };
    for (ii, ri) in rec.items.iter().enumerate() {
        let Some(site) = site_of_item[ii] else { continue };
        match &ri.item {
            Item::AssumeScalar { out, .. } => set_bit(&mut bits, *out, site),
            Item::AssumeVec { out, .. } => {
                for &r in out {
                    set_bit(&mut bits, r, site);
                }
            }
            // discrete sites produce no register; their influence on the
            // walk (branching) is structural, not dataflow
            Item::AssumeInt { .. } => {}
            _ => unreachable!(),
        }
    }
    let mut acc = vec![0u64; words];
    for rop in &rec.ops {
        acc.iter_mut().for_each(|w| *w = 0);
        visit_op_srcs(&rop.op, &mut |s| {
            if let Src::Reg(r) = s {
                let row = &bits[*r as usize * words..*r as usize * words + words];
                for (a, w) in acc.iter_mut().zip(row) {
                    *a |= *w;
                }
            }
        });
        let out = rop.out as usize * words;
        for (i, a) in acc.iter().enumerate() {
            bits[out + i] |= *a;
        }
    }
    let dep = DepMap {
        n_sites,
        words,
        bits,
        site_of_item,
    };

    // 3. Parent edges + observation terms + blanket links.
    let mut parents: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n_sites];
    let mut children: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n_sites];
    let mut blanket: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n_sites];
    let mut feeds_obs = vec![false; n_sites];
    let mut n_obs_items = 0usize;
    for (ii, ri) in rec.items.iter().enumerate() {
        if let Some(site) = dep.site_of_item[ii] {
            let ps = dep.item_sites(&ri.item);
            for &p in &ps {
                parents[site].insert(p);
                children[p].insert(site);
            }
            // co-parents of this site share its conditional
            for &p in &ps {
                for &q in &ps {
                    if p != q {
                        blanket[p].insert(q);
                    }
                }
            }
        } else if is_obs_item(&ri.item) {
            n_obs_items += 1;
            let ps = dep.item_sites(&ri.item);
            for &p in &ps {
                feeds_obs[p] = true;
                sites[p].n_obs_terms += 1;
                for &q in &ps {
                    if p != q {
                        blanket[p].insert(q);
                    }
                }
            }
        }
    }

    // 4. Observation reachability: a site is identified if it feeds an
    //    observation directly or through a chain of child priors.
    let mut reach = feeds_obs;
    let mut queue: Vec<usize> = (0..n_sites).filter(|&s| reach[s]).collect();
    while let Some(s) = queue.pop() {
        for &p in &parents[s] {
            if !reach[p] {
                reach[p] = true;
                queue.push(p);
            }
        }
    }

    // 5. Plates: maximal runs of ≥ 2 consecutive scalar/int observes with
    //    the same family + parameter sources, plus explicit plate items.
    let mut plates: Vec<PlateInfo> = Vec::new();
    let mut plate_members: Vec<(usize, BTreeSet<usize>)> = Vec::new();
    let items = &rec.items;
    let mut i = 0usize;
    while i < items.len() {
        match &items[i].item {
            Item::Observe { dist, ps, np, obs } => {
                let mut j = i + 1;
                let mut constant = true;
                while j < items.len() {
                    if let Item::Observe {
                        dist: d2,
                        ps: p2,
                        np: n2,
                        obs: o2,
                    } = &items[j].item
                    {
                        if std::mem::discriminant(dist) == std::mem::discriminant(d2)
                            && ps == p2
                            && np == n2
                        {
                            constant &= obs.to_bits() == o2.to_bits();
                            j += 1;
                            continue;
                        }
                    }
                    break;
                }
                if j - i >= 2 {
                    plate_members.push((plates.len(), dep.item_sites(&items[i].item)));
                    plates.push(PlateInfo {
                        rows: j - i,
                        family: sdist_name(dist),
                        sites: Vec::new(),
                        constant_data: constant,
                    });
                }
                i = j;
            }
            Item::ObserveInt { dist, p, obs } => {
                let mut j = i + 1;
                let mut constant = true;
                while j < items.len() {
                    if let Item::ObserveInt {
                        dist: d2,
                        p: p2,
                        obs: o2,
                    } = &items[j].item
                    {
                        if std::mem::discriminant(dist) == std::mem::discriminant(d2) && p == p2 {
                            constant &= obs == o2;
                            j += 1;
                            continue;
                        }
                    }
                    break;
                }
                if j - i >= 2 {
                    plate_members.push((plates.len(), dep.item_sites(&items[i].item)));
                    plates.push(PlateInfo {
                        rows: j - i,
                        family: ddist_name(dist),
                        sites: Vec::new(),
                        constant_data: constant,
                    });
                }
                i = j;
            }
            Item::PlateScalar { dist, obs, .. } => {
                let constant = obs.windows(2).all(|w| w[0].to_bits() == w[1].to_bits());
                plate_members.push((plates.len(), dep.item_sites(&items[i].item)));
                plates.push(PlateInfo {
                    rows: obs.len(),
                    family: sdist_name(dist),
                    sites: Vec::new(),
                    constant_data: constant,
                });
                i += 1;
            }
            Item::PlateInt { dist, obs, .. } => {
                let constant = obs.windows(2).all(|w| w[0] == w[1]);
                plate_members.push((plates.len(), dep.item_sites(&items[i].item)));
                plates.push(PlateInfo {
                    rows: obs.len(),
                    family: ddist_name(dist),
                    sites: Vec::new(),
                    constant_data: constant,
                });
                i += 1;
            }
            _ => i += 1,
        }
    }
    for (pi, members) in plate_members {
        for &s in &members {
            plates[pi].sites.push(s);
            sites[s].plates.push(pi);
        }
    }

    // 6. Finalize per-site vectors.
    for (si, site) in sites.iter_mut().enumerate() {
        site.parents = parents[si].iter().copied().collect();
        site.children = children[si].iter().copied().collect();
        let mut b = blanket[si].clone();
        b.extend(parents[si].iter().copied());
        b.extend(children[si].iter().copied());
        b.remove(&si);
        site.blanket = b.into_iter().collect();
        site.observed_reachable = reach[si];
    }

    (
        SiteGraph {
            sites,
            plates,
            n_obs_items,
        },
        dep,
    )
}
