//! Probability queries — the paper's `prob"..."` string macro (§3.5).
//!
//! A query string has the shape
//!
//! ```text
//! lhs₁ = v₁, lhs₂ = v₂ | rhs₁ = w₁, …, model = name [, chain]
//! ```
//!
//! and is evaluated against a [`ModelRegistry`] of model builders:
//!
//! - parameters on the LHS, nothing bound on the RHS → **prior**
//!   probability of those parameter values;
//! - data on the LHS, parameters on the RHS → **likelihood** of the data
//!   given the parameters;
//! - data *and* parameters on the LHS → **joint** probability;
//! - data on the LHS, `chain` on the RHS → **posterior predictive**
//!   probability averaged over the chain's draws.
//!
//! Values support scalar (`1.5`), vector (`[1.0, 2.0]`) and integer-vector
//! (`[0, 1, 1]i`) literals. All results are returned as **log**-probability
//! ([`QueryResult::log_prob`]); `.prob()` exponentiates.

use std::collections::HashMap;

use crate::context::{Accumulator, Context};
use crate::dist::{DiscreteDist, ScalarDist, VecDist};
use crate::model::{Model, TildeApi};
use crate::value::Value;
use crate::varname::VarName;

/// Parsed variable bindings.
pub type Bindings = Vec<(String, Value)>;

/// A parsed probability query.
#[derive(Clone, Debug)]
pub struct Query {
    pub lhs: Bindings,
    pub rhs: Bindings,
    pub model: Option<String>,
    pub use_chain: bool,
}

/// Model builders: name → closure(data bindings) → model instance.
/// Builders look up the data fields they need in the bindings (LHS ∪ RHS)
/// and default to empty data when absent (so pure prior queries work).
#[derive(Default)]
pub struct ModelRegistry {
    builders: HashMap<String, Box<dyn Fn(&Bindings) -> Box<dyn Model> + Send + Sync>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register<F>(&mut self, name: &str, f: F)
    where
        F: Fn(&Bindings) -> Box<dyn Model> + Send + Sync + 'static,
    {
        self.builders.insert(name.to_string(), Box::new(f));
    }

    pub fn build(&self, name: &str, data: &Bindings) -> Result<Box<dyn Model>, String> {
        self.builders
            .get(name)
            .map(|b| b(data))
            .ok_or_else(|| format!("unknown model {name:?}"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.builders.keys().map(|s| s.as_str()).collect()
    }
}

/// Result of a query evaluation.
#[derive(Clone, Copy, Debug)]
pub struct QueryResult {
    pub log_prob: f64,
}

impl QueryResult {
    pub fn prob(&self) -> f64 {
        self.log_prob.exp()
    }
}

impl Query {
    /// Parse `"a = 1.5, b = [1, 2] | s = 0.3, model = linreg"`.
    pub fn parse(s: &str) -> Result<Query, String> {
        let (lhs_s, rhs_s) = s
            .split_once('|')
            .ok_or_else(|| "query must contain '|'".to_string())?;
        let lhs = parse_bindings(lhs_s)?;
        let mut rhs = Vec::new();
        let mut model = None;
        let mut use_chain = false;
        for (k, v_raw) in split_assignments(rhs_s)? {
            match k.as_str() {
                "model" => model = Some(v_raw.trim().to_string()),
                "chain" => use_chain = true,
                _ => rhs.push((k, parse_value(&v_raw)?)),
            }
        }
        Ok(Query {
            lhs,
            rhs,
            model,
            use_chain,
        })
    }
}

fn split_assignments(s: &str) -> Result<Vec<(String, String)>, String> {
    // split on commas not inside brackets
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '[' => {
                depth += 1;
                cur.push(ch);
            }
            ']' => {
                depth = depth.saturating_sub(1);
                cur.push(ch);
            }
            ',' if depth == 0 => {
                if !cur.trim().is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
            }
            _ => cur.push(ch),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out.into_iter()
        .map(|frag| {
            let frag = frag.trim();
            if frag == "chain" {
                return Ok(("chain".to_string(), String::new()));
            }
            let (k, v) = frag
                .split_once('=')
                .ok_or_else(|| format!("expected 'name = value' in {frag:?}"))?;
            Ok((k.trim().to_string(), v.trim().to_string()))
        })
        .collect()
}

fn parse_bindings(s: &str) -> Result<Bindings, String> {
    split_assignments(s)?
        .into_iter()
        .map(|(k, v)| Ok((k, parse_value(&v)?)))
        .collect()
}

/// Parse a value literal: `1.5`, `[1.0, 2.0]`, `[0, 1, 1]i` (int vector),
/// `3i` (integer).
pub fn parse_value(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if let Some(body) = s.strip_suffix('i') {
        let body = body.trim();
        if let Some(inner) = body.strip_prefix('[').and_then(|b| b.strip_suffix(']')) {
            let v: Result<Vec<i64>, _> = inner
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| p.trim().parse::<i64>())
                .collect();
            return v
                .map(Value::IntVec)
                .map_err(|e| format!("bad int vector {s:?}: {e}"));
        }
        return body
            .parse::<i64>()
            .map(Value::Int)
            .map_err(|e| format!("bad int {s:?}: {e}"));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|b| b.strip_suffix(']')) {
        let v: Result<Vec<f64>, _> = inner
            .split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|p| p.trim().parse::<f64>())
            .collect();
        return v
            .map(Value::Vec)
            .map_err(|e| format!("bad vector {s:?}: {e}"));
    }
    s.parse::<f64>()
        .map(Value::F64)
        .map_err(|e| format!("bad scalar {s:?}: {e}"))
}

/// A [`TildeApi`] that reads every parameter from a fixed binding map and
/// accumulates the context-weighted log-density. Parameters missing from
/// the bindings are an error (the query must pin every parameter the model
/// visits).
struct FixedValuesExecutor<'a> {
    values: &'a HashMap<VarName, Value>,
    acc: Accumulator<f64>,
    ctx: Context,
    missing: Option<String>,
}

impl<'a> FixedValuesExecutor<'a> {
    fn new(values: &'a HashMap<VarName, Value>, ctx: Context) -> Self {
        Self {
            values,
            acc: Accumulator::new(ctx),
            ctx,
            missing: None,
        }
    }

    fn fetch(&mut self, vn: &VarName) -> Option<&'a Value> {
        let v = self.values.get(vn);
        if v.is_none() && self.missing.is_none() {
            self.missing = Some(vn.to_string());
            self.acc.reject();
        }
        v
    }
}

impl<'a> TildeApi<f64> for FixedValuesExecutor<'a> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<f64>) -> f64 {
        match self.fetch(&vn).and_then(|v| v.as_f64()) {
            Some(x) => {
                self.acc.add_prior(dist.logpdf(x));
                x
            }
            None => 0.0,
        }
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<f64>) -> Vec<f64> {
        match self.fetch(&vn).and_then(|v| v.as_slice()) {
            Some(x) => {
                self.acc.add_prior(dist.logpdf(x));
                x.to_vec()
            }
            None => vec![0.0; dist.len()],
        }
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<f64>) -> i64 {
        match self.fetch(&vn).and_then(|v| v.as_int()) {
            Some(k) => {
                self.acc.add_prior(dist.logpmf(k));
                k
            }
            None => 0,
        }
    }

    fn observe(&mut self, dist: &ScalarDist<f64>, obs: f64) {
        self.acc.add_obs(dist.logpdf(obs));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<f64>, obs: i64) {
        self.acc.add_obs(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<f64>, obs: &[f64]) {
        self.acc.add_obs(dist.logpdf(obs));
    }

    fn add_obs_logp(&mut self, lp: f64) {
        self.acc.add_obs(lp);
    }

    fn add_prior_logp(&mut self, lp: f64) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        self.acc.skip_obs(n);
    }
}

fn bindings_to_map(bs: &Bindings) -> Result<HashMap<VarName, Value>, String> {
    let mut map = HashMap::new();
    for (k, v) in bs {
        map.insert(VarName::parse(k)?, v.clone());
    }
    Ok(map)
}

/// Evaluate the model's context-weighted log-density with every parameter
/// pinned to `params` — the fixed-binding executor behind [`eval_query`],
/// exposed for callers (the serving runtime) that precompute their own
/// parameter maps instead of going through the query-string front end.
pub fn run_fixed(
    model: &dyn Model,
    params: &HashMap<VarName, Value>,
    ctx: Context,
) -> Result<f64, String> {
    let mut exec = FixedValuesExecutor::new(params, ctx);
    model.eval_f64(&mut exec);
    if let Some(m) = exec.missing {
        return Err(format!(
            "query does not bind parameter {m} (and no chain was provided)"
        ));
    }
    Ok(exec.acc.total())
}

/// Rebuild one parameter map per chain draw: columns are grouped back
/// into scalar/vector values by symbol (`w[0]`, `w[1]` → `w = [·, ·]`),
/// one `HashMap` per row, in row order. Posterior-predictive evaluation
/// is then a [`run_fixed`] per map. Computing the grouping **once** per
/// chain — rather than per query row — is what the serving runtime's
/// microsecond-latency path relies on.
pub fn chain_param_maps(
    chain: &crate::chain::Chain,
) -> Result<Vec<HashMap<VarName, Value>>, String> {
    // group the column layout once: sym → sorted (idx, column) pairs
    let mut by_sym: Vec<(String, Vec<(usize, usize)>)> = Vec::new();
    let mut sym_index: HashMap<String, usize> = HashMap::new();
    for (ci, name) in chain.names().iter().enumerate() {
        let (sym, idx) = match name.split_once('[') {
            Some((s, rest)) => {
                let idx: usize = rest
                    .trim_end_matches(']')
                    .parse()
                    .map_err(|_| format!("bad chain column {name}"))?;
                (s.to_string(), idx)
            }
            None => (name.clone(), 0),
        };
        let si = *sym_index.entry(sym.clone()).or_insert_with(|| {
            by_sym.push((sym, Vec::new()));
            by_sym.len() - 1
        });
        by_sym[si].1.push((idx, ci));
    }
    for (_, elems) in by_sym.iter_mut() {
        elems.sort_by_key(|(i, _)| *i);
    }
    let vector_syms: Vec<bool> = by_sym
        .iter()
        .map(|(sym, elems)| elems.len() > 1 || chain.names().contains(&format!("{sym}[0]")))
        .collect();

    let mut maps = Vec::with_capacity(chain.len());
    for row in chain.rows() {
        let mut params = HashMap::with_capacity(by_sym.len());
        for ((sym, elems), &is_vec) in by_sym.iter().zip(&vector_syms) {
            let value = if is_vec {
                Value::Vec(elems.iter().map(|&(_, ci)| row[ci]).collect())
            } else {
                Value::F64(row[elems[0].1])
            };
            params.insert(VarName::new(sym), value);
        }
        maps.push(params);
    }
    Ok(maps)
}

/// Evaluate a query against the registry (and a chain for posterior
/// predictive queries). Returns log-probability.
pub fn eval_query(
    q: &Query,
    registry: &ModelRegistry,
    chain: Option<&crate::chain::Chain>,
) -> Result<QueryResult, String> {
    let model_name = q
        .model
        .as_deref()
        .ok_or_else(|| "query must bind 'model = <name>'".to_string())
    // `model=` may be absent only in chain queries that still name it
    ;
    let model_name = model_name?;

    // all data bindings visible to the builder
    let mut data: Bindings = q.lhs.clone();
    data.extend(q.rhs.iter().cloned());
    let model = registry.build(model_name, &data)?;

    if q.use_chain {
        // Posterior predictive: average the LHS likelihood over chain draws.
        let chain = chain.ok_or_else(|| "query says 'chain' but none was passed".to_string())?;
        let maps = chain_param_maps(chain)?;
        let mut log_terms = Vec::with_capacity(maps.len());
        for params in &maps {
            log_terms.push(run_fixed(model.as_ref(), params, Context::Likelihood)?);
        }
        // log mean exp
        let lme = crate::util::math::log_sum_exp(&log_terms) - (log_terms.len() as f64).ln();
        return Ok(QueryResult { log_prob: lme });
    }

    let lhs_map = bindings_to_map(&q.lhs)?;
    let rhs_map = bindings_to_map(&q.rhs)?;

    // Which side binds parameters decides the context:
    //   params only on LHS            → prior probability of those params
    //   params on RHS (data on LHS)   → likelihood of the LHS data
    //   params + data on LHS          → joint
    let mut params: HashMap<VarName, Value> = rhs_map.clone();
    for (k, v) in &lhs_map {
        params.insert(k.clone(), v.clone());
    }
    // Which side binds parameters decides the semantics (paper's examples):
    //  - no RHS params: LHS holds parameters (and possibly data the builder
    //    consumed) → prior of the params, plus the likelihood of any
    //    observations the model scores = prior or joint, automatically.
    //  - RHS params present: LHS is data → likelihood given the params.
    if rhs_map.is_empty() {
        let prior = run_fixed(model.as_ref(), &params, Context::Prior)?;
        let lik = run_fixed(model.as_ref(), &params, Context::Likelihood)?;
        Ok(QueryResult {
            log_prob: prior + lik,
        })
    } else {
        let lp = run_fixed(model.as_ref(), &params, Context::Likelihood)?;
        Ok(QueryResult { log_prob: lp })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars_vectors_ints() {
        assert_eq!(parse_value("1.5").unwrap(), Value::F64(1.5));
        assert_eq!(
            parse_value("[1.0, 2.5]").unwrap(),
            Value::Vec(vec![1.0, 2.5])
        );
        assert_eq!(parse_value("3i").unwrap(), Value::Int(3));
        assert_eq!(
            parse_value("[0, 1, 1]i").unwrap(),
            Value::IntVec(vec![0, 1, 1])
        );
        assert!(parse_value("abc").is_err());
    }

    #[test]
    fn parse_query_structure() {
        let q = Query::parse("X = [1.0, 2.0], y = [2.0] | w = [0.5, 0.0], s = 1.0, model = linreg")
            .unwrap();
        assert_eq!(q.lhs.len(), 2);
        assert_eq!(q.rhs.len(), 2);
        assert_eq!(q.model.as_deref(), Some("linreg"));
        assert!(!q.use_chain);
    }

    #[test]
    fn parse_chain_query() {
        let q = Query::parse("y = [2.0] | chain, model = linreg").unwrap();
        assert!(q.use_chain);
        assert_eq!(q.model.as_deref(), Some("linreg"));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Query::parse("no pipe here").is_err());
        assert!(Query::parse("a = [1,2 | model = m").is_err());
        assert!(Query::parse("a == 3 | model = m").is_err());
    }

    #[test]
    fn commas_inside_brackets_are_kept() {
        let b = parse_bindings("a = [1.0, 2.0, 3.0], b = 4.0").unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].1, Value::Vec(vec![1.0, 2.0, 3.0]));
    }
}
