//! PJRT runtime: load AOT-compiled HLO artifacts and execute them on the
//! sampling hot path.
//!
//! This is the "generated efficient machine code" of the reproduction: the
//! typed trace fixes the parameter layout at specialization time, and the
//! matching `value_and_grad` HLO (lowered once by `python/compile/aot.py`)
//! is compiled here by the PJRT CPU client and called as
//! [`XlaDensity::logp_grad`] — no Python anywhere at run time.
//!
//! # Build gating
//!
//! The PJRT path needs the `xla` (and `anyhow`) crates from the
//! rust_pallas toolchain image, which are not part of the offline
//! dependency set. The real implementation lives in [`pjrt`] behind the
//! `xla` cargo feature; the default build ships the API-compatible
//! [`stub`] whose loaders return a descriptive error, so every caller
//! (bench harness, coordinator, examples) compiles and degrades
//! gracefully — exactly like running without `make artifacts`.

use std::path::PathBuf;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{Runtime, XlaDensity, XlaTrajectory};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Runtime, XlaDensity, XlaTrajectory};

/// One data input for a compiled model.
pub enum DataInput {
    F64 { data: Vec<f64>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl DataInput {
    pub fn f64(data: Vec<f64>, dims: &[usize]) -> Self {
        DataInput::F64 {
            data,
            dims: dims.to_vec(),
        }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Self {
        DataInput::I32 {
            data,
            dims: dims.to_vec(),
        }
    }
}

/// Locate the artifacts directory: `$DPPL_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DPPL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the artifact for `model` exists (used to skip AOT-dependent
/// tests/benches when `make artifacts` hasn't run).
pub fn artifact_exists(model: &str) -> bool {
    artifacts_dir().join(format!("{model}.vg.hlo.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_input_constructors() {
        match DataInput::f64(vec![1.0, 2.0], &[2]) {
            DataInput::F64 { data, dims } => {
                assert_eq!(data, vec![1.0, 2.0]);
                assert_eq!(dims, vec![2]);
            }
            _ => panic!(),
        }
        match DataInput::i32(vec![3], &[1]) {
            DataInput::I32 { data, dims } => {
                assert_eq!(data, vec![3]);
                assert_eq!(dims, vec![1]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn artifacts_dir_env_override() {
        // default (no env) is ./artifacts; with env it's the env value.
        let d = artifacts_dir();
        assert!(d.as_os_str().len() > 0);
        assert!(!artifact_exists("definitely_not_a_model_name"));
    }
}
