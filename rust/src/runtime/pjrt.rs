//! PJRT-backed implementation of the runtime (requires the `xla` feature
//! and the rust_pallas toolchain's `xla` + `anyhow` crates).

use std::path::Path;

use anyhow::{anyhow, Context as _, Result};

use super::{artifacts_dir, DataInput};
use crate::gradient::LogDensity;

/// Shared PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
    }

    /// Upload an f64 buffer to the device.
    pub fn upload_f64(&self, data: &[f64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("{e:?}"))
    }

    /// Upload an i32 buffer to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("{e:?}"))
    }
}

/// The AOT log-density: `(theta, data…) → (logp, grad)` compiled from the
/// matching JAX model. Data buffers are uploaded once at construction;
/// only θ moves per call.
pub struct XlaDensity {
    exe: xla::PjRtLoadedExecutable,
    runtime: Runtime,
    data_bufs: Vec<xla::PjRtBuffer>,
    dim: usize,
}

// The PJRT CPU client is internally synchronized; we only share immutable
// handles across sampler threads.
unsafe impl Sync for XlaDensity {}
unsafe impl Send for XlaDensity {}

impl XlaDensity {
    /// Load `artifacts/<model>.vg.hlo.txt` and upload its data inputs.
    pub fn load(artifacts_dir: &Path, model: &str, dim: usize, data: &[DataInput]) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let path = artifacts_dir.join(format!("{model}.vg.hlo.txt"));
        let exe = runtime
            .compile_hlo_text(&path)
            .with_context(|| format!("loading artifact for {model}"))?;
        let mut data_bufs = Vec::with_capacity(data.len());
        for d in data {
            data_bufs.push(match d {
                DataInput::F64 { data, dims } => runtime.upload_f64(data, dims)?,
                DataInput::I32 { data, dims } => runtime.upload_i32(data, dims)?,
            });
        }
        Ok(Self {
            exe,
            runtime,
            data_bufs,
            dim,
        })
    }

    /// Execute at θ; returns (logp, grad).
    pub fn call(&self, theta: &[f64]) -> Result<(f64, Vec<f64>)> {
        assert_eq!(theta.len(), self.dim);
        let tb = self.runtime.upload_f64(theta, &[theta.len()])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.data_bufs.len());
        args.push(&tb);
        args.extend(self.data_bufs.iter());
        let out = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let row = &out[0];
        match row.len() {
            // untupled outputs: (logp, grad) as two buffers
            2 => {
                let mut lp = [0.0f64];
                row[0]
                    .copy_raw_to_host_sync(&mut lp, 0)
                    .map_err(|e| anyhow!("{e:?}"))?;
                let mut grad = vec![0.0f64; self.dim];
                row[1]
                    .copy_raw_to_host_sync(&mut grad, 0)
                    .map_err(|e| anyhow!("{e:?}"))?;
                Ok((lp[0], grad))
            }
            // tupled output: one buffer holding (logp, grad)
            1 => {
                let lit = row[0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
                let parts = lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
                if parts.len() != 2 {
                    return Err(anyhow!("expected 2-tuple, got {}", parts.len()));
                }
                let lp: f64 = parts[0]
                    .get_first_element()
                    .map_err(|e| anyhow!("{e:?}"))?;
                let grad = parts[1].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
                Ok((lp, grad))
            }
            n => Err(anyhow!("unexpected output arity {n}")),
        }
    }
}

impl LogDensity for XlaDensity {
    fn dim(&self) -> usize {
        self.dim
    }

    fn logp(&self, theta: &[f64]) -> f64 {
        self.call(theta).expect("XLA execution failed").0
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        self.call(theta).expect("XLA execution failed")
    }
}

/// The fused static-HMC trajectory artifact (§Perf):
/// `(θ, p, ε, data…) → (θ_L, p_L, logp_L)` running all `L` leapfrog steps
/// (identity mass) inside one XLA executable — one PJRT call per HMC
/// iteration instead of `L + 1`.
pub struct XlaTrajectory {
    exe: xla::PjRtLoadedExecutable,
    runtime: Runtime,
    data_bufs: Vec<xla::PjRtBuffer>,
    dim: usize,
}

unsafe impl Sync for XlaTrajectory {}
unsafe impl Send for XlaTrajectory {}

impl XlaTrajectory {
    /// Load `artifacts/<model>.traj4.hlo.txt`.
    pub fn load(
        artifacts_dir: &Path,
        model: &str,
        dim: usize,
        data: &[DataInput],
    ) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let path = artifacts_dir.join(format!("{model}.traj4.hlo.txt"));
        let exe = runtime
            .compile_hlo_text(&path)
            .with_context(|| format!("loading trajectory artifact for {model}"))?;
        let mut data_bufs = Vec::with_capacity(data.len());
        for d in data {
            data_bufs.push(match d {
                DataInput::F64 { data, dims } => runtime.upload_f64(data, dims)?,
                DataInput::I32 { data, dims } => runtime.upload_i32(data, dims)?,
            });
        }
        Ok(Self {
            exe,
            runtime,
            data_bufs,
            dim,
        })
    }

    /// Run the fused trajectory; θ, p and the threaded gradient g are
    /// updated in place; returns logp(θ_L).
    pub fn run(&self, theta: &mut [f64], p: &mut [f64], eps: f64, g: &mut [f64]) -> Result<f64> {
        assert_eq!(theta.len(), self.dim);
        let tb = self.runtime.upload_f64(theta, &[self.dim])?;
        let pb = self.runtime.upload_f64(p, &[self.dim])?;
        let eb = self.runtime.upload_f64(&[eps], &[])?;
        let gb = self.runtime.upload_f64(g, &[self.dim])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 + self.data_bufs.len());
        args.push(&tb);
        args.push(&pb);
        args.push(&eb);
        args.push(&gb);
        args.extend(self.data_bufs.iter());
        let out = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let row = &out[0];
        if row.len() == 4 {
            row[0]
                .copy_raw_to_host_sync(theta, 0)
                .map_err(|e| anyhow!("{e:?}"))?;
            row[1]
                .copy_raw_to_host_sync(p, 0)
                .map_err(|e| anyhow!("{e:?}"))?;
            let mut lp = [0.0f64];
            row[2]
                .copy_raw_to_host_sync(&mut lp, 0)
                .map_err(|e| anyhow!("{e:?}"))?;
            row[3]
                .copy_raw_to_host_sync(g, 0)
                .map_err(|e| anyhow!("{e:?}"))?;
            Ok(lp[0])
        } else {
            let lit = row[0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
            let parts = lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
            if parts.len() != 4 {
                return Err(anyhow!("expected 4-tuple, got {}", parts.len()));
            }
            let th = parts[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            let pv = parts[1].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            theta.copy_from_slice(&th);
            p.copy_from_slice(&pv);
            let gv = parts[3].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            g.copy_from_slice(&gv);
            parts[2].get_first_element().map_err(|e| anyhow!("{e:?}"))
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn traj_artifact_exists(model: &str) -> bool {
        artifacts_dir().join(format!("{model}.traj4.hlo.txt")).exists()
    }
}
