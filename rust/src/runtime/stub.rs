//! API-compatible stand-in for the PJRT runtime used when the crate is
//! built without the `xla` feature (the fully-offline configuration).
//!
//! Loaders always return an error naming the missing feature; the types
//! are uninhabited (they hold [`std::convert::Infallible`]) so the
//! executing methods are statically unreachable. Callers that guard on
//! [`super::artifact_exists`] behave exactly as they do when artifacts
//! have not been built.

use std::convert::Infallible;
use std::fmt;
use std::path::Path;

use super::DataInput;
use crate::gradient::LogDensity;

/// Error produced by every stub entry point.
pub struct RuntimeError(String);

impl fmt::Debug for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

fn unavailable(what: &str) -> RuntimeError {
    RuntimeError(format!(
        "{what} requires the PJRT runtime — rebuild with `--features xla` \
         on the rust_pallas toolchain image"
    ))
}

/// Stub PJRT client: cannot be constructed.
pub struct Runtime {
    never: Infallible,
}

impl Runtime {
    pub fn cpu() -> Result<Self, RuntimeError> {
        Err(unavailable("Runtime::cpu"))
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }
}

/// Stub AOT log-density: `load` always fails; the type is uninhabited.
pub struct XlaDensity {
    never: Infallible,
}

impl XlaDensity {
    pub fn load(
        _artifacts_dir: &Path,
        model: &str,
        _dim: usize,
        _data: &[DataInput],
    ) -> Result<Self, RuntimeError> {
        Err(unavailable(&format!("XlaDensity::load({model:?})")))
    }

    pub fn call(&self, _theta: &[f64]) -> Result<(f64, Vec<f64>), RuntimeError> {
        match self.never {}
    }
}

impl LogDensity for XlaDensity {
    fn dim(&self) -> usize {
        match self.never {}
    }

    fn logp(&self, _theta: &[f64]) -> f64 {
        match self.never {}
    }

    fn logp_grad(&self, _theta: &[f64]) -> (f64, Vec<f64>) {
        match self.never {}
    }
}

/// Stub fused-trajectory executable; see [`XlaDensity`].
pub struct XlaTrajectory {
    never: Infallible,
}

impl XlaTrajectory {
    pub fn load(
        _artifacts_dir: &Path,
        model: &str,
        _dim: usize,
        _data: &[DataInput],
    ) -> Result<Self, RuntimeError> {
        Err(unavailable(&format!("XlaTrajectory::load({model:?})")))
    }

    pub fn run(
        &self,
        _theta: &mut [f64],
        _p: &mut [f64],
        _eps: f64,
        _g: &mut [f64],
    ) -> Result<f64, RuntimeError> {
        match self.never {}
    }

    pub fn dim(&self) -> usize {
        match self.never {}
    }

    pub fn traj_artifact_exists(model: &str) -> bool {
        super::artifacts_dir()
            .join(format!("{model}.traj4.hlo.txt"))
            .exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loaders_fail_with_feature_hint() {
        let err = Runtime::cpu().map(|_| ()).unwrap_err();
        assert!(format!("{err:?}").contains("features xla"));
        let err = XlaDensity::load(Path::new("artifacts"), "gauss_unknown", 2, &[]).map(|_| ());
        assert!(err.is_err());
        let err = XlaTrajectory::load(Path::new("artifacts"), "gauss_unknown", 2, &[]).map(|_| ());
        assert!(err.is_err());
        assert!(!XlaTrajectory::traj_artifact_exists("nope"));
    }
}
