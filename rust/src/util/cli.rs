//! Minimal command-line argument parsing (clap is not in the vendored
//! dependency set). Supports subcommands, `--flag`, `--key value`,
//! `--key=value` and positional arguments, with typed getters and
//! automatic usage generation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed arguments: subcommand path, options, flags, positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw argv fragments (excluding the program/subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // "--" separator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--") || n.parse::<f64>().is_ok())
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value for --{name}: {s:?}")),
        }
    }

    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }
}

/// Declarative usage text builder for subcommands.
pub struct Usage {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<(&'static str, &'static str)>,
}

impl Usage {
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:", self.program);
        let w = self.commands.iter().map(|(c, _)| c.len()).max().unwrap_or(0);
        for (c, d) in &self.commands {
            let _ = writeln!(s, "  {c:<w$}  {d}");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_both_forms() {
        let a = parse(&["--model", "lda", "--iters=500"]);
        assert_eq!(a.get("model"), Some("lda"));
        assert_eq!(a.get_parse::<u32>("iters").unwrap(), Some(500));
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse(&["run", "--verbose", "--seed", "3", "extra"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get_parse_or::<u64>("seed", 0).unwrap(), 3);
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse(&["--offset", "-1.5"]);
        assert_eq!(a.get_parse::<f64>("offset").unwrap(), Some(-1.5));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn default_fallbacks() {
        let a = parse(&[]);
        assert_eq!(a.get_or("model", "all"), "all");
        assert_eq!(a.get_parse_or("threads", 4usize).unwrap(), 4);
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.get_parse::<u32>("n").is_err());
    }

    #[test]
    fn usage_renders() {
        let u = Usage {
            program: "dppl",
            about: "demo",
            commands: vec![("bench", "run benchmarks"), ("sample", "draw samples")],
        };
        let s = u.render();
        assert!(s.contains("bench") && s.contains("sample"));
    }
}
