//! Summary statistics over sample vectors: mean/variance (Welford),
//! quantiles, autocorrelation, effective sample size (Geyer initial
//! monotone sequence) and split-R̂ (Vehtari et al. 2021) — the diagnostics
//! MCMCChains.jl provides in the paper's ecosystem.

/// Streaming mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample (n−1) variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Arithmetic mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample variance (n−1 denominator).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated quantile (type-7, same as numpy default). `q` ∈ [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Sample autocovariance at lag `k` (biased, n denominator — standard for
/// ESS estimation).
pub fn autocovariance(xs: &[f64], k: usize) -> f64 {
    let n = xs.len();
    assert!(k < n);
    let m = mean(xs);
    let mut s = 0.0;
    for i in 0..n - k {
        s += (xs[i] - m) * (xs[i + k] - m);
    }
    s / n as f64
}

/// Effective sample size of a single chain via Geyer's initial monotone
/// positive sequence estimator. Zero-variance (degenerate) chains carry
/// exactly `n` independent observations of their one value, so the draw
/// count is returned — the autocorrelation ratios would be 0/0 at exact
/// zero variance and numerically meaningless just above it (mean-sum
/// rounding leaves a tiny spurious c₀).
pub fn ess(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return n as f64;
    }
    if xs.iter().all(|&x| x == xs[0]) {
        return n as f64; // constant chain (exact, before any rounding)
    }
    let c0 = autocovariance(xs, 0);
    if c0.is_nan() || c0 <= 0.0 {
        return n as f64; // zero/negative/NaN variance
    }
    let max_lag = (n - 2).min(n / 2);
    // Sum of adjacent-pair autocorrelations, truncated at first negative
    // pair, enforcing monotone decrease.
    let mut rho_sum = 0.0;
    let mut prev_pair = f64::INFINITY;
    let mut k = 1;
    while k + 1 <= max_lag {
        let pair = (autocovariance(xs, k) + autocovariance(xs, k + 1)) / c0;
        if pair <= 0.0 {
            break;
        }
        let pair = pair.min(prev_pair);
        rho_sum += pair;
        prev_pair = pair;
        k += 2;
    }
    let tau = 1.0 + 2.0 * rho_sum;
    (n as f64 / tau).min(n as f64).max(1.0)
}

/// Split-R̂ across `chains` (each a slice of equal length): Gelman–Rubin
/// potential scale reduction with chain splitting. A zero-variance
/// (degenerate) parameter is perfectly mixed by definition: R̂ = 1 — the
/// between/within ratio would otherwise be rounding noise over rounding
/// noise.
pub fn split_rhat(chains: &[&[f64]]) -> f64 {
    // Degenerate column: every draw of every chain is the same value.
    if let Some(&first) = chains.first().and_then(|c| c.first()) {
        if chains.iter().all(|c| c.iter().all(|&x| x == first)) {
            return 1.0;
        }
    }
    // Split each chain in half → 2m sequences.
    let mut seqs: Vec<&[f64]> = Vec::with_capacity(chains.len() * 2);
    for c in chains {
        let h = c.len() / 2;
        if h < 2 {
            return f64::NAN;
        }
        seqs.push(&c[..h]);
        seqs.push(&c[h..2 * h]);
    }
    let m = seqs.len() as f64;
    let n = seqs[0].len() as f64;
    let means: Vec<f64> = seqs.iter().map(|s| mean(s)).collect();
    let vars: Vec<f64> = seqs.iter().map(|s| variance(s)).collect();
    let grand = mean(&means);
    let b = n / (m - 1.0) * means.iter().map(|&x| (x - grand) * (x - grand)).sum::<f64>();
    let w = mean(&vars);
    if w <= 0.0 {
        return 1.0; // constant chains
    }
    let var_plus = (n - 1.0) / n * w + b / n;
    (var_plus / w).sqrt()
}

/// Rank-normalized split-R̂ (Vehtari, Gelman, Simpson, Carpenter, Bürkner
/// 2021): pool all draws, replace each by the normal quantile of its
/// fractional rank (Blom offsets), then compute [`split_rhat`] on the
/// transformed chains. Robust to heavy tails and scale, and — because
/// each chain is still split in half — sensitive to within-chain trends
/// (single-chain non-stationarity).
pub fn rank_normalized_split_rhat(chains: &[&[f64]]) -> f64 {
    let n_per: Vec<usize> = chains.iter().map(|c| c.len()).collect();
    let total: usize = n_per.iter().sum();
    if total == 0 {
        return f64::NAN;
    }
    let pooled: Vec<f64> = chains.iter().flat_map(|c| c.iter().copied()).collect();
    if pooled.iter().any(|x| x.is_nan()) {
        return f64::NAN; // match classic split_rhat's graceful NaN
    }
    let mut idx: Vec<usize> = (0..total).collect();
    idx.sort_by(|&a, &b| pooled[a].partial_cmp(&pooled[b]).unwrap());
    // average 1-based ranks over ties
    let mut rank = vec![0.0f64; total];
    let mut i = 0;
    while i < total {
        let mut j = i;
        while j + 1 < total && pooled[idx[j + 1]] == pooled[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            rank[k] = avg;
        }
        i = j + 1;
    }
    let z: Vec<f64> = rank
        .iter()
        .map(|&r| crate::util::math::norm_inv_cdf((r - 0.375) / (total as f64 + 0.25)))
        .collect();
    let mut zchains: Vec<&[f64]> = Vec::with_capacity(chains.len());
    let mut off = 0;
    for &n in &n_per {
        zchains.push(&z[off..off + n]);
        off += n;
    }
    split_rhat(&zchains)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256pp};

    #[test]
    fn running_stats_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut rs = RunningStats::new();
        for &x in &xs {
            rs.push(x);
        }
        assert!((rs.mean() - mean(&xs)).abs() < 1e-12);
        assert!((rs.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(rs.min(), -3.0);
        assert_eq!(rs.max(), 16.5);
        assert_eq!(rs.count(), 6);
    }

    #[test]
    fn quantile_pins() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn ess_iid_close_to_n() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        let xs: Vec<f64> = (0..4000).map(|_| r.normal()).collect();
        let e = ess(&xs);
        assert!(e > 3000.0, "iid ESS should be near n, got {e}");
    }

    #[test]
    fn ess_ar1_reduced() {
        // AR(1) with phi=0.9 → tau ≈ (1+phi)/(1-phi) = 19
        let mut r = Xoshiro256pp::seed_from_u64(8);
        let mut x = 0.0;
        let xs: Vec<f64> = (0..20_000)
            .map(|_| {
                x = 0.9 * x + r.normal();
                x
            })
            .collect();
        let e = ess(&xs);
        let expect = xs.len() as f64 / 19.0;
        assert!(
            e > expect * 0.5 && e < expect * 2.0,
            "ESS {e}, expected ≈ {expect}"
        );
    }

    #[test]
    fn ess_of_degenerate_chain_is_the_draw_count() {
        // regression: 0.1 is not exactly representable, so the running
        // mean of a constant chain picks up rounding noise and the old
        // estimator produced a garbage (near-1 or NaN) ESS from 0/0-ish
        // autocorrelation ratios
        let xs = vec![0.1; 2000];
        assert_eq!(ess(&xs), 2000.0);
        let ys = vec![-3.7; 5];
        assert_eq!(ess(&ys), 5.0);
    }

    #[test]
    fn rhat_of_degenerate_chains_is_one() {
        let a = vec![0.1; 100];
        let b = vec![0.1; 100];
        assert_eq!(split_rhat(&[&a, &b]), 1.0);
        assert_eq!(rank_normalized_split_rhat(&[&a, &b]), 1.0);
        // even when the chains are too short to split
        let c = [2.5, 2.5];
        assert_eq!(split_rhat(&[&c]), 1.0);
    }

    #[test]
    fn rhat_mixed_chains_near_one() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let a: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let b: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let rh = split_rhat(&[&a, &b]);
        assert!((rh - 1.0).abs() < 0.02, "R̂ {rh}");
    }

    #[test]
    fn rhat_detects_disagreement() {
        let mut r = Xoshiro256pp::seed_from_u64(10);
        let a: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let b: Vec<f64> = (0..2000).map(|_| r.normal() + 5.0).collect();
        let rh = split_rhat(&[&a, &b]);
        assert!(rh > 2.0, "R̂ should flag separated chains, got {rh}");
    }

    #[test]
    fn rank_rhat_mixed_chains_near_one() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let a: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let b: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let rh = rank_normalized_split_rhat(&[&a, &b]);
        assert!((rh - 1.0).abs() < 0.02, "rank R̂ {rh}");
    }

    #[test]
    fn rank_rhat_detects_single_chain_trend() {
        // one drifting chain: classic multi-chain R̂ can't see this with
        // m = 1, but the split halves disagree after rank normalization
        let mut r = Xoshiro256pp::seed_from_u64(12);
        let a: Vec<f64> = (0..2000)
            .map(|i| r.normal() + i as f64 / 200.0)
            .collect();
        let rh = rank_normalized_split_rhat(&[&a]);
        assert!(rh > 1.2, "rank R̂ should flag the trend, got {rh}");
        // a stationary single chain is fine
        let b: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let rh = rank_normalized_split_rhat(&[&b]);
        assert!((rh - 1.0).abs() < 0.03, "{rh}");
    }

    #[test]
    fn rank_rhat_is_nan_on_nan_draws_not_a_panic() {
        let a = [0.1, f64::NAN, 0.3, 0.4, 0.5, 0.6];
        let b = [0.2, 0.3, 0.1, 0.5, 0.4, 0.7];
        assert!(rank_normalized_split_rhat(&[&a, &b]).is_nan());
    }

    #[test]
    fn rank_rhat_is_scale_invariant_under_heavy_tails() {
        // Cauchy-ish draws break moment-based R̂; ranks don't care
        let mut r = Xoshiro256pp::seed_from_u64(13);
        let heavy = |r: &mut Xoshiro256pp| {
            let u = std::f64::consts::PI * (r.uniform() - 0.5);
            u.tan()
        };
        let a: Vec<f64> = (0..4000).map(|_| heavy(&mut r)).collect();
        let b: Vec<f64> = (0..4000).map(|_| heavy(&mut r)).collect();
        let rh = rank_normalized_split_rhat(&[&a, &b]);
        assert!((rh - 1.0).abs() < 0.03, "rank R̂ {rh}");
    }
}
