//! A minimal JSON reader (and string escaper) for the serving protocol.
//!
//! The crate writes JSON by hand everywhere (no serde in the offline
//! dependency set) but never had to *read* any until the posterior server
//! grew a line-delimited request protocol. This is a small recursive-
//! descent parser over the full JSON grammar — objects, arrays, strings
//! with escapes, numbers, booleans, null — tuned for one-line requests,
//! not for streaming gigabytes.

/// A parsed JSON value. Object keys keep insertion order (lookup is a
/// linear scan — protocol requests have a handful of keys).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document (trailing whitespace allowed,
    /// trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Numeric array → `Vec<f64>` (`None` if any element is not a number).
    pub fn num_vec(&self) -> Option<Vec<f64>> {
        match self {
            Json::Arr(items) => items.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a hand-written JSON document.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Deepest object/array nesting the parser follows. A recursive-descent
/// parser turns attacker-controlled nesting into call-stack depth; this
/// bound converts a `[[[[…` bomb into a parse error instead of a stack
/// overflow. Far above any protocol request (which nests 2–3 levels).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    /// Track one object/array descent against [`MAX_DEPTH`]. The matching
    /// decrement happens on the container's successful exit; error paths
    /// abort the whole parse, so their counts never matter.
    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!(
                "nesting deeper than {MAX_DEPTH} levels at byte {}",
                self.pos
            ));
        }
        Ok(())
    }
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}",
                b as char, self.pos
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(format!("unexpected {:?} at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.enter()?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            // surrogate pairs are rejected rather than
                            // combined — requests never carry them
                            out.push(
                                char::from_u32(code)
                                    .ok_or(format!("invalid \\u{code:04x} escape"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is a &str, so the
                    // byte stream is valid UTF-8 by construction)
                    let rest = &self.bytes[self.pos..];
                    let s = unsafe { std::str::from_utf8_unchecked(rest) };
                    let c = s.chars().next().ok_or("unexpected end of string")?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "bad number")?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_protocol_shapes() {
        let v = Json::parse(
            r#"{"op": "update", "model": "kalman", "y": [1.5, -2e-1, 0.0], "moves": 2, "warm": true, "note": null}"#,
        )
        .unwrap();
        assert_eq!(v.get("op").and_then(Json::as_str), Some("update"));
        assert_eq!(v.get("moves").and_then(Json::as_u64), Some(2));
        assert_eq!(v.get("warm").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("note"), Some(&Json::Null));
        assert_eq!(
            v.get("y").and_then(Json::num_vec),
            Some(vec![1.5, -0.2, 0.0])
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let raw = "a \"quoted\"\nline\twith \\ stuff";
        let doc = format!("{{\"k\": \"{}\"}}", escape(raw));
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("k").and_then(Json::as_str), Some(raw));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn deep_nesting_is_rejected_not_overflowed() {
        // A `[[[[…` bomb must come back as a parse error, not blow the
        // call stack — 200 levels is well past MAX_DEPTH.
        let bomb = format!("{}{}", "[".repeat(200), "]".repeat(200));
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.contains("nesting"), "unexpected error: {err}");

        // Mixed object/array nesting hits the same bound.
        let obj_bomb = format!("{}1{}", "{\"k\":[".repeat(100), "]}".repeat(100));
        let err = Json::parse(&obj_bomb).unwrap_err();
        assert!(err.contains("nesting"), "unexpected error: {err}");

        // Anything at or under the bound still parses; the depth counter
        // must also unwind, so many *sibling* containers stay fine.
        let deep_ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep_ok).is_ok());
        let siblings = format!("[{}]", vec!["[[[1]]]"; 64].join(","));
        assert!(Json::parse(&siblings).is_ok());
    }

    #[test]
    fn escape_roundtrips_control_and_unicode() {
        let cases = [
            "\u{0001}\u{0002}\u{001f} bells \u{0007}",
            "tab\there\nnewline\rcarriage",
            "mixed \"quotes\" and \\ backslashes \u{0008}\u{000c}",
            "unicode: π ≈ 3.14159, 日本語, emoji \u{1F600}",
            "",
        ];
        for raw in cases {
            let doc = format!("{{\"k\": \"{}\"}}", escape(raw));
            let v = Json::parse(&doc)
                .unwrap_or_else(|e| panic!("failed on {raw:?}: {e}"));
            assert_eq!(v.get("k").and_then(Json::as_str), Some(raw), "case {raw:?}");
        }
    }

    #[test]
    fn unicode_escapes_parse() {
        let v = Json::parse(r#""Aé中""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé中"));
    }

    #[test]
    fn malformed_inputs_are_rejected() {
        let bad = [
            r#""\u00"#,        // truncated \u escape at end of input
            r#""\u00zz""#,     // non-hex digits in \u escape
            r#""\ud800""#,     // lone surrogate (rejected, not combined)
            r#""\x41""#,       // invalid escape letter
            r#""never ends"#,  // unterminated string
            "1e",              // dangling exponent
            "--1",             // double sign
            "tru",             // truncated literal
            "[1 2]",           // missing comma
            r#"{"a" 1}"#,      // missing colon
            "",                // empty input
            "[",               // unclosed array
        ];
        for doc in bad {
            assert!(Json::parse(doc).is_err(), "accepted malformed {doc:?}");
        }
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"[{"a": [1, 2]}, {"b": {"c": false}}]"#).unwrap();
        match &v {
            Json::Arr(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[0].get("a").and_then(Json::num_vec), Some(vec![1.0, 2.0]));
                assert_eq!(
                    items[1].get("b").and_then(|b| b.get("c")).and_then(Json::as_bool),
                    Some(false)
                );
            }
            _ => panic!("expected array"),
        }
    }
}
