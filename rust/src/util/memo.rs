//! Memoization of expensive model sub-computations (paper §3.4).
//!
//! The paper points at Memoization.jl for caching expensive functions
//! inside models during Gibbs sampling, where a block update recomputes
//! terms that depend only on *other* blocks' (unchanged) values. [`Memo`]
//! is that utility: a bounded, hash-keyed cache over quantized f64 keys
//! (bit-exact keys — two calls hit only if the inputs are identical,
//! which is precisely the Gibbs case where other blocks are frozen).

use std::collections::HashMap;

/// A bounded memo cache from `Vec<u64>` (f64 bit patterns) to `V`.
pub struct Memo<V: Clone> {
    map: HashMap<Vec<u64>, V>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
}

impl<V: Clone> Memo<V> {
    pub fn new(capacity: usize) -> Self {
        Self {
            map: HashMap::new(),
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
        }
    }

    fn key(args: &[f64]) -> Vec<u64> {
        args.iter().map(|x| x.to_bits()).collect()
    }

    /// Look up `args`, computing and caching on miss. When the cache is
    /// full it is cleared (cheap epoch eviction — Gibbs access patterns
    /// are phase-local, so LRU buys nothing over epochs).
    pub fn get_or<F: FnOnce() -> V>(&mut self, args: &[f64], f: F) -> V {
        let k = Self::key(args);
        if let Some(v) = self.map.get(&k) {
            self.hits += 1;
            return v.clone();
        }
        self.misses += 1;
        let v = f();
        if self.map.len() >= self.capacity {
            self.map.clear();
        }
        self.map.insert(k, v.clone());
        v
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    #[test]
    fn caches_and_counts() {
        let mut memo = Memo::new(16);
        let evals = Cell::new(0u32);
        let mut f = |x: f64| {
            memo.get_or(&[x], || {
                evals.set(evals.get() + 1);
                x * x
            })
        };
        assert_eq!(f(2.0), 4.0);
        assert_eq!(f(2.0), 4.0);
        assert_eq!(f(3.0), 9.0);
        assert_eq!(evals.get(), 2);
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.misses, 2);
        assert!(memo.hit_rate() > 0.3);
    }

    #[test]
    fn distinguishes_bit_patterns() {
        let mut memo = Memo::new(4);
        let a = memo.get_or(&[0.0], || 1);
        let b = memo.get_or(&[-0.0], || 2); // -0.0 has a different bit pattern
        assert_eq!((a, b), (1, 2));
    }

    #[test]
    fn epoch_eviction_bounds_size() {
        let mut memo = Memo::new(8);
        for i in 0..100 {
            let _ = memo.get_or(&[i as f64], || i);
        }
        assert!(memo.len() <= 8);
    }

    /// The paper's Gibbs use case: a block update holds other blocks
    /// fixed, so the expensive term keyed on the frozen block hits.
    #[test]
    fn gibbs_pattern_hit_rate() {
        let mut memo = Memo::new(64);
        let frozen = [1.5, -0.3]; // "other block" values, constant this sweep
        let mut total_evals = 0;
        for _step in 0..50 {
            let _ = memo.get_or(&frozen, || {
                total_evals += 1;
                frozen.iter().map(|x| x.exp()).sum::<f64>()
            });
        }
        assert_eq!(total_evals, 1);
        assert_eq!(memo.hits, 49);
    }
}
