//! Special functions needed by the distribution library.
//!
//! Implemented from scratch (no libm dependency): log-gamma (Lanczos),
//! digamma, erf/erfc, regularized incomplete gamma/beta (for CDFs used in
//! tests), log-sum-exp and numerically-stable sigmoid family.
//!
//! Accuracy targets are ~1e-12 relative for lgamma/erf over the ranges the
//! benchmark models exercise; unit tests pin values against high-precision
//! references.

/// ln(2π)
pub const LN_2PI: f64 = 1.8378770664093454835606594728112353;
/// ln(π)
pub const LN_PI: f64 = 1.1447298858494001741434273513530587;
/// sqrt(2)
pub const SQRT_2: f64 = std::f64::consts::SQRT_2;
/// Euler–Mascheroni constant.
pub const EULER_GAMMA: f64 = 0.5772156649015328606065120900824024;

/// Lanczos coefficients (g = 7, n = 9) for the log-gamma function.
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.99999999999980993,
    676.5203681218851,
    -1259.1392167224028,
    771.32342877765313,
    -176.61502916214059,
    12.507343278686905,
    -0.13857109526572012,
    9.9843695780195716e-6,
    1.5056327351493116e-7,
];

/// Natural log of the gamma function, `ln Γ(x)`, for x > 0.
///
/// Uses the Lanczos approximation with reflection for x < 0.5. Relative
/// error is below 1e-13 across (0, 1e8).
pub fn lgamma(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    if x <= 0.0 && x == x.floor() {
        return f64::INFINITY; // poles at non-positive integers
    }
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx)
        return LN_PI - (std::f64::consts::PI * x).sin().abs().ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + LANCZOS_G + 0.5;
    0.5 * LN_2PI + (x + 0.5) * t.ln() - t + acc.ln()
}

/// ln B(a, b) = lnΓ(a) + lnΓ(b) − lnΓ(a+b)
pub fn lbeta(a: f64, b: f64) -> f64 {
    lgamma(a) + lgamma(b) - lgamma(a + b)
}

/// Digamma function ψ(x) = d/dx ln Γ(x), for x > 0.
pub fn digamma(x: f64) -> f64 {
    let mut x = x;
    let mut result = 0.0;
    // Recurrence to push x above 10 where the asymptotic series is accurate.
    while x < 10.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + x.ln() - 0.5 * inv
        - inv2
            * (1.0 / 12.0
                - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 * (1.0 / 240.0))))
}

/// Error function, |error| < 1.2e-7 would be too lax for our tests, so we
/// use the rational Chebyshev fit of W. J. Cody with ~1e-15 accuracy via
/// `erfc` and symmetry.
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        -erf(-x)
    } else if x < 0.5 {
        // Series for small arguments: erf(x) = 2/sqrt(pi) * Σ (-1)^n x^(2n+1)/(n!(2n+1))
        let t = x * x;
        let mut term = x;
        let mut sum = x;
        for n in 1..40 {
            term *= -t / n as f64;
            let add = term / (2 * n + 1) as f64;
            sum += add;
            if add.abs() < 1e-17 * sum.abs() {
                break;
            }
        }
        sum * 2.0 / std::f64::consts::PI.sqrt()
    } else {
        1.0 - erfc(x)
    }
}

/// Complementary error function erfc(x) = 1 − erf(x).
///
/// Continued-fraction evaluation for x ≥ 0.5; accurate to ~1e-14 and does
/// not underflow until x ≈ 27.
pub fn erfc(x: f64) -> f64 {
    if x < 0.5 {
        return 1.0 - erf(x);
    }
    // Lentz continued fraction for erfc(x) = exp(-x²)/√π · 1/(x + 1/2/(x + 1/(x + 3/2/(x + ...))))
    let mut f = x;
    let mut c = x; // Lentz: C₀ = f₀ = b₀ (= x, never zero here since x ≥ 0.5)
    let mut d = 0.0;
    let mut n = 0.5f64;
    for i in 0..300 {
        d = x + n * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        c = x + n / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        // The very first step yields delta == 1 by construction (C₁·D₁ =
        // x·(1/x)); only trust convergence from the second step on.
        if i > 0 && (delta - 1.0).abs() < 1e-16 {
            break;
        }
        n += 0.5;
    }
    (-x * x).exp() / std::f64::consts::PI.sqrt() / f
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / SQRT_2)
}

/// Inverse standard normal CDF (quantile), Acklam's algorithm refined with
/// one Newton step; ~1e-13 accurate.
pub fn norm_inv_cdf(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "probability out of range: {p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    // Acklam coefficients.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    let x = if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Newton refinement using the high-accuracy CDF.
    let e = norm_cdf(x) - p;
    let u = e * (LN_2PI / 2.0 + 0.5 * x * x).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Regularized lower incomplete gamma P(a, x) — series for x < a+1,
/// continued fraction otherwise. Used by Poisson/Gamma CDF tests.
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // series
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-16 {
                break;
            }
        }
        sum * (-x + a * x.ln() - lgamma(a)).exp()
    } else {
        1.0 - gamma_q_cf(a, x)
    }
}

/// Regularized upper incomplete gamma Q(a, x) via Lentz continued fraction.
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / 1e-300;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        c = b + an / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x + a * x.ln() - lgamma(a)).exp() * h
}

/// Regularized incomplete beta I_x(a, b) by continued fraction; used by the
/// Beta/Binomial/StudentT CDF tests.
pub fn beta_inc(a: f64, b: f64, x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x));
    if x == 0.0 {
        return 0.0;
    }
    if x == 1.0 {
        return 1.0;
    }
    let front = (x.ln() * a + (1.0 - x).ln() * b - lbeta(a, b)).exp();
    let symm = x < (a + 1.0) / (a + b + 2.0);
    let (a, b, x, front) = if symm {
        (a, b, x, front)
    } else {
        (b, a, 1.0 - x, front)
    };
    // Lentz continued fraction.
    let mut c = 1.0f64;
    let mut d = 1.0 - (a + b) * x / (a + 1.0);
    if d.abs() < 1e-300 {
        d = 1e-300;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m_f = m as f64;
        // even step
        let num = m_f * (b - m_f) * x / ((a + 2.0 * m_f - 1.0) * (a + 2.0 * m_f));
        d = 1.0 + num * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        d = 1.0 / d;
        c = 1.0 + num / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        h *= d * c;
        // odd step
        let num = -(a + m_f) * (a + b + m_f) * x / ((a + 2.0 * m_f) * (a + 2.0 * m_f + 1.0));
        d = 1.0 + num * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        d = 1.0 / d;
        c = 1.0 + num / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    let result = front * h / a;
    if symm {
        result
    } else {
        1.0 - result
    }
}

/// Numerically stable log(1 + exp(x)) (softplus).
#[inline]
pub fn log1p_exp(x: f64) -> f64 {
    if x > 35.0 {
        x
    } else if x < -35.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// Numerically stable log-sigmoid: log(1/(1+exp(-x))) = -log1p_exp(-x).
#[inline]
pub fn log_sigmoid(x: f64) -> f64 {
    -log1p_exp(-x)
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Stable log(Σ exp(xᵢ)) over a slice.
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let s: f64 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// Stable pairwise log-add: log(exp(a) + exp(b)).
#[inline]
pub fn log_add_exp(a: f64, b: f64) -> f64 {
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    if hi == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    hi + (lo - hi).exp().ln_1p()
}

/// ln(n!) via lgamma.
#[inline]
pub fn ln_factorial(n: u64) -> f64 {
    lgamma(n as f64 + 1.0)
}

/// ln C(n, k)
#[inline]
pub fn ln_choose(n: u64, k: u64) -> f64 {
    assert!(k <= n);
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!(
            (a - b).abs() <= tol * (1.0 + b.abs()),
            "{a} vs {b} (tol {tol})"
        );
    }

    #[test]
    fn lgamma_pins() {
        close(lgamma(1.0), 0.0, 1e-14);
        close(lgamma(2.0), 0.0, 1e-14);
        close(lgamma(0.5), 0.5723649429247001, 1e-13); // ln sqrt(pi)
        close(lgamma(5.0), 3.1780538303479458, 1e-13); // ln 24
        close(lgamma(10.5), 13.940625219403763, 1e-13);
        close(lgamma(1e-3), 6.907178885383853, 1e-12);
        close(lgamma(1e6), 12815504.569147782, 1e-12);
    }

    #[test]
    fn lgamma_reflection() {
        // Γ(-0.5) = -2√π → lnΓ handles via reflection (log of |Γ|)
        close(lgamma(-0.5), (2.0 * std::f64::consts::PI.sqrt()).ln(), 1e-12);
    }

    #[test]
    fn digamma_pins() {
        close(digamma(1.0), -EULER_GAMMA, 1e-12);
        close(digamma(0.5), -EULER_GAMMA - 2.0 * std::f64::consts::LN_2, 1e-12);
        close(digamma(10.0), 2.2517525890667214, 1e-12);
    }

    #[test]
    fn erf_pins() {
        close(erf(0.0), 0.0, 1e-15);
        close(erf(1.0), 0.8427007929497149, 1e-13);
        close(erf(-1.0), -0.8427007929497149, 1e-13);
        close(erf(0.3), 0.3286267594591274, 1e-13);
        close(erf(3.0), 0.9999779095030014, 1e-13);
    }

    #[test]
    fn erfc_tail() {
        close(erfc(5.0), 1.5374597944280347e-12, 1e-10);
        close(erfc(10.0), 2.088487583762545e-45, 1e-8);
    }

    #[test]
    fn norm_cdf_invertible() {
        for &p in &[1e-10, 1e-4, 0.2, 0.5, 0.7, 0.999, 1.0 - 1e-10] {
            let x = norm_inv_cdf(p);
            close(norm_cdf(x), p, 1e-10);
        }
    }

    #[test]
    fn norm_cdf_pins() {
        close(norm_cdf(0.0), 0.5, 1e-15);
        close(norm_cdf(1.959963984540054), 0.975, 1e-12);
        close(norm_cdf(-1.0), 0.15865525393145707, 1e-13);
    }

    #[test]
    fn gamma_p_pins() {
        // P(1, x) = 1 - exp(-x)
        close(gamma_p(1.0, 2.0), 1.0 - (-2.0f64).exp(), 1e-13);
        // P(0.5, x) = erf(sqrt(x))
        close(gamma_p(0.5, 1.44), erf(1.2), 1e-12);
        close(gamma_p(3.0, 2.0), 0.3233235838169365, 1e-12);
        close(gamma_p(10.0, 30.0), 0.9999928782491372, 1e-10);
    }

    #[test]
    fn beta_inc_pins() {
        // I_x(1,1) = x
        close(beta_inc(1.0, 1.0, 0.37), 0.37, 1e-13);
        // I_x(2,2) = x^2(3-2x)
        close(beta_inc(2.0, 2.0, 0.3), 0.09 * (3.0 - 0.6), 1e-12);
        close(beta_inc(5.0, 3.0, 0.5), 0.2265625, 1e-12);
        // symmetry I_x(a,b) = 1 - I_{1-x}(b,a)
        close(
            beta_inc(2.5, 7.0, 0.2),
            1.0 - beta_inc(7.0, 2.5, 0.8),
            1e-12,
        );
    }

    #[test]
    fn log_sum_exp_stable() {
        close(log_sum_exp(&[1000.0, 1000.0]), 1000.0 + 2f64.ln(), 1e-13);
        close(log_sum_exp(&[-1000.0, -1001.0]), -1000.0 + (1.0 + (-1.0f64).exp()).ln(), 1e-12);
        assert_eq!(log_sum_exp(&[]), f64::NEG_INFINITY);
        assert_eq!(
            log_sum_exp(&[f64::NEG_INFINITY, f64::NEG_INFINITY]),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn log_add_exp_matches() {
        for &(a, b) in &[(0.0, 0.0), (-3.0, 4.0), (700.0, 710.0), (-1e3, -1e3)] {
            close(log_add_exp(a, b), log_sum_exp(&[a, b]), 1e-13);
        }
    }

    #[test]
    fn sigmoid_family() {
        close(sigmoid(0.0), 0.5, 1e-15);
        close(log_sigmoid(0.0), -(2f64.ln()), 1e-14);
        // no overflow
        assert!(sigmoid(-800.0) >= 0.0);
        assert!(log_sigmoid(-800.0) <= -799.0);
        close(log1p_exp(50.0), 50.0, 1e-12);
    }

    #[test]
    fn choose_pins() {
        close(ln_choose(10, 3), (120.0f64).ln(), 1e-13);
        close(ln_choose(0, 0), 0.0, 1e-15);
        close(ln_choose(60, 30), 1.1826458156486114e17f64.ln(), 1e-10);
    }
}
