//! Benchmark timing: warmup + replicated measurement producing mean ± std,
//! the exact reporting format of the paper's Table 1. Used both by the
//! criterion-free `cargo bench` harnesses and the `dppl bench` CLI.

use std::time::Instant;

use super::stats::RunningStats;

/// One benchmark measurement: replicate wall-clock times in seconds.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub times: Vec<f64>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        crate::util::stats::mean(&self.times)
    }

    pub fn std(&self) -> f64 {
        if self.times.len() < 2 {
            0.0
        } else {
            crate::util::stats::std(&self.times)
        }
    }

    /// `mean ± std` in adaptive units.
    pub fn display(&self) -> String {
        let (scale, unit) = pick_unit(self.mean());
        format!(
            "{:.3} ± {:.3} {}",
            self.mean() * scale,
            self.std() * scale,
            unit
        )
    }
}

fn pick_unit(secs: f64) -> (f64, &'static str) {
    if secs >= 1.0 {
        (1.0, "s")
    } else if secs >= 1e-3 {
        (1e3, "ms")
    } else if secs >= 1e-6 {
        (1e6, "µs")
    } else {
        (1e9, "ns")
    }
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        times,
    }
}

/// Adaptive micro-bench: repeat the closure in growing batches until a
/// target per-measurement duration is hit, returning per-iteration seconds.
/// Suitable for nanosecond-scale bodies where one call is below timer
/// resolution.
pub fn bench_micro<F: FnMut()>(name: &str, target_secs: f64, reps: usize, mut f: F) -> Measurement {
    // Find a batch size where one batch takes ≥ target_secs.
    let mut batch = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= target_secs || batch >= 1 << 30 {
            break;
        }
        batch = if dt <= 0.0 {
            batch * 16
        } else {
            ((batch as f64 * target_secs / dt * 1.2) as usize).max(batch * 2)
        };
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    Measurement {
        name: name.to_string(),
        times,
    }
}

/// Render a list of measurements as an aligned text table.
pub fn render_table(title: &str, rows: &[Measurement]) -> String {
    let name_w = rows.iter().map(|r| r.name.len()).max().unwrap_or(4).max(4);
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!("{:<name_w$}  {:>20}\n", "name", "time (mean ± std)"));
    for r in rows {
        out.push_str(&format!("{:<name_w$}  {:>20}\n", r.name, r.display()));
    }
    out
}

/// Blackbox to defeat dead-code elimination in benches (std::hint::black_box
/// wrapper kept behind one name so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Simple throughput helper: items/sec given a Measurement and batch size.
pub fn throughput(m: &Measurement, items_per_rep: usize) -> f64 {
    items_per_rep as f64 / m.mean()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut acc = RunningStats::new();
        let m = bench("sleepless", 1, 5, || {
            acc.push(1.0);
        });
        assert_eq!(m.times.len(), 5);
        assert!(m.mean() >= 0.0);
        assert!(!m.display().is_empty());
    }

    #[test]
    fn micro_bench_batches() {
        let mut x = 0u64;
        let m = bench_micro("incr", 1e-4, 3, || {
            x = x.wrapping_add(1);
        });
        assert_eq!(m.times.len(), 3);
        assert!(m.mean() < 1e-4, "per-iter time should be tiny: {}", m.mean());
    }

    #[test]
    fn unit_scaling() {
        let m = Measurement {
            name: "x".into(),
            times: vec![2.5e-6, 2.5e-6],
        };
        assert!(m.display().contains("µs"));
        let m = Measurement {
            name: "x".into(),
            times: vec![3.0, 3.0],
        };
        assert!(m.display().ends_with("s"));
    }

    #[test]
    fn table_renders_all_rows() {
        let rows = vec![
            Measurement {
                name: "alpha".into(),
                times: vec![0.1],
            },
            Measurement {
                name: "beta".into(),
                times: vec![0.2],
            },
        ];
        let t = render_table("demo", &rows);
        assert!(t.contains("alpha") && t.contains("beta") && t.contains("demo"));
    }

    #[test]
    fn throughput_sane() {
        let m = Measurement {
            name: "x".into(),
            times: vec![0.5],
        };
        assert!((throughput(&m, 100) - 200.0).abs() < 1e-9);
    }
}
