//! A small scoped thread pool for running independent MCMC chains in
//! parallel. Built on std::thread + channels (no tokio/rayon in the vendored
//! dependency set). Work items are boxed closures; results are collected in
//! submission order.
//!
//! **Nested-parallelism budget.** Every parallel helper here draws its
//! extra workers from one process-wide budget of `cores − 1` slots (the
//! caller thread is always the `+1`). An outer parallel section that has
//! claimed the budget leaves nothing for sections nested inside its jobs
//! — those degrade to serial loops instead of oversubscribing the machine
//! with `threads²` runnable threads. Results never depend on how many
//! workers a section actually got (work is indexed, reductions are
//! serial), so the budget changes wall-clock only. When inner work has a
//! batchable K-lane axis, prefer lane-batching
//! ([`crate::model::batched`]) over nested thread fan-out: one SIMD-able
//! kernel walk beats contended threads that the budget would serialize
//! anyway.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

/// The shared extra-worker budget (capacity `cores − 1`, lazily init).
fn budget() -> &'static AtomicUsize {
    static B: OnceLock<AtomicUsize> = OnceLock::new();
    B.get_or_init(|| AtomicUsize::new(default_threads().saturating_sub(1)))
}

/// Claim up to `want` extra workers from the shared budget; returns how
/// many were actually granted (possibly 0 → run serial). Never blocks.
fn acquire_workers(want: usize) -> usize {
    let b = budget();
    let mut cur = b.load(Ordering::Relaxed);
    loop {
        let take = want.min(cur);
        if take == 0 {
            return 0;
        }
        match b.compare_exchange_weak(cur, cur - take, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return take,
            Err(c) => cur = c,
        }
    }
}

/// Return workers to the budget (panic-safe via [`BudgetGuard`]).
fn release_workers(n: usize) {
    if n > 0 {
        budget().fetch_add(n, Ordering::AcqRel);
    }
}

/// RAII release so a panicking job cannot leak budget slots.
struct BudgetGuard(usize);

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        release_workers(self.0);
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are executed FIFO by any idle worker.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("dppl-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped → shut down
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        Self {
            workers,
            tx: Some(tx),
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `n` jobs produced by `make_job(i)` in parallel on up to `threads`
/// workers and return their results in index order. Panics in jobs are
/// propagated.
pub fn parallel_map<T, F>(threads: usize, n: usize, make_job: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return (0..n).map(make_job).collect();
    }
    // the caller blocks collecting results, so the pool itself holds the
    // `+1` caller slot and only `threads − 1` come from the shared budget
    let grant = BudgetGuard(acquire_workers(threads - 1));
    let threads = 1 + grant.0;
    if threads == 1 {
        return (0..n).map(make_job).collect();
    }
    let make_job = Arc::new(make_job);
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<T>)>();
    let pool = ThreadPool::new(threads);
    for i in 0..n {
        let tx = tx.clone();
        let mj = Arc::clone(&make_job);
        pool.execute(move || {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| mj(i)));
            let _ = tx.send((i, out));
        });
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (i, res) = rx.recv().expect("worker dropped result channel");
        match res {
            Ok(v) => slots[i] = Some(v),
            Err(p) => std::panic::resume_unwind(p),
        }
    }
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// Apply `f(i, &mut items[i])` to every element, splitting the slice into
/// contiguous chunks across up to `threads` scoped worker threads.
///
/// Unlike [`parallel_map`], the closure may borrow non-`'static` state
/// (the model, the data) because the threads are scoped — this is the
/// particle-propagation primitive: each particle is advanced in place,
/// and determinism is preserved because the result layout is fixed by
/// index, not by completion order (callers must derive any randomness
/// from `i`, never from thread identity). Panics in `f` propagate.
pub fn parallel_for_each_mut<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    let grant = if threads > 1 {
        BudgetGuard(acquire_workers(threads - 1))
    } else {
        BudgetGuard(0)
    };
    let threads = 1 + grant.0;
    if threads == 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = (n + threads - 1) / threads;
    // the caller thread takes the first chunk itself, so the section uses
    // exactly `grant + 1` runnable threads
    thread::scope(|scope| {
        let mut chunks = items.chunks_mut(chunk).enumerate();
        let first = chunks.next();
        for (ci, items_chunk) in chunks {
            let f = &f;
            scope.spawn(move || {
                for (j, item) in items_chunk.iter_mut().enumerate() {
                    f(ci * chunk + j, item);
                }
            });
        }
        if let Some((_, items_chunk)) = first {
            for (j, item) in items_chunk.iter_mut().enumerate() {
                f(j, item);
            }
        }
    });
}

/// Default parallelism: number of available CPUs (≥1).
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(4, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_path() {
        let out = parallel_map(1, 5, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_for_each_mut_touches_every_item_in_order() {
        for threads in [1, 2, 4, 7] {
            let mut items: Vec<usize> = vec![0; 23];
            parallel_for_each_mut(threads, &mut items, |i, x| *x = i * i);
            assert_eq!(items, (0..23).map(|i| i * i).collect::<Vec<_>>(), "{threads}");
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_for_each_mut(4, &mut empty, |_, _| {});
    }

    #[test]
    fn parallel_for_each_mut_borrows_local_state() {
        // non-'static capture: the whole point vs parallel_map
        let offset = 100usize;
        let mut items = vec![0usize; 8];
        parallel_for_each_mut(3, &mut items, |i, x| *x = i + offset);
        assert_eq!(items[7], 107);
    }

    #[test]
    fn nested_parallel_sections_degrade_to_serial_not_oversubscribe() {
        // the outer section drains the budget; inner sections get 0 extra
        // workers and fall back to serial loops — same results, no thread²
        let out = parallel_map(4, 8, |i| {
            let inner = parallel_map(4, 4, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|i| 4 * (i * 10) + 6).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn worker_budget_never_overcommits() {
        let cap = default_threads().saturating_sub(1);
        let a = acquire_workers(usize::MAX);
        let b = acquire_workers(usize::MAX);
        // outstanding grants can never exceed the whole budget, no matter
        // what other tests hold concurrently
        assert!(a + b <= cap, "{a} + {b} > {cap}");
        assert_eq!(acquire_workers(0), 0);
        release_workers(a + b);
    }

    #[test]
    #[should_panic]
    fn parallel_map_propagates_panics() {
        let _ = parallel_map(2, 4, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}
