//! Vendored FNV-1a hashing (no external deps).
//!
//! `std`'s default `HashMap` hasher is SipHash-1-3 — DoS-resistant but
//! expensive on the short keys the boxed trace hashes on every access
//! (`VarName` = interned symbol + ≤2 indices, a dozen bytes). Trace keys
//! are program-controlled, not attacker-controlled, so the boxed path uses
//! FNV-1a instead: one xor-multiply per byte, the classic small-key choice
//! (and what the `fnv` crate ships; vendored here because the offline
//! build takes no external crates).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const FNV_OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming 64-bit FNV-1a.
pub struct FnvHasher(u64);

impl Default for FnvHasher {
    fn default() -> Self {
        FnvHasher(FNV_OFFSET_BASIS)
    }
}

impl Hasher for FnvHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }
}

/// `BuildHasher` for FNV-keyed maps.
pub type FnvBuildHasher = BuildHasherDefault<FnvHasher>;

/// `HashMap` with FNV-1a hashing — drop-in for the trace-index maps.
pub type FnvHashMap<K, V> = HashMap<K, V, FnvBuildHasher>;

/// `HashSet` with FNV-1a hashing.
pub type FnvHashSet<T> = HashSet<T, FnvBuildHasher>;

/// One-shot FNV-1a of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FnvHasher::default();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn map_roundtrip_with_varnames() {
        use crate::varname::VarName;
        let mut m: FnvHashMap<VarName, usize> = FnvHashMap::default();
        for i in 0..100 {
            m.insert(VarName::indexed("h", i), i);
        }
        m.insert(VarName::new("sigma"), 1000);
        assert_eq!(m.len(), 101);
        for i in 0..100 {
            assert_eq!(m[&VarName::indexed("h", i)], i);
        }
        assert_eq!(m[&VarName::new("sigma")], 1000);
        assert!(!m.contains_key(&VarName::new("phi")));
    }

    #[test]
    fn short_key_distribution_is_sane() {
        // indexed names must not collide in the low bits a HashMap uses
        let mut low7 = FnvHashSet::default();
        for i in 0..128u64 {
            let h = fnv1a(format!("h[{i}]").as_bytes());
            low7.insert(h % 128);
        }
        assert!(low7.len() > 70, "low-bit spread {}", low7.len());
    }
}
