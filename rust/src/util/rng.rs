//! Pseudo-random number generation, built from scratch.
//!
//! [`Xoshiro256pp`] (xoshiro256++ by Blackman & Vigna) is the workhorse
//! generator: 256-bit state, jump-free splitting via SplitMix64 seeding,
//! and passes BigCrush. It implements [`rand_core::RngCore`] so external
//! code expecting the standard traits interoperates.
//!
//! Scalar variate samplers (normal, gamma, …) live on the [`Rng`] extension
//! trait; distribution objects in [`crate::dist`] call into these.

use rand_core::{Error, RngCore};

/// SplitMix64 — used to expand a 64-bit seed into xoshiro state and as a
/// tiny standalone generator for tests.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed from a single u64 via SplitMix64 (the authors' recommendation).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 cannot produce 4 zeros from
        // any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64_inline(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// The canonical jump function: advances the stream by 2^128 steps.
    /// Used to derive independent per-chain streams from one seed.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180ec6d33cfd0aba,
            0xd5a61266f0c9392c,
            0xa9582618e03fc9aa,
            0x39abdc4529b1661c,
        ];
        let mut s = [0u64; 4];
        for &j in &JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64_inline();
            }
        }
        self.s = s;
    }

    /// A new generator 2^128 steps ahead (and advances self): independent
    /// stream for chain `i` when called `i` times.
    pub fn split(&mut self) -> Self {
        let child = self.clone();
        self.jump();
        child
    }
}

impl RngCore for Xoshiro256pp {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_inline() >> 32) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.next_u64_inline()
    }

    fn fill_bytes(&mut self, dest: &mut [u8]) {
        let mut chunks = dest.chunks_exact_mut(8);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&self.next_u64_inline().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.next_u64_inline().to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }

    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

/// Extension trait with the variate samplers the PPL needs. Blanket-implemented
/// for every `RngCore`.
pub trait Rng: RngCore {
    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    fn uniform_pos(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) by Lemire's method.
    fn uniform_usize(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        // 128-bit multiply rejection sampling (Lemire 2018).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via the polar (Marsaglia) method.
    ///
    /// Stateless (no cached second value) — slightly wasteful but keeps the
    /// generator `Clone`-safe and reproducible across call sites.
    fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Exponential(1) via inversion.
    #[inline]
    fn exponential(&mut self) -> f64 {
        -self.uniform_pos().ln()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; shape may be < 1 (boosted).
    fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.uniform_pos().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform_pos();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Poisson(λ): Knuth multiplication for λ < 30, else PTRS transformed
    /// rejection (Hörmann 1993).
    fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // PTRS
            let b = 0.931 + 2.53 * lambda.sqrt();
            let a = -0.059 + 0.02483 * b;
            let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
            let v_r = 0.9277 - 3.6224 / (b - 2.0);
            loop {
                let u = self.uniform() - 0.5;
                let v = self.uniform();
                let us = 0.5 - u.abs();
                let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
                if us >= 0.07 && v <= v_r {
                    return k as u64;
                }
                if k < 0.0 || (us < 0.013 && v > us) {
                    continue;
                }
                if v.ln() * inv_alpha / (a / (us * us) + b)
                    <= k * lambda.ln() - lambda - crate::util::math::lgamma(k + 1.0)
                {
                    return k as u64;
                }
            }
        }
    }

    /// Binomial(n, p) by inversion for small n·p, else BTPE-lite (sum of
    /// bernoullis fallback for moderate n — n in our models is small).
    fn binomial(&mut self, n: u64, p: f64) -> u64 {
        assert!((0.0..=1.0).contains(&p));
        if p == 0.0 {
            return 0;
        }
        if p == 1.0 {
            return n;
        }
        // Symmetry: sample the rarer outcome.
        if p > 0.5 {
            return n - self.binomial(n, 1.0 - p);
        }
        // For the model sizes used here (n ≤ a few thousand) a waiting-time
        // / geometric skip method is plenty fast.
        if n < 64 {
            let mut k = 0;
            for _ in 0..n {
                if self.uniform() < p {
                    k += 1;
                }
            }
            return k;
        }
        // Geometric skipping: trials to first success ~ Geometric(p).
        let lq = (1.0 - p).ln();
        let mut k = 0u64;
        let mut i = 0u64;
        loop {
            let g = (self.uniform_pos().ln() / lq).floor() as u64 + 1;
            i += g;
            if i > n {
                break;
            }
            k += 1;
        }
        k
    }

    /// Bernoulli(p) as bool.
    #[inline]
    fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Categorical draw from (unnormalized) probabilities; linear scan.
    fn categorical(&mut self, probs: &[f64]) -> usize {
        let total: f64 = probs.iter().sum();
        assert!(total > 0.0, "categorical probabilities sum to zero");
        let mut u = self.uniform() * total;
        for (i, &p) in probs.iter().enumerate() {
            u -= p;
            if u < 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Dirichlet(α) via normalized gammas, written into `out`.
    fn dirichlet_into(&mut self, alpha: &[f64], out: &mut [f64]) {
        assert_eq!(alpha.len(), out.len());
        let mut sum = 0.0;
        for (o, &a) in out.iter_mut().zip(alpha) {
            *o = self.gamma(a);
            sum += *o;
        }
        // Guard against all-zero underflow for tiny α.
        if sum <= 0.0 {
            let n = out.len() as f64;
            for o in out.iter_mut() {
                *o = 1.0 / n;
            }
            return;
        }
        for o in out.iter_mut() {
            *o /= sum;
        }
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

impl<R: RngCore + ?Sized> Rng for R {}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(42)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = rng();
        let mut b = rng();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn jump_decorrelates() {
        let mut a = rng();
        let mut b = rng();
        b.jump();
        let eq = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(eq, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = rng();
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            let v = r.uniform_pos();
            assert!(v > 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn uniform_usize_bounds_and_coverage() {
        let mut r = rng();
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = r.uniform_usize(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = rng();
        let n = 200_000;
        let (mut m, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            m2 += x * x;
        }
        m /= n as f64;
        m2 /= n as f64;
        assert!(m.abs() < 0.01, "mean {m}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = rng();
        for &shape in &[0.5, 1.0, 2.5, 10.0] {
            let n = 100_000;
            let mut m = 0.0;
            for _ in 0..n {
                m += r.gamma(shape);
            }
            m /= n as f64;
            assert!(
                (m - shape).abs() < 0.05 * shape.max(1.0),
                "shape {shape}: mean {m}"
            );
        }
    }

    #[test]
    fn poisson_moments() {
        let mut r = rng();
        for &lam in &[0.5, 5.0, 80.0] {
            let n = 60_000;
            let mut m = 0.0;
            for _ in 0..n {
                m += r.poisson(lam) as f64;
            }
            m /= n as f64;
            assert!((m - lam).abs() < 0.05 * lam.max(1.0), "λ {lam}: mean {m}");
        }
    }

    #[test]
    fn binomial_moments() {
        let mut r = rng();
        for &(n_tr, p) in &[(10u64, 0.3), (500u64, 0.02), (200u64, 0.9)] {
            let n = 40_000;
            let mut m = 0.0;
            for _ in 0..n {
                m += r.binomial(n_tr, p) as f64;
            }
            m /= n as f64;
            let expect = n_tr as f64 * p;
            assert!(
                (m - expect).abs() < 0.06 * expect.max(1.0),
                "n={n_tr} p={p}: mean {m} want {expect}"
            );
        }
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = rng();
        let probs = [0.1, 0.2, 0.7];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[r.categorical(&probs)] += 1;
        }
        for (c, p) in counts.iter().zip(&probs) {
            let f = *c as f64 / n as f64;
            assert!((f - p).abs() < 0.01, "{f} vs {p}");
        }
    }

    #[test]
    fn dirichlet_simplex() {
        let mut r = rng();
        let alpha = [0.5, 1.0, 3.0, 0.1];
        let mut out = [0.0; 4];
        for _ in 0..100 {
            r.dirichlet_into(&alpha, &mut out);
            let s: f64 = out.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            assert!(out.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn beta_mean() {
        let mut r = rng();
        let (a, b) = (2.0, 5.0);
        let n = 100_000;
        let mut m = 0.0;
        for _ in 0..n {
            m += r.beta(a, b);
        }
        m /= n as f64;
        assert!((m - a / (a + b)).abs() < 0.01);
    }

    #[test]
    fn fill_bytes_works() {
        let mut r = rng();
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = rng();
        let mut xs: Vec<u32> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(xs, (0..20).collect::<Vec<_>>()); // astronomically unlikely
    }
}
