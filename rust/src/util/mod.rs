//! Shared substrates: special-function math, RNG, CLI parsing, a small
//! thread pool, and summary statistics.
//!
//! Everything here is built from scratch — the only external crates on the
//! hot path are `xla` (PJRT) and the std library. This mirrors the paper's
//! stance that the tracing library itself must own its performance story.

pub mod cli;
pub mod hash;
pub mod json;
pub mod math;
pub mod memo;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timing;
