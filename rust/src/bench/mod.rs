//! Table-1 benchmark harness: times static HMC (4 leapfrog steps, paper
//! step sizes) across execution backends for every benchmark model, and
//! renders the paper-shaped comparison table.
//!
//! The paper reports seconds for 2,000 iterations. Slow backends (the
//! boxed/tape paths exist precisely to be slow) are run for fewer
//! iterations and linearly extrapolated — per-iteration cost is constant
//! in iteration count for static HMC, so this preserves the ordering and
//! ratios Table 1 is about. Extrapolated cells are marked `~`.

use std::fmt::Write as _;

use crate::context::Context;
use crate::gradient::{Backend, LogDensity, NativeDensity, UntypedDensity};
use crate::inference::Hmc;
use crate::model::{init_trace, typed_logp};
use crate::models::{build, BenchModel};
use crate::runtime::{artifact_exists, artifacts_dir, XlaDensity};
use crate::stanlike::stanlike_density;
use crate::util::rng::Xoshiro256pp;
use crate::varinfo::TypedVarInfo;
use crate::vi::{Advi, ViFamily};

/// Execution backend for a Table-1 cell (DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchBackend {
    /// Boxed trace + tape reverse AD: the pre-specialization dynamic path.
    Untyped,
    /// Typed trace + tape reverse AD (Tracker.jl analogue).
    TypedTape,
    /// Typed trace + arena-fused reverse AD (the native default — Stan's
    /// fused-`_lpdf` design; see `crate::ad::arena`).
    TypedFused,
    /// Typed trace + forward-mode duals (ForwardDiff.jl analogue).
    TypedForward,
    /// Typed layout + AOT-compiled XLA logp∇ (the paper's headline path).
    TypedXla,
    /// XLA with the fused 4-leapfrog trajectory artifact (§Perf).
    TypedXlaFused,
    /// Hand-coded static Rust + analytic gradients (the Stan comparator).
    StanLike,
}

impl BenchBackend {
    pub fn label(&self) -> &'static str {
        match self {
            BenchBackend::Untyped => "untyped",
            BenchBackend::TypedTape => "typed+tape",
            BenchBackend::TypedFused => "typed+fused",
            BenchBackend::TypedForward => "typed+fwd",
            BenchBackend::TypedXla => "typed+xla",
            BenchBackend::TypedXlaFused => "typed+xla-fused",
            BenchBackend::StanLike => "stanlike",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        // bare native-engine names ("fused", "tape", "forward", aliases)
        // go through the one `gradient::Backend` naming table; only the
        // typed+/xla/stan spellings are bench-specific
        if let Ok(b) = s.parse::<Backend>() {
            return Some(BenchBackend::from(b));
        }
        Some(match s {
            "untyped" => BenchBackend::Untyped,
            "typed+tape" => BenchBackend::TypedTape,
            // `fused` names the native arena engine; the XLA trajectory
            // artifact stays reachable as `xla-fused`
            "typed+fused" => BenchBackend::TypedFused,
            "typed+fwd" => BenchBackend::TypedForward,
            "typed+xla" | "xla" => BenchBackend::TypedXla,
            "typed+xla-fused" | "xla-fused" => BenchBackend::TypedXlaFused,
            "stanlike" | "stan" => BenchBackend::StanLike,
            _ => return None,
        })
    }

    /// Iteration budget fraction relative to the full 2,000 (slow paths
    /// are extrapolated; see module docs).
    fn iter_fraction(&self) -> f64 {
        match self {
            BenchBackend::Untyped | BenchBackend::TypedTape | BenchBackend::TypedForward => 0.02,
            BenchBackend::TypedFused => 0.2,
            _ => 1.0,
        }
    }
}

/// The typed-trace Table-1 cell for a native AD engine.
impl From<Backend> for BenchBackend {
    fn from(b: Backend) -> Self {
        match b {
            Backend::ReverseFused => BenchBackend::TypedFused,
            Backend::Reverse => BenchBackend::TypedTape,
            Backend::Forward => BenchBackend::TypedForward,
        }
    }
}

/// Default backend set for the Table-1 run.
pub const DEFAULT_BACKENDS: [BenchBackend; 5] = [
    BenchBackend::Untyped,
    BenchBackend::TypedTape,
    BenchBackend::TypedFused,
    BenchBackend::TypedXla,
    BenchBackend::StanLike,
];

/// One Table-1 cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub model: String,
    pub backend: BenchBackend,
    /// seconds per `iters` iterations (mean over reps)
    pub mean: f64,
    pub std: f64,
    pub extrapolated: bool,
    pub note: Option<String>,
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct Table1Config {
    /// target iteration count reported (paper: 2,000)
    pub iters: usize,
    pub reps: usize,
    pub seed: u64,
    pub backends: Vec<BenchBackend>,
    pub models: Vec<String>,
    /// cap on actually-executed iterations per cell (None = full); cells
    /// below `iters` are extrapolated and marked `~`
    pub max_run_iters: Option<usize>,
}

impl Default for Table1Config {
    fn default() -> Self {
        Self {
            iters: 2000,
            reps: 3,
            seed: 42,
            backends: DEFAULT_BACKENDS.to_vec(),
            models: crate::models::ALL_MODELS.iter().map(|s| s.to_string()).collect(),
            // bound plain `cargo bench` runs: every cell is measured over
            // ≤ 200 executed iterations and extrapolated to `iters`
            // (marked `~`); set to None / T1_FULL=1 for full-length runs
            max_run_iters: Some(200),
        }
    }
}

/// Time static HMC over a density: returns seconds per `target_iters`.
fn time_hmc(
    ld: &dyn LogDensity,
    theta0: &[f64],
    step_size: f64,
    target_iters: usize,
    run_iters: usize,
    reps: usize,
    seed: u64,
) -> (f64, f64) {
    let hmc = Hmc::paper(step_size);
    let mut times = Vec::with_capacity(reps);
    for r in 0..reps {
        let mut rng = Xoshiro256pp::seed_from_u64(seed + r as u64);
        let t0 = std::time::Instant::now();
        let out = hmc.sample(ld, theta0, 0, run_iters, &mut rng);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(out.logps.last());
        times.push(dt * target_iters as f64 / run_iters as f64);
    }
    (
        crate::util::stats::mean(&times),
        if reps > 1 {
            crate::util::stats::std(&times)
        } else {
            0.0
        },
    )
}

/// Starting point: a stable point near the typed trace's prior draw,
/// shrunk toward 0 so every backend starts from an identical, numerically
/// safe position.
fn start_point(bm: &BenchModel, seed: u64) -> (TypedVarInfo, Vec<f64>) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let vi = init_trace(bm.model.as_ref(), &mut rng);
    let tvi = TypedVarInfo::from_untyped(&vi);
    let theta0: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.1).collect();
    // sanity: must be finite
    let lp = typed_logp(bm.model.as_ref(), &tvi, &theta0, Context::Default);
    assert!(lp.is_finite(), "{}: start point has logp {lp}", bm.name);
    (tvi, theta0)
}

/// Run one cell.
pub fn run_cell(
    name: &str,
    backend: BenchBackend,
    cfg: &Table1Config,
) -> Cell {
    let bm = build(name, cfg.seed);
    let (tvi, theta0) = start_point(&bm, cfg.seed);
    let mut run_iters =
        ((cfg.iters as f64 * backend.iter_fraction()) as usize).clamp(5, cfg.iters);
    if let Some(cap) = cfg.max_run_iters {
        run_iters = run_iters.min(cap.max(5));
    }
    let extrapolated = run_iters < cfg.iters;

    let (mean, std) = match backend {
        BenchBackend::Untyped => {
            let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
            let vi = init_trace(bm.model.as_ref(), &mut rng);
            let ld = UntypedDensity::new(bm.model.as_ref(), &vi, Backend::Reverse);
            time_hmc(&ld, &theta0, bm.step_size, cfg.iters, run_iters, cfg.reps, cfg.seed)
        }
        BenchBackend::TypedTape => {
            let ld = NativeDensity::new(bm.model.as_ref(), &tvi, Backend::Reverse);
            time_hmc(&ld, &theta0, bm.step_size, cfg.iters, run_iters, cfg.reps, cfg.seed)
        }
        BenchBackend::TypedFused => {
            // pin the dynamic fused walk: `fused()` auto-promotes static
            // models to the compiled replay, which `bench static` measures
            // separately — this cell stays the dynamic-engine baseline
            let ld = NativeDensity::fused_dynamic(bm.model.as_ref(), &tvi);
            time_hmc(&ld, &theta0, bm.step_size, cfg.iters, run_iters, cfg.reps, cfg.seed)
        }
        BenchBackend::TypedForward => {
            let ld = NativeDensity::new(bm.model.as_ref(), &tvi, Backend::Forward);
            time_hmc(&ld, &theta0, bm.step_size, cfg.iters, run_iters, cfg.reps, cfg.seed)
        }
        BenchBackend::TypedXla => {
            if !artifact_exists(name) {
                return Cell {
                    model: name.into(),
                    backend,
                    mean: f64::NAN,
                    std: 0.0,
                    extrapolated: false,
                    note: Some("artifact missing (make artifacts)".into()),
                };
            }
            let ld = XlaDensity::load(&artifacts_dir(), name, bm.theta_dim, &bm.data)
                .expect("artifact load failed");
            time_hmc(&ld, &theta0, bm.step_size, cfg.iters, run_iters, cfg.reps, cfg.seed)
        }
        BenchBackend::TypedXlaFused => {
            if !artifact_exists(name)
                || !crate::runtime::XlaTrajectory::traj_artifact_exists(name)
            {
                return Cell {
                    model: name.into(),
                    backend,
                    mean: f64::NAN,
                    std: 0.0,
                    extrapolated: false,
                    note: Some("artifact missing (make artifacts)".into()),
                };
            }
            let traj =
                crate::runtime::XlaTrajectory::load(&artifacts_dir(), name, bm.theta_dim, &bm.data)
                    .expect("trajectory artifact load failed");
            let vg = XlaDensity::load(&artifacts_dir(), name, bm.theta_dim, &bm.data)
                .expect("artifact load failed");
            let sampler = crate::inference::hmc::HmcFusedXla {
                traj: &traj,
                vg: &vg,
                step_size: bm.step_size,
            };
            let mut times = Vec::with_capacity(cfg.reps);
            for r in 0..cfg.reps {
                let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed + r as u64);
                let t0 = std::time::Instant::now();
                let out = sampler.sample(&theta0, 0, run_iters, &mut rng);
                let dt = t0.elapsed().as_secs_f64();
                std::hint::black_box(out.logps.last());
                times.push(dt * cfg.iters as f64 / run_iters as f64);
            }
            (
                crate::util::stats::mean(&times),
                if cfg.reps > 1 { crate::util::stats::std(&times) } else { 0.0 },
            )
        }
        BenchBackend::StanLike => {
            let ld = stanlike_density(&bm);
            time_hmc(
                ld.as_ref(),
                &theta0,
                bm.step_size,
                cfg.iters,
                run_iters,
                cfg.reps,
                cfg.seed,
            )
        }
    };
    Cell {
        model: name.into(),
        backend,
        mean,
        std,
        extrapolated,
        note: None,
    }
}

/// Run the full table.
pub fn run_table1(cfg: &Table1Config) -> Vec<Cell> {
    let mut cells = Vec::new();
    for name in &cfg.models {
        for &backend in &cfg.backends {
            eprintln!("bench: {name} / {}", backend.label());
            cells.push(run_cell(name, backend, cfg));
        }
    }
    cells
}

/// Render the paper-shaped table: rows = backends, columns = models.
pub fn render_table1(cells: &[Cell], cfg: &Table1Config) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Table 1 — inference time for {} iterations of static HMC(4 leapfrog), seconds;\n\
         smaller is better. `~` marks cells extrapolated from a shorter run.\n",
        cfg.iters
    );
    let col_w = 16usize;
    let _ = write!(out, "{:<12}", "backend");
    for m in &cfg.models {
        let _ = write!(out, "{:>col_w$}", m);
    }
    let _ = writeln!(out);
    for &backend in &cfg.backends {
        let _ = write!(out, "{:<12}", backend.label());
        for m in &cfg.models {
            let cell = cells
                .iter()
                .find(|c| &c.model == m && c.backend == backend);
            match cell {
                Some(c) if c.mean.is_finite() => {
                    let mark = if c.extrapolated { "~" } else { "" };
                    let _ = write!(
                        out,
                        "{:>col_w$}",
                        format!("{mark}{:.3}±{:.3}", c.mean, c.std)
                    );
                }
                Some(c) => {
                    let _ = write!(out, "{:>col_w$}", c.note.as_deref().unwrap_or("n/a"));
                }
                None => {
                    let _ = write!(out, "{:>col_w$}", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    // headline ratios
    let _ = writeln!(out, "\nspeedups (× vs typed+xla):");
    for m in &cfg.models {
        let xla = cells
            .iter()
            .find(|c| &c.model == m && c.backend == BenchBackend::TypedXla)
            .map(|c| c.mean);
        if let Some(x) = xla.filter(|x| x.is_finite()) {
            let _ = write!(out, "  {m}:");
            for &b in &cfg.backends {
                if b == BenchBackend::TypedXla {
                    continue;
                }
                if let Some(c) = cells
                    .iter()
                    .find(|c| &c.model == m && c.backend == b)
                    .filter(|c| c.mean.is_finite())
                {
                    let _ = write!(out, " {}={:.1}×", b.label(), c.mean / x);
                }
            }
            let _ = writeln!(out);
        }
    }
    out
}

// ------------------------------------------------------------------- SMC

/// Which particle-replay path an SMC bench row measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SmcPath {
    /// Typed fast path: cursor walks over forked `TypedVarInfo` buffers
    /// (automatic demotion on dynamic structure change).
    Typed,
    /// Boxed baseline: hash-addressed `ReplayExecutor` replay.
    Boxed,
}

impl SmcPath {
    pub fn label(&self) -> &'static str {
        match self {
            SmcPath::Typed => "typed",
            SmcPath::Boxed => "boxed",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "typed" => SmcPath::Typed,
            "boxed" => SmcPath::Boxed,
            _ => return None,
        })
    }
}

/// One SMC benchmark row: the particle workload the Table-1 HMC harness
/// cannot express (evidence estimation over sequential models), measured
/// per replay path so `BENCH_SMC.json` records the typed-vs-boxed speedup.
#[derive(Clone, Debug)]
pub struct SmcRow {
    pub model: String,
    /// Replay path this row measured (`typed` / `boxed`).
    pub path: SmcPath,
    pub n_particles: usize,
    /// Observe-statement count = SMC step count of the model.
    pub n_obs: usize,
    /// Log-marginal-likelihood estimate.
    pub log_evidence: f64,
    /// ESS after the final observation (weight health).
    pub final_ess: f64,
    pub resamples: usize,
    /// Steps that actually executed on the typed fast path.
    pub typed_steps: usize,
    /// Mid-sweep demotions to the boxed path.
    pub demotions: usize,
    pub wall_secs: f64,
    pub threads: usize,
    pub seed: u64,
}

/// SMC benchmark configuration.
#[derive(Clone, Debug)]
pub struct SmcBenchConfig {
    pub models: Vec<String>,
    pub n_particles: usize,
    pub seed: u64,
    pub threads: usize,
    /// Use the reduced workloads (default — the full StoVol/HMM workloads
    /// re-execute the whole body per observation and are bench-only).
    pub small: bool,
    /// Replay paths to measure (default: both, so the JSON carries the
    /// speedup at equal particle count).
    pub paths: Vec<SmcPath>,
}

impl Default for SmcBenchConfig {
    fn default() -> Self {
        Self {
            models: vec!["hmm_semisup".into(), "sto_volatility".into()],
            n_particles: 512,
            seed: 42,
            threads: 1,
            small: true,
            paths: vec![SmcPath::Typed, SmcPath::Boxed],
        }
    }
}

/// Run SMC over each configured model × path and collect rows.
pub fn run_smc_bench(cfg: &SmcBenchConfig) -> Vec<SmcRow> {
    let mut rows = Vec::with_capacity(cfg.models.len() * cfg.paths.len());
    for name in &cfg.models {
        let bm = if cfg.small {
            crate::models::build_small(name, cfg.seed)
        } else {
            build(name, cfg.seed)
        };
        for &path in &cfg.paths {
            eprintln!("bench: {name} / smc×{} ({})", cfg.n_particles, path.label());
            let smc = crate::inference::Smc {
                n_particles: cfg.n_particles,
                threads: cfg.threads,
                use_typed: path == SmcPath::Typed,
                ..crate::inference::Smc::default()
            };
            let out = smc.run(bm.model.as_ref(), cfg.seed);
            rows.push(SmcRow {
                model: name.clone(),
                path,
                n_particles: cfg.n_particles,
                n_obs: out.cloud.n_obs(),
                log_evidence: out.log_evidence,
                final_ess: out.ess_trace.last().copied().unwrap_or(f64::NAN),
                resamples: out.resamples,
                typed_steps: out.typed_steps,
                demotions: out.demotions,
                wall_secs: out.wall_secs,
                threads: cfg.threads,
                seed: cfg.seed,
            });
        }
    }
    rows
}

/// Human-readable SMC table, with per-model typed-vs-boxed speedups when
/// both paths were measured.
pub fn render_smc_table(rows: &[SmcRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "SMC — log-evidence / ESS / wall time per model × replay path (N particles, ESS-triggered systematic resampling)\n"
    );
    let _ = writeln!(
        out,
        "{:<16} {:>6} {:>10} {:>6} {:>14} {:>10} {:>10} {:>10}",
        "model", "path", "particles", "steps", "log Ẑ", "final ESS", "resamples", "wall (s)"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<16} {:>6} {:>10} {:>6} {:>14.4} {:>10.1} {:>10} {:>10.3}",
            r.model,
            r.path.label(),
            r.n_particles,
            r.n_obs,
            r.log_evidence,
            r.final_ess,
            r.resamples,
            r.wall_secs
        );
    }
    let mut wrote_header = false;
    for r in rows.iter().filter(|r| r.path == SmcPath::Typed) {
        if let Some(b) = rows
            .iter()
            .find(|b| b.path == SmcPath::Boxed && b.model == r.model)
        {
            if !wrote_header {
                let _ = writeln!(out, "\nspeedups (boxed / typed wall time):");
                wrote_header = true;
            }
            let _ = writeln!(
                out,
                "  {:<16} {:.2}×  (evidence bit-identical: {})",
                r.model,
                b.wall_secs / r.wall_secs,
                r.log_evidence.to_bits() == b.log_evidence.to_bits()
            );
        }
    }
    out
}

// ------------------------------------------------------------------ grad

/// One `bench grad` row: raw gradient-evaluation cost of one engine
/// ([`gradient::Backend`], labeled/parsed by its own `label`/`FromStr`)
/// on one model — the per-leapfrog-step quantity every Table-1 HMC cell
/// is built from, isolated from sampler logic.
///
/// [`gradient::Backend`]: crate::gradient::Backend
#[derive(Clone, Debug)]
pub struct GradRow {
    pub model: String,
    pub engine: Backend,
    /// Unconstrained dimension.
    pub dim: usize,
    /// Mean wall-clock seconds per gradient evaluation.
    pub secs_per_grad: f64,
    /// Tape nodes per evaluation (fused: arena nodes beyond the leaves;
    /// tape: full per-op node count; forward: 0).
    pub tape_nodes: usize,
    /// Fused engines only: direct analytic-adjoint seeds per evaluation.
    pub seeds: usize,
    /// Tilde statements (assume + observe + raw-logp terms) per model run.
    pub tilde_stmts: usize,
    /// Max relative error vs the forward-dual gradient (NaN when forward
    /// was not run).
    pub max_rel_err_vs_forward: f64,
    /// Wall-clock speedup vs the tape engine (fused/forward rows; NaN when
    /// tape was not measured).
    pub speedup_vs_tape: f64,
    /// Fused only: arena-tape capacity was bit-stable across the timed run
    /// (zero steady-state allocation in the gradient *engine*; the `Vec`
    /// each vector-valued assume returns to the model body is outside this
    /// probe — scalar-tilde models are fully allocation-free).
    pub alloc_steady: bool,
    pub seed: u64,
}

/// `bench grad` configuration.
#[derive(Clone, Debug)]
pub struct GradBenchConfig {
    pub models: Vec<String>,
    pub engines: Vec<Backend>,
    pub seed: u64,
    /// Use the reduced workloads (default) or the full Table-1 sizes.
    pub small: bool,
    /// Target seconds per timed measurement (per rep).
    pub target_secs: f64,
    pub reps: usize,
}

impl Default for GradBenchConfig {
    fn default() -> Self {
        Self {
            models: crate::models::ALL_MODELS.iter().map(|s| s.to_string()).collect(),
            engines: vec![Backend::ReverseFused, Backend::Reverse, Backend::Forward],
            seed: 42,
            small: true,
            target_secs: 5e-3,
            reps: 5,
        }
    }
}

/// Forward mode is n full passes — skip it above this dimension, unless
/// forward is the *only* engine requested (an explicit single-engine run).
const FORWARD_DIM_CAP: usize = 1500;

/// Run the gradient-engine comparison and collect rows.
pub fn run_grad_bench(cfg: &GradBenchConfig) -> Vec<GradRow> {
    use crate::model::{
        init_typed, typed_grad_forward, typed_grad_fused_into, typed_grad_reverse,
    };

    let mut rows = Vec::new();
    for name in &cfg.models {
        let bm = if cfg.small {
            crate::models::build_small(name, cfg.seed)
        } else {
            build(name, cfg.seed)
        };
        let model = bm.model.as_ref();
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
        let tvi = init_typed(model, &mut rng);
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.3).collect();
        let dim = theta.len();
        let mut grad = vec![0.0; dim];

        // one diagnostic eval per *requested* engine: node counts +
        // reference gradients (the fused eval always runs — it is the
        // cheapest engine and supplies the tilde/node diagnostics)
        let want = |e: Backend| cfg.engines.contains(&e);
        let lp_fused = typed_grad_fused_into(model, &tvi, &theta, Context::Default, &mut grad);
        assert!(lp_fused.is_finite(), "{name}: fused logp {lp_fused}");
        let fused_stats = crate::ad::arena::last_stats();
        let g_fused = grad.clone();
        let tape_nodes = if want(Backend::Reverse) {
            let _ = typed_grad_reverse(model, &tvi, &theta, Context::Default);
            crate::ad::reverse::last_tape_len()
        } else {
            0
        };
        let run_forward =
            want(Backend::Forward) && (dim <= FORWARD_DIM_CAP || cfg.engines.len() == 1);
        let g_forward = if run_forward {
            Some(typed_grad_forward(model, &tvi, &theta, Context::Default).1)
        } else {
            if want(Backend::Forward) {
                eprintln!(
                    "bench: {name}: skipping forward (dim {dim} > {FORWARD_DIM_CAP}; run with --engines forward to force)"
                );
            }
            None
        };
        let max_rel_err = match &g_forward {
            Some(gf) => g_fused
                .iter()
                .zip(gf)
                .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
                .fold(0.0, f64::max),
            None => f64::NAN,
        };

        let mut per_engine: Vec<(Backend, f64, usize, bool)> = Vec::new();
        for &engine in &cfg.engines {
            eprintln!("bench: {name} / grad×{}", engine.label());
            let (m, nodes, steady) = match engine {
                Backend::ReverseFused => {
                    let cap_before = crate::ad::arena::capacity_bytes();
                    let m = crate::util::timing::bench_micro(
                        &format!("{name}/fused"),
                        cfg.target_secs,
                        cfg.reps,
                        || {
                            std::hint::black_box(typed_grad_fused_into(
                                model,
                                &tvi,
                                &theta,
                                Context::Default,
                                &mut grad,
                            ));
                        },
                    );
                    let steady = crate::ad::arena::capacity_bytes() == cap_before;
                    (m, fused_stats.nodes, steady)
                }
                Backend::Reverse => {
                    let m = crate::util::timing::bench_micro(
                        &format!("{name}/tape"),
                        cfg.target_secs,
                        cfg.reps,
                        || {
                            std::hint::black_box(typed_grad_reverse(
                                model,
                                &tvi,
                                &theta,
                                Context::Default,
                            ));
                        },
                    );
                    (m, tape_nodes, false)
                }
                Backend::Forward => {
                    if !run_forward {
                        continue;
                    }
                    let m = crate::util::timing::bench_micro(
                        &format!("{name}/forward"),
                        cfg.target_secs,
                        cfg.reps,
                        || {
                            std::hint::black_box(typed_grad_forward(
                                model,
                                &tvi,
                                &theta,
                                Context::Default,
                            ));
                        },
                    );
                    (m, 0, false)
                }
            };
            per_engine.push((engine, m.mean(), nodes, steady));
        }

        let tape_secs = per_engine
            .iter()
            .find(|(e, ..)| *e == Backend::Reverse)
            .map(|&(_, s, ..)| s);
        for (engine, secs, nodes, steady) in per_engine {
            rows.push(GradRow {
                model: name.clone(),
                engine,
                dim,
                secs_per_grad: secs,
                tape_nodes: nodes,
                seeds: if engine == Backend::ReverseFused {
                    fused_stats.seeds
                } else {
                    0
                },
                tilde_stmts: fused_stats.tilde_stmts,
                max_rel_err_vs_forward: if engine == Backend::ReverseFused {
                    max_rel_err
                } else {
                    f64::NAN
                },
                speedup_vs_tape: match (engine, tape_secs) {
                    (Backend::Reverse, _) | (_, None) => f64::NAN,
                    (_, Some(t)) => t / secs,
                },
                alloc_steady: steady,
                seed: cfg.seed,
            });
        }
    }
    rows
}

/// Human-readable gradient-engine table.
pub fn render_grad_table(rows: &[GradRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "grad — one gradient evaluation per engine (the per-leapfrog-step cost under every Table-1 HMC cell)\n"
    );
    let _ = writeln!(
        out,
        "{:<16} {:>8} {:>5} {:>12} {:>11} {:>8} {:>7} {:>14} {:>9}",
        "model", "engine", "dim", "µs/grad", "nodes/eval", "seeds", "tildes", "vs-tape", "alloc"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<16} {:>8} {:>5} {:>12.2} {:>11} {:>8} {:>7} {:>14} {:>9}",
            r.model,
            r.engine.label(),
            r.dim,
            r.secs_per_grad * 1e6,
            r.tape_nodes,
            r.seeds,
            r.tilde_stmts,
            if r.speedup_vs_tape.is_finite() {
                format!("{:.1}×", r.speedup_vs_tape)
            } else {
                "-".into()
            },
            if r.engine == Backend::ReverseFused {
                if r.alloc_steady { "steady" } else { "GREW" }
            } else {
                "-"
            },
        );
    }
    out
}

/// Serialize grad rows as the coordinator's `BENCH_GRAD.json` payload.
pub fn grad_rows_to_json(rows: &[GradRow], cfg: &GradBenchConfig) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\n  \"bench\": \"grad\",\n  \"seed\": {},\n  \"small\": {},\n  \"rows\": [\n",
        cfg.seed, cfg.small
    );
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"engine\": \"{}\", \"dim\": {}, \"secs_per_grad\": {}, \
             \"tape_nodes\": {}, \"seeds\": {}, \"tilde_stmts\": {}, \
             \"max_rel_err_vs_forward\": {}, \"speedup_vs_tape\": {}, \"alloc_steady\": {}, \
             \"seed\": {}}}",
            r.model,
            r.engine.label(),
            r.dim,
            json_num(r.secs_per_grad),
            r.tape_nodes,
            r.seeds,
            r.tilde_stmts,
            json_num(r.max_rel_err_vs_forward),
            json_num(r.speedup_vs_tape),
            r.alloc_steady,
            r.seed,
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// One `bench batch` measurement: the lane-batched fused engine at lane
/// count K, normalized to seconds per *lane gradient*.
#[derive(Clone, Debug)]
pub struct BatchRow {
    pub model: String,
    /// Unconstrained dimension (per lane).
    pub dim: usize,
    /// Lane count K of this measurement.
    pub lanes: usize,
    /// Mean wall-clock seconds per lane gradient (one batched evaluation
    /// costs `lanes × secs_per_grad`).
    pub secs_per_grad: f64,
    /// Per-gradient speedup vs this model's K = 1 batched row (NaN when
    /// K = 1 was not in the sweep).
    pub speedup_vs_k1: f64,
    /// Per-gradient speedup vs the sequential scalar fused engine — the
    /// path K independent chains would otherwise each take.
    pub speedup_vs_seq: f64,
    pub seed: u64,
}

/// `bench batch` configuration.
#[derive(Clone, Debug)]
pub struct BatchBenchConfig {
    pub models: Vec<String>,
    /// Lane counts to sweep (a `1` entry is the batched-engine baseline
    /// the `vs-K1` column normalizes against).
    pub lane_counts: Vec<usize>,
    pub seed: u64,
    /// Use the reduced workloads (default) or the full Table-1 sizes.
    pub small: bool,
    /// Target seconds per timed measurement (per rep).
    pub target_secs: f64,
    pub reps: usize,
}

impl Default for BatchBenchConfig {
    fn default() -> Self {
        Self {
            // continuous-θ workloads across the shape spectrum: scalar
            // glue (gauss_unknown), vector kernels (logreg), tall data
            // (logreg_tall), long scalar loops (sto_volatility)
            models: ["gauss_unknown", "logreg", "logreg_tall", "sto_volatility"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            lane_counts: vec![1, 4, 16, 64],
            seed: 42,
            small: true,
            target_secs: 5e-3,
            reps: 5,
        }
    }
}

/// Run the lane-count sweep over the batched fused engine.
pub fn run_batch_bench(cfg: &BatchBenchConfig) -> Vec<BatchRow> {
    use crate::model::batched::typed_grad_batch_into;
    use crate::model::{init_typed, typed_grad_fused_into};

    let mut rows = Vec::new();
    for name in &cfg.models {
        let bm = if cfg.small {
            crate::models::build_small(name, cfg.seed)
        } else {
            build(name, cfg.seed)
        };
        let model = bm.model.as_ref();
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
        let tvi = init_typed(model, &mut rng);
        let base: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.3).collect();
        let dim = base.len();
        let mut grad = vec![0.0; dim];

        // the sequential comparator: the scalar fused engine each of K
        // independent chains would run (also the bitwise reference)
        let lp_seq = typed_grad_fused_into(model, &tvi, &base, Context::Default, &mut grad);
        assert!(lp_seq.is_finite(), "{name}: fused logp {lp_seq}");
        eprintln!("bench: {name} / batch seq-baseline");
        let seq_secs = crate::util::timing::bench_micro(
            &format!("{name}/seq"),
            cfg.target_secs,
            cfg.reps,
            || {
                std::hint::black_box(typed_grad_fused_into(
                    model,
                    &tvi,
                    &base,
                    Context::Default,
                    &mut grad,
                ));
            },
        )
        .mean();

        let mut per_k: Vec<(usize, f64)> = Vec::new();
        for &k in &cfg.lane_counts {
            eprintln!("bench: {name} / batch×K{k}");
            // lane 0 carries the sequential θ; later lanes are nudged so
            // the lane loops cannot collapse to a broadcast
            let mut thetas = vec![0.0; dim * k];
            for l in 0..k {
                for j in 0..dim {
                    thetas[l * dim + j] = base[j] + 1e-3 * l as f64;
                }
            }
            let mut lps = vec![0.0; k];
            let mut grads = vec![0.0; dim * k];
            typed_grad_batch_into(model, &tvi, &thetas, k, Context::Default, &mut lps, &mut grads);
            assert!(
                lps.iter().all(|lp| lp.is_finite()),
                "{name}: K{k} rejected a lane: {lps:?}"
            );
            assert_eq!(
                lps[0].to_bits(),
                lp_seq.to_bits(),
                "{name}: lane 0 must be bitwise the sequential evaluation"
            );
            let m = crate::util::timing::bench_micro(
                &format!("{name}/K{k}"),
                cfg.target_secs,
                cfg.reps,
                || {
                    typed_grad_batch_into(
                        model,
                        &tvi,
                        std::hint::black_box(&thetas),
                        k,
                        Context::Default,
                        &mut lps,
                        &mut grads,
                    );
                },
            );
            per_k.push((k, m.mean() / k as f64));
        }

        let k1_secs = per_k.iter().find(|&&(k, _)| k == 1).map(|&(_, s)| s);
        for (k, secs) in per_k {
            rows.push(BatchRow {
                model: name.clone(),
                dim,
                lanes: k,
                secs_per_grad: secs,
                speedup_vs_k1: match k1_secs {
                    Some(s1) => s1 / secs,
                    None => f64::NAN,
                },
                speedup_vs_seq: seq_secs / secs,
                seed: cfg.seed,
            });
        }
    }
    rows
}

/// Human-readable lane-sweep table.
pub fn render_batch_table(rows: &[BatchRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "batch — one fused logp∇ pass over K lanes, normalized per lane gradient\n"
    );
    let _ = writeln!(
        out,
        "{:<16} {:>5} {:>5} {:>12} {:>8} {:>8}",
        "model", "dim", "K", "µs/grad", "vs-K1", "vs-seq"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<16} {:>5} {:>5} {:>12.2} {:>8} {:>8}",
            r.model,
            r.dim,
            r.lanes,
            r.secs_per_grad * 1e6,
            if r.speedup_vs_k1.is_finite() {
                format!("{:.1}×", r.speedup_vs_k1)
            } else {
                "-".into()
            },
            if r.speedup_vs_seq.is_finite() {
                format!("{:.1}×", r.speedup_vs_seq)
            } else {
                "-".into()
            },
        );
    }
    out
}

/// Serialize batch rows as the coordinator's `BENCH_BATCH.json` payload.
pub fn batch_rows_to_json(rows: &[BatchRow], cfg: &BatchBenchConfig) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\n  \"bench\": \"batch\",\n  \"seed\": {},\n  \"small\": {},\n  \"rows\": [\n",
        cfg.seed, cfg.small
    );
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"dim\": {}, \"lanes\": {}, \"secs_per_grad\": {}, \
             \"speedup_vs_k1\": {}, \"speedup_vs_seq\": {}, \"seed\": {}}}",
            r.model,
            r.dim,
            r.lanes,
            json_num(r.secs_per_grad),
            json_num(r.speedup_vs_k1),
            json_num(r.speedup_vs_seq),
            r.seed,
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// One `bench static` row: the compiled static-structure replay vs the
/// dynamic fused walk of the same density — the quantity the
/// structure compiler exists to improve, isolated per model.
#[derive(Clone, Debug)]
pub struct StaticRow {
    pub model: String,
    /// Unconstrained dimension.
    pub dim: usize,
    /// The recorder promoted this model: two structurally identical
    /// recordings plus a bitwise cross-check against the dynamic walk.
    pub promoted: bool,
    /// Observe plates the compiler formed, and the total data rows they
    /// route through the row-batched kernels.
    pub n_plates: usize,
    pub plate_rows: usize,
    /// Mean wall-clock seconds per gradient, dynamic fused walk.
    pub secs_dynamic: f64,
    /// Mean wall-clock seconds per gradient, compiled replay (NaN when
    /// the model did not promote).
    pub secs_compiled: f64,
    /// `secs_dynamic / secs_compiled` (NaN when the model did not promote).
    pub speedup: f64,
    pub seed: u64,
}

/// `bench static` configuration.
#[derive(Clone, Debug)]
pub struct StaticBenchConfig {
    pub models: Vec<String>,
    pub seed: u64,
    /// Use the reduced workloads (default) or the full Table-1 sizes.
    pub small: bool,
    /// Target seconds per timed measurement (per rep).
    pub target_secs: f64,
    pub reps: usize,
}

impl Default for StaticBenchConfig {
    fn default() -> Self {
        // every Table-1 model plus the tall flagship where plate grouping
        // and hash-free replay have the most data rows to amortize over
        let mut models: Vec<String> =
            crate::models::ALL_MODELS.iter().map(|s| s.to_string()).collect();
        models.push("logreg_tall".into());
        Self {
            models,
            seed: 42,
            small: true,
            target_secs: 5e-3,
            reps: 5,
        }
    }
}

/// Run the compiled-vs-dynamic comparison and collect rows.
pub fn run_static_bench(cfg: &StaticBenchConfig) -> Vec<StaticRow> {
    use crate::model::{compiled, init_typed, typed_grad_fused_into};

    let mut rows = Vec::new();
    for name in &cfg.models {
        let bm = if cfg.small {
            crate::models::build_small(name, cfg.seed)
        } else {
            build(name, cfg.seed)
        };
        let model = bm.model.as_ref();
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
        let tvi = init_typed(model, &mut rng);
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.3).collect();
        let dim = theta.len();
        let mut grad = vec![0.0; dim];

        let lp_dyn = typed_grad_fused_into(model, &tvi, &theta, Context::Default, &mut grad);
        assert!(lp_dyn.is_finite(), "{name}: fused logp {lp_dyn}");
        let g_dyn = grad.clone();
        eprintln!("bench: {name} / static dynamic-baseline");
        let secs_dynamic = crate::util::timing::bench_micro(
            &format!("{name}/dynamic"),
            cfg.target_secs,
            cfg.reps,
            || {
                std::hint::black_box(typed_grad_fused_into(
                    model,
                    &tvi,
                    &theta,
                    Context::Default,
                    &mut grad,
                ));
            },
        )
        .mean();

        let prog = compiled::try_compile(model, &tvi);
        let (n_plates, plate_rows, secs_compiled) = match &prog {
            Some(p) => {
                // end-to-end bitwise check at the bench point (the compiler
                // already cross-validated at its own probe point)
                let lp_c = p.logp_grad_into(&tvi, &theta, Context::Default, &mut grad);
                assert_eq!(
                    lp_c.to_bits(),
                    lp_dyn.to_bits(),
                    "{name}: compiled logp diverges from the dynamic walk"
                );
                for (j, (a, b)) in grad.iter().zip(&g_dyn).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{name}: compiled grad[{j}] diverges from the dynamic walk"
                    );
                }
                eprintln!("bench: {name} / static compiled-replay");
                let secs = crate::util::timing::bench_micro(
                    &format!("{name}/compiled"),
                    cfg.target_secs,
                    cfg.reps,
                    || {
                        std::hint::black_box(p.logp_grad_into(
                            &tvi,
                            &theta,
                            Context::Default,
                            &mut grad,
                        ));
                    },
                )
                .mean();
                (p.n_plates(), p.plate_rows(), secs)
            }
            None => {
                eprintln!("bench: {name}: did not promote (structure is not static)");
                (0, 0, f64::NAN)
            }
        };

        rows.push(StaticRow {
            model: name.clone(),
            dim,
            promoted: prog.is_some(),
            n_plates,
            plate_rows,
            secs_dynamic,
            secs_compiled,
            speedup: secs_dynamic / secs_compiled,
            seed: cfg.seed,
        });
    }
    rows
}

/// Human-readable compiled-vs-dynamic table.
pub fn render_static_table(rows: &[StaticRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "static — compiled structure replay vs the dynamic fused walk, one gradient per side\n"
    );
    let _ = writeln!(
        out,
        "{:<16} {:>5} {:>9} {:>7} {:>10} {:>12} {:>12} {:>8}",
        "model", "dim", "promoted", "plates", "plate-rows", "µs/dynamic", "µs/compiled", "speedup"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<16} {:>5} {:>9} {:>7} {:>10} {:>12.2} {:>12} {:>8}",
            r.model,
            r.dim,
            if r.promoted { "yes" } else { "NO" },
            r.n_plates,
            r.plate_rows,
            r.secs_dynamic * 1e6,
            if r.secs_compiled.is_finite() {
                format!("{:.2}", r.secs_compiled * 1e6)
            } else {
                "-".into()
            },
            if r.speedup.is_finite() {
                format!("{:.2}×", r.speedup)
            } else {
                "-".into()
            },
        );
    }
    out
}

/// Serialize static rows as the coordinator's `BENCH_STATIC.json` payload.
pub fn static_rows_to_json(rows: &[StaticRow], cfg: &StaticBenchConfig) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\n  \"bench\": \"static\",\n  \"seed\": {},\n  \"small\": {},\n  \"rows\": [\n",
        cfg.seed, cfg.small
    );
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"dim\": {}, \"promoted\": {}, \"n_plates\": {}, \
             \"plate_rows\": {}, \"secs_dynamic\": {}, \"secs_compiled\": {}, \
             \"speedup\": {}, \"seed\": {}}}",
            r.model,
            r.dim,
            r.promoted,
            r.n_plates,
            r.plate_rows,
            json_num(r.secs_dynamic),
            json_num(r.secs_compiled),
            json_num(r.speedup),
            r.seed,
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// The `--assert-speedup` gate: every promoted model must be at least
/// break-even against the dynamic walk, and the tall flagship
/// (`logreg_tall`) must reach `min_tall` and must have promoted at all.
/// Returns one message per violation (empty = gate passed).
pub fn check_static_speedups(rows: &[StaticRow], min_tall: f64) -> Vec<String> {
    let mut bad = Vec::new();
    for r in rows {
        if !r.promoted {
            if r.model == "logreg_tall" {
                bad.push(format!("{}: did not promote to the compiled executor", r.model));
            }
            continue;
        }
        let floor = if r.model == "logreg_tall" { min_tall } else { 1.0 };
        if !(r.speedup >= floor) {
            bad.push(format!(
                "{}: compiled speedup {:.2}× below required {:.2}×",
                r.model, r.speedup, floor
            ));
        }
    }
    bad
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// One `bench conjugate` row: the same one-RwMh-block-per-site Gibbs
/// sampler run twice — analyzer collapse off (plain MH-within-Gibbs) vs
/// on (exact closed-form conditional draws) — scored by the slowest
/// coordinate's effective samples per second.
#[derive(Clone, Debug)]
pub struct ConjugateRow {
    pub model: String,
    pub dim: usize,
    /// Conjugacy certificates the analyzer issued (0 = nothing collapses
    /// and the two arms are the same sampler).
    pub n_certs: usize,
    pub iters: usize,
    pub secs_mh: f64,
    pub secs_collapsed: f64,
    /// Minimum per-coordinate ESS across the constrained draw matrix.
    pub ess_mh: f64,
    pub ess_collapsed: f64,
    pub ess_rate_mh: f64,
    pub ess_rate_collapsed: f64,
    /// `ess_rate_collapsed / ess_rate_mh` — the Rao-Blackwellization win.
    pub speedup: f64,
    pub seed: u64,
}

/// Config for `bench conjugate` (`BENCH_CONJUGATE.json`).
pub struct ConjugateBenchConfig {
    pub models: Vec<String>,
    pub seed: u64,
    pub small: bool,
    pub warmup: usize,
    pub iters: usize,
}

impl Default for ConjugateBenchConfig {
    fn default() -> Self {
        Self {
            models: vec!["conjugate_hier".to_string()],
            seed: 42,
            small: true,
            warmup: 500,
            iters: 4000,
        }
    }
}

/// Run the Rao-Blackwellized-Gibbs benchmark: for each model, build one
/// RwMh Gibbs block per site symbol and run the sampler with `collapse`
/// off and on from the same seed. Both arms see identical block layouts,
/// so the only difference is the analyzer's conjugate upgrade.
pub fn run_conjugate_bench(cfg: &ConjugateBenchConfig) -> Vec<ConjugateRow> {
    use crate::inference::gibbs::{GibbsDraws, GibbsGrad};
    use crate::inference::{Gibbs, GibbsBlock};

    let mut out = Vec::new();
    for name in &cfg.models {
        let bm = if cfg.small {
            crate::models::build_small(name, cfg.seed)
        } else {
            build(name, cfg.seed)
        };
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
        let tvi = crate::model::init_typed(bm.model.as_ref(), &mut rng);
        // one RwMh block per continuous site symbol, in visit order
        let mut syms: Vec<String> = Vec::new();
        for s in tvi.slots() {
            if s.unc_len == 0 {
                continue;
            }
            let sym = s.vn.sym().as_str();
            if !syms.contains(&sym) {
                syms.push(sym);
            }
        }
        let blocks: Vec<GibbsBlock> = syms
            .iter()
            .map(|s| GibbsBlock::rwmh(&[s.as_str()], 0.25))
            .collect();
        let n_certs =
            crate::analysis::analyze(bm.model.as_ref(), &tvi).map_or(0, |a| a.certs.len());
        let run = |collapse: bool| {
            let gibbs = Gibbs {
                blocks: blocks.clone(),
                grad: GibbsGrad::Forward,
                collapse,
            };
            let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ 0xC011);
            gibbs.sample(bm.model.as_ref(), &tvi, cfg.warmup, cfg.iters, &mut rng)
        };
        eprintln!(
            "bench conjugate: {name} (dim {}, {} certs) baseline MH…",
            bm.theta_dim, n_certs
        );
        let mh = run(false);
        eprintln!("bench conjugate: {name} collapsed…");
        let col = run(true);
        let min_ess = |d: &GibbsDraws| {
            let dim = d.rows.first().map_or(0, Vec::len);
            let mut lo = f64::INFINITY;
            for j in 0..dim {
                let series: Vec<f64> = d.rows.iter().map(|r| r[j]).collect();
                lo = lo.min(crate::util::stats::ess(&series));
            }
            if lo.is_finite() {
                lo
            } else {
                0.0
            }
        };
        let (ess_mh, ess_col) = (min_ess(&mh), min_ess(&col));
        let secs_mh = mh.stats.sampling_secs.max(1e-12);
        let secs_col = col.stats.sampling_secs.max(1e-12);
        let rate_mh = ess_mh / secs_mh;
        let rate_col = ess_col / secs_col;
        out.push(ConjugateRow {
            model: name.clone(),
            dim: bm.theta_dim,
            n_certs,
            iters: cfg.iters,
            secs_mh,
            secs_collapsed: secs_col,
            ess_mh,
            ess_collapsed: ess_col,
            ess_rate_mh: rate_mh,
            ess_rate_collapsed: rate_col,
            speedup: if rate_mh > 0.0 {
                rate_col / rate_mh
            } else {
                f64::NAN
            },
            seed: cfg.seed,
        });
    }
    out
}

/// Render the conjugate-bench comparison table.
pub fn render_conjugate_table(rows: &[ConjugateRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<16} {:>4} {:>6} {:>12} {:>12} {:>12} {:>12} {:>9}",
        "model", "dim", "certs", "mh secs", "coll secs", "mh ess/s", "coll ess/s", "speedup"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<16} {:>4} {:>6} {:>12.4} {:>12.4} {:>12.1} {:>12.1} {:>8.2}x",
            r.model,
            r.dim,
            r.n_certs,
            r.secs_mh,
            r.secs_collapsed,
            r.ess_rate_mh,
            r.ess_rate_collapsed,
            r.speedup
        );
    }
    out
}

/// Serialize conjugate rows as the coordinator's `BENCH_CONJUGATE.json`
/// payload.
pub fn conjugate_rows_to_json(rows: &[ConjugateRow], cfg: &ConjugateBenchConfig) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\n  \"bench\": \"conjugate\",\n  \"seed\": {},\n  \"small\": {},\n  \"warmup\": {},\n  \"iters\": {},\n  \"rows\": [\n",
        cfg.seed, cfg.small, cfg.warmup, cfg.iters
    );
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"dim\": {}, \"n_certs\": {}, \"iters\": {}, \
             \"secs_mh\": {}, \"secs_collapsed\": {}, \"ess_mh\": {}, \"ess_collapsed\": {}, \
             \"ess_rate_mh\": {}, \"ess_rate_collapsed\": {}, \"speedup\": {}, \"seed\": {}}}",
            r.model,
            r.dim,
            r.n_certs,
            r.iters,
            json_num(r.secs_mh),
            json_num(r.secs_collapsed),
            json_num(r.ess_mh),
            json_num(r.ess_collapsed),
            json_num(r.ess_rate_mh),
            json_num(r.ess_rate_collapsed),
            json_num(r.speedup),
            r.seed,
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// The `--assert-speedup` gate for `bench conjugate`: every model must
/// certify (≥ 1 conjugacy certificate) and the collapsed arm's ESS/sec
/// must reach `min` times the MH baseline's. Returns one message per
/// violation (empty = gate passed).
pub fn check_conjugate_speedups(rows: &[ConjugateRow], min: f64) -> Vec<String> {
    let mut bad = Vec::new();
    for r in rows {
        if r.n_certs == 0 {
            bad.push(format!("{}: analyzer issued no conjugacy certificates", r.model));
            continue;
        }
        if !(r.speedup >= min) {
            bad.push(format!(
                "{}: collapsed ESS/sec speedup {:.2}× below required {:.2}×",
                r.model, r.speedup, min
            ));
        }
    }
    bad
}

/// Serialize SMC rows as the coordinator's `BENCH_SMC.json` payload
/// (hand-rolled writer — no serde in the offline dependency set).
pub fn smc_rows_to_json(rows: &[SmcRow]) -> String {
    let mut out = String::from("{\n  \"bench\": \"smc\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"path\": \"{}\", \"n_particles\": {}, \"n_obs\": {}, \
             \"log_evidence\": {}, \"final_ess\": {}, \"resamples\": {}, \
             \"typed_steps\": {}, \"demotions\": {}, \
             \"wall_secs\": {}, \"threads\": {}, \"seed\": {}}}",
            r.model,
            r.path.label(),
            r.n_particles,
            r.n_obs,
            json_num(r.log_evidence),
            json_num(r.final_ess),
            r.resamples,
            r.typed_steps,
            r.demotions,
            json_num(r.wall_secs),
            r.threads,
            r.seed,
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// Serialize Table-1 cells as the coordinator's `BENCH_TABLE1.json`
/// payload — the paper's headline table in machine-readable form, so the
/// perf trajectory across PRs is fully scriptable.
pub fn table1_cells_to_json(cells: &[Cell], cfg: &Table1Config) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\n  \"bench\": \"table1\",\n  \"iters\": {},\n  \"reps\": {},\n  \"seed\": {},\n  \"cells\": [\n",
        cfg.iters, cfg.reps, cfg.seed
    );
    for (i, c) in cells.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"backend\": \"{}\", \"mean_secs\": {}, \
             \"std_secs\": {}, \"extrapolated\": {}, \"note\": {}}}",
            c.model,
            c.backend.label(),
            json_num(c.mean),
            json_num(c.std),
            c.extrapolated,
            match &c.note {
                Some(n) => format!("\"{n}\""),
                None => "null".to_string(),
            },
        );
        out.push_str(if i + 1 < cells.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

// -------------------------------------------------------------------- vi

/// One `bench vi` row: an ADVI fit on one model × family, with its ELBO
/// trajectory plus a NUTS reference run at matched model so the JSON
/// carries the wall-clock and accuracy trade of variational inference —
/// the workload class neither the Table-1 HMC harness nor `bench smc`
/// covers. With `--minibatch B`, tall models additionally get a
/// minibatched row per family whose accuracy is measured against the
/// full-data fit (full-vs-minibatch: secs/iter, iters-to-converge,
/// posterior agreement).
#[derive(Clone, Debug)]
pub struct ViRow {
    pub model: String,
    pub family: ViFamily,
    /// Unconstrained dimension.
    pub dim: usize,
    /// Minibatch size this row fitted with (0 = full-data gradients).
    pub minibatch: usize,
    /// Best evaluated ELBO and its Monte-Carlo standard error.
    pub elbo: f64,
    pub elbo_se: f64,
    pub converged: bool,
    /// Optimizer iterations actually run (≤ configured max) — the
    /// iters-to-converge figure when `converged` is set.
    pub iters: usize,
    /// η chosen by the Stan-style ladder search.
    pub eta: f64,
    pub secs_per_iter: f64,
    pub wall_secs: f64,
    /// (iteration, ELBO) at every evaluation point.
    pub elbo_trace: Vec<(usize, f64)>,
    /// NUTS reference: wall seconds at matched model (NaN when the model
    /// is too tall for an honest full-data NUTS reference).
    pub nuts_wall_secs: f64,
    /// nuts_wall_secs / wall_secs.
    pub speedup_vs_nuts: f64,
    /// max over constrained columns of |mean_vi − mean_nuts| / (1 + |mean_nuts|).
    pub max_mean_err_vs_nuts: f64,
    /// Same for per-column standard deviations.
    pub max_sd_err_vs_nuts: f64,
    /// Minibatch rows only: max posterior-mean / sd error vs the
    /// full-data fit of the same family (NaN on full rows).
    pub max_mean_err_vs_full: f64,
    pub max_sd_err_vs_full: f64,
    pub seed: u64,
}

/// `bench vi` configuration.
#[derive(Clone, Debug)]
pub struct ViBenchConfig {
    pub models: Vec<String>,
    pub families: Vec<ViFamily>,
    pub seed: u64,
    /// Use the reduced workloads (default) or the full Table-1 sizes.
    pub small: bool,
    /// Posterior draws per fit for the accuracy comparison.
    pub draws: usize,
    pub nuts_warmup: usize,
    pub nuts_iters: usize,
    /// Minibatch size B: models with more than B observation sites get an
    /// extra minibatched row per family (models at or below B would be a
    /// full-data fit in disguise and are skipped).
    pub minibatch: Option<usize>,
    /// Base ADVI configuration (`family` is overridden per row).
    pub advi: Advi,
}

impl Default for ViBenchConfig {
    fn default() -> Self {
        Self {
            // low-dimensional posteriors where both families are cheap
            // and NUTS is an honest, fast reference
            models: vec!["gauss_unknown".into(), "hier_poisson".into()],
            families: vec![ViFamily::MeanField, ViFamily::FullRank],
            seed: 42,
            small: true,
            draws: 2000,
            nuts_warmup: 500,
            nuts_iters: 1000,
            minibatch: None,
            advi: Advi {
                max_iters: 1000,
                eval_every: 25,
                grad_samples: 2,
                elbo_samples: 50,
                ..Advi::default()
            },
        }
    }
}

/// NUTS at full data stops being an honest, *fast* reference somewhere in
/// the thousands of observations; above this cap the VI rows carry NaN
/// reference fields — the tall-data regime is exactly where full-N NUTS
/// is unaffordable, and minibatch accuracy is tracked against the
/// full-data fit instead.
const NUTS_REFERENCE_OBS_CAP: usize = 4096;

/// Max per-column posterior mean / sd discrepancy of `chain` vs `reference`
/// (relative, 1-regularized).
fn chain_errs(chain: &crate::chain::Chain, reference: &crate::chain::Chain) -> (f64, f64) {
    let mut max_mean_err = 0.0f64;
    let mut max_sd_err = 0.0f64;
    for col in reference.names() {
        let (rm, rs) = (reference.mean(col).unwrap(), reference.std(col).unwrap());
        let (vm, vs) = (chain.mean(col).unwrap(), chain.std(col).unwrap());
        max_mean_err = max_mean_err.max((vm - rm).abs() / (1.0 + rm.abs()));
        max_sd_err = max_sd_err.max((vs - rs).abs() / (1.0 + rs.abs()));
    }
    (max_mean_err, max_sd_err)
}

/// Run ADVI × family (full-data, plus minibatched on tall models) against
/// a NUTS reference on each configured model.
pub fn run_vi_bench(cfg: &ViBenchConfig) -> Vec<ViRow> {
    use crate::inference::{raw_to_chain, sample_chain, Nuts, SamplerKind};
    use crate::model::{count_obs_sites, init_typed};
    use crate::vi::MinibatchTarget;

    let mut rows = Vec::with_capacity(cfg.models.len() * cfg.families.len());
    for name in &cfg.models {
        let bm = if cfg.small {
            crate::models::build_small(name, cfg.seed)
        } else {
            build(name, cfg.seed)
        };
        let model = bm.model.as_ref();
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
        let tvi = init_typed(model, &mut rng);
        let theta0: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.1).collect();
        let ld = NativeDensity::fused(model, &tvi);
        let n_obs = count_obs_sites(model, &tvi);

        // NUTS reference on the same fused density (tall models skip it)
        let nuts = if n_obs <= NUTS_REFERENCE_OBS_CAP {
            eprintln!("bench: {name} / nuts reference");
            Some(sample_chain(
                &ld,
                &tvi,
                &SamplerKind::Nuts(Nuts {
                    step_size: bm.step_size,
                    ..Nuts::default()
                }),
                cfg.nuts_warmup,
                cfg.nuts_iters,
                cfg.seed,
            ))
        } else {
            eprintln!("bench: {name}: skipping NUTS reference ({n_obs} observations)");
            None
        };

        let make_row = |family: ViFamily,
                        fit: &crate::vi::ViFit,
                        chain: &crate::chain::Chain,
                        full_chain: Option<&crate::chain::Chain>|
         -> ViRow {
            let (nuts_mean_err, nuts_sd_err) = match &nuts {
                Some(n) => chain_errs(chain, n),
                None => (f64::NAN, f64::NAN),
            };
            let (full_mean_err, full_sd_err) = match full_chain {
                Some(f) => chain_errs(chain, f),
                None => (f64::NAN, f64::NAN),
            };
            ViRow {
                model: name.clone(),
                family,
                dim: tvi.dim(),
                minibatch: fit.minibatch.unwrap_or(0),
                elbo: fit.elbo,
                elbo_se: fit.elbo_se,
                converged: fit.converged,
                iters: fit.iters,
                eta: fit.eta,
                // main-loop time only: the η ladder search is a one-off
                // setup cost and would overstate the per-iteration figure
                secs_per_iter: fit.opt_wall_secs / fit.iters.max(1) as f64,
                wall_secs: fit.wall_secs,
                elbo_trace: fit.elbo_trace.clone(),
                nuts_wall_secs: nuts
                    .as_ref()
                    .map_or(f64::NAN, |n| n.stats.wall_secs),
                speedup_vs_nuts: nuts
                    .as_ref()
                    .map_or(f64::NAN, |n| n.stats.wall_secs / fit.wall_secs),
                max_mean_err_vs_nuts: nuts_mean_err,
                max_sd_err_vs_nuts: nuts_sd_err,
                max_mean_err_vs_full: full_mean_err,
                max_sd_err_vs_full: full_sd_err,
                seed: cfg.seed,
            }
        };

        for &family in &cfg.families {
            eprintln!("bench: {name} / advi×{}", family.label());
            let advi = Advi {
                family,
                ..cfg.advi.clone()
            };
            let mut vi_rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ 0x5EED);
            let fit = advi.fit(&ld, &theta0, &mut vi_rng);
            let raw = fit.sample_raw(&ld, cfg.draws, &mut vi_rng);
            // constrained-space chain of approximation draws, through the
            // same conversion path as the `sample_chain` driver
            let chain = raw_to_chain(&raw, &tvi);
            rows.push(make_row(family, &fit, &chain, None));

            // full-vs-minibatch comparison on tall models
            if let Some(b) = cfg.minibatch {
                if n_obs > b {
                    eprintln!("bench: {name} / advi×{}×minibatch-{b}", family.label());
                    let target = MinibatchTarget::new(model, &tvi, b, Backend::ReverseFused);
                    let mut mb_rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ 0xB16B);
                    let mb_fit = advi.fit_minibatch(&target, &theta0, &mut mb_rng);
                    let mb_raw = mb_fit.sample_raw(&ld, cfg.draws, &mut mb_rng);
                    let mb_chain = raw_to_chain(&mb_raw, &tvi);
                    rows.push(make_row(family, &mb_fit, &mb_chain, Some(&chain)));
                }
            }
        }
    }
    rows
}

/// Human-readable VI table.
pub fn render_vi_table(rows: &[ViRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "vi — ADVI fit per model × family vs a NUTS reference (errors are vs the NUTS posterior;\n\
         minibatch rows additionally report the error vs the full-data fit)\n"
    );
    let _ = writeln!(
        out,
        "{:<16} {:>10} {:>5} {:>6} {:>12} {:>5} {:>6} {:>11} {:>10} {:>8} {:>10} {:>9} {:>9}",
        "model", "family", "dim", "batch", "ELBO", "conv", "iters", "secs/iter", "wall (s)",
        "×nuts", "mean-err", "sd-err", "vs-full"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<16} {:>10} {:>5} {:>6} {:>12.3} {:>5} {:>6} {:>11.5} {:>10.3} {:>8} {:>10} {:>9} {:>9}",
            r.model,
            r.family.label(),
            r.dim,
            if r.minibatch == 0 {
                "full".to_string()
            } else {
                format!("{}", r.minibatch)
            },
            r.elbo,
            if r.converged { "yes" } else { "NO" },
            r.iters,
            r.secs_per_iter,
            r.wall_secs,
            if r.speedup_vs_nuts.is_finite() {
                format!("{:.1}", r.speedup_vs_nuts)
            } else {
                "-".into()
            },
            if r.max_mean_err_vs_nuts.is_finite() {
                format!("{:.4}", r.max_mean_err_vs_nuts)
            } else {
                "-".into()
            },
            if r.max_sd_err_vs_nuts.is_finite() {
                format!("{:.4}", r.max_sd_err_vs_nuts)
            } else {
                "-".into()
            },
            if r.max_mean_err_vs_full.is_finite() {
                format!("{:.4}", r.max_mean_err_vs_full)
            } else {
                "-".into()
            },
        );
    }
    // headline full-vs-minibatch per-iteration speedups
    let mut wrote_header = false;
    for r in rows.iter().filter(|r| r.minibatch > 0) {
        if let Some(full) = rows
            .iter()
            .find(|f| f.minibatch == 0 && f.model == r.model && f.family == r.family)
        {
            if !wrote_header {
                let _ = writeln!(out, "\nminibatch speedups (full / minibatch secs per iteration):");
                wrote_header = true;
            }
            let _ = writeln!(
                out,
                "  {:<16} {:<10} B={:<6} {:.1}×  (mean-err vs full fit: {:.4})",
                r.model,
                r.family.label(),
                r.minibatch,
                full.secs_per_iter / r.secs_per_iter,
                r.max_mean_err_vs_full,
            );
        }
    }
    out
}

/// Serialize VI rows as the coordinator's `BENCH_VI.json` payload.
pub fn vi_rows_to_json(rows: &[ViRow], cfg: &ViBenchConfig) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\n  \"bench\": \"vi\",\n  \"seed\": {},\n  \"small\": {},\n  \"rows\": [\n",
        cfg.seed, cfg.small
    );
    for (i, r) in rows.iter().enumerate() {
        let mut trace = String::from("[");
        for (j, (it, e)) in r.elbo_trace.iter().enumerate() {
            if j > 0 {
                trace.push_str(", ");
            }
            let _ = write!(trace, "[{it}, {}]", json_num(*e));
        }
        trace.push(']');
        let _ = write!(
            out,
            "    {{\"model\": \"{}\", \"family\": \"{}\", \"dim\": {}, \"minibatch\": {}, \
             \"elbo\": {}, \
             \"elbo_se\": {}, \"converged\": {}, \"iters\": {}, \"eta\": {}, \
             \"secs_per_iter\": {}, \"wall_secs\": {}, \"nuts_wall_secs\": {}, \
             \"speedup_vs_nuts\": {}, \"max_mean_err_vs_nuts\": {}, \
             \"max_sd_err_vs_nuts\": {}, \"max_mean_err_vs_full\": {}, \
             \"max_sd_err_vs_full\": {}, \"seed\": {}, \"elbo_trace\": {}}}",
            r.model,
            r.family.label(),
            r.dim,
            r.minibatch,
            json_num(r.elbo),
            json_num(r.elbo_se),
            r.converged,
            r.iters,
            json_num(r.eta),
            json_num(r.secs_per_iter),
            json_num(r.wall_secs),
            json_num(r.nuts_wall_secs),
            json_num(r.speedup_vs_nuts),
            json_num(r.max_mean_err_vs_nuts),
            json_num(r.max_sd_err_vs_nuts),
            json_num(r.max_mean_err_vs_full),
            json_num(r.max_sd_err_vs_full),
            r.seed,
            trace,
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

// ================================================================= serve

/// Configuration for the posterior-serving benchmark: cached-query
/// latency on the conjugate Normal–Normal stream, streaming-update
/// economics on the Kalman stream.
pub struct ServeBenchConfig {
    pub seed: u64,
    /// Timed cached posterior-predictive queries.
    pub n_queries: usize,
    /// SMC particles (= posterior draws per artifact).
    pub particles: usize,
    /// Normal–Normal stream length.
    pub t_init: usize,
    /// Kalman stream length before the streaming update…
    pub t_kalman: usize,
    /// …and observations appended by it. Small on purpose: the whole
    /// point of streaming is that the update pays for the appended steps,
    /// not the history.
    pub t_stream: usize,
    pub threads: usize,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            n_queries: 400,
            particles: 192,
            t_init: 40,
            t_kalman: 160,
            t_stream: 2,
            threads: 1,
        }
    }
}

/// One serving measurement (flat metric rows — the serving story is a
/// handful of scalars, not a per-model matrix).
pub struct ServeRow {
    pub metric: String,
    pub value: f64,
    pub unit: String,
}

fn serve_row(metric: &str, value: f64, unit: &str) -> ServeRow {
    ServeRow {
        metric: metric.into(),
        value,
        unit: unit.into(),
    }
}

/// Run the serving benchmark and collect metric rows.
pub fn run_serve_bench(cfg: &ServeBenchConfig) -> Vec<ServeRow> {
    use crate::serve::query::ServeQuery;
    use crate::serve::update::UpdateKind;
    use crate::serve::{
        build_stream_model, kalman_oracle, simulate_kalman, FitSpec, ServeConfig, ServeHandle,
    };
    use crate::util::rng::Rng as _;
    use std::time::Instant;

    let mut rows = Vec::new();
    let handle = ServeHandle::new(ServeConfig {
        cache_capacity: 8,
        threads: cfg.threads,
        // the bench times the reweighting fast path; the rejuvenation
        // sweep's correctness is the streaming tests' job
        rejuvenation_moves: 0,
        ..ServeConfig::default()
    });
    let spec = FitSpec::smc(cfg.particles, cfg.seed);

    // ---- cached-query serving on the Normal–Normal stream
    let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
    let y0: Vec<f64> = (0..cfg.t_init).map(|_| 0.7 + rng.normal()).collect();
    handle
        .init_stream("normal_normal", y0)
        .expect("init normal_normal stream");
    // a rotating set of held-out records keeps the queries distinct
    // without letting allocation noise into the timings
    let y_new: Vec<Vec<f64>> = (0..8)
        .map(|_| (0..5).map(|_| 0.7 + rng.normal()).collect())
        .collect();

    eprintln!("bench: serve / fit-per-query baseline");
    let reps = 3usize;
    let t0 = Instant::now();
    for k in 0..reps {
        // a stateless system refits for every question — drop the cache
        // so each query pays the full inference cost
        handle.invalidate("normal_normal");
        let v = handle
            .query(
                "normal_normal",
                &spec,
                &ServeQuery::LogPredictive {
                    y: y_new[k % y_new.len()].clone(),
                },
            )
            .expect("fit-per-query");
        assert!(v.is_finite(), "fit-per-query predictive {v}");
    }
    let fit_per_query = t0.elapsed().as_secs_f64() / reps as f64;

    eprintln!("bench: serve / cached-query latency ({} queries)", cfg.n_queries);
    // warm the artifact, then time queries that all hit it
    let _ = handle
        .query(
            "normal_normal",
            &spec,
            &ServeQuery::LogPredictive { y: y_new[0].clone() },
        )
        .expect("warm fit");
    let mut lat = Vec::with_capacity(cfg.n_queries);
    let t_all = Instant::now();
    for i in 0..cfg.n_queries {
        let q = ServeQuery::LogPredictive {
            y: y_new[i % y_new.len()].clone(),
        };
        let t = Instant::now();
        let v = handle.query("normal_normal", &spec, &q).expect("cached query");
        lat.push(t.elapsed().as_secs_f64());
        std::hint::black_box(v);
    }
    let total = t_all.elapsed().as_secs_f64();
    lat.sort_by(f64::total_cmp);
    let pct = |p: usize| lat[((lat.len() - 1) * p) / 100];
    let cached_mean = total / cfg.n_queries as f64;

    // summary statistics are a column fold — the microsecond tier
    let mut sum_lat = Vec::with_capacity(cfg.n_queries);
    for _ in 0..cfg.n_queries {
        let t = Instant::now();
        let v = handle
            .query("normal_normal", &spec, &ServeQuery::Mean { param: "m".into() })
            .expect("summary query");
        sum_lat.push(t.elapsed().as_secs_f64());
        std::hint::black_box(v);
    }
    sum_lat.sort_by(f64::total_cmp);

    // batched predictive: all queries in one sweep over the draw matrix
    let batch: Vec<Vec<f64>> = (0..64).map(|i| y_new[i % y_new.len()].clone()).collect();
    let t = Instant::now();
    let vs = handle
        .predictive_batch("normal_normal", &spec, &batch)
        .expect("batched predictive");
    let batch_per_query = t.elapsed().as_secs_f64() / vs.len() as f64;

    let stats = handle.stats();
    rows.push(serve_row("queries_per_sec", cfg.n_queries as f64 / total, "1/s"));
    rows.push(serve_row("cached_query_p50", pct(50) * 1e6, "us"));
    rows.push(serve_row("cached_query_p99", pct(99) * 1e6, "us"));
    rows.push(serve_row("cached_query_mean", cached_mean * 1e6, "us"));
    rows.push(serve_row("summary_query_p50", sum_lat[(sum_lat.len() - 1) / 2] * 1e6, "us"));
    rows.push(serve_row("batched_query_mean", batch_per_query * 1e6, "us"));
    rows.push(serve_row("fit_per_query", fit_per_query * 1e6, "us"));
    rows.push(serve_row("cached_speedup", fit_per_query / cached_mean, "x"));
    rows.push(serve_row("cache_hit_rate", stats.hit_rate, "frac"));

    // ---- streaming update vs from-scratch refit on the Kalman stream
    eprintln!("bench: serve / kalman streaming update");
    let y = simulate_kalman(cfg.t_kalman + cfg.t_stream, cfg.seed ^ 0xD5);
    let (y_init, y_tail) = y.split_at(cfg.t_kalman);
    handle
        .init_stream("kalman", y_init.to_vec())
        .expect("init kalman stream");
    let _ = handle.fit("kalman", &spec).expect("initial kalman fit");
    let t = Instant::now();
    let rep = handle
        .update_stream("kalman", y_tail, &spec)
        .expect("streaming update");
    let update_secs = t.elapsed().as_secs_f64();

    // the stateless baseline: refit the whole extended record from
    // scratch and rebuild the servable artifact pieces
    let smc = crate::inference::Smc {
        n_particles: cfg.particles,
        threads: cfg.threads,
        ..crate::inference::Smc::default()
    };
    let full = build_stream_model("kalman", &y).expect("kalman model");
    let refit_seed = cfg.seed ^ 0x51;
    let t = Instant::now();
    let refit = smc.run(full.as_ref(), refit_seed);
    let refit_chain = smc.chain_from_result(full.as_ref(), &refit, refit_seed);
    let refit_maps = crate::query::chain_param_maps(&refit_chain).expect("param maps");
    let refit_secs = t.elapsed().as_secs_f64();
    std::hint::black_box(refit_maps.len());

    // accuracy at matched work: last-state posterior mean vs the exact
    // RTS smoother (the filtering tail is what SMC estimates best; early
    // states degenerate for streamed and batch clouds alike)
    let (_, smoothed) = kalman_oracle(&y);
    let last = format!("h[{}]", y.len() - 1);
    let stream_mean = handle
        .query("kalman", &spec, &ServeQuery::Mean { param: last.clone() })
        .expect("streamed mean");
    let refit_mean = refit_chain.mean(&last).expect("refit mean");
    let truth = *smoothed.last().expect("smoother means");

    rows.push(serve_row("stream_update_secs", update_secs, "s"));
    rows.push(serve_row("refit_secs", refit_secs, "s"));
    rows.push(serve_row("stream_speedup", refit_secs / update_secs, "x"));
    rows.push(serve_row(
        "stream_streamed",
        if rep.kind == UpdateKind::Streamed { 1.0 } else { 0.0 },
        "bool",
    ));
    rows.push(serve_row("stream_update_ess", rep.ess, "particles"));
    rows.push(serve_row("stream_evidence_increment", rep.increment, "nats"));
    rows.push(serve_row("stream_mean_err", (stream_mean - truth).abs(), "abs"));
    rows.push(serve_row("refit_mean_err", (refit_mean - truth).abs(), "abs"));
    rows
}

/// Human-readable serving table.
pub fn render_serve_table(rows: &[ServeRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "serve — cached posterior queries vs fit-per-query, streaming update vs refit\n"
    );
    let _ = writeln!(out, "{:<26} {:>14} {:<9}", "metric", "value", "unit");
    for r in rows {
        let _ = writeln!(
            out,
            "{:<26} {:>14} {:<9}",
            r.metric,
            if r.value.is_finite() {
                format!("{:.3}", r.value)
            } else {
                "-".into()
            },
            r.unit
        );
    }
    out
}

/// Serialize serve rows as the coordinator's `BENCH_SERVE.json` payload.
pub fn serve_rows_to_json(rows: &[ServeRow], cfg: &ServeBenchConfig) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\n  \"bench\": \"serve\",\n  \"seed\": {},\n  \"n_queries\": {},\n  \
         \"particles\": {},\n  \"rows\": [\n",
        cfg.seed, cfg.n_queries, cfg.particles
    );
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"metric\": \"{}\", \"value\": {}, \"unit\": \"{}\"}}",
            r.metric,
            json_num(r.value),
            r.unit
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// The serve CI gate: cached queries must beat fit-per-query by
/// `min_cached`×, the streaming update must beat the from-scratch refit
/// by `min_stream`× *via the streamed path* (a fallback refit "winning"
/// is a failure), the cache must actually be hitting, latencies must be
/// finite, and both posteriors must sit on the exact smoother answer.
/// Returns one message per violation (empty = gate passed).
pub fn check_serve_gates(rows: &[ServeRow], min_cached: f64, min_stream: f64) -> Vec<String> {
    let mut bad = Vec::new();
    let need = |bad: &mut Vec<String>, name: &str| -> f64 {
        match rows.iter().find(|r| r.metric == name) {
            Some(r) => r.value,
            None => {
                bad.push(format!("missing metric row {name:?}"));
                f64::NAN
            }
        }
    };
    let cached = need(&mut bad, "cached_speedup");
    if !(cached >= min_cached) {
        bad.push(format!(
            "cached_speedup {cached:.1}x below required {min_cached:.1}x"
        ));
    }
    let stream = need(&mut bad, "stream_speedup");
    if !(stream >= min_stream) {
        bad.push(format!(
            "stream_speedup {stream:.2}x below required {min_stream:.2}x"
        ));
    }
    if need(&mut bad, "stream_streamed") != 1.0 {
        bad.push("streaming update fell back to a refit".into());
    }
    let hit = need(&mut bad, "cache_hit_rate");
    if !(hit >= 0.5) {
        bad.push(format!("cache_hit_rate {hit:.3} below 0.5"));
    }
    let p99 = need(&mut bad, "cached_query_p99");
    if !p99.is_finite() {
        bad.push("cached_query_p99 is not finite".into());
    }
    for name in ["stream_mean_err", "refit_mean_err"] {
        let err = need(&mut bad, name);
        if !(err <= 0.5) {
            bad.push(format!("{name} {err:.3} exceeds 0.5 vs the exact smoother"));
        }
    }
    bad
}

/// One `(model, label, secs)` measurement inside a bench-history row —
/// the minimal shape all four bench families share, so a plotting script
/// can track any benchmark over time from one file.
#[derive(Clone, Debug)]
pub struct HistoryEntry {
    pub model: String,
    /// Backend / engine / replay-path / family label of the measurement.
    pub label: String,
    /// The row's headline seconds figure (per-gradient, per-iteration or
    /// wall-clock — whichever the bench family reports).
    pub secs: f64,
}

/// Serialize one `bench --history` row: a single-line JSON object (no
/// embedded newlines) ready to append to `BENCH_HISTORY.jsonl`,
/// timestamped at call time.
pub fn history_line(bench: &str, seed: u64, entries: &[HistoryEntry]) -> String {
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut out = format!(
        "{{\"unix_secs\": {unix_secs}, \"bench\": \"{bench}\", \"seed\": {seed}, \"entries\": ["
    );
    for (i, e) in entries.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(
            out,
            "{{\"model\": \"{}\", \"label\": \"{}\", \"secs\": {}}}",
            e.model,
            e.label,
            json_num(e.secs)
        );
    }
    out.push_str("]}");
    out
}

/// Append one history row (newline-terminated) to `path`, creating the
/// file on first use.
pub fn append_history(path: &str, line: &str) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "{line}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_labels_roundtrip() {
        for b in [
            BenchBackend::Untyped,
            BenchBackend::TypedTape,
            BenchBackend::TypedFused,
            BenchBackend::TypedForward,
            BenchBackend::TypedXla,
            BenchBackend::TypedXlaFused,
            BenchBackend::StanLike,
        ] {
            assert_eq!(BenchBackend::parse(b.label()), Some(b));
        }
        // `fused` names the native arena engine (the XLA trajectory path
        // moved to `xla-fused`)
        assert_eq!(BenchBackend::parse("fused"), Some(BenchBackend::TypedFused));
        assert_eq!(
            BenchBackend::parse("xla-fused"),
            Some(BenchBackend::TypedXlaFused)
        );
        assert_eq!(BenchBackend::parse("nope"), None);
    }

    #[test]
    fn batch_bench_rows_and_json_shape() {
        let cfg = BatchBenchConfig {
            models: vec!["gauss_unknown".into()],
            lane_counts: vec![1, 2],
            target_secs: 1e-4,
            reps: 1,
            ..BatchBenchConfig::default()
        };
        let rows = run_batch_bench(&cfg);
        assert_eq!(rows.len(), 2);
        // the K = 1 row is its own baseline
        assert!((rows[0].speedup_vs_k1 - 1.0).abs() < 1e-12, "{rows:?}");
        assert!(rows.iter().all(|r| r.secs_per_grad > 0.0));
        let json = batch_rows_to_json(&rows, &cfg);
        assert!(json.contains("\"bench\": \"batch\""));
        assert!(json.contains("\"lanes\": 2"));
        assert!(render_batch_table(&rows).contains("vs-K1"));
    }

    #[test]
    fn static_bench_rows_and_json_shape() {
        let cfg = StaticBenchConfig {
            models: vec!["gauss_unknown".into(), "hier_poisson".into()],
            target_secs: 1e-4,
            reps: 1,
            ..StaticBenchConfig::default()
        };
        let rows = run_static_bench(&cfg);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.promoted, "{}: static structure should promote", r.model);
            assert!(r.secs_dynamic > 0.0 && r.secs_compiled > 0.0);
            assert!(r.speedup.is_finite());
        }
        // hier_poisson: one Poisson plate per group, 5 rows each
        let hp = rows.iter().find(|r| r.model == "hier_poisson").unwrap();
        assert_eq!(hp.n_plates, 10);
        assert_eq!(hp.plate_rows, 50);
        let json = static_rows_to_json(&rows, &cfg);
        assert!(json.contains("\"bench\": \"static\""));
        assert!(json.contains("\"promoted\": true"));
        assert!(json.contains("\"plate_rows\": 50"));
        assert!(render_static_table(&rows).contains("speedup"));
    }

    #[test]
    fn static_speedup_gate_flags_violations() {
        let mk = |model: &str, promoted: bool, speedup: f64| StaticRow {
            model: model.into(),
            dim: 3,
            promoted,
            n_plates: 0,
            plate_rows: 0,
            secs_dynamic: 1.0,
            secs_compiled: 1.0 / speedup,
            speedup,
            seed: 42,
        };
        // passing run: flagship over its bar, the rest at break-even
        let rows = vec![mk("logreg_tall", true, 1.5), mk("gauss_unknown", true, 1.01)];
        assert!(check_static_speedups(&rows, 1.3).is_empty());
        // flagship under its bar AND a regressed static model
        let rows = vec![mk("logreg_tall", true, 1.1), mk("gauss_unknown", true, 0.9)];
        let bad = check_static_speedups(&rows, 1.3);
        assert_eq!(bad.len(), 2, "{bad:?}");
        // non-promoted flagship is itself a violation; other models may
        // legitimately stay dynamic
        let rows = vec![mk("logreg_tall", false, f64::NAN), mk("lda", false, f64::NAN)];
        let bad = check_static_speedups(&rows, 1.3);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("logreg_tall"));
    }

    #[test]
    fn history_line_is_single_line_json() {
        let entries = vec![
            HistoryEntry {
                model: "gauss_unknown".into(),
                label: "fused".into(),
                secs: 1.25e-7,
            },
            HistoryEntry {
                model: "hier_poisson".into(),
                label: "tape".into(),
                secs: f64::NAN,
            },
        ];
        let line = history_line("grad", 42, &entries);
        assert!(!line.contains('\n'), "JSONL rows must be single-line");
        assert!(line.starts_with("{\"unix_secs\": "));
        assert!(line.contains("\"bench\": \"grad\""));
        assert!(line.contains("\"seed\": 42"));
        assert!(line.contains("\"model\": \"gauss_unknown\""));
        assert!(line.contains("\"secs\": null"), "non-finite must serialize as null");
        assert!(!line.contains("NaN"));
        assert_eq!(line.matches('{').count(), line.matches('}').count());
        assert_eq!(line.matches('[').count(), line.matches(']').count());
    }

    #[test]
    fn grad_bench_rows_and_json() {
        let cfg = GradBenchConfig {
            models: vec!["gauss_unknown".into(), "sto_volatility".into()],
            seed: 6,
            target_secs: 2e-4,
            reps: 2,
            ..GradBenchConfig::default()
        };
        let rows = run_grad_bench(&cfg);
        // fused + tape + forward per model
        assert_eq!(rows.len(), 6);
        for model in ["gauss_unknown", "sto_volatility"] {
            let fused = rows
                .iter()
                .find(|r| r.model == model && r.engine == Backend::ReverseFused)
                .unwrap();
            let tape = rows
                .iter()
                .find(|r| r.model == model && r.engine == Backend::Reverse)
                .unwrap();
            assert!(fused.secs_per_grad > 0.0 && tape.secs_per_grad > 0.0);
            // tilde-dominated models collapse ~5×; models whose likelihood
            // is hand-written body arithmetic (gauss_unknown) shrink less
            let required = if model == "sto_volatility" {
                tape.tape_nodes / 4
            } else {
                tape.tape_nodes
            };
            assert!(
                fused.tape_nodes < required,
                "{model}: fused {} vs tape {} nodes",
                fused.tape_nodes,
                tape.tape_nodes
            );
            assert!(fused.alloc_steady, "{model}: arena grew during timed run");
            assert!(
                fused.max_rel_err_vs_forward < 1e-8,
                "{model}: rel err {}",
                fused.max_rel_err_vs_forward
            );
            assert!(fused.tilde_stmts > 0 && fused.seeds > 0);
        }
        let table = render_grad_table(&rows);
        assert!(table.contains("sto_volatility") && table.contains("fused"));
        let json = grad_rows_to_json(&rows, &cfg);
        assert!(json.contains("\"bench\": \"grad\""));
        assert!(json.contains("\"engine\": \"fused\""));
        assert!(json.contains("\"engine\": \"tape\""));
        assert!(json.contains("\"engine\": \"forward\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn tiny_cell_runs_typed_fused() {
        let cfg = Table1Config {
            iters: 10,
            reps: 1,
            seed: 3,
            backends: vec![BenchBackend::TypedFused],
            models: vec!["gauss_unknown".into()],
            max_run_iters: None,
        };
        let cell = run_cell("gauss_unknown", BenchBackend::TypedFused, &cfg);
        assert!(cell.mean.is_finite() && cell.mean > 0.0);
    }

    #[test]
    fn tiny_cell_runs_stanlike() {
        let cfg = Table1Config {
            iters: 10,
            reps: 1,
            seed: 3,
            backends: vec![BenchBackend::StanLike],
            models: vec!["hier_poisson".into()],
            max_run_iters: None,
        };
        let cell = run_cell("hier_poisson", BenchBackend::StanLike, &cfg);
        assert!(cell.mean.is_finite() && cell.mean > 0.0);
        let table = render_table1(&[cell], &cfg);
        assert!(table.contains("hier_poisson"));
    }

    #[test]
    fn smc_bench_rows_and_json() {
        let cfg = SmcBenchConfig {
            models: vec!["hmm_semisup".into()],
            n_particles: 32,
            seed: 4,
            threads: 1,
            small: true,
            ..SmcBenchConfig::default()
        };
        let rows = run_smc_bench(&cfg);
        // one typed + one boxed row, bit-identical evidence
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].path, SmcPath::Typed);
        assert_eq!(rows[1].path, SmcPath::Boxed);
        assert!(rows[0].log_evidence.is_finite());
        assert_eq!(
            rows[0].log_evidence.to_bits(),
            rows[1].log_evidence.to_bits(),
            "typed and boxed paths must agree bitwise"
        );
        assert_eq!(rows[0].typed_steps, rows[0].n_obs);
        assert_eq!(rows[1].typed_steps, 0);
        assert!(rows[0].n_obs >= 1);
        let table = render_smc_table(&rows);
        assert!(table.contains("hmm_semisup"));
        assert!(table.contains("speedups"));
        let json = smc_rows_to_json(&rows);
        assert!(json.contains("\"bench\": \"smc\""));
        assert!(json.contains("\"model\": \"hmm_semisup\""));
        assert!(json.contains("\"path\": \"typed\""));
        assert!(json.contains("\"path\": \"boxed\""));
        assert!(json.contains("\"log_evidence\": "));
        // valid-ish JSON: balanced braces/brackets, no trailing comma
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn table1_json_is_balanced_and_labeled() {
        let cfg = Table1Config {
            iters: 10,
            reps: 1,
            seed: 3,
            backends: vec![BenchBackend::StanLike],
            models: vec!["hier_poisson".into()],
            max_run_iters: None,
        };
        let cell = run_cell("hier_poisson", BenchBackend::StanLike, &cfg);
        let json = table1_cells_to_json(&[cell], &cfg);
        assert!(json.contains("\"bench\": \"table1\""));
        assert!(json.contains("\"model\": \"hier_poisson\""));
        assert!(json.contains("\"backend\": \"stanlike\""));
        assert!(json.contains("\"mean_secs\": "));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn vi_bench_rows_and_json() {
        let cfg = ViBenchConfig {
            models: vec!["gauss_unknown".into()],
            seed: 8,
            draws: 400,
            nuts_warmup: 100,
            nuts_iters: 200,
            advi: Advi {
                max_iters: 300,
                eval_every: 25,
                grad_samples: 2,
                elbo_samples: 50,
                ..Advi::default()
            },
            ..ViBenchConfig::default()
        };
        let rows = run_vi_bench(&cfg);
        // one mean-field + one full-rank row
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].family, ViFamily::MeanField);
        assert_eq!(rows[1].family, ViFamily::FullRank);
        for r in &rows {
            assert_eq!(r.dim, 2);
            assert!(r.elbo.is_finite(), "{r:?}");
            assert!(!r.elbo_trace.is_empty());
            assert!(r.wall_secs > 0.0 && r.secs_per_iter > 0.0);
            // both families agree with NUTS on this near-Gaussian
            // posterior (loose: short reference run)
            assert!(r.max_mean_err_vs_nuts < 0.2, "{r:?}");
        }
        let table = render_vi_table(&rows);
        assert!(table.contains("gauss_unknown") && table.contains("meanfield"));
        let json = vi_rows_to_json(&rows, &cfg);
        assert!(json.contains("\"bench\": \"vi\""));
        assert!(json.contains("\"family\": \"meanfield\""));
        assert!(json.contains("\"family\": \"fullrank\""));
        assert!(json.contains("\"elbo_trace\": [["));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn vi_bench_emits_minibatch_rows_on_tall_models() {
        let cfg = ViBenchConfig {
            models: vec!["logreg_tall".into()],
            families: vec![ViFamily::MeanField],
            seed: 9,
            draws: 200,
            minibatch: Some(512),
            advi: Advi {
                max_iters: 60,
                eval_every: 20,
                grad_samples: 2,
                elbo_samples: 20,
                adapt_iters: 10,
                ..Advi::default()
            },
            ..ViBenchConfig::default()
        };
        let rows = run_vi_bench(&cfg);
        // one full row + one minibatch row for the single family
        assert_eq!(rows.len(), 2);
        let (full, mb) = (&rows[0], &rows[1]);
        assert_eq!(full.minibatch, 0);
        assert_eq!(mb.minibatch, 512);
        // tall model: the full-N NUTS reference is skipped, accuracy is
        // tracked against the full-data fit instead
        assert!(full.nuts_wall_secs.is_nan() && mb.speedup_vs_nuts.is_nan());
        assert!(full.max_mean_err_vs_full.is_nan());
        assert!(mb.max_mean_err_vs_full.is_finite());
        // a B=512 step touches ~2.5% of the 20k rows: strictly cheaper
        // per iteration, even with the periodic full-data ELBO checks
        assert!(
            mb.secs_per_iter < full.secs_per_iter,
            "minibatch {} vs full {} secs/iter",
            mb.secs_per_iter,
            full.secs_per_iter
        );
        let json = vi_rows_to_json(&rows, &cfg);
        assert!(json.contains("\"minibatch\": 512"));
        assert!(json.contains("\"minibatch\": 0"));
        assert!(json.contains("\"max_mean_err_vs_full\": "));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        let table = render_vi_table(&rows);
        assert!(table.contains("minibatch speedups"));
    }

    #[test]
    fn json_num_maps_non_finite_to_null() {
        assert_eq!(json_num(1.5), "1.5");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::NEG_INFINITY), "null");
    }

    #[test]
    fn tiny_cell_runs_typed_tape() {
        let cfg = Table1Config {
            iters: 10,
            reps: 1,
            seed: 3,
            backends: vec![BenchBackend::TypedTape],
            models: vec!["gauss_unknown".into()],
            max_run_iters: None,
        };
        let cell = run_cell("gauss_unknown", BenchBackend::TypedTape, &cfg);
        assert!(cell.mean.is_finite() && cell.mean > 0.0);
    }
}
