//! T1.1 (10,000-D Gaussian) and T1.2 (Gauss Unknown).

use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// `x ~ IsoNormal(0, 1, dim)` — the 10,000-D Gaussian benchmark. Pure
    /// prior; the hot spot is the long iid-normal reduction (L1 kernel
    /// `gauss_logpdf` on the AOT path).
    pub GaussianKd {
        dim: usize,
    }
    fn body<T>(this, api) {
        let _x = tilde_vec!(api, x ~ IsoNormal(c(0.0), c(1.0), this.dim));
    }
}

/// Full Table-1 workload: 10,000 dimensions.
pub fn gaussian_10kd() -> BenchModel {
    gaussian_kd(10_000)
}

pub fn gaussian_kd(dim: usize) -> BenchModel {
    BenchModel {
        name: "gaussian_10kd",
        theta_dim: dim,
        step_size: 0.08,
        model: Box::new(GaussianKd { dim }),
        data: vec![],
    }
}

model! {
    /// Gauss Unknown (gdemo at scale): `s ~ InverseGamma(2,3);
    /// m ~ Normal(0, √s); y .~ Normal(m, √s)` with 10,000 observations.
    pub GaussUnknown {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let s = tilde!(api, s ~ InverseGamma(c(2.0), c(3.0)));
        check_reject!(api);
        let sd = s.sqrt();
        let m = tilde!(api, m ~ Normal(c(0.0), sd));
        // manual iid loop (hot path): identical to obs_iid! but avoids
        // re-creating the distribution per observation
        let mut ss = c::<T>(0.0);
        for &yi in &this.y {
            let z = (m - yi) / sd;
            ss = ss + z * z;
        }
        let n = this.y.len() as f64;
        api.add_obs_logp(ss * (-0.5) - sd.ln() * n - 0.5 * crate::util::math::LN_2PI * n);
    }
}

/// Full Table-1 workload: 10,000 one-dimensional observations.
pub fn gauss_unknown(seed: u64) -> BenchModel {
    gauss_unknown_n(seed, 10_000)
}

pub fn gauss_unknown_n(seed: u64, n: usize) -> BenchModel {
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA001);
    // ground truth: m = 1.5, sd = 0.7
    let y: Vec<f64> = (0..n).map(|_| 1.5 + 0.7 * rng.normal()).collect();
    let data = vec![DataInput::f64(y.clone(), &[n])];
    BenchModel {
        name: "gauss_unknown",
        theta_dim: 2,
        step_size: 0.002,
        model: Box::new(GaussUnknown { y }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};
    use crate::util::math::LN_2PI;

    #[test]
    fn gauss_unknown_matches_manual() {
        let bm = gauss_unknown_n(1, 50);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta = [0.3f64, 1.1];
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
        // manual
        let s = theta[0].exp();
        let sd = s.sqrt();
        let mut lp = InverseGamma::new(2.0, 3.0).logpdf(s) + theta[0];
        lp += Normal::new(0.0, sd).logpdf(theta[1]);
        let y = match &bm.data[0] {
            DataInput::F64 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        for yi in y {
            lp += Normal::new(theta[1], sd).logpdf(yi);
        }
        assert!((got - lp).abs() < 1e-10, "{got} vs {lp}");
        let _ = LN_2PI;
    }

    #[test]
    fn gaussian_kd_is_std_normal() {
        let bm = gaussian_kd(10);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta = vec![0.5; 10];
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
        let want = IsoNormal::new(0.0, 1.0, 10).logpdf(&theta);
        assert!((got - want).abs() < 1e-12);
    }
}
