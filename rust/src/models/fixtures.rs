//! Analyzer fixture models: a deliberately defective model the linter
//! must flag, and a fully conjugate hierarchy the conjugacy detector must
//! certify. Neither is part of the Table-1 grid — they exist for
//! `dppl lint` / `dppl bench conjugate` and the analysis test suite.

use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// Every seeded defect the pedantic lint pass must catch:
    ///
    /// - `unused` has no dataflow path to any observation (dead parameter);
    /// - `tau` is Real-domain but feeds the sd of `x`'s prior directly
    ///   (domain-mismatch error), which also makes `x` a centered funnel;
    /// - the observation plate holds bitwise-identical values
    ///   (constant-data plate).
    pub LintFixture {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let _unused = tilde!(api, unused ~ Normal(c(0.0), c(1.0)));
        let tau = tilde!(api, tau ~ Normal(c(0.0), c(1.0)));
        let x = tilde!(api, x ~ Normal(c(0.0), tau));
        for &yi in &this.y {
            obs!(api, yi => Normal(x, c(1.0)));
        }
    }
}

/// The defective fixture with its constant "data": 12 identical rows.
pub fn lint_fixture() -> BenchModel {
    let y = vec![1.25f64; 12];
    let data = vec![DataInput::f64(y.clone(), &[12])];
    BenchModel {
        name: "lint_fixture",
        theta_dim: 3,
        step_size: 0.01,
        model: Box::new(LintFixture { y }),
        data,
    }
}

model! {
    /// Fully conjugate Normal–InverseGamma hierarchy:
    /// `v ~ InverseGamma(2, 3); m ~ Normal(0, √(2v)); y_i ~ Normal(m, √v)`.
    ///
    /// Both latents certify — `m` as Normal–Normal (its value feeds every
    /// observation mean through identity glue) and `v` as
    /// Normal–InverseGamma (`√(2v)` and `√v` are both pure `sqrt(a·v)`
    /// scales, over `m`'s prior and the observations respectively) — so a
    /// two-block RwMh Gibbs sampler collapses entirely to exact draws.
    pub ConjugateHier {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let v = tilde!(api, v ~ InverseGamma(c(2.0), c(3.0)));
        check_reject!(api);
        let m = tilde!(api, m ~ Normal(c(0.0), (v * 2.0).sqrt()));
        let sd = v.sqrt();
        for &yi in &this.y {
            obs!(api, yi => Normal(m, sd));
        }
    }
}

pub fn conjugate_hier(seed: u64) -> BenchModel {
    conjugate_hier_n(seed, 400)
}

pub fn conjugate_hier_n(seed: u64, n: usize) -> BenchModel {
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA00C);
    // ground truth: m = 0.8, sd = 0.6
    let y: Vec<f64> = (0..n).map(|_| 0.8 + 0.6 * rng.normal()).collect();
    let data = vec![DataInput::f64(y.clone(), &[n])];
    BenchModel {
        name: "conjugate_hier",
        theta_dim: 2,
        step_size: 0.01,
        model: Box::new(ConjugateHier { y }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};

    #[test]
    fn conjugate_hier_density_matches_manual() {
        let bm = conjugate_hier_n(1, 20);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta = [0.2f64, 0.9];
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
        let v = theta[0].exp();
        let mut want = InverseGamma::new(2.0, 3.0).logpdf(v) + theta[0];
        want += Normal::new(0.0, (2.0 * v).sqrt()).logpdf(theta[1]);
        let y = match &bm.data[0] {
            DataInput::F64 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        for yi in y {
            want += Normal::new(theta[1], v.sqrt()).logpdf(yi);
        }
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn lint_fixture_builds_and_evaluates() {
        let bm = lint_fixture();
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        assert_eq!(tvi.dim(), 3);
        // the density may be NaN when the sampled tau is negative — that
        // is the seeded defect; the walk itself must complete
        let _ = typed_logp(bm.model.as_ref(), &tvi, &tvi.unconstrained, Context::Default);
    }
}
