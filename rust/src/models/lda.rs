//! T1.8 LDA: V=100 vocabulary, K=5 topics, 10 documents × ~1,000 words,
//! topic assignments marginalized (the HMC-compatible collapsed form used
//! by both the Stan and Turing benchmark suites).

use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// `theta[d] ~ Dirichlet(1,K)` per doc; `phi[k] ~ Dirichlet(1,V)` per
    /// topic; token n: `w_n ~ Mixture_k(theta[doc_n,k], phi[k])`.
    pub Lda {
        w: Vec<usize>,
        doc: Vec<usize>,
        n_topics: usize,
        vocab: usize,
        n_docs: usize,
    }
    fn body<T>(this, api) {
        let (kk, vv) = (this.n_topics, this.vocab);
        let mut th: Vec<Vec<T>> = Vec::with_capacity(this.n_docs);
        for d in 0..this.n_docs {
            th.push(tilde_vec!(api, theta[d] ~ Dirichlet(vec![1.0; kk])));
        }
        let mut ph: Vec<Vec<T>> = Vec::with_capacity(kk);
        for k in 0..kk {
            ph.push(tilde_vec!(api, phi[k] ~ Dirichlet(vec![1.0; vv])));
        }
        check_reject!(api);
        let mut lp = c::<T>(0.0);
        for (n, (&wn, &dn)) in this.w.iter().zip(&this.doc).enumerate() {
            let td = &th[dn];
            let mut p = c::<T>(0.0);
            for k in 0..kk {
                p = p + td[k] * ph[k][wn];
            }
            lp = lp + p.ln();
            // accumulate in chunks so a single rejection exits early
            if n % 512 == 511 {
                api.add_obs_logp(lp);
                lp = c::<T>(0.0);
                check_reject!(api);
            }
        }
        api.add_obs_logp(lp);
    }
}

/// Full Table-1 workload: N = 10,000 tokens over 10 docs.
pub fn lda(seed: u64) -> BenchModel {
    lda_n(seed, 10_000)
}

pub fn lda_n(seed: u64, n_tokens: usize) -> BenchModel {
    let (kk, vv, dd) = (5usize, 100usize, 10usize);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA008);
    // ground truth: sparse topics
    let mut phi = vec![vec![0.0f64; vv]; kk];
    for row in phi.iter_mut() {
        rng.dirichlet_into(&vec![0.3; vv], row);
    }
    let mut theta = vec![vec![0.0f64; kk]; dd];
    for row in theta.iter_mut() {
        rng.dirichlet_into(&vec![0.8; kk], row);
    }
    let mut w = Vec::with_capacity(n_tokens);
    let mut doc = Vec::with_capacity(n_tokens);
    for n in 0..n_tokens {
        let d = n * dd / n_tokens; // ~equal-length docs
        let z = rng.categorical(&theta[d]);
        w.push(rng.categorical(&phi[z]));
        doc.push(d);
    }
    let data = vec![
        DataInput::i32(w.iter().map(|&x| x as i32).collect(), &[n_tokens]),
        DataInput::i32(doc.iter().map(|&x| x as i32).collect(), &[n_tokens]),
    ];
    BenchModel {
        name: "lda",
        theta_dim: dd * (kk - 1) + kk * (vv - 1),
        step_size: 0.003,
        model: Box::new(Lda {
            w,
            doc,
            n_topics: kk,
            vocab: vv,
            n_docs: dd,
        }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};

    #[test]
    fn token_likelihood_matches_manual_mixture() {
        let bm = lda_n(13, 100);
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = (0..bm.theta_dim)
            .map(|i| 0.03 * ((i % 17) as f64) - 0.2)
            .collect();
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Likelihood);

        use crate::dist::bijector::invlink;
        use crate::dist::Domain;
        let (kk, vv, dd) = (5usize, 100usize, 10usize);
        let mut off = 0;
        let mut th = Vec::new();
        for _ in 0..dd {
            let mut row = Vec::new();
            let _ = invlink(&Domain::Simplex(kk), &theta[off..off + kk - 1], &mut row);
            th.push(row);
            off += kk - 1;
        }
        let mut ph = Vec::new();
        for _ in 0..kk {
            let mut row = Vec::new();
            let _ = invlink(&Domain::Simplex(vv), &theta[off..off + vv - 1], &mut row);
            ph.push(row);
            off += vv - 1;
        }
        let (w, doc) = match (&bm.data[0], &bm.data[1]) {
            (
                crate::runtime::DataInput::I32 { data: w, .. },
                crate::runtime::DataInput::I32 { data: d, .. },
            ) => (w.clone(), d.clone()),
            _ => unreachable!(),
        };
        let mut want = 0.0;
        for n in 0..100 {
            let (wn, dn) = (w[n] as usize, doc[n] as usize);
            let p: f64 = (0..kk).map(|k| th[dn][k] * ph[k][wn]).sum();
            want += p.ln();
        }
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }
}
