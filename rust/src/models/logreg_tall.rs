//! Tall logistic regression: the deep-PPL / millions-of-users regime
//! (Baudart et al., *Extending Stan for Deep Probabilistic Programming*).
//! N ≈ 100,000 observations — the workload where full-data sweeps are the
//! bottleneck and stochastic VI over `Context::Subsample` minibatches is
//! the intended estimator.
//!
//! The body is **window-aware**: it reads the context's observation
//! window and iterates only the in-window rows, bracketing the loop with
//! `skip_obs` so the observation-site indices stay identical to a body
//! that visits every row. Under `Context::Subsample { lo, hi, .. }` an
//! evaluation therefore costs O(batch) — and on the fused gradient path
//! the out-of-window rows contribute **zero arena nodes**, because their
//! logit chains are never built. Under any full-window context the model
//! is statement-for-statement the same likelihood as `models::logreg`.

use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// `w ~ IsoNormal(0,1,D); y[i] ~ BernoulliLogit(x_i · w)`, N tall.
    /// `x` is row-major (n × d).
    pub LogRegTall {
        x: Vec<f64>,
        y: Vec<i64>,
        d: usize,
    }
    fn body<T>(this, api) {
        let d = this.d;
        let n = this.y.len();
        let w = tilde_vec!(api, w ~ IsoNormal(c(0.0), c(1.0), d));
        check_reject!(api);
        // visit only the context's observation window; the skipped blocks
        // still count as sites, so window indices match a full visit
        let (lo, hi) = api.context().obs_window();
        let lo = lo.min(n);
        let hi = hi.min(n);
        api.skip_obs(lo);
        for i in lo..hi {
            let row = &this.x[i * d..(i + 1) * d];
            let mut logit = c::<T>(0.0);
            for j in 0..d {
                logit = logit + w[j] * row[j];
            }
            // log σ(s·logit) with s = ±1 — fused, avoids building a dist
            let s = if this.y[i] == 1 { logit } else { -logit };
            api.add_obs_logp(s.log_sigmoid());
        }
        api.skip_obs(n - hi);
    }
}

/// Full tall workload: N=100,000, D=16.
pub fn logreg_tall(seed: u64) -> BenchModel {
    logreg_tall_n(seed, 100_000, 16)
}

/// Reduced tall workload for tests and the default (small) bench runs —
/// still tall enough that minibatching at B=512 is a real subsample.
pub fn logreg_tall_small(seed: u64) -> BenchModel {
    logreg_tall_n(seed, 20_000, 10)
}

pub fn logreg_tall_n(seed: u64, n: usize, d: usize) -> BenchModel {
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA7A1);
    // true weights: sparse-ish signal (same recipe as models::logreg)
    let w_true: Vec<f64> = (0..d)
        .map(|j| if j % 7 == 0 { rng.normal() } else { 0.1 * rng.normal() })
        .collect();
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut logit = 0.0;
        for j in 0..d {
            let v = rng.normal();
            logit += v * w_true[j];
            x.push(v);
        }
        y.push(rng.bernoulli(crate::util::math::sigmoid(logit)) as i64);
    }
    let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let data = vec![
        DataInput::f64(x.clone(), &[n, d]),
        DataInput::f64(yf, &[n]),
    ];
    BenchModel {
        name: "logreg_tall",
        theta_dim: d,
        step_size: 0.01,
        model: Box::new(LogRegTall { x, y, d }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{count_obs_sites, init_typed, typed_logp};
    use crate::models::logreg::LogReg;

    /// The window-aware body must agree with the plain (full-visit) logreg
    /// body under every context — `skip_obs` keeps the site indices equal.
    #[test]
    fn window_aware_body_matches_full_visit_body() {
        let bm = logreg_tall_n(5, 120, 4);
        let tall = bm.model.as_ref();
        let plain = LogReg {
            x: match &bm.data[0] {
                DataInput::F64 { data, .. } => data.clone(),
                _ => unreachable!(),
            },
            y: match &bm.data[1] {
                DataInput::F64 { data, .. } => data.iter().map(|&v| v as i64).collect(),
                _ => unreachable!(),
            },
            d: 4,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let tvi = init_typed(tall, &mut rng);
        assert_eq!(count_obs_sites(tall, &tvi), 120);
        let theta: Vec<f64> = (0..4).map(|i| 0.2 * i as f64 - 0.3).collect();
        for ctx in [
            Context::Default,
            Context::Prior,
            Context::Likelihood,
            Context::MiniBatch { scale: 3.0 },
            Context::Subsample { lo: 10, hi: 42, scale: 3.75 },
            Context::Subsample { lo: 0, hi: 0, scale: 1.0 },
        ] {
            let a = typed_logp(tall, &tvi, &theta, ctx);
            let b = typed_logp(&plain, &tvi, &theta, ctx);
            assert!((a - b).abs() < 1e-9, "{ctx:?}: tall {a} vs plain {b}");
        }
    }

    /// Subsample logp equals the manual prior + scaled window sum.
    #[test]
    fn subsample_window_matches_manual_sum() {
        let bm = logreg_tall_n(7, 60, 3);
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta = [0.1, -0.4, 0.3];
        let prior = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Prior);
        let full_lik = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Likelihood);
        // windows tile the data: scaled windows must average to the
        // full likelihood
        let scale = 4.0;
        let mut acc = 0.0;
        for k in 0..4 {
            let ctx = Context::Subsample { lo: k * 15, hi: (k + 1) * 15, scale };
            acc += typed_logp(bm.model.as_ref(), &tvi, &theta, ctx) - prior;
        }
        assert!(
            (acc / scale - full_lik).abs() < 1e-9,
            "tiled windows {acc} vs full {full_lik}"
        );
    }
}
