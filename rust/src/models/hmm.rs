//! T1.7 Semi-supervised HMM: K=5 latent states, V=20 symbols, 300 steps
//! (first 100 supervised, last 200 marginalized by the forward algorithm).
//!
//! The forward recursion is a dense scalar log-sum-exp loop — together with
//! StoVol this is the workload class where the paper reports Stan ≫
//! Turing because of Tracker.jl overhead.

use crate::ad::log_sum_exp_t;
use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// `trans[k] ~ Dirichlet(1,K)` rows, `emit[k] ~ Dirichlet(1,V)` rows;
    /// supervised segment scores exact transitions/emissions, the
    /// unsupervised suffix is forward-marginalized.
    pub HmmSemisup {
        w: Vec<usize>,
        z_sup: Vec<usize>,
        k: usize,
        v: usize,
    }
    fn body<T>(this, api) {
        let (kk, vv) = (this.k, this.v);
        let mut log_trans: Vec<Vec<T>> = Vec::with_capacity(kk);
        for i in 0..kk {
            let row = tilde_vec!(api, trans[i] ~ Dirichlet(vec![1.0; kk]));
            log_trans.push(row.iter().map(|p| p.ln()).collect());
        }
        let mut log_emit: Vec<Vec<T>> = Vec::with_capacity(kk);
        for i in 0..kk {
            let row = tilde_vec!(api, emit[i] ~ Dirichlet(vec![1.0; vv]));
            log_emit.push(row.iter().map(|p| p.ln()).collect());
        }
        check_reject!(api);

        let t_sup = this.z_sup.len();
        // supervised segment
        let mut lp = c::<T>(0.0);
        for t in 0..t_sup {
            lp = lp + log_emit[this.z_sup[t]][this.w[t]];
        }
        for t in 1..t_sup {
            lp = lp + log_trans[this.z_sup[t - 1]][this.z_sup[t]];
        }

        // forward algorithm over the unsupervised suffix
        let t_total = this.w.len();
        let mut alpha: Vec<T> = (0..kk)
            .map(|j| log_trans[this.z_sup[t_sup - 1]][j] + log_emit[j][this.w[t_sup]])
            .collect();
        let mut scratch: Vec<T> = vec![c::<T>(0.0); kk];
        for t in t_sup + 1..t_total {
            let wt = this.w[t];
            for (j, s) in scratch.iter_mut().enumerate() {
                let mut terms: Vec<T> = Vec::with_capacity(kk);
                for i in 0..kk {
                    terms.push(alpha[i] + log_trans[i][j]);
                }
                *s = log_sum_exp_t(&terms) + log_emit[j][wt];
            }
            std::mem::swap(&mut alpha, &mut scratch);
        }
        lp = lp + log_sum_exp_t(&alpha);
        api.add_obs_logp(lp);
    }
}

/// Full Table-1 workload: K=5, V=20, T=300 with 100 supervised steps.
pub fn hmm_semisup(seed: u64) -> BenchModel {
    hmm_semisup_t(seed, 300, 100)
}

pub fn hmm_semisup_t(seed: u64, t_total: usize, t_sup: usize) -> BenchModel {
    assert!(t_sup >= 1 && t_sup < t_total);
    let (kk, vv) = (5usize, 20usize);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA007);
    // ground-truth sticky chain with peaked emissions
    let mut trans = vec![vec![0.0f64; kk]; kk];
    for (i, row) in trans.iter_mut().enumerate() {
        for (j, p) in row.iter_mut().enumerate() {
            *p = if i == j { 0.6 } else { 0.4 / (kk - 1) as f64 };
        }
    }
    let mut emit = vec![vec![0.0f64; vv]; kk];
    for (i, row) in emit.iter_mut().enumerate() {
        for (j, p) in row.iter_mut().enumerate() {
            *p = if j % kk == i { 0.15 } else { 0.85 / (vv as f64 - (vv / kk) as f64) };
        }
        let s: f64 = row.iter().sum();
        row.iter_mut().for_each(|p| *p /= s);
    }
    let mut z = rng.uniform_usize(kk);
    let mut w = Vec::with_capacity(t_total);
    let mut z_all = Vec::with_capacity(t_total);
    for _ in 0..t_total {
        z = rng.categorical(&trans[z]);
        z_all.push(z);
        w.push(rng.categorical(&emit[z]));
    }
    let z_sup: Vec<usize> = z_all[..t_sup].to_vec();
    let data = vec![
        DataInput::i32(w.iter().map(|&x| x as i32).collect(), &[t_total]),
        DataInput::i32(z_sup.iter().map(|&x| x as i32).collect(), &[t_sup]),
    ];
    BenchModel {
        name: "hmm_semisup",
        theta_dim: kk * (kk - 1) + kk * (vv - 1),
        step_size: 0.01,
        model: Box::new(HmmSemisup { w, z_sup, k: kk, v: vv }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};

    /// Fully-supervised vs marginalized consistency: with one unsupervised
    /// step the forward marginal must equal log Σ_z p(z|z_prev)p(w|z).
    #[test]
    fn single_step_marginal_is_exact() {
        let bm = hmm_semisup_t(11, 11, 10);
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = (0..bm.theta_dim).map(|i| 0.05 * ((i % 13) as f64) - 0.3).collect();
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Likelihood);

        // manual: decode simplexes via the bijector, compute directly
        use crate::dist::bijector::invlink;
        use crate::dist::Domain;
        let (kk, vv) = (5usize, 20usize);
        let mut off = 0;
        let mut trans = Vec::new();
        for _ in 0..kk {
            let mut row = Vec::new();
            let _ = invlink(&Domain::Simplex(kk), &theta[off..off + kk - 1], &mut row);
            trans.push(row);
            off += kk - 1;
        }
        let mut emit = Vec::new();
        for _ in 0..kk {
            let mut row = Vec::new();
            let _ = invlink(&Domain::Simplex(vv), &theta[off..off + vv - 1], &mut row);
            emit.push(row);
            off += vv - 1;
        }
        let hm = HmmSemisup {
            w: vec![],
            z_sup: vec![],
            k: kk,
            v: vv,
        };
        let _ = hm;
        // rebuild data
        let w: Vec<usize> = match &bm.data[0] {
            crate::runtime::DataInput::I32 { data, .. } => {
                data.iter().map(|&x| x as usize).collect()
            }
            _ => unreachable!(),
        };
        let z: Vec<usize> = match &bm.data[1] {
            crate::runtime::DataInput::I32 { data, .. } => {
                data.iter().map(|&x| x as usize).collect()
            }
            _ => unreachable!(),
        };
        let mut want = 0.0;
        for t in 0..10 {
            want += emit[z[t]][w[t]].ln();
        }
        for t in 1..10 {
            want += trans[z[t - 1]][z[t]].ln();
        }
        // one marginal step
        let mut terms = Vec::new();
        for j in 0..kk {
            terms.push(trans[z[9]][j].ln() + emit[j][w[10]].ln());
        }
        want += crate::util::math::log_sum_exp(&terms);
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }
}
