//! T1.3 Naive Bayes: 1,000 observations × 40 dims, 10 classes.
//!
//! Data substitution (DESIGN.md §7): the paper uses MNIST projected to 40
//! PCA dimensions; we generate 10 class-conditional Gaussian clusters with
//! the same shapes, exercising the identical compute path.

use crate::prelude::*;
use crate::runtime::DataInput;
use crate::util::math::LN_2PI;

use super::BenchModel;

model! {
    /// `mu[c] ~ IsoNormal(0,1,D)` per class; `x_i ~ Normal(mu[c_i], 1)`
    /// per dimension, labels observed (supervised NB, as in the Turing
    /// benchmark suite — Stan cannot sample the discrete labels).
    pub NaiveBayes {
        x: Vec<f64>,
        labels: Vec<usize>,
        n_classes: usize,
        dim: usize,
    }
    fn body<T>(this, api) {
        let (cc, dd) = (this.n_classes, this.dim);
        let mut mus: Vec<Vec<T>> = Vec::with_capacity(cc);
        for k in 0..cc {
            mus.push(tilde_vec!(api, mu[k] ~ IsoNormal(c(0.0), c(1.0), dd)));
        }
        check_reject!(api);
        for (i, &ci) in this.labels.iter().enumerate() {
            let mu_c = &mus[ci];
            let row = &this.x[i * dd..(i + 1) * dd];
            let mut ss = c::<T>(0.0);
            for j in 0..dd {
                let z = mu_c[j] - row[j];
                ss = ss + z * z;
            }
            api.add_obs_logp(ss * (-0.5) - 0.5 * LN_2PI * dd as f64);
        }
    }
}

/// Full Table-1 workload: N=1,000, D=40, C=10.
pub fn naive_bayes(seed: u64) -> BenchModel {
    naive_bayes_n(seed, 1000)
}

pub fn naive_bayes_n(seed: u64, n: usize) -> BenchModel {
    let (cc, dd) = (10usize, 40usize);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA003);
    let centers: Vec<Vec<f64>> = (0..cc)
        .map(|_| (0..dd).map(|_| 1.5 * rng.normal()).collect())
        .collect();
    let mut x = Vec::with_capacity(n * dd);
    let mut labels = Vec::with_capacity(n);
    let mut onehot = vec![0.0f64; n * cc];
    for i in 0..n {
        let ci = rng.uniform_usize(cc);
        labels.push(ci);
        onehot[i * cc + ci] = 1.0;
        for j in 0..dd {
            x.push(centers[ci][j] + rng.normal());
        }
    }
    let data = vec![
        DataInput::f64(x.clone(), &[n, dd]),
        DataInput::f64(onehot, &[n, cc]),
    ];
    BenchModel {
        name: "naive_bayes",
        theta_dim: cc * dd,
        step_size: 0.01,
        model: Box::new(NaiveBayes {
            x,
            labels,
            n_classes: cc,
            dim: dd,
        }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};

    #[test]
    fn matches_distribution_based_formulation() {
        let bm = naive_bayes_n(9, 20);
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = (0..400).map(|i| (i as f64 * 0.01).sin() * 0.3).collect();
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
        // reference with Normal objects
        let x = match &bm.data[0] {
            DataInput::F64 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        let onehot = match &bm.data[1] {
            DataInput::F64 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        let mut want = IsoNormal::new(0.0, 1.0, 400).logpdf(&theta);
        for i in 0..20 {
            let ci = (0..10).find(|&k| onehot[i * 10 + k] == 1.0).unwrap();
            for j in 0..40 {
                want += Normal::new(theta[ci * 40 + j], 1.0).logpdf(x[i * 40 + j]);
            }
        }
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }
}
