//! T1.5 Hierarchical Poisson: 10 groups × 5 observations = 50 counts.

use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// `a0 ~ Normal(0,10); σ ~ Exponential(1); b[g] ~ Normal(0,σ);
    /// y_gm ~ Poisson(exp(a0 + b_g))`.
    pub HierPoisson {
        y: Vec<i64>,
        groups: usize,
        per_group: usize,
    }
    fn body<T>(this, api) {
        let a0 = tilde!(api, a0 ~ Normal(c(0.0), c(10.0)));
        let sigma = tilde!(api, sigma ~ Exponential(c(1.0)));
        check_reject!(api);
        let g = this.groups;
        let b = tilde_vec!(api, b ~ IsoNormal(c(0.0), sigma, g));
        check_reject!(api);
        for gi in 0..g {
            let eta = a0 + b[gi];
            let rate = eta.exp();
            for mi in 0..this.per_group {
                let k = this.y[gi * this.per_group + mi];
                obs_int!(api, k => Poisson(rate));
            }
        }
    }
}

/// Full Table-1 workload: 50 observations (10 × 5).
pub fn hier_poisson(seed: u64) -> BenchModel {
    let (g, m) = (10usize, 5usize);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA005);
    let a0 = 1.0;
    let sigma = 0.5;
    let b: Vec<f64> = (0..g).map(|_| sigma * rng.normal()).collect();
    let mut y = Vec::with_capacity(g * m);
    for gi in 0..g {
        let lam = (a0 + b[gi]).exp();
        for _ in 0..m {
            y.push(rng.poisson(lam) as i64);
        }
    }
    let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let data = vec![DataInput::f64(yf, &[g, m])];
    BenchModel {
        name: "hier_poisson",
        theta_dim: 2 + g,
        step_size: 0.02,
        model: Box::new(HierPoisson {
            y,
            groups: g,
            per_group: m,
        }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};

    #[test]
    fn matches_manual_density() {
        let bm = hier_poisson(4);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = (0..12).map(|i| 0.1 * (i as f64) - 0.5).collect();
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
        let y = match &bm.data[0] {
            DataInput::F64 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        let a0 = theta[0];
        let sigma = theta[1].exp();
        let b = &theta[2..];
        let mut want = Normal::new(0.0, 10.0).logpdf(a0)
            + Exponential::new(1.0).logpdf(sigma)
            + theta[1]
            + IsoNormal::new(0.0, sigma, 10).logpdf(b);
        for gi in 0..10 {
            let rate = (a0 + b[gi]).exp();
            for mi in 0..5 {
                want += Poisson::new(rate).logpmf(y[gi * 5 + mi] as i64);
            }
        }
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }
}
