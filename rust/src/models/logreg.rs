//! T1.4 Logistic Regression: 10,000 observations × 100 dimensions.

use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// `w ~ IsoNormal(0,1,D); y[i] ~ BernoulliLogit(x_i · w)`.
    /// `x` is row-major (n × d).
    pub LogReg {
        x: Vec<f64>,
        y: Vec<i64>,
        d: usize,
    }
    fn body<T>(this, api) {
        let d = this.d;
        let w = tilde_vec!(api, w ~ IsoNormal(c(0.0), c(1.0), d));
        check_reject!(api);
        for (i, &yi) in this.y.iter().enumerate() {
            let row = &this.x[i * d..(i + 1) * d];
            let mut logit = c::<T>(0.0);
            for j in 0..d {
                logit = logit + w[j] * row[j];
            }
            // log σ(s·logit) with s = ±1 — fused, avoids building a dist
            let s = if yi == 1 { logit } else { -logit };
            api.add_obs_logp(s.log_sigmoid());
        }
    }
}

/// Full Table-1 workload: N=10,000, D=100.
pub fn logreg(seed: u64) -> BenchModel {
    logreg_n(seed, 10_000, 100)
}

pub fn logreg_n(seed: u64, n: usize, d: usize) -> BenchModel {
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA004);
    // true weights: sparse-ish signal
    let w_true: Vec<f64> = (0..d)
        .map(|j| if j % 7 == 0 { rng.normal() } else { 0.1 * rng.normal() })
        .collect();
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut logit = 0.0;
        for j in 0..d {
            let v = rng.normal();
            logit += v * w_true[j];
            x.push(v);
        }
        y.push(rng.bernoulli(crate::util::math::sigmoid(logit)) as i64);
    }
    let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let data = vec![
        DataInput::f64(x.clone(), &[n, d]),
        DataInput::f64(yf, &[n]),
    ];
    BenchModel {
        name: "logreg",
        theta_dim: d,
        step_size: 0.006,
        model: Box::new(LogReg { x, y, d }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};

    #[test]
    fn matches_distribution_based_formulation() {
        let bm = logreg_n(3, 40, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = (0..5).map(|i| 0.1 * i as f64 - 0.2).collect();
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
        // reference using the BernoulliLogit distribution object
        let m = match bm.model.as_ref().name() {
            "LogReg" => (),
            _ => panic!(),
        };
        let _ = m;
        let lr = LogReg {
            x: match &bm.data[0] {
                DataInput::F64 { data, .. } => data.clone(),
                _ => unreachable!(),
            },
            y: match &bm.data[1] {
                DataInput::F64 { data, .. } => data.iter().map(|&v| v as i64).collect(),
                _ => unreachable!(),
            },
            d: 5,
        };
        let mut want = IsoNormal::new(0.0, 1.0, 5).logpdf(&theta);
        for i in 0..40 {
            let logit: f64 = (0..5).map(|j| theta[j] * lr.x[i * 5 + j]).sum();
            want += BernoulliLogit::new(logit).logpmf(lr.y[i]);
        }
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }
}
