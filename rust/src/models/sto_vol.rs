//! T1.6 Stochastic Volatility: 500-step AR(1) latent log-variance.
//!
//! The scalar time-series loop is the workload where the paper finds the
//! tape-based reverse AD (Tracker.jl) slowest — each of the 500 latent
//! states participates in two sequential density terms.

use crate::prelude::*;
use crate::runtime::DataInput;

use super::BenchModel;

model! {
    /// `φ ~ Uniform(-1,1); σ ~ HalfCauchy(2); μ ~ Cauchy(0,10);
    /// h₀ ~ N(μ, σ/√(1-φ²)); h_t ~ N(μ+φ(h_{t-1}-μ), σ);
    /// y_t ~ N(0, exp(h_t/2))`.
    pub StoVol {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let phi = tilde!(api, phi ~ Uniform(c(-1.0), c(1.0)));
        let sigma = tilde!(api, sigma ~ HalfCauchy(c(2.0)));
        let mu = tilde!(api, mu ~ Cauchy(c(0.0), c(10.0)));
        check_reject!(api);
        let t_len = this.y.len();
        let sd0 = sigma / (-(phi * phi) + 1.0).sqrt();
        let mut h_prev = tilde!(api, h[0] ~ Normal(mu, sd0));
        obs!(api, this.y[0] => Normal(c(0.0), (h_prev * 0.5).exp()));
        for t in 1..t_len {
            let m = mu + phi * (h_prev - mu);
            let h_t = tilde!(api, h[t] ~ Normal(m, sigma));
            obs!(api, this.y[t] => Normal(c(0.0), (h_t * 0.5).exp()));
            h_prev = h_t;
        }
    }
}

/// Full Table-1 workload: T = 500.
pub fn sto_volatility(seed: u64) -> BenchModel {
    sto_volatility_t(seed, 500)
}

pub fn sto_volatility_t(seed: u64, t_len: usize) -> BenchModel {
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xA006);
    let (phi, sigma, mu) = (0.95, 0.25, -1.0);
    let mut h = mu;
    let mut y = Vec::with_capacity(t_len);
    for _ in 0..t_len {
        h = mu + phi * (h - mu) + sigma * rng.normal();
        y.push((h / 2.0).exp() * rng.normal());
    }
    let data = vec![DataInput::f64(y.clone(), &[t_len])];
    BenchModel {
        name: "sto_volatility",
        theta_dim: 3 + t_len,
        step_size: 0.004,
        model: Box::new(StoVol { y }),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};

    #[test]
    fn matches_manual_density() {
        let bm = sto_volatility_t(8, 20);
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = (0..23).map(|i| 0.05 * i as f64 - 0.4).collect();
        let got = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);

        let y = match &bm.data[0] {
            DataInput::F64 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        // manual, mirroring python/compile/models.py::sto_vol_logp
        let u = theta[0];
        let sig_u = crate::util::math::sigmoid(u);
        let phi = -1.0 + 2.0 * sig_u;
        let ladj_phi = crate::util::math::log_sigmoid(u)
            + crate::util::math::log_sigmoid(-u)
            + 2.0f64.ln();
        let sigma = theta[1].exp();
        let mu = theta[2];
        let h = &theta[3..];
        let mut want = Uniform::new(-1.0, 1.0).logpdf(phi) + ladj_phi;
        want += HalfCauchy::new(2.0).logpdf(sigma) + theta[1];
        want += Cauchy::new(0.0, 10.0).logpdf(mu);
        let sd0 = sigma / (1.0 - phi * phi).sqrt();
        want += Normal::new(mu, sd0).logpdf(h[0]);
        for t in 1..20 {
            want += Normal::new(mu + phi * (h[t - 1] - mu), sigma).logpdf(h[t]);
        }
        for t in 0..20 {
            want += Normal::new(0.0, (h[t] / 2.0).exp()).logpdf(y[t]);
        }
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }
}
