//! The 8 Table-1 benchmark models, written in the tilde DSL, plus their
//! synthetic workload generators.
//!
//! Each model mirrors — statement for statement — the JAX definition in
//! `python/compile/models.py`: same visit order, same transforms, same
//! parameterizations, so the typed Rust executor and the AOT artifact
//! compute the same log-density at the same unconstrained point
//! (`rust/tests/runtime_aot.rs` checks this numerically).
//!
//! Workloads are the paper's Table-1 sizes, generated synthetically with a
//! fixed seed (see DESIGN.md §7 for the MNIST substitution).

pub mod fixtures;
pub mod gauss;
pub mod hier_poisson;
pub mod hmm;
pub mod lda;
pub mod logreg;
pub mod logreg_tall;
pub mod naive_bayes;
pub mod sto_vol;

use crate::model::Model;
use crate::runtime::DataInput;

/// A benchmark model instance: the DSL model, its XLA data inputs (in
/// artifact argument order), and the paper's HMC step size for it.
pub struct BenchModel {
    pub name: &'static str,
    pub theta_dim: usize,
    /// static-HMC step size used by the Table-1 harness ("step size varies
    /// for different models").
    pub step_size: f64,
    pub model: Box<dyn Model>,
    pub data: Vec<DataInput>,
}

/// All Table-1 model names, in the paper's order.
pub const ALL_MODELS: [&str; 8] = [
    "gaussian_10kd",
    "gauss_unknown",
    "naive_bayes",
    "logreg",
    "hier_poisson",
    "sto_volatility",
    "hmm_semisup",
    "lda",
];

/// Workload models beyond Table 1 (not part of the paper's benchmark
/// grid): currently the tall-data logistic regression driving the
/// minibatched-VI workload.
pub const EXTRA_MODELS: [&str; 1] = ["logreg_tall"];

/// Analyzer fixtures ([`fixtures`]): buildable by name for `dppl lint` /
/// `dppl bench conjugate`, but excluded from the benchmark grids.
pub const FIXTURE_MODELS: [&str; 2] = ["lint_fixture", "conjugate_hier"];

/// Whether `name` is a buildable workload model (Table 1, extra, or
/// analyzer fixture).
pub fn is_known(name: &str) -> bool {
    ALL_MODELS.contains(&name) || EXTRA_MODELS.contains(&name) || FIXTURE_MODELS.contains(&name)
}

/// Build a benchmark model with its synthetic Table-1 workload.
pub fn build(name: &str, seed: u64) -> BenchModel {
    match name {
        "gaussian_10kd" => gauss::gaussian_10kd(),
        "gauss_unknown" => gauss::gauss_unknown(seed),
        "naive_bayes" => naive_bayes::naive_bayes(seed),
        "logreg" => logreg::logreg(seed),
        "logreg_tall" => logreg_tall::logreg_tall(seed),
        "hier_poisson" => hier_poisson::hier_poisson(seed),
        "sto_volatility" => sto_vol::sto_volatility(seed),
        "hmm_semisup" => hmm::hmm_semisup(seed),
        "lda" => lda::lda(seed),
        "lint_fixture" => fixtures::lint_fixture(),
        "conjugate_hier" => fixtures::conjugate_hier(seed),
        other => panic!(
            "unknown benchmark model {other:?} (known: {ALL_MODELS:?} + {EXTRA_MODELS:?} + {FIXTURE_MODELS:?})"
        ),
    }
}

/// Smaller variants of the same models for fast tests and the untyped-path
/// benchmarks (same code paths, reduced N).
pub fn build_small(name: &str, seed: u64) -> BenchModel {
    match name {
        "gaussian_10kd" => gauss::gaussian_kd(100),
        "gauss_unknown" => gauss::gauss_unknown_n(seed, 200),
        "naive_bayes" => naive_bayes::naive_bayes_n(seed, 50),
        "logreg" => logreg::logreg_n(seed, 200, 10),
        "logreg_tall" => logreg_tall::logreg_tall_small(seed),
        "hier_poisson" => hier_poisson::hier_poisson(seed),
        "sto_volatility" => sto_vol::sto_volatility_t(seed, 50),
        "hmm_semisup" => hmm::hmm_semisup_t(seed, 30, 10),
        "lda" => lda::lda_n(seed, 300),
        "lint_fixture" => fixtures::lint_fixture(),
        "conjugate_hier" => fixtures::conjugate_hier_n(seed, 100),
        other => panic!("unknown benchmark model {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::Context;
    use crate::model::{init_trace, init_typed, typed_logp};
    use crate::util::rng::Xoshiro256pp;
    use crate::varinfo::TypedVarInfo;

    #[test]
    fn all_models_build_and_have_expected_dims() {
        let dims = [10_000, 2, 400, 100, 12, 503, 115, 535];
        for (name, dim) in ALL_MODELS.iter().zip(dims) {
            let bm = build_small(name, 3);
            assert_eq!(bm.name, *name);
            let full = build(name, 3);
            assert_eq!(full.theta_dim, dim, "{name}");
        }
    }

    #[test]
    fn typed_trace_dims_match_declared() {
        for name in ALL_MODELS {
            let bm = build_small(name, 5);
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            let tvi = init_typed(bm.model.as_ref(), &mut rng);
            // small variants have their own dims; just check logp finite
            let lp = typed_logp(
                bm.model.as_ref(),
                &tvi,
                &tvi.unconstrained,
                Context::Default,
            );
            assert!(lp.is_finite(), "{name}: logp {lp}");
        }
    }

    #[test]
    fn extra_models_build_and_are_known() {
        assert!(is_known("logreg"));
        assert!(is_known("logreg_tall"));
        assert!(!is_known("frobnicate"));
        let bm = build_small("logreg_tall", 3);
        assert_eq!(bm.name, "logreg_tall");
        assert_eq!(bm.theta_dim, 10);
        assert_eq!(bm.model.as_ref().name(), "LogRegTall");
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let lp = typed_logp(
            bm.model.as_ref(),
            &tvi,
            &tvi.unconstrained,
            Context::Default,
        );
        assert!(lp.is_finite(), "logreg_tall logp {lp}");
    }

    #[test]
    fn full_workloads_evaluate_finite() {
        for name in ALL_MODELS {
            let bm = build(name, 7);
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            let vi = init_trace(bm.model.as_ref(), &mut rng);
            assert_eq!(
                vi.num_unconstrained(),
                bm.theta_dim,
                "{name}: trace dim vs declared"
            );
            let tvi = TypedVarInfo::from_untyped(&vi);
            let lp = typed_logp(
                bm.model.as_ref(),
                &tvi,
                &tvi.unconstrained,
                Context::Default,
            );
            assert!(lp.is_finite(), "{name}: logp {lp}");
        }
    }
}
