//! Dynamically-typed run-time values.
//!
//! [`Value`] is the boxed representation used by `UntypedVarInfo` — the
//! analogue of the paper's `Vector{Real}` storage where the element type is
//! abstract and every access pays a dispatch/unbox cost. The typed trace
//! (`TypedVarInfo`) stores flat `f64` buffers instead and never touches
//! this enum on the hot path.

use std::fmt;

/// A dynamically-typed value: scalar, integer, vector, integer vector or
/// dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F64(f64),
    Int(i64),
    Vec(Vec<f64>),
    IntVec(Vec<i64>),
    Matrix { data: Vec<f64>, rows: usize, cols: usize },
}

impl Value {
    /// Number of f64 slots this value occupies when flattened into a
    /// parameter vector (integers are not flattened — they are discrete and
    /// never HMC parameters).
    pub fn num_elements(&self) -> usize {
        match self {
            Value::F64(_) => 1,
            Value::Int(_) => 1,
            Value::Vec(v) => v.len(),
            Value::IntVec(v) => v.len(),
            Value::Matrix { data, .. } => data.len(),
        }
    }

    /// True if the value holds continuous (f64) data.
    pub fn is_continuous(&self) -> bool {
        matches!(self, Value::F64(_) | Value::Vec(_) | Value::Matrix { .. })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(x) => Some(*x),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::F64(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_slice(&self) -> Option<&[f64]> {
        match self {
            Value::Vec(v) => Some(v),
            Value::Matrix { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_int_slice(&self) -> Option<&[i64]> {
        match self {
            Value::IntVec(v) => Some(v),
            _ => None,
        }
    }

    /// Flatten continuous content into `out`. Panics on integer values.
    pub fn flatten_into(&self, out: &mut Vec<f64>) {
        match self {
            Value::F64(x) => out.push(*x),
            Value::Vec(v) => out.extend_from_slice(v),
            Value::Matrix { data, .. } => out.extend_from_slice(data),
            Value::Int(_) | Value::IntVec(_) => {
                panic!("cannot flatten discrete value into continuous parameter vector")
            }
        }
    }

    /// Rebuild a value of the same shape as `self` from a flat slice,
    /// consuming `self.num_elements()` entries.
    pub fn unflatten_from(&self, flat: &[f64]) -> Value {
        match self {
            Value::F64(_) => Value::F64(flat[0]),
            Value::Vec(v) => Value::Vec(flat[..v.len()].to_vec()),
            Value::Matrix { rows, cols, .. } => Value::Matrix {
                data: flat[..rows * cols].to_vec(),
                rows: *rows,
                cols: *cols,
            },
            Value::Int(_) | Value::IntVec(_) => {
                panic!("cannot unflatten discrete value from continuous parameter vector")
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::F64(x) => write!(f, "{x}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Vec(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::IntVec(v) => write!(f, "{v:?}"),
            Value::Matrix { rows, cols, .. } => write!(f, "<{rows}×{cols} matrix>"),
        }
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::F64(x)
    }
}

impl From<i64> for Value {
    fn from(x: i64) -> Self {
        Value::Int(x)
    }
}

impl From<Vec<f64>> for Value {
    fn from(v: Vec<f64>) -> Self {
        Value::Vec(v)
    }
}

impl From<Vec<i64>> for Value {
    fn from(v: Vec<i64>) -> Self {
        Value::IntVec(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_roundtrip_scalar() {
        let v = Value::F64(2.5);
        let mut flat = Vec::new();
        v.flatten_into(&mut flat);
        assert_eq!(flat, vec![2.5]);
        assert_eq!(v.unflatten_from(&flat), v);
    }

    #[test]
    fn flatten_roundtrip_vec_and_matrix() {
        let v = Value::Vec(vec![1.0, 2.0, 3.0]);
        let m = Value::Matrix {
            data: vec![1.0, 2.0, 3.0, 4.0],
            rows: 2,
            cols: 2,
        };
        let mut flat = Vec::new();
        v.flatten_into(&mut flat);
        m.flatten_into(&mut flat);
        assert_eq!(flat.len(), 7);
        assert_eq!(v.unflatten_from(&flat[..3]), v);
        assert_eq!(m.unflatten_from(&flat[3..]), m);
    }

    #[test]
    fn accessors() {
        assert_eq!(Value::F64(1.5).as_f64(), Some(1.5));
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::F64(2.0).as_int(), Some(2));
        assert_eq!(Value::F64(2.5).as_int(), None);
        assert_eq!(Value::Vec(vec![1.0]).as_slice(), Some(&[1.0][..]));
        assert!(Value::Int(1).as_slice().is_none());
        assert_eq!(Value::IntVec(vec![1, 2]).as_int_slice(), Some(&[1i64, 2][..]));
    }

    #[test]
    fn continuity_flags() {
        assert!(Value::F64(0.0).is_continuous());
        assert!(!Value::Int(0).is_continuous());
        assert!(!Value::IntVec(vec![]).is_continuous());
    }

    #[test]
    #[should_panic]
    fn flatten_discrete_panics() {
        let mut out = Vec::new();
        Value::Int(1).flatten_into(&mut out);
    }

    #[test]
    fn display_forms() {
        assert_eq!(Value::F64(1.0).to_string(), "1");
        assert_eq!(Value::Vec(vec![1.0, 2.0]).to_string(), "[1, 2]");
    }
}
