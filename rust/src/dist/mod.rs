//! The distribution library (the Distributions.jl slice the paper's models
//! need), written once, generically over the AD [`Scalar`].
//!
//! Three families mirror the three tilde forms of the DSL:
//!
//! - [`ScalarDist`] — univariate continuous (`tilde!` / `obs!`);
//! - [`VecDist`] — fixed-length multivariate (`tilde_vec!` / `obs_vec!`);
//! - [`DiscreteDist`] — integer-valued (`tilde_int!` / `obs_int!`).
//!
//! Every distribution knows its [`Domain`] (support metadata driving the
//! [`bijector`] link/invlink transforms and trace layout) and its exact
//! log-density including normalization constants — the hand-coded
//! `stanlike` densities and the AOT JAX artifacts pin the same constants,
//! so all execution backends agree to 1e-10.
//!
//! [`AnyDist`] is the boxed, `f64`-specialized form stored inside
//! [`crate::varinfo::UntypedVarInfo`] records: it can sample a fresh
//! [`Value`] (prior draws, particle regeneration) and score a boxed value
//! (the MH slow path).

pub mod bijector;

use rand_core::RngCore;

use crate::ad::Scalar;
use crate::util::math;
use crate::util::rng::Rng as _;
use crate::value::Value;

/// Support metadata for one random variable: what the bijector needs to
/// map it to unconstrained coordinates, and what the trace layout records.
#[derive(Clone, Debug, PartialEq)]
pub enum Domain {
    /// ℝ (identity transform).
    Real,
    /// (0, ∞) (log transform).
    Positive,
    /// (lo, hi) (scaled-logit transform).
    Interval(f64, f64),
    /// ℝⁿ.
    RealVec(usize),
    /// (0, ∞)ⁿ (elementwise log).
    PositiveVec(usize),
    /// The (n−1)-simplex embedded in ℝⁿ (stick-breaking transform).
    Simplex(usize),
    /// {0, 1}.
    DiscreteBool,
    /// {0, …, k−1}.
    DiscreteCategory(usize),
    /// ℕ (unbounded counts; observation-only in the benchmark set).
    DiscreteCount,
}

impl Domain {
    /// True for integer-valued supports (never HMC coordinates).
    pub fn is_discrete(&self) -> bool {
        matches!(
            self,
            Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount
        )
    }

    /// Number of unconstrained (ℝ) coordinates the value flattens to.
    pub fn unconstrained_dim(&self) -> usize {
        match self {
            Domain::Real | Domain::Positive | Domain::Interval(_, _) => 1,
            Domain::RealVec(n) | Domain::PositiveVec(n) => *n,
            Domain::Simplex(n) => n - 1,
            Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount => 0,
        }
    }

    /// Structural compatibility: same support variant and dimensions.
    /// `Interval` bounds are **not** compared — distribution parameters may
    /// depend on other parameters (`Uniform(0, theta)`) without changing
    /// the trace layout, so the typed replay path treats them as the same
    /// slot shape. Strict equality (`==`) is what layout *specialization*
    /// checks; this is what per-visit cursor walks check.
    pub fn compatible(&self, other: &Domain) -> bool {
        match (self, other) {
            (Domain::Real, Domain::Real)
            | (Domain::Positive, Domain::Positive)
            | (Domain::Interval(_, _), Domain::Interval(_, _))
            | (Domain::DiscreteBool, Domain::DiscreteBool)
            | (Domain::DiscreteCount, Domain::DiscreteCount) => true,
            (Domain::RealVec(a), Domain::RealVec(b))
            | (Domain::PositiveVec(a), Domain::PositiveVec(b))
            | (Domain::Simplex(a), Domain::Simplex(b))
            | (Domain::DiscreteCategory(a), Domain::DiscreteCategory(b)) => a == b,
            _ => false,
        }
    }

    /// Number of constrained scalar elements of the value.
    pub fn constrained_dim(&self) -> usize {
        match self {
            Domain::Real | Domain::Positive | Domain::Interval(_, _) => 1,
            Domain::RealVec(n) | Domain::PositiveVec(n) | Domain::Simplex(n) => *n,
            Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount => 0,
        }
    }
}

// ------------------------------------------------------------------ scalar

/// Normal(mean, sd).
#[derive(Clone, Copy, Debug)]
pub struct Normal<T: Scalar> {
    pub mean: T,
    pub sd: T,
}

impl<T: Scalar> Normal<T> {
    pub fn new(mean: T, sd: T) -> Self {
        Self { mean, sd }
    }

    /// Standard normal.
    pub fn std() -> Self {
        Self {
            mean: T::constant(0.0),
            sd: T::constant(1.0),
        }
    }

    pub fn logpdf(&self, x: T) -> T {
        if self.sd.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let z = (x - self.mean) / self.sd;
        -(z * z) * 0.5 - self.sd.ln() - 0.5 * math::LN_2PI
    }
}

/// InverseGamma(shape α, scale β): density ∝ x^{−α−1} e^{−β/x}.
#[derive(Clone, Copy, Debug)]
pub struct InverseGamma<T: Scalar> {
    pub shape: T,
    pub scale: T,
}

impl<T: Scalar> InverseGamma<T> {
    pub fn new(shape: T, scale: T) -> Self {
        Self { shape, scale }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.shape * self.scale.ln() - self.shape.lgamma()
            - (self.shape + 1.0) * x.ln()
            - self.scale / x
    }
}

/// Gamma(shape α, rate β): mean α/β.
#[derive(Clone, Copy, Debug)]
pub struct Gamma<T: Scalar> {
    pub shape: T,
    pub rate: T,
}

impl<T: Scalar> Gamma<T> {
    pub fn new(shape: T, rate: T) -> Self {
        Self { shape, rate }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.shape * self.rate.ln() - self.shape.lgamma()
            + (self.shape - 1.0) * x.ln()
            - self.rate * x
    }
}

/// Beta(a, b) on (0, 1).
#[derive(Clone, Copy, Debug)]
pub struct Beta<T: Scalar> {
    pub a: T,
    pub b: T,
}

impl<T: Scalar> Beta<T> {
    pub fn new(a: T, b: T) -> Self {
        Self { a, b }
    }

    pub fn logpdf(&self, x: T) -> T {
        let xv = x.value();
        if xv <= 0.0 || xv >= 1.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let lbeta = self.a.lgamma() + self.b.lgamma() - (self.a + self.b).lgamma();
        (self.a - 1.0) * x.ln() + (self.b - 1.0) * (T::constant(1.0) - x).ln() - lbeta
    }
}

/// Exponential(rate λ): mean 1/λ.
#[derive(Clone, Copy, Debug)]
pub struct Exponential<T: Scalar> {
    pub rate: T,
}

impl<T: Scalar> Exponential<T> {
    pub fn new(rate: T) -> Self {
        Self { rate }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() < 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.rate.ln() - self.rate * x
    }
}

/// Uniform(lo, hi).
#[derive(Clone, Copy, Debug)]
pub struct Uniform<T: Scalar> {
    pub lo: T,
    pub hi: T,
}

impl<T: Scalar> Uniform<T> {
    pub fn new(lo: T, hi: T) -> Self {
        Self { lo, hi }
    }

    pub fn logpdf(&self, x: T) -> T {
        let xv = x.value();
        if xv < self.lo.value() || xv > self.hi.value() {
            return T::constant(f64::NEG_INFINITY);
        }
        -((self.hi - self.lo).ln())
    }
}

/// Cauchy(loc, scale).
#[derive(Clone, Copy, Debug)]
pub struct Cauchy<T: Scalar> {
    pub loc: T,
    pub scale: T,
}

impl<T: Scalar> Cauchy<T> {
    pub fn new(loc: T, scale: T) -> Self {
        Self { loc, scale }
    }

    pub fn logpdf(&self, x: T) -> T {
        let z = (x - self.loc) / self.scale;
        T::constant(-math::LN_PI) - self.scale.ln() - (z * z).ln_1p()
    }
}

/// HalfCauchy(scale): |Cauchy(0, scale)|, supported on [0, ∞).
#[derive(Clone, Copy, Debug)]
pub struct HalfCauchy<T: Scalar> {
    pub scale: T,
}

impl<T: Scalar> HalfCauchy<T> {
    pub fn new(scale: T) -> Self {
        Self { scale }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() < 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let z = x / self.scale;
        T::constant(std::f64::consts::LN_2 - math::LN_PI) - self.scale.ln() - (z * z).ln_1p()
    }
}

/// Univariate continuous distributions.
#[derive(Clone, Debug)]
pub enum ScalarDist<T: Scalar> {
    Normal(Normal<T>),
    InverseGamma(InverseGamma<T>),
    Gamma(Gamma<T>),
    Beta(Beta<T>),
    Exponential(Exponential<T>),
    Uniform(Uniform<T>),
    Cauchy(Cauchy<T>),
    HalfCauchy(HalfCauchy<T>),
}

impl<T: Scalar> ScalarDist<T> {
    pub fn logpdf(&self, x: T) -> T {
        match self {
            ScalarDist::Normal(d) => d.logpdf(x),
            ScalarDist::InverseGamma(d) => d.logpdf(x),
            ScalarDist::Gamma(d) => d.logpdf(x),
            ScalarDist::Beta(d) => d.logpdf(x),
            ScalarDist::Exponential(d) => d.logpdf(x),
            ScalarDist::Uniform(d) => d.logpdf(x),
            ScalarDist::Cauchy(d) => d.logpdf(x),
            ScalarDist::HalfCauchy(d) => d.logpdf(x),
        }
    }

    pub fn domain(&self) -> Domain {
        match self {
            ScalarDist::Normal(_) | ScalarDist::Cauchy(_) => Domain::Real,
            ScalarDist::InverseGamma(_)
            | ScalarDist::Gamma(_)
            | ScalarDist::Exponential(_)
            | ScalarDist::HalfCauchy(_) => Domain::Positive,
            ScalarDist::Beta(_) => Domain::Interval(0.0, 1.0),
            ScalarDist::Uniform(d) => Domain::Interval(d.lo.value(), d.hi.value()),
        }
    }
}

impl ScalarDist<f64> {
    /// Box into the dynamically-typed form stored in `UntypedVarInfo`.
    pub fn boxed(&self) -> AnyDist {
        AnyDist::Scalar(self.clone())
    }

    /// Draw one value (prior sampling / particle regeneration).
    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> f64 {
        match self {
            ScalarDist::Normal(d) => d.mean + d.sd * rng.normal(),
            ScalarDist::InverseGamma(d) => d.scale / rng.gamma(d.shape),
            ScalarDist::Gamma(d) => rng.gamma(d.shape) / d.rate,
            ScalarDist::Beta(d) => rng.beta(d.a, d.b),
            ScalarDist::Exponential(d) => rng.exponential() / d.rate,
            ScalarDist::Uniform(d) => rng.uniform_range(d.lo, d.hi),
            ScalarDist::Cauchy(d) => {
                d.loc + d.scale * (std::f64::consts::PI * (rng.uniform() - 0.5)).tan()
            }
            ScalarDist::HalfCauchy(d) => {
                (d.scale * (std::f64::consts::PI * (rng.uniform() - 0.5)).tan()).abs()
            }
        }
    }
}

// ------------------------------------------------------------------ vector

/// Isotropic normal: n iid Normal(mean, sd) coordinates.
#[derive(Clone, Copy, Debug)]
pub struct IsoNormal<T: Scalar> {
    pub mean: T,
    pub sd: T,
    pub n: usize,
}

impl<T: Scalar> IsoNormal<T> {
    pub fn new(mean: T, sd: T, n: usize) -> Self {
        Self { mean, sd, n }
    }

    pub fn logpdf(&self, x: &[T]) -> T {
        debug_assert_eq!(x.len(), self.n);
        if self.sd.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let mut ss = T::constant(0.0);
        for &xi in x {
            let z = (xi - self.mean) / self.sd;
            ss = ss + z * z;
        }
        let n = self.n as f64;
        ss * (-0.5) - self.sd.ln() * n - 0.5 * math::LN_2PI * n
    }
}

/// Dirichlet(α) over the (n−1)-simplex. α is data (never a parameter in
/// the benchmark set), so it stays `f64`.
#[derive(Clone, Debug)]
pub struct Dirichlet {
    pub alpha: Vec<f64>,
}

impl Dirichlet {
    pub fn new(alpha: Vec<f64>) -> Self {
        assert!(!alpha.is_empty() && alpha.iter().all(|&a| a > 0.0));
        Self { alpha }
    }

    /// Symmetric Dirichlet(a, …, a) of length n.
    pub fn symmetric(a: f64, n: usize) -> Self {
        Self::new(vec![a; n])
    }

    pub fn logpdf<T: Scalar>(&self, x: &[T]) -> T {
        debug_assert_eq!(x.len(), self.alpha.len());
        let mut lp = T::constant(self.log_norm());
        for (&a, &xi) in self.alpha.iter().zip(x) {
            if xi.value() <= 0.0 {
                return T::constant(f64::NEG_INFINITY);
            }
            // skip α=1 terms: exact zero, and avoids 0·ln(x) tape nodes
            if a != 1.0 {
                lp = lp + xi.ln() * (a - 1.0);
            }
        }
        lp
    }

    /// lnΓ(Σα) − Σ lnΓ(αᵢ).
    fn log_norm(&self) -> f64 {
        let sum: f64 = self.alpha.iter().sum();
        math::lgamma(sum) - self.alpha.iter().map(|&a| math::lgamma(a)).sum::<f64>()
    }
}

/// Fixed-length multivariate distributions.
#[derive(Clone, Debug)]
pub enum VecDist<T: Scalar> {
    IsoNormal(IsoNormal<T>),
    Dirichlet(Dirichlet),
}

impl<T: Scalar> VecDist<T> {
    pub fn logpdf(&self, x: &[T]) -> T {
        match self {
            VecDist::IsoNormal(d) => d.logpdf(x),
            VecDist::Dirichlet(d) => d.logpdf(x),
        }
    }

    pub fn domain(&self) -> Domain {
        match self {
            VecDist::IsoNormal(d) => Domain::RealVec(d.n),
            VecDist::Dirichlet(d) => Domain::Simplex(d.alpha.len()),
        }
    }

    /// Length of the constrained value vector.
    pub fn len(&self) -> usize {
        match self {
            VecDist::IsoNormal(d) => d.n,
            VecDist::Dirichlet(d) => d.alpha.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl VecDist<f64> {
    pub fn boxed(&self) -> AnyDist {
        AnyDist::Vector(self.clone())
    }

    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> Vec<f64> {
        match self {
            VecDist::IsoNormal(d) => (0..d.n).map(|_| d.mean + d.sd * rng.normal()).collect(),
            VecDist::Dirichlet(d) => {
                let mut out = vec![0.0; d.alpha.len()];
                rng.dirichlet_into(&d.alpha, &mut out);
                out
            }
        }
    }
}

// ---------------------------------------------------------------- discrete

/// Bernoulli(p) over {0, 1}.
#[derive(Clone, Copy, Debug)]
pub struct Bernoulli<T: Scalar> {
    pub p: T,
}

impl<T: Scalar> Bernoulli<T> {
    pub fn new(p: T) -> Self {
        Self { p }
    }

    pub fn logpmf(&self, k: i64) -> T {
        match k {
            1 => self.p.ln(),
            0 => (T::constant(1.0) - self.p).ln(),
            _ => T::constant(f64::NEG_INFINITY),
        }
    }
}

/// Bernoulli on the logit scale: P(1) = σ(logit).
#[derive(Clone, Copy, Debug)]
pub struct BernoulliLogit<T: Scalar> {
    pub logit: T,
}

impl<T: Scalar> BernoulliLogit<T> {
    pub fn new(logit: T) -> Self {
        Self { logit }
    }

    pub fn logpmf(&self, k: i64) -> T {
        match k {
            1 => self.logit.log_sigmoid(),
            0 => (-self.logit).log_sigmoid(),
            _ => T::constant(f64::NEG_INFINITY),
        }
    }
}

/// Poisson(rate λ).
#[derive(Clone, Copy, Debug)]
pub struct Poisson<T: Scalar> {
    pub rate: T,
}

impl<T: Scalar> Poisson<T> {
    pub fn new(rate: T) -> Self {
        Self { rate }
    }

    pub fn logpmf(&self, k: i64) -> T {
        if k < 0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.rate.ln() * (k as f64) - self.rate - math::ln_factorial(k as u64)
    }
}

/// Categorical over {0, …, K−1} with fixed (data-side) probabilities.
#[derive(Clone, Debug)]
pub struct Categorical {
    pub probs: Vec<f64>,
}

impl Categorical {
    /// Normalize (possibly unnormalized) probabilities.
    pub fn from_probs(probs: &[f64]) -> Self {
        let total: f64 = probs.iter().sum();
        assert!(total > 0.0, "categorical probabilities sum to zero");
        Self {
            probs: probs.iter().map(|&p| p / total).collect(),
        }
    }

    pub fn logpmf<T: Scalar>(&self, k: i64) -> T {
        if k < 0 || k as usize >= self.probs.len() {
            return T::constant(f64::NEG_INFINITY);
        }
        T::constant(self.probs[k as usize].ln())
    }
}

/// Integer-valued distributions.
#[derive(Clone, Debug)]
pub enum DiscreteDist<T: Scalar> {
    Bernoulli(Bernoulli<T>),
    BernoulliLogit(BernoulliLogit<T>),
    Poisson(Poisson<T>),
    Categorical(Categorical),
}

impl<T: Scalar> DiscreteDist<T> {
    pub fn logpmf(&self, k: i64) -> T {
        match self {
            DiscreteDist::Bernoulli(d) => d.logpmf(k),
            DiscreteDist::BernoulliLogit(d) => d.logpmf(k),
            DiscreteDist::Poisson(d) => d.logpmf(k),
            DiscreteDist::Categorical(d) => d.logpmf(k),
        }
    }

    pub fn domain(&self) -> Domain {
        match self {
            DiscreteDist::Bernoulli(_) | DiscreteDist::BernoulliLogit(_) => Domain::DiscreteBool,
            DiscreteDist::Poisson(_) => Domain::DiscreteCount,
            DiscreteDist::Categorical(d) => Domain::DiscreteCategory(d.probs.len()),
        }
    }
}

impl DiscreteDist<f64> {
    pub fn boxed(&self) -> AnyDist {
        AnyDist::Discrete(self.clone())
    }

    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> i64 {
        match self {
            DiscreteDist::Bernoulli(d) => rng.bernoulli(d.p) as i64,
            DiscreteDist::BernoulliLogit(d) => rng.bernoulli(math::sigmoid(d.logit)) as i64,
            DiscreteDist::Poisson(d) => rng.poisson(d.rate) as i64,
            DiscreteDist::Categorical(d) => rng.categorical(&d.probs) as i64,
        }
    }
}

// ------------------------------------------------------------------- boxed

/// The dynamically-typed (boxed, `f64`-specialized) distribution stored in
/// `UntypedVarInfo` records — the paper's abstract-element-type storage.
#[derive(Clone, Debug)]
pub enum AnyDist {
    Scalar(ScalarDist<f64>),
    Vector(VecDist<f64>),
    Discrete(DiscreteDist<f64>),
}

impl AnyDist {
    pub fn domain(&self) -> Domain {
        match self {
            AnyDist::Scalar(d) => d.domain(),
            AnyDist::Vector(d) => d.domain(),
            AnyDist::Discrete(d) => d.domain(),
        }
    }

    /// Draw a fresh boxed value from the distribution.
    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> Value {
        match self {
            AnyDist::Scalar(d) => Value::F64(d.sample(rng)),
            AnyDist::Vector(d) => Value::Vec(d.sample(rng)),
            AnyDist::Discrete(d) => Value::Int(d.sample(rng)),
        }
    }

    /// Log-density of a boxed value (constrained space, no Jacobian).
    pub fn logpdf(&self, v: &Value) -> f64 {
        match self {
            AnyDist::Scalar(d) => match v.as_f64() {
                Some(x) => d.logpdf(x),
                None => f64::NEG_INFINITY,
            },
            AnyDist::Vector(d) => match v.as_slice() {
                Some(x) => d.logpdf(x),
                None => f64::NEG_INFINITY,
            },
            AnyDist::Discrete(d) => match v.as_int() {
                Some(k) => d.logpmf(k),
                None => f64::NEG_INFINITY,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::forward::Dual;
    use crate::ad::finite_diff_grad;
    use crate::util::rng::Xoshiro256pp;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn normal_pins() {
        // N(0,1) at 0: -0.5 ln 2π
        close(Normal::new(0.0, 1.0).logpdf(0.0), -0.5 * math::LN_2PI, 1e-14);
        close(
            Normal::new(1.0, 2.0).logpdf(3.0),
            -0.5 - (2.0f64).ln() - 0.5 * math::LN_2PI,
            1e-14,
        );
        assert_eq!(Normal::new(0.0, 0.0).logpdf(0.0), f64::NEG_INFINITY);
        close(Normal::<f64>::std().logpdf(1.0), -0.5 - 0.5 * math::LN_2PI, 1e-14);
    }

    #[test]
    fn inverse_gamma_pins() {
        // IG(2,3) at x: 2 ln3 − lnΓ(2) − 3 ln x − 3/x
        let d = InverseGamma::new(2.0, 3.0);
        close(d.logpdf(1.0), 2.0 * 3.0f64.ln() - 3.0, 1e-13);
        assert_eq!(d.logpdf(-1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn gamma_exponential_consistency() {
        // Gamma(1, λ) = Exponential(λ)
        for &x in &[0.1, 1.0, 4.2] {
            close(
                Gamma::new(1.0, 2.5).logpdf(x),
                Exponential::new(2.5).logpdf(x),
                1e-13,
            );
        }
    }

    #[test]
    fn beta_uniform_consistency() {
        // Beta(1,1) = Uniform(0,1)
        for &x in &[0.2, 0.5, 0.9] {
            close(Beta::new(1.0, 1.0).logpdf(x), 0.0, 1e-13);
            close(Uniform::new(0.0, 1.0).logpdf(x), 0.0, 1e-14);
        }
        assert_eq!(Uniform::new(0.0, 1.0).logpdf(1.5), f64::NEG_INFINITY);
    }

    #[test]
    fn cauchy_and_half_cauchy() {
        // Cauchy(0,1) at 0: −ln π
        close(Cauchy::new(0.0, 1.0).logpdf(0.0), -math::LN_PI, 1e-14);
        // HalfCauchy doubles the density on the positive side
        close(
            HalfCauchy::new(2.0).logpdf(1.3),
            Cauchy::new(0.0, 2.0).logpdf(1.3) + 2.0f64.ln(),
            1e-13,
        );
        assert_eq!(HalfCauchy::new(1.0).logpdf(-0.1), f64::NEG_INFINITY);
    }

    #[test]
    fn iso_normal_is_sum_of_normals() {
        let d = IsoNormal::new(0.5, 1.5, 3);
        let x = [0.1, -0.2, 2.0];
        let want: f64 = x.iter().map(|&xi| Normal::new(0.5, 1.5).logpdf(xi)).sum();
        close(d.logpdf(&x), want, 1e-13);
    }

    #[test]
    fn dirichlet_uniform_is_log_gamma_k() {
        // Dirichlet(1,…,1) over the K-simplex has constant density Γ(K)
        let d = Dirichlet::symmetric(1.0, 4);
        close(d.logpdf(&[0.1f64, 0.2, 0.3, 0.4]), math::lgamma(4.0), 1e-13);
        // general α
        let d = Dirichlet::new(vec![2.0, 3.0, 0.5]);
        let x = [0.3f64, 0.5, 0.2];
        let want = math::lgamma(5.5) - math::lgamma(2.0) - math::lgamma(3.0)
            - math::lgamma(0.5)
            + 1.0 * x[0].ln()
            + 2.0 * x[1].ln()
            - 0.5 * x[2].ln();
        close(d.logpdf(&x), want, 1e-12);
        assert_eq!(
            d.logpdf(&[1.0f64, 0.0, 0.0]),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn discrete_pmfs() {
        close(Bernoulli::new(0.3).logpmf(1), 0.3f64.ln(), 1e-14);
        close(Bernoulli::new(0.3).logpmf(0), 0.7f64.ln(), 1e-14);
        assert_eq!(Bernoulli::new(0.3).logpmf(2), f64::NEG_INFINITY);
        // BernoulliLogit(logit(0.3)) == Bernoulli(0.3)
        let logit = (0.3f64 / 0.7).ln();
        close(
            BernoulliLogit::new(logit).logpmf(1),
            0.3f64.ln(),
            1e-12,
        );
        // Poisson(2) at k=3: 3 ln2 − 2 − ln 6
        close(
            Poisson::new(2.0).logpmf(3),
            3.0 * 2.0f64.ln() - 2.0 - 6.0f64.ln(),
            1e-13,
        );
        let c = Categorical::from_probs(&[1.0, 1.0, 2.0]);
        close(c.logpmf::<f64>(2), 0.5f64.ln(), 1e-14);
        assert_eq!(c.logpmf::<f64>(3), f64::NEG_INFINITY);
    }

    #[test]
    fn domains_are_consistent() {
        assert_eq!(ScalarDist::Normal(Normal::<f64>::std()).domain(), Domain::Real);
        assert_eq!(
            ScalarDist::Gamma(Gamma::new(1.0, 1.0)).domain(),
            Domain::Positive
        );
        assert_eq!(
            ScalarDist::Uniform(Uniform::new(-2.0, 3.0)).domain(),
            Domain::Interval(-2.0, 3.0)
        );
        assert_eq!(
            VecDist::<f64>::Dirichlet(Dirichlet::symmetric(1.0, 5)).domain(),
            Domain::Simplex(5)
        );
        assert_eq!(
            DiscreteDist::<f64>::Categorical(Categorical::from_probs(&[0.5, 0.5])).domain(),
            Domain::DiscreteCategory(2)
        );
        assert!(Domain::DiscreteBool.is_discrete());
        assert_eq!(Domain::Simplex(4).unconstrained_dim(), 3);
        assert_eq!(Domain::Simplex(4).constrained_dim(), 4);
        assert_eq!(Domain::DiscreteCategory(3).unconstrained_dim(), 0);
    }

    #[test]
    fn sampling_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(99);
        let n = 40_000;
        // Normal(2, 0.5)
        let d = ScalarDist::Normal(Normal::new(2.0, 0.5));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 2.0, 0.02);
        // Gamma(3, 2): mean 1.5
        let d = ScalarDist::Gamma(Gamma::new(3.0, 2.0));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 1.5, 0.03);
        // InverseGamma(3, 2): mean b/(a−1) = 1
        let d = ScalarDist::InverseGamma(InverseGamma::new(3.0, 2.0));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 1.0, 0.05);
        // Uniform(-1, 3): mean 1
        let d = ScalarDist::Uniform(Uniform::new(-1.0, 3.0));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 1.0, 0.05);
        // Bernoulli(0.3)
        let d = DiscreteDist::Bernoulli(Bernoulli::new(0.3));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        close(m, 0.3, 0.05);
        // Dirichlet samples live on the simplex
        let d = VecDist::Dirichlet(Dirichlet::symmetric(0.7, 4));
        let v = d.sample(&mut rng);
        close(v.iter().sum::<f64>(), 1.0, 1e-12);
    }

    #[test]
    fn any_dist_boxed_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let any = ScalarDist::Gamma(Gamma::new(2.0, 3.0)).boxed();
        assert_eq!(any.domain(), Domain::Positive);
        let v = any.sample(&mut rng);
        let x = v.as_f64().unwrap();
        assert!(x > 0.0);
        close(any.logpdf(&v), Gamma::new(2.0, 3.0).logpdf(x), 1e-14);
        // type mismatch scores −∞
        assert_eq!(any.logpdf(&Value::Vec(vec![1.0])), f64::NEG_INFINITY);

        let anyv = VecDist::IsoNormal(IsoNormal::new(0.0, 1.0, 3)).boxed();
        let v = anyv.sample(&mut rng);
        assert_eq!(v.as_slice().unwrap().len(), 3);
        let anyd = DiscreteDist::Categorical(Categorical::from_probs(&[0.2, 0.8])).boxed();
        let v = anyd.sample(&mut rng);
        assert!(matches!(v, Value::Int(0 | 1)));
    }

    #[test]
    fn dual_gradients_match_finite_differences() {
        // d/dx of several log-densities via forward duals vs FD
        let fd_check = |f: &dyn Fn(f64) -> f64, fdual: &dyn Fn(Dual) -> Dual, x0: f64| {
            let g_fd = finite_diff_grad(|x| f(x[0]), &[x0], 1e-6)[0];
            let g_ad = fdual(Dual::var(x0)).d;
            assert!((g_fd - g_ad).abs() < 1e-5, "{g_fd} vs {g_ad} at {x0}");
        };
        fd_check(
            &|x| Normal::new(0.5, 2.0).logpdf(x),
            &|x| Normal::new(Dual::constant(0.5), Dual::constant(2.0)).logpdf(x),
            1.3,
        );
        fd_check(
            &|x| Gamma::new(2.0, 3.0).logpdf(x),
            &|x| Gamma::new(Dual::constant(2.0), Dual::constant(3.0)).logpdf(x),
            0.8,
        );
        fd_check(
            &|x| HalfCauchy::new(2.0).logpdf(x),
            &|x| HalfCauchy::new(Dual::constant(2.0)).logpdf(x),
            1.1,
        );
        // gradient w.r.t. a *parameter*
        let g_fd = finite_diff_grad(|m| Normal::new(m[0], 1.0).logpdf(0.7), &[0.2], 1e-6)[0];
        let g_ad = Normal::new(Dual::var(0.2), Dual::constant(1.0))
            .logpdf(Dual::constant(0.7))
            .d;
        assert!((g_fd - g_ad).abs() < 1e-6);
    }
}
