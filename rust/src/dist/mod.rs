//! The distribution library (the Distributions.jl slice the paper's models
//! need), written once, generically over the AD [`Scalar`].
//!
//! Three families mirror the three tilde forms of the DSL:
//!
//! - [`ScalarDist`] — univariate continuous (`tilde!` / `obs!`);
//! - [`VecDist`] — fixed-length multivariate (`tilde_vec!` / `obs_vec!`);
//! - [`DiscreteDist`] — integer-valued (`tilde_int!` / `obs_int!`).
//!
//! Every distribution knows its [`Domain`] (support metadata driving the
//! [`bijector`] link/invlink transforms and trace layout) and its exact
//! log-density including normalization constants — the hand-coded
//! `stanlike` densities and the AOT JAX artifacts pin the same constants,
//! so all execution backends agree to 1e-10.
//!
//! [`AnyDist`] is the boxed, `f64`-specialized form stored inside
//! [`crate::varinfo::UntypedVarInfo`] records: it can sample a fresh
//! [`Value`] (prior draws, particle regeneration) and score a boxed value
//! (the MH slow path).

pub mod bijector;

use rand_core::RngCore;

use crate::ad::forward::Dual;
use crate::ad::Scalar;
use crate::util::math;
use crate::util::rng::Rng as _;
use crate::value::Value;

/// Maximum number of scalar parameters any built-in distribution carries.
pub const MAX_DIST_PARAMS: usize = 2;

/// Fused analytic adjoint of one density statement: the log-density value
/// plus its partials w.r.t. the point and each distribution parameter —
/// what Stan's math library computes inside a single `*_lpdf` vari. The
/// arena executors turn one of these into seed contributions instead of
/// ~20 scalar-op tape nodes.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarAdj {
    pub lp: f64,
    /// ∂ logpdf / ∂ x (for vector densities the per-component partials go
    /// into a caller buffer instead; this field stays 0).
    pub d_x: f64,
    /// ∂ logpdf / ∂ paramᵢ, in [`param_vars`](ScalarDist::param_vars) order.
    pub d_p: [f64; MAX_DIST_PARAMS],
}

impl ScalarAdj {
    fn neg_inf() -> Self {
        ScalarAdj {
            lp: f64::NEG_INFINITY,
            ..ScalarAdj::default()
        }
    }
}

/// Generic fused-adjoint fallback: differentiate a log-density written
/// once over the AD [`Scalar`] with forward duals — one pass for the point
/// and one per parameter. Custom distributions that don't provide
/// closed-form partials use this to join the fused arena tape unchanged;
/// every built-in analytic kernel is cross-checked against it in the
/// tests.
pub fn scalar_adj_via_dual<F>(f: F, x: f64, params: &[f64]) -> ScalarAdj
where
    F: Fn(Dual, &[Dual]) -> Dual,
{
    debug_assert!(params.len() <= MAX_DIST_PARAMS);
    let mut pd = [Dual::constant(0.0); MAX_DIST_PARAMS];
    for (slot, &p) in pd.iter_mut().zip(params) {
        *slot = Dual::constant(p);
    }
    let out = f(Dual::var(x), &pd[..params.len()]);
    let mut adj = ScalarAdj {
        lp: out.v,
        d_x: out.d,
        d_p: [0.0; MAX_DIST_PARAMS],
    };
    for i in 0..params.len() {
        pd[i].d = 1.0;
        adj.d_p[i] = f(Dual::constant(x), &pd[..params.len()]).d;
        pd[i].d = 0.0;
    }
    adj
}

/// Support metadata for one random variable: what the bijector needs to
/// map it to unconstrained coordinates, and what the trace layout records.
#[derive(Clone, Debug, PartialEq)]
pub enum Domain {
    /// ℝ (identity transform).
    Real,
    /// (0, ∞) (log transform).
    Positive,
    /// (lo, hi) (scaled-logit transform).
    Interval(f64, f64),
    /// ℝⁿ.
    RealVec(usize),
    /// (0, ∞)ⁿ (elementwise log).
    PositiveVec(usize),
    /// The (n−1)-simplex embedded in ℝⁿ (stick-breaking transform).
    Simplex(usize),
    /// {0, 1}.
    DiscreteBool,
    /// {0, …, k−1}.
    DiscreteCategory(usize),
    /// ℕ (unbounded counts; observation-only in the benchmark set).
    DiscreteCount,
}

impl Domain {
    /// True for integer-valued supports (never HMC coordinates).
    pub fn is_discrete(&self) -> bool {
        matches!(
            self,
            Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount
        )
    }

    /// Number of unconstrained (ℝ) coordinates the value flattens to.
    pub fn unconstrained_dim(&self) -> usize {
        match self {
            Domain::Real | Domain::Positive | Domain::Interval(_, _) => 1,
            Domain::RealVec(n) | Domain::PositiveVec(n) => *n,
            Domain::Simplex(n) => n - 1,
            Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount => 0,
        }
    }

    /// Structural compatibility: same support variant and dimensions.
    /// `Interval` bounds are **not** compared — distribution parameters may
    /// depend on other parameters (`Uniform(0, theta)`) without changing
    /// the trace layout, so the typed replay path treats them as the same
    /// slot shape. Strict equality (`==`) is what layout *specialization*
    /// checks; this is what per-visit cursor walks check.
    pub fn compatible(&self, other: &Domain) -> bool {
        match (self, other) {
            (Domain::Real, Domain::Real)
            | (Domain::Positive, Domain::Positive)
            | (Domain::Interval(_, _), Domain::Interval(_, _))
            | (Domain::DiscreteBool, Domain::DiscreteBool)
            | (Domain::DiscreteCount, Domain::DiscreteCount) => true,
            (Domain::RealVec(a), Domain::RealVec(b))
            | (Domain::PositiveVec(a), Domain::PositiveVec(b))
            | (Domain::Simplex(a), Domain::Simplex(b))
            | (Domain::DiscreteCategory(a), Domain::DiscreteCategory(b)) => a == b,
            _ => false,
        }
    }

    /// Number of constrained scalar elements of the value.
    pub fn constrained_dim(&self) -> usize {
        match self {
            Domain::Real | Domain::Positive | Domain::Interval(_, _) => 1,
            Domain::RealVec(n) | Domain::PositiveVec(n) | Domain::Simplex(n) => *n,
            Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount => 0,
        }
    }
}

// ------------------------------------------------------------------ scalar

/// Normal(mean, sd).
#[derive(Clone, Copy, Debug)]
pub struct Normal<T: Scalar> {
    pub mean: T,
    pub sd: T,
}

impl<T: Scalar> Normal<T> {
    pub fn new(mean: T, sd: T) -> Self {
        Self { mean, sd }
    }

    /// Standard normal.
    pub fn std() -> Self {
        Self {
            mean: T::constant(0.0),
            sd: T::constant(1.0),
        }
    }

    pub fn logpdf(&self, x: T) -> T {
        if self.sd.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let z = (x - self.mean) / self.sd;
        -(z * z) * 0.5 - self.sd.ln() - 0.5 * math::LN_2PI
    }
}

/// InverseGamma(shape α, scale β): density ∝ x^{−α−1} e^{−β/x}.
#[derive(Clone, Copy, Debug)]
pub struct InverseGamma<T: Scalar> {
    pub shape: T,
    pub scale: T,
}

impl<T: Scalar> InverseGamma<T> {
    pub fn new(shape: T, scale: T) -> Self {
        Self { shape, scale }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.shape * self.scale.ln() - self.shape.lgamma()
            - (self.shape + 1.0) * x.ln()
            - self.scale / x
    }
}

/// Gamma(shape α, rate β): mean α/β.
#[derive(Clone, Copy, Debug)]
pub struct Gamma<T: Scalar> {
    pub shape: T,
    pub rate: T,
}

impl<T: Scalar> Gamma<T> {
    pub fn new(shape: T, rate: T) -> Self {
        Self { shape, rate }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.shape * self.rate.ln() - self.shape.lgamma()
            + (self.shape - 1.0) * x.ln()
            - self.rate * x
    }
}

/// Beta(a, b) on (0, 1).
#[derive(Clone, Copy, Debug)]
pub struct Beta<T: Scalar> {
    pub a: T,
    pub b: T,
}

impl<T: Scalar> Beta<T> {
    pub fn new(a: T, b: T) -> Self {
        Self { a, b }
    }

    pub fn logpdf(&self, x: T) -> T {
        let xv = x.value();
        if xv <= 0.0 || xv >= 1.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let lbeta = self.a.lgamma() + self.b.lgamma() - (self.a + self.b).lgamma();
        (self.a - 1.0) * x.ln() + (self.b - 1.0) * (T::constant(1.0) - x).ln() - lbeta
    }
}

/// Exponential(rate λ): mean 1/λ.
#[derive(Clone, Copy, Debug)]
pub struct Exponential<T: Scalar> {
    pub rate: T,
}

impl<T: Scalar> Exponential<T> {
    pub fn new(rate: T) -> Self {
        Self { rate }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() < 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.rate.ln() - self.rate * x
    }
}

/// Uniform(lo, hi).
#[derive(Clone, Copy, Debug)]
pub struct Uniform<T: Scalar> {
    pub lo: T,
    pub hi: T,
}

impl<T: Scalar> Uniform<T> {
    pub fn new(lo: T, hi: T) -> Self {
        Self { lo, hi }
    }

    pub fn logpdf(&self, x: T) -> T {
        let xv = x.value();
        if xv < self.lo.value() || xv > self.hi.value() {
            return T::constant(f64::NEG_INFINITY);
        }
        -((self.hi - self.lo).ln())
    }
}

/// Cauchy(loc, scale).
#[derive(Clone, Copy, Debug)]
pub struct Cauchy<T: Scalar> {
    pub loc: T,
    pub scale: T,
}

impl<T: Scalar> Cauchy<T> {
    pub fn new(loc: T, scale: T) -> Self {
        Self { loc, scale }
    }

    pub fn logpdf(&self, x: T) -> T {
        let z = (x - self.loc) / self.scale;
        T::constant(-math::LN_PI) - self.scale.ln() - (z * z).ln_1p()
    }
}

/// HalfCauchy(scale): |Cauchy(0, scale)|, supported on [0, ∞).
#[derive(Clone, Copy, Debug)]
pub struct HalfCauchy<T: Scalar> {
    pub scale: T,
}

impl<T: Scalar> HalfCauchy<T> {
    pub fn new(scale: T) -> Self {
        Self { scale }
    }

    pub fn logpdf(&self, x: T) -> T {
        if x.value() < 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let z = x / self.scale;
        T::constant(std::f64::consts::LN_2 - math::LN_PI) - self.scale.ln() - (z * z).ln_1p()
    }
}

/// Univariate continuous distributions.
#[derive(Clone, Debug)]
pub enum ScalarDist<T: Scalar> {
    Normal(Normal<T>),
    InverseGamma(InverseGamma<T>),
    Gamma(Gamma<T>),
    Beta(Beta<T>),
    Exponential(Exponential<T>),
    Uniform(Uniform<T>),
    Cauchy(Cauchy<T>),
    HalfCauchy(HalfCauchy<T>),
}

impl<T: Scalar> ScalarDist<T> {
    pub fn logpdf(&self, x: T) -> T {
        match self {
            ScalarDist::Normal(d) => d.logpdf(x),
            ScalarDist::InverseGamma(d) => d.logpdf(x),
            ScalarDist::Gamma(d) => d.logpdf(x),
            ScalarDist::Beta(d) => d.logpdf(x),
            ScalarDist::Exponential(d) => d.logpdf(x),
            ScalarDist::Uniform(d) => d.logpdf(x),
            ScalarDist::Cauchy(d) => d.logpdf(x),
            ScalarDist::HalfCauchy(d) => d.logpdf(x),
        }
    }

    pub fn domain(&self) -> Domain {
        match self {
            ScalarDist::Normal(_) | ScalarDist::Cauchy(_) => Domain::Real,
            ScalarDist::InverseGamma(_)
            | ScalarDist::Gamma(_)
            | ScalarDist::Exponential(_)
            | ScalarDist::HalfCauchy(_) => Domain::Positive,
            ScalarDist::Beta(_) => Domain::Interval(0.0, 1.0),
            ScalarDist::Uniform(d) => Domain::Interval(d.lo.value(), d.hi.value()),
        }
    }

    /// The distribution's scalar parameters (copies) and their count, in
    /// the order [`logpdf_adj`](Self::logpdf_adj) reports partials.
    pub fn param_vars(&self) -> ([T; MAX_DIST_PARAMS], usize) {
        let zero = T::constant(0.0);
        match self {
            ScalarDist::Normal(d) => ([d.mean, d.sd], 2),
            ScalarDist::InverseGamma(d) => ([d.shape, d.scale], 2),
            ScalarDist::Gamma(d) => ([d.shape, d.rate], 2),
            ScalarDist::Beta(d) => ([d.a, d.b], 2),
            ScalarDist::Exponential(d) => ([d.rate, zero], 1),
            ScalarDist::Uniform(d) => ([d.lo, d.hi], 2),
            ScalarDist::Cauchy(d) => ([d.loc, d.scale], 2),
            ScalarDist::HalfCauchy(d) => ([d.scale, zero], 1),
        }
    }

    /// Rebuild this distribution with plain-`f64` parameters `p`, in
    /// [`param_vars`](Self::param_vars) order. The lane-batched executors
    /// use this to evaluate one lane's fused kernel: read each tracked
    /// parameter's lane value, rebuild, call [`logpdf_adj`](Self::logpdf_adj)
    /// — identical arithmetic to the sequential fused path.
    pub fn with_f64_params(&self, p: &[f64; MAX_DIST_PARAMS]) -> ScalarDist<f64> {
        match self {
            ScalarDist::Normal(_) => ScalarDist::Normal(Normal::new(p[0], p[1])),
            ScalarDist::InverseGamma(_) => ScalarDist::InverseGamma(InverseGamma::new(p[0], p[1])),
            ScalarDist::Gamma(_) => ScalarDist::Gamma(Gamma::new(p[0], p[1])),
            ScalarDist::Beta(_) => ScalarDist::Beta(Beta::new(p[0], p[1])),
            ScalarDist::Exponential(_) => ScalarDist::Exponential(Exponential::new(p[0])),
            ScalarDist::Uniform(_) => ScalarDist::Uniform(Uniform::new(p[0], p[1])),
            ScalarDist::Cauchy(_) => ScalarDist::Cauchy(Cauchy::new(p[0], p[1])),
            ScalarDist::HalfCauchy(_) => ScalarDist::HalfCauchy(HalfCauchy::new(p[0])),
        }
    }

    /// Rebuild this distribution over any scalar type from parameters in
    /// [`param_vars`](Self::param_vars) order — the compiled executor uses
    /// this to re-seat a recorded site's template on live arena variables.
    pub fn with_params<U: Scalar>(&self, p: &[U; MAX_DIST_PARAMS]) -> ScalarDist<U> {
        match self {
            ScalarDist::Normal(_) => ScalarDist::Normal(Normal::new(p[0], p[1])),
            ScalarDist::InverseGamma(_) => ScalarDist::InverseGamma(InverseGamma::new(p[0], p[1])),
            ScalarDist::Gamma(_) => ScalarDist::Gamma(Gamma::new(p[0], p[1])),
            ScalarDist::Beta(_) => ScalarDist::Beta(Beta::new(p[0], p[1])),
            ScalarDist::Exponential(_) => ScalarDist::Exponential(Exponential::new(p[0])),
            ScalarDist::Uniform(_) => ScalarDist::Uniform(Uniform::new(p[0], p[1])),
            ScalarDist::Cauchy(_) => ScalarDist::Cauchy(Cauchy::new(p[0], p[1])),
            ScalarDist::HalfCauchy(_) => ScalarDist::HalfCauchy(HalfCauchy::new(p[0])),
        }
    }

    /// Fused analytic adjoint: logpdf value + partials w.r.t. `x` and each
    /// parameter, all in one pass over primal values. Mirrors the guard
    /// branches of the generic `logpdf` exactly (out-of-support → −∞ with
    /// zero partials). Custom distributions can default to
    /// [`scalar_adj_via_dual`]; every kernel here is the closed form.
    pub fn logpdf_adj(&self, x: f64) -> ScalarAdj {
        let mut adj = ScalarAdj::default();
        match self {
            ScalarDist::Normal(d) => {
                let (m, s) = (d.mean.value(), d.sd.value());
                if s <= 0.0 {
                    return ScalarAdj::neg_inf();
                }
                let z = (x - m) / s;
                adj.lp = -0.5 * z * z - s.ln() - 0.5 * math::LN_2PI;
                adj.d_x = -z / s;
                adj.d_p[0] = z / s;
                adj.d_p[1] = (z * z - 1.0) / s;
            }
            ScalarDist::InverseGamma(d) => {
                let (a, b) = (d.shape.value(), d.scale.value());
                if x <= 0.0 {
                    return ScalarAdj::neg_inf();
                }
                adj.lp = a * b.ln() - math::lgamma(a) - (a + 1.0) * x.ln() - b / x;
                adj.d_x = -(a + 1.0) / x + b / (x * x);
                adj.d_p[0] = b.ln() - math::digamma(a) - x.ln();
                adj.d_p[1] = a / b - 1.0 / x;
            }
            ScalarDist::Gamma(d) => {
                let (a, r) = (d.shape.value(), d.rate.value());
                if x <= 0.0 {
                    return ScalarAdj::neg_inf();
                }
                adj.lp = a * r.ln() - math::lgamma(a) + (a - 1.0) * x.ln() - r * x;
                adj.d_x = (a - 1.0) / x - r;
                adj.d_p[0] = r.ln() - math::digamma(a) + x.ln();
                adj.d_p[1] = a / r - x;
            }
            ScalarDist::Beta(d) => {
                let (a, b) = (d.a.value(), d.b.value());
                if x <= 0.0 || x >= 1.0 {
                    return ScalarAdj::neg_inf();
                }
                adj.lp = (a - 1.0) * x.ln() + (b - 1.0) * (1.0 - x).ln() - math::lgamma(a)
                    - math::lgamma(b)
                    + math::lgamma(a + b);
                adj.d_x = (a - 1.0) / x - (b - 1.0) / (1.0 - x);
                let dig_ab = math::digamma(a + b);
                adj.d_p[0] = x.ln() - math::digamma(a) + dig_ab;
                adj.d_p[1] = (1.0 - x).ln() - math::digamma(b) + dig_ab;
            }
            ScalarDist::Exponential(d) => {
                let r = d.rate.value();
                if x < 0.0 {
                    return ScalarAdj::neg_inf();
                }
                adj.lp = r.ln() - r * x;
                adj.d_x = -r;
                adj.d_p[0] = 1.0 / r - x;
            }
            ScalarDist::Uniform(d) => {
                let (lo, hi) = (d.lo.value(), d.hi.value());
                if x < lo || x > hi {
                    return ScalarAdj::neg_inf();
                }
                let w = hi - lo;
                adj.lp = -w.ln();
                adj.d_p[0] = 1.0 / w;
                adj.d_p[1] = -1.0 / w;
            }
            ScalarDist::Cauchy(d) => {
                let (l, s) = (d.loc.value(), d.scale.value());
                let z = (x - l) / s;
                let den = s * (1.0 + z * z);
                adj.lp = -math::LN_PI - s.ln() - (z * z).ln_1p();
                adj.d_x = -2.0 * z / den;
                adj.d_p[0] = 2.0 * z / den;
                adj.d_p[1] = -1.0 / s + 2.0 * z * z / den;
            }
            ScalarDist::HalfCauchy(d) => {
                let s = d.scale.value();
                if x < 0.0 {
                    return ScalarAdj::neg_inf();
                }
                let z = x / s;
                let den = s * (1.0 + z * z);
                adj.lp = std::f64::consts::LN_2 - math::LN_PI - s.ln() - (z * z).ln_1p();
                adj.d_x = -2.0 * z / den;
                adj.d_p[0] = -1.0 / s + 2.0 * z * z / den;
            }
        }
        adj
    }
}

impl ScalarDist<f64> {
    /// Box into the dynamically-typed form stored in `UntypedVarInfo`.
    pub fn boxed(&self) -> AnyDist {
        AnyDist::Scalar(self.clone())
    }

    /// Row-batched fused adjoint for an observation *plate*: all rows share
    /// this distribution's parameters, so pure-parameter subexpressions
    /// (`ln`, `lgamma`, `digamma` of the parameters) are hoisted out of the
    /// loop once. Every per-row operation is kept textually identical to
    /// [`logpdf_adj`](Self::logpdf_adj) — same order, same divisions — so
    /// each row's `lp`/`d_p` is **bitwise** equal to the sequential kernel.
    /// `d_x` is not produced: plate rows are data, never parameters.
    pub fn logpdf_adj_rows(
        &self,
        xs: &[f64],
        lp: &mut [f64],
        d_p: &mut [[f64; MAX_DIST_PARAMS]],
    ) {
        debug_assert_eq!(xs.len(), lp.len());
        debug_assert_eq!(xs.len(), d_p.len());
        match self {
            ScalarDist::Normal(d) => {
                let (m, s) = (d.mean, d.sd);
                if s <= 0.0 {
                    lp.fill(f64::NEG_INFINITY);
                    d_p.fill([0.0; MAX_DIST_PARAMS]);
                    return;
                }
                let s_ln = s.ln();
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    let z = (x - m) / s;
                    *l = -0.5 * z * z - s_ln - 0.5 * math::LN_2PI;
                    dp[0] = z / s;
                    dp[1] = (z * z - 1.0) / s;
                }
            }
            ScalarDist::InverseGamma(d) => {
                let (a, b) = (d.shape, d.scale);
                let b_ln = b.ln();
                let head = a * b_ln - math::lgamma(a);
                let a1 = a + 1.0;
                let c0 = b_ln - math::digamma(a);
                let a_over_b = a / b;
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    if x <= 0.0 {
                        *l = f64::NEG_INFINITY;
                        *dp = [0.0; MAX_DIST_PARAMS];
                        continue;
                    }
                    let x_ln = x.ln();
                    *l = head - a1 * x_ln - b / x;
                    dp[0] = c0 - x_ln;
                    dp[1] = a_over_b - 1.0 / x;
                }
            }
            ScalarDist::Gamma(d) => {
                let (a, r) = (d.shape, d.rate);
                let r_ln = r.ln();
                let head = a * r_ln - math::lgamma(a);
                let am1 = a - 1.0;
                let c0 = r_ln - math::digamma(a);
                let a_over_r = a / r;
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    if x <= 0.0 {
                        *l = f64::NEG_INFINITY;
                        *dp = [0.0; MAX_DIST_PARAMS];
                        continue;
                    }
                    let x_ln = x.ln();
                    *l = head + am1 * x_ln - r * x;
                    dp[0] = c0 + x_ln;
                    dp[1] = a_over_r - x;
                }
            }
            ScalarDist::Beta(d) => {
                let (a, b) = (d.a, d.b);
                let (am1, bm1) = (a - 1.0, b - 1.0);
                let (lg_a, lg_b, lg_ab) = (math::lgamma(a), math::lgamma(b), math::lgamma(a + b));
                let (dg_a, dg_b) = (math::digamma(a), math::digamma(b));
                let dig_ab = math::digamma(a + b);
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    if x <= 0.0 || x >= 1.0 {
                        *l = f64::NEG_INFINITY;
                        *dp = [0.0; MAX_DIST_PARAMS];
                        continue;
                    }
                    let x_ln = x.ln();
                    let omx_ln = (1.0 - x).ln();
                    *l = am1 * x_ln + bm1 * omx_ln - lg_a - lg_b + lg_ab;
                    dp[0] = x_ln - dg_a + dig_ab;
                    dp[1] = omx_ln - dg_b + dig_ab;
                }
            }
            ScalarDist::Exponential(d) => {
                let r = d.rate;
                let r_ln = r.ln();
                let inv_r = 1.0 / r;
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    if x < 0.0 {
                        *l = f64::NEG_INFINITY;
                        *dp = [0.0; MAX_DIST_PARAMS];
                        continue;
                    }
                    *l = r_ln - r * x;
                    dp[0] = inv_r - x;
                    dp[1] = 0.0;
                }
            }
            ScalarDist::Uniform(d) => {
                let (lo, hi) = (d.lo, d.hi);
                let w = hi - lo;
                let lp_c = -w.ln();
                let (dp0, dp1) = (1.0 / w, -1.0 / w);
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    if x < lo || x > hi {
                        *l = f64::NEG_INFINITY;
                        *dp = [0.0; MAX_DIST_PARAMS];
                        continue;
                    }
                    *l = lp_c;
                    dp[0] = dp0;
                    dp[1] = dp1;
                }
            }
            ScalarDist::Cauchy(d) => {
                let (loc, s) = (d.loc, d.scale);
                let head = -math::LN_PI - s.ln();
                let neg_inv_s = -1.0 / s;
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    let z = (x - loc) / s;
                    let den = s * (1.0 + z * z);
                    *l = head - (z * z).ln_1p();
                    dp[0] = 2.0 * z / den;
                    dp[1] = neg_inv_s + 2.0 * z * z / den;
                }
            }
            ScalarDist::HalfCauchy(d) => {
                let s = d.scale;
                let head = std::f64::consts::LN_2 - math::LN_PI - s.ln();
                let neg_inv_s = -1.0 / s;
                for ((&x, l), dp) in xs.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    if x < 0.0 {
                        *l = f64::NEG_INFINITY;
                        *dp = [0.0; MAX_DIST_PARAMS];
                        continue;
                    }
                    let z = x / s;
                    let den = s * (1.0 + z * z);
                    *l = head - (z * z).ln_1p();
                    dp[0] = neg_inv_s + 2.0 * z * z / den;
                    dp[1] = 0.0;
                }
            }
        }
    }

    /// Draw one value (prior sampling / particle regeneration).
    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> f64 {
        match self {
            ScalarDist::Normal(d) => d.mean + d.sd * rng.normal(),
            ScalarDist::InverseGamma(d) => d.scale / rng.gamma(d.shape),
            ScalarDist::Gamma(d) => rng.gamma(d.shape) / d.rate,
            ScalarDist::Beta(d) => rng.beta(d.a, d.b),
            ScalarDist::Exponential(d) => rng.exponential() / d.rate,
            ScalarDist::Uniform(d) => rng.uniform_range(d.lo, d.hi),
            ScalarDist::Cauchy(d) => {
                d.loc + d.scale * (std::f64::consts::PI * (rng.uniform() - 0.5)).tan()
            }
            ScalarDist::HalfCauchy(d) => {
                (d.scale * (std::f64::consts::PI * (rng.uniform() - 0.5)).tan()).abs()
            }
        }
    }
}

// ------------------------------------------------------------------ vector

/// Isotropic normal: n iid Normal(mean, sd) coordinates.
#[derive(Clone, Copy, Debug)]
pub struct IsoNormal<T: Scalar> {
    pub mean: T,
    pub sd: T,
    pub n: usize,
}

impl<T: Scalar> IsoNormal<T> {
    pub fn new(mean: T, sd: T, n: usize) -> Self {
        Self { mean, sd, n }
    }

    pub fn logpdf(&self, x: &[T]) -> T {
        debug_assert_eq!(x.len(), self.n);
        if self.sd.value() <= 0.0 {
            return T::constant(f64::NEG_INFINITY);
        }
        let mut ss = T::constant(0.0);
        for &xi in x {
            let z = (xi - self.mean) / self.sd;
            ss = ss + z * z;
        }
        let n = self.n as f64;
        ss * (-0.5) - self.sd.ln() * n - 0.5 * math::LN_2PI * n
    }
}

/// Dirichlet(α) over the (n−1)-simplex. α is data (never a parameter in
/// the benchmark set), so it stays `f64`.
#[derive(Clone, Debug)]
pub struct Dirichlet {
    pub alpha: Vec<f64>,
}

impl Dirichlet {
    pub fn new(alpha: Vec<f64>) -> Self {
        assert!(!alpha.is_empty() && alpha.iter().all(|&a| a > 0.0));
        Self { alpha }
    }

    /// Symmetric Dirichlet(a, …, a) of length n.
    pub fn symmetric(a: f64, n: usize) -> Self {
        Self::new(vec![a; n])
    }

    pub fn logpdf<T: Scalar>(&self, x: &[T]) -> T {
        debug_assert_eq!(x.len(), self.alpha.len());
        let mut lp = T::constant(self.log_norm());
        for (&a, &xi) in self.alpha.iter().zip(x) {
            if xi.value() <= 0.0 {
                return T::constant(f64::NEG_INFINITY);
            }
            // skip α=1 terms: exact zero, and avoids 0·ln(x) tape nodes
            if a != 1.0 {
                lp = lp + xi.ln() * (a - 1.0);
            }
        }
        lp
    }

    /// lnΓ(Σα) − Σ lnΓ(αᵢ).
    fn log_norm(&self) -> f64 {
        let sum: f64 = self.alpha.iter().sum();
        math::lgamma(sum) - self.alpha.iter().map(|&a| math::lgamma(a)).sum::<f64>()
    }
}

/// Fixed-length multivariate distributions.
#[derive(Clone, Debug)]
pub enum VecDist<T: Scalar> {
    IsoNormal(IsoNormal<T>),
    Dirichlet(Dirichlet),
}

impl<T: Scalar> VecDist<T> {
    pub fn logpdf(&self, x: &[T]) -> T {
        match self {
            VecDist::IsoNormal(d) => d.logpdf(x),
            VecDist::Dirichlet(d) => d.logpdf(x),
        }
    }

    pub fn domain(&self) -> Domain {
        match self {
            VecDist::IsoNormal(d) => Domain::RealVec(d.n),
            VecDist::Dirichlet(d) => Domain::Simplex(d.alpha.len()),
        }
    }

    /// Length of the constrained value vector.
    pub fn len(&self) -> usize {
        match self {
            VecDist::IsoNormal(d) => d.n,
            VecDist::Dirichlet(d) => d.alpha.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Scalar parameters (copies) and their count; Dirichlet α is data.
    pub fn param_vars(&self) -> ([T; MAX_DIST_PARAMS], usize) {
        let zero = T::constant(0.0);
        match self {
            VecDist::IsoNormal(d) => ([d.mean, d.sd], 2),
            VecDist::Dirichlet(_) => ([zero, zero], 0),
        }
    }

    /// Rebuild with plain-`f64` parameters in [`param_vars`](Self::param_vars)
    /// order; data-side structure (lengths, Dirichlet α) carries over. See
    /// [`ScalarDist::with_f64_params`].
    pub fn with_f64_params(&self, p: &[f64; MAX_DIST_PARAMS]) -> VecDist<f64> {
        match self {
            VecDist::IsoNormal(d) => VecDist::IsoNormal(IsoNormal::new(p[0], p[1], d.n)),
            VecDist::Dirichlet(d) => VecDist::Dirichlet(d.clone()),
        }
    }

    /// Rebuild over any scalar type from parameters in
    /// [`param_vars`](Self::param_vars) order; data-side structure (lengths,
    /// Dirichlet α) carries over. See [`ScalarDist::with_params`].
    pub fn with_params<U: Scalar>(&self, p: &[U; MAX_DIST_PARAMS]) -> VecDist<U> {
        match self {
            VecDist::IsoNormal(d) => VecDist::IsoNormal(IsoNormal::new(p[0], p[1], d.n)),
            VecDist::Dirichlet(d) => VecDist::Dirichlet(d.clone()),
        }
    }

    /// Fused analytic adjoint of a vector log-density: per-component
    /// partials go into `d_x` (overwritten, `len()` entries), parameter
    /// partials into the returned [`ScalarAdj::d_p`]. Guard branches
    /// mirror the generic `logpdf` (−∞ with zeroed partials).
    pub fn logpdf_adj(&self, x: &[f64], d_x: &mut [f64]) -> ScalarAdj {
        debug_assert_eq!(x.len(), self.len());
        debug_assert_eq!(d_x.len(), self.len());
        d_x.fill(0.0);
        let mut adj = ScalarAdj::default();
        match self {
            VecDist::IsoNormal(d) => {
                let (m, s) = (d.mean.value(), d.sd.value());
                if s <= 0.0 {
                    return ScalarAdj::neg_inf();
                }
                let mut ss = 0.0;
                for (g, &xi) in d_x.iter_mut().zip(x) {
                    let z = (xi - m) / s;
                    ss += z * z;
                    *g = -z / s;
                    adj.d_p[0] += z / s;
                    adj.d_p[1] += (z * z - 1.0) / s;
                }
                let n = d.n as f64;
                adj.lp = -0.5 * ss - n * s.ln() - 0.5 * math::LN_2PI * n;
            }
            VecDist::Dirichlet(d) => {
                let mut lp = d.log_norm();
                for ((g, &a), &xi) in d_x.iter_mut().zip(&d.alpha).zip(x) {
                    if xi <= 0.0 {
                        return ScalarAdj::neg_inf();
                    }
                    // α=1 terms are exactly zero — same skip rule as the
                    // generic logpdf, so values agree bitwise
                    if a != 1.0 {
                        lp += (a - 1.0) * xi.ln();
                        *g = (a - 1.0) / xi;
                    }
                }
                adj.lp = lp;
            }
        }
        adj
    }
}

impl VecDist<f64> {
    pub fn boxed(&self) -> AnyDist {
        AnyDist::Vector(self.clone())
    }

    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> Vec<f64> {
        match self {
            VecDist::IsoNormal(d) => (0..d.n).map(|_| d.mean + d.sd * rng.normal()).collect(),
            VecDist::Dirichlet(d) => {
                let mut out = vec![0.0; d.alpha.len()];
                rng.dirichlet_into(&d.alpha, &mut out);
                out
            }
        }
    }
}

// ---------------------------------------------------------------- discrete

/// Bernoulli(p) over {0, 1}.
#[derive(Clone, Copy, Debug)]
pub struct Bernoulli<T: Scalar> {
    pub p: T,
}

impl<T: Scalar> Bernoulli<T> {
    pub fn new(p: T) -> Self {
        Self { p }
    }

    pub fn logpmf(&self, k: i64) -> T {
        match k {
            1 => self.p.ln(),
            0 => (T::constant(1.0) - self.p).ln(),
            _ => T::constant(f64::NEG_INFINITY),
        }
    }
}

/// Bernoulli on the logit scale: P(1) = σ(logit).
#[derive(Clone, Copy, Debug)]
pub struct BernoulliLogit<T: Scalar> {
    pub logit: T,
}

impl<T: Scalar> BernoulliLogit<T> {
    pub fn new(logit: T) -> Self {
        Self { logit }
    }

    pub fn logpmf(&self, k: i64) -> T {
        match k {
            1 => self.logit.log_sigmoid(),
            0 => (-self.logit).log_sigmoid(),
            _ => T::constant(f64::NEG_INFINITY),
        }
    }
}

/// Poisson(rate λ).
#[derive(Clone, Copy, Debug)]
pub struct Poisson<T: Scalar> {
    pub rate: T,
}

impl<T: Scalar> Poisson<T> {
    pub fn new(rate: T) -> Self {
        Self { rate }
    }

    pub fn logpmf(&self, k: i64) -> T {
        if k < 0 {
            return T::constant(f64::NEG_INFINITY);
        }
        self.rate.ln() * (k as f64) - self.rate - math::ln_factorial(k as u64)
    }
}

/// Categorical over {0, …, K−1} with fixed (data-side) probabilities.
#[derive(Clone, Debug)]
pub struct Categorical {
    pub probs: Vec<f64>,
}

impl Categorical {
    /// Normalize (possibly unnormalized) probabilities.
    pub fn from_probs(probs: &[f64]) -> Self {
        let total: f64 = probs.iter().sum();
        assert!(total > 0.0, "categorical probabilities sum to zero");
        Self {
            probs: probs.iter().map(|&p| p / total).collect(),
        }
    }

    pub fn logpmf<T: Scalar>(&self, k: i64) -> T {
        if k < 0 || k as usize >= self.probs.len() {
            return T::constant(f64::NEG_INFINITY);
        }
        T::constant(self.probs[k as usize].ln())
    }
}

/// Integer-valued distributions.
#[derive(Clone, Debug)]
pub enum DiscreteDist<T: Scalar> {
    Bernoulli(Bernoulli<T>),
    BernoulliLogit(BernoulliLogit<T>),
    Poisson(Poisson<T>),
    Categorical(Categorical),
}

impl<T: Scalar> DiscreteDist<T> {
    pub fn logpmf(&self, k: i64) -> T {
        match self {
            DiscreteDist::Bernoulli(d) => d.logpmf(k),
            DiscreteDist::BernoulliLogit(d) => d.logpmf(k),
            DiscreteDist::Poisson(d) => d.logpmf(k),
            DiscreteDist::Categorical(d) => d.logpmf(k),
        }
    }

    pub fn domain(&self) -> Domain {
        match self {
            DiscreteDist::Bernoulli(_) | DiscreteDist::BernoulliLogit(_) => Domain::DiscreteBool,
            DiscreteDist::Poisson(_) => Domain::DiscreteCount,
            DiscreteDist::Categorical(d) => Domain::DiscreteCategory(d.probs.len()),
        }
    }

    /// The (single, optional) scalar parameter; Categorical probs are data.
    pub fn param_var(&self) -> Option<T> {
        match self {
            DiscreteDist::Bernoulli(d) => Some(d.p),
            DiscreteDist::BernoulliLogit(d) => Some(d.logit),
            DiscreteDist::Poisson(d) => Some(d.rate),
            DiscreteDist::Categorical(_) => None,
        }
    }

    /// Rebuild with a plain-`f64` parameter (see [`param_var`](Self::param_var));
    /// Categorical probs are data and carry over. See
    /// [`ScalarDist::with_f64_params`].
    pub fn with_f64_param(&self, p: f64) -> DiscreteDist<f64> {
        match self {
            DiscreteDist::Bernoulli(_) => DiscreteDist::Bernoulli(Bernoulli::new(p)),
            DiscreteDist::BernoulliLogit(_) => DiscreteDist::BernoulliLogit(BernoulliLogit::new(p)),
            DiscreteDist::Poisson(_) => DiscreteDist::Poisson(Poisson::new(p)),
            DiscreteDist::Categorical(d) => DiscreteDist::Categorical(d.clone()),
        }
    }

    /// Rebuild over any scalar type (see [`param_var`](Self::param_var));
    /// the compiled executor uses this to re-seat a recorded site's
    /// template on a live arena variable.
    pub fn with_param<U: Scalar>(&self, p: U) -> DiscreteDist<U> {
        match self {
            DiscreteDist::Bernoulli(_) => DiscreteDist::Bernoulli(Bernoulli::new(p)),
            DiscreteDist::BernoulliLogit(_) => DiscreteDist::BernoulliLogit(BernoulliLogit::new(p)),
            DiscreteDist::Poisson(_) => DiscreteDist::Poisson(Poisson::new(p)),
            DiscreteDist::Categorical(d) => DiscreteDist::Categorical(d.clone()),
        }
    }

    /// Fused analytic adjoint: `(logpmf, ∂logpmf/∂param)`. Out-of-support
    /// `k` gives `(−∞, 0)`, matching the generic `logpmf` guards.
    pub fn logpmf_adj(&self, k: i64) -> (f64, f64) {
        match self {
            DiscreteDist::Bernoulli(d) => {
                let p = d.p.value();
                match k {
                    1 => (p.ln(), 1.0 / p),
                    0 => ((1.0 - p).ln(), -1.0 / (1.0 - p)),
                    _ => (f64::NEG_INFINITY, 0.0),
                }
            }
            DiscreteDist::BernoulliLogit(d) => {
                let l = d.logit.value();
                match k {
                    1 => (math::log_sigmoid(l), math::sigmoid(-l)),
                    0 => (math::log_sigmoid(-l), -math::sigmoid(l)),
                    _ => (f64::NEG_INFINITY, 0.0),
                }
            }
            DiscreteDist::Poisson(d) => {
                let lam = d.rate.value();
                if k < 0 {
                    return (f64::NEG_INFINITY, 0.0);
                }
                (
                    lam.ln() * (k as f64) - lam - math::ln_factorial(k as u64),
                    k as f64 / lam - 1.0,
                )
            }
            DiscreteDist::Categorical(d) => (d.logpmf::<f64>(k), 0.0),
        }
    }
}

impl DiscreteDist<f64> {
    pub fn boxed(&self) -> AnyDist {
        AnyDist::Discrete(self.clone())
    }

    /// Row-batched fused adjoint for a discrete observation plate: all rows
    /// share this distribution's parameter, so pure-parameter subexpressions
    /// are hoisted out of the loop once. Per-row arithmetic is textually
    /// identical to [`logpmf_adj`](Self::logpmf_adj), so each row is
    /// **bitwise** equal to the sequential kernel.
    pub fn logpmf_adj_rows(&self, ks: &[i64], lp: &mut [f64], d_p: &mut [f64]) {
        debug_assert_eq!(ks.len(), lp.len());
        debug_assert_eq!(ks.len(), d_p.len());
        match self {
            DiscreteDist::Bernoulli(d) => {
                let p = d.p;
                let (lp1, dp1) = (p.ln(), 1.0 / p);
                let (lp0, dp0) = ((1.0 - p).ln(), -1.0 / (1.0 - p));
                for ((&k, l), dp) in ks.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    (*l, *dp) = match k {
                        1 => (lp1, dp1),
                        0 => (lp0, dp0),
                        _ => (f64::NEG_INFINITY, 0.0),
                    };
                }
            }
            DiscreteDist::BernoulliLogit(d) => {
                let l0 = d.logit;
                let (lp1, dp1) = (math::log_sigmoid(l0), math::sigmoid(-l0));
                let (lp0, dp0) = (math::log_sigmoid(-l0), -math::sigmoid(l0));
                for ((&k, l), dp) in ks.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    (*l, *dp) = match k {
                        1 => (lp1, dp1),
                        0 => (lp0, dp0),
                        _ => (f64::NEG_INFINITY, 0.0),
                    };
                }
            }
            DiscreteDist::Poisson(d) => {
                let lam = d.rate;
                let lam_ln = lam.ln();
                for ((&k, l), dp) in ks.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    if k < 0 {
                        (*l, *dp) = (f64::NEG_INFINITY, 0.0);
                        continue;
                    }
                    *l = lam_ln * (k as f64) - lam - math::ln_factorial(k as u64);
                    *dp = k as f64 / lam - 1.0;
                }
            }
            DiscreteDist::Categorical(d) => {
                for ((&k, l), dp) in ks.iter().zip(lp.iter_mut()).zip(d_p.iter_mut()) {
                    *l = d.logpmf::<f64>(k);
                    *dp = 0.0;
                }
            }
        }
    }

    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> i64 {
        match self {
            DiscreteDist::Bernoulli(d) => rng.bernoulli(d.p) as i64,
            DiscreteDist::BernoulliLogit(d) => rng.bernoulli(math::sigmoid(d.logit)) as i64,
            DiscreteDist::Poisson(d) => rng.poisson(d.rate) as i64,
            DiscreteDist::Categorical(d) => rng.categorical(&d.probs) as i64,
        }
    }
}

// ------------------------------------------------------------------- boxed

/// The dynamically-typed (boxed, `f64`-specialized) distribution stored in
/// `UntypedVarInfo` records — the paper's abstract-element-type storage.
#[derive(Clone, Debug)]
pub enum AnyDist {
    Scalar(ScalarDist<f64>),
    Vector(VecDist<f64>),
    Discrete(DiscreteDist<f64>),
}

impl AnyDist {
    pub fn domain(&self) -> Domain {
        match self {
            AnyDist::Scalar(d) => d.domain(),
            AnyDist::Vector(d) => d.domain(),
            AnyDist::Discrete(d) => d.domain(),
        }
    }

    /// Draw a fresh boxed value from the distribution.
    pub fn sample<R: RngCore + ?Sized>(&self, rng: &mut R) -> Value {
        match self {
            AnyDist::Scalar(d) => Value::F64(d.sample(rng)),
            AnyDist::Vector(d) => Value::Vec(d.sample(rng)),
            AnyDist::Discrete(d) => Value::Int(d.sample(rng)),
        }
    }

    /// Log-density of a boxed value (constrained space, no Jacobian).
    pub fn logpdf(&self, v: &Value) -> f64 {
        match self {
            AnyDist::Scalar(d) => match v.as_f64() {
                Some(x) => d.logpdf(x),
                None => f64::NEG_INFINITY,
            },
            AnyDist::Vector(d) => match v.as_slice() {
                Some(x) => d.logpdf(x),
                None => f64::NEG_INFINITY,
            },
            AnyDist::Discrete(d) => match v.as_int() {
                Some(k) => d.logpmf(k),
                None => f64::NEG_INFINITY,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::forward::Dual;
    use crate::ad::finite_diff_grad;
    use crate::util::rng::Xoshiro256pp;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn normal_pins() {
        // N(0,1) at 0: -0.5 ln 2π
        close(Normal::new(0.0, 1.0).logpdf(0.0), -0.5 * math::LN_2PI, 1e-14);
        close(
            Normal::new(1.0, 2.0).logpdf(3.0),
            -0.5 - (2.0f64).ln() - 0.5 * math::LN_2PI,
            1e-14,
        );
        assert_eq!(Normal::new(0.0, 0.0).logpdf(0.0), f64::NEG_INFINITY);
        close(Normal::<f64>::std().logpdf(1.0), -0.5 - 0.5 * math::LN_2PI, 1e-14);
    }

    #[test]
    fn inverse_gamma_pins() {
        // IG(2,3) at x: 2 ln3 − lnΓ(2) − 3 ln x − 3/x
        let d = InverseGamma::new(2.0, 3.0);
        close(d.logpdf(1.0), 2.0 * 3.0f64.ln() - 3.0, 1e-13);
        assert_eq!(d.logpdf(-1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn gamma_exponential_consistency() {
        // Gamma(1, λ) = Exponential(λ)
        for &x in &[0.1, 1.0, 4.2] {
            close(
                Gamma::new(1.0, 2.5).logpdf(x),
                Exponential::new(2.5).logpdf(x),
                1e-13,
            );
        }
    }

    #[test]
    fn beta_uniform_consistency() {
        // Beta(1,1) = Uniform(0,1)
        for &x in &[0.2, 0.5, 0.9] {
            close(Beta::new(1.0, 1.0).logpdf(x), 0.0, 1e-13);
            close(Uniform::new(0.0, 1.0).logpdf(x), 0.0, 1e-14);
        }
        assert_eq!(Uniform::new(0.0, 1.0).logpdf(1.5), f64::NEG_INFINITY);
    }

    #[test]
    fn cauchy_and_half_cauchy() {
        // Cauchy(0,1) at 0: −ln π
        close(Cauchy::new(0.0, 1.0).logpdf(0.0), -math::LN_PI, 1e-14);
        // HalfCauchy doubles the density on the positive side
        close(
            HalfCauchy::new(2.0).logpdf(1.3),
            Cauchy::new(0.0, 2.0).logpdf(1.3) + 2.0f64.ln(),
            1e-13,
        );
        assert_eq!(HalfCauchy::new(1.0).logpdf(-0.1), f64::NEG_INFINITY);
    }

    #[test]
    fn iso_normal_is_sum_of_normals() {
        let d = IsoNormal::new(0.5, 1.5, 3);
        let x = [0.1, -0.2, 2.0];
        let want: f64 = x.iter().map(|&xi| Normal::new(0.5, 1.5).logpdf(xi)).sum();
        close(d.logpdf(&x), want, 1e-13);
    }

    #[test]
    fn dirichlet_uniform_is_log_gamma_k() {
        // Dirichlet(1,…,1) over the K-simplex has constant density Γ(K)
        let d = Dirichlet::symmetric(1.0, 4);
        close(d.logpdf(&[0.1f64, 0.2, 0.3, 0.4]), math::lgamma(4.0), 1e-13);
        // general α
        let d = Dirichlet::new(vec![2.0, 3.0, 0.5]);
        let x = [0.3f64, 0.5, 0.2];
        let want = math::lgamma(5.5) - math::lgamma(2.0) - math::lgamma(3.0)
            - math::lgamma(0.5)
            + 1.0 * x[0].ln()
            + 2.0 * x[1].ln()
            - 0.5 * x[2].ln();
        close(d.logpdf(&x), want, 1e-12);
        assert_eq!(
            d.logpdf(&[1.0f64, 0.0, 0.0]),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn discrete_pmfs() {
        close(Bernoulli::new(0.3).logpmf(1), 0.3f64.ln(), 1e-14);
        close(Bernoulli::new(0.3).logpmf(0), 0.7f64.ln(), 1e-14);
        assert_eq!(Bernoulli::new(0.3).logpmf(2), f64::NEG_INFINITY);
        // BernoulliLogit(logit(0.3)) == Bernoulli(0.3)
        let logit = (0.3f64 / 0.7).ln();
        close(
            BernoulliLogit::new(logit).logpmf(1),
            0.3f64.ln(),
            1e-12,
        );
        // Poisson(2) at k=3: 3 ln2 − 2 − ln 6
        close(
            Poisson::new(2.0).logpmf(3),
            3.0 * 2.0f64.ln() - 2.0 - 6.0f64.ln(),
            1e-13,
        );
        let c = Categorical::from_probs(&[1.0, 1.0, 2.0]);
        close(c.logpmf::<f64>(2), 0.5f64.ln(), 1e-14);
        assert_eq!(c.logpmf::<f64>(3), f64::NEG_INFINITY);
    }

    #[test]
    fn domains_are_consistent() {
        assert_eq!(ScalarDist::Normal(Normal::<f64>::std()).domain(), Domain::Real);
        assert_eq!(
            ScalarDist::Gamma(Gamma::new(1.0, 1.0)).domain(),
            Domain::Positive
        );
        assert_eq!(
            ScalarDist::Uniform(Uniform::new(-2.0, 3.0)).domain(),
            Domain::Interval(-2.0, 3.0)
        );
        assert_eq!(
            VecDist::<f64>::Dirichlet(Dirichlet::symmetric(1.0, 5)).domain(),
            Domain::Simplex(5)
        );
        assert_eq!(
            DiscreteDist::<f64>::Categorical(Categorical::from_probs(&[0.5, 0.5])).domain(),
            Domain::DiscreteCategory(2)
        );
        assert!(Domain::DiscreteBool.is_discrete());
        assert_eq!(Domain::Simplex(4).unconstrained_dim(), 3);
        assert_eq!(Domain::Simplex(4).constrained_dim(), 4);
        assert_eq!(Domain::DiscreteCategory(3).unconstrained_dim(), 0);
    }

    #[test]
    fn sampling_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(99);
        let n = 40_000;
        // Normal(2, 0.5)
        let d = ScalarDist::Normal(Normal::new(2.0, 0.5));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 2.0, 0.02);
        // Gamma(3, 2): mean 1.5
        let d = ScalarDist::Gamma(Gamma::new(3.0, 2.0));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 1.5, 0.03);
        // InverseGamma(3, 2): mean b/(a−1) = 1
        let d = ScalarDist::InverseGamma(InverseGamma::new(3.0, 2.0));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 1.0, 0.05);
        // Uniform(-1, 3): mean 1
        let d = ScalarDist::Uniform(Uniform::new(-1.0, 3.0));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        close(m, 1.0, 0.05);
        // Bernoulli(0.3)
        let d = DiscreteDist::Bernoulli(Bernoulli::new(0.3));
        let m: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        close(m, 0.3, 0.05);
        // Dirichlet samples live on the simplex
        let d = VecDist::Dirichlet(Dirichlet::symmetric(0.7, 4));
        let v = d.sample(&mut rng);
        close(v.iter().sum::<f64>(), 1.0, 1e-12);
    }

    #[test]
    fn any_dist_boxed_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let any = ScalarDist::Gamma(Gamma::new(2.0, 3.0)).boxed();
        assert_eq!(any.domain(), Domain::Positive);
        let v = any.sample(&mut rng);
        let x = v.as_f64().unwrap();
        assert!(x > 0.0);
        close(any.logpdf(&v), Gamma::new(2.0, 3.0).logpdf(x), 1e-14);
        // type mismatch scores −∞
        assert_eq!(any.logpdf(&Value::Vec(vec![1.0])), f64::NEG_INFINITY);

        let anyv = VecDist::IsoNormal(IsoNormal::new(0.0, 1.0, 3)).boxed();
        let v = anyv.sample(&mut rng);
        assert_eq!(v.as_slice().unwrap().len(), 3);
        let anyd = DiscreteDist::Categorical(Categorical::from_probs(&[0.2, 0.8])).boxed();
        let v = anyd.sample(&mut rng);
        assert!(matches!(v, Value::Int(0 | 1)));
    }

    /// Every closed-form `logpdf_adj` kernel must agree with the generic
    /// dual-based fallback (`scalar_adj_via_dual`) — the default a custom
    /// distribution would use — in value, point-partial and parameter
    /// partials.
    #[test]
    fn scalar_adj_kernels_match_dual_fallback() {
        let cases: Vec<(ScalarDist<f64>, f64)> = vec![
            (ScalarDist::Normal(Normal::new(0.4, 1.7)), 1.2),
            (ScalarDist::InverseGamma(InverseGamma::new(2.0, 3.0)), 0.8),
            (ScalarDist::Gamma(Gamma::new(2.5, 1.4)), 2.2),
            (ScalarDist::Beta(Beta::new(2.0, 3.5)), 0.37),
            (ScalarDist::Exponential(Exponential::new(1.3)), 0.9),
            (ScalarDist::Uniform(Uniform::new(-2.0, 5.0)), 1.1),
            (ScalarDist::Cauchy(Cauchy::new(0.3, 2.1)), -1.4),
            (ScalarDist::HalfCauchy(HalfCauchy::new(2.0)), 0.6),
        ];
        for (dist, x) in cases {
            let adj = dist.logpdf_adj(x);
            let (pv, np) = dist.param_vars();
            // rebuild the same distribution over duals from the params
            let rebuild = |p: &[Dual]| -> ScalarDist<Dual> {
                match &dist {
                    ScalarDist::Normal(_) => ScalarDist::Normal(Normal::new(p[0], p[1])),
                    ScalarDist::InverseGamma(_) => {
                        ScalarDist::InverseGamma(InverseGamma::new(p[0], p[1]))
                    }
                    ScalarDist::Gamma(_) => ScalarDist::Gamma(Gamma::new(p[0], p[1])),
                    ScalarDist::Beta(_) => ScalarDist::Beta(Beta::new(p[0], p[1])),
                    ScalarDist::Exponential(_) => {
                        ScalarDist::Exponential(Exponential::new(p[0]))
                    }
                    ScalarDist::Uniform(_) => ScalarDist::Uniform(Uniform::new(p[0], p[1])),
                    ScalarDist::Cauchy(_) => ScalarDist::Cauchy(Cauchy::new(p[0], p[1])),
                    ScalarDist::HalfCauchy(_) => {
                        ScalarDist::HalfCauchy(HalfCauchy::new(p[0]))
                    }
                }
            };
            let generic = scalar_adj_via_dual(
                |xd, pd| rebuild(pd).logpdf(xd),
                x,
                &pv[..np],
            );
            let label = format!("{dist:?}");
            close(adj.lp, generic.lp, 1e-11);
            assert!(
                (adj.d_x - generic.d_x).abs() < 1e-9,
                "{label}: d_x {} vs {}",
                adj.d_x,
                generic.d_x
            );
            for i in 0..np {
                assert!(
                    (adj.d_p[i] - generic.d_p[i]).abs() < 1e-8,
                    "{label}: d_p[{i}] {} vs {}",
                    adj.d_p[i],
                    generic.d_p[i]
                );
            }
        }
        // out-of-support mirrors the generic guards
        let adj = ScalarDist::Gamma(Gamma::new(2.0, 1.0)).logpdf_adj(-0.5);
        assert_eq!(adj.lp, f64::NEG_INFINITY);
        assert_eq!(adj.d_x, 0.0);
    }

    #[test]
    fn vec_adj_kernels_match_duals() {
        // IsoNormal: point + parameter partials
        let d = VecDist::IsoNormal(IsoNormal::new(0.5, 1.5, 3));
        let x = [0.1, -0.2, 2.0];
        let mut dx = [0.0; 3];
        let adj = d.logpdf_adj(&x, &mut dx);
        close(adj.lp, d.logpdf(&x), 1e-12);
        for i in 0..3 {
            let g = finite_diff_grad(
                |xs| d.logpdf(&[xs[0], xs[1], xs[2]]),
                &x,
                1e-6,
            )[i];
            assert!((dx[i] - g).abs() < 1e-5, "dx[{i}]: {} vs {g}", dx[i]);
        }
        let dm = IsoNormal::new(Dual::var(0.5), Dual::constant(1.5), 3)
            .logpdf(&[Dual::constant(0.1), Dual::constant(-0.2), Dual::constant(2.0)])
            .d;
        assert!((adj.d_p[0] - dm).abs() < 1e-10, "{} vs {dm}", adj.d_p[0]);
        let ds = IsoNormal::new(Dual::constant(0.5), Dual::var(1.5), 3)
            .logpdf(&[Dual::constant(0.1), Dual::constant(-0.2), Dual::constant(2.0)])
            .d;
        assert!((adj.d_p[1] - ds).abs() < 1e-10, "{} vs {ds}", adj.d_p[1]);

        // Dirichlet: α=1 components have exactly zero point-partial
        let d = VecDist::<f64>::Dirichlet(Dirichlet::new(vec![2.0, 1.0, 0.5]));
        let x = [0.3, 0.45, 0.25];
        let mut dx = [0.0; 3];
        let adj = d.logpdf_adj(&x, &mut dx);
        close(adj.lp, d.logpdf(&x), 1e-12);
        assert_eq!(dx[1], 0.0);
        assert!((dx[0] - 1.0 / 0.3).abs() < 1e-12);
        assert!((dx[2] - (-0.5 / 0.25)).abs() < 1e-12);
    }

    #[test]
    fn discrete_adj_kernels_match_duals() {
        let check = |d: DiscreteDist<f64>, k: i64| {
            let (lp, dp) = d.logpmf_adj(k);
            close(lp, d.logpmf(k), 1e-12);
            let dd: DiscreteDist<Dual> = match &d {
                DiscreteDist::Bernoulli(b) => {
                    DiscreteDist::Bernoulli(Bernoulli::new(Dual::var(b.p)))
                }
                DiscreteDist::BernoulliLogit(b) => {
                    DiscreteDist::BernoulliLogit(BernoulliLogit::new(Dual::var(b.logit)))
                }
                DiscreteDist::Poisson(p) => {
                    DiscreteDist::Poisson(Poisson::new(Dual::var(p.rate)))
                }
                DiscreteDist::Categorical(c) => DiscreteDist::Categorical(c.clone()),
            };
            let want = dd.logpmf(k).d;
            assert!((dp - want).abs() < 1e-10, "{d:?} at {k}: {dp} vs {want}");
        };
        check(DiscreteDist::Bernoulli(Bernoulli::new(0.3)), 1);
        check(DiscreteDist::Bernoulli(Bernoulli::new(0.3)), 0);
        check(DiscreteDist::BernoulliLogit(BernoulliLogit::new(0.7)), 1);
        check(DiscreteDist::BernoulliLogit(BernoulliLogit::new(0.7)), 0);
        check(DiscreteDist::Poisson(Poisson::new(2.5)), 3);
        check(
            DiscreteDist::Categorical(Categorical::from_probs(&[0.2, 0.8])),
            1,
        );
        // out of support
        let (lp, dp) = DiscreteDist::Poisson(Poisson::new(2.0)).logpmf_adj(-1);
        assert_eq!(lp, f64::NEG_INFINITY);
        assert_eq!(dp, 0.0);
    }

    /// The plate kernels must be *bitwise* equal to the sequential fused
    /// adjoint per row — the compiled executor's bit-identity guarantee
    /// rests on this.
    #[test]
    fn row_kernels_bitwise_match_sequential() {
        let dists: Vec<ScalarDist<f64>> = vec![
            ScalarDist::Normal(Normal::new(0.4, 1.7)),
            ScalarDist::InverseGamma(InverseGamma::new(2.0, 3.0)),
            ScalarDist::Gamma(Gamma::new(2.5, 1.4)),
            ScalarDist::Beta(Beta::new(2.0, 3.5)),
            ScalarDist::Exponential(Exponential::new(1.3)),
            ScalarDist::Uniform(Uniform::new(-2.0, 5.0)),
            ScalarDist::Cauchy(Cauchy::new(0.3, 2.1)),
            ScalarDist::HalfCauchy(HalfCauchy::new(2.0)),
        ];
        // mix of in-support and out-of-support points (clamped to each
        // support by the kernels' own guards, which is the point)
        let xs = [0.9, 0.37, 2.2, -0.5, 0.04, 1.1, 7.3, 0.6];
        let n = xs.len();
        for dist in &dists {
            let mut lp = vec![0.0; n];
            let mut dp = vec![[0.0; MAX_DIST_PARAMS]; n];
            dist.logpdf_adj_rows(&xs, &mut lp, &mut dp);
            for i in 0..n {
                let want = dist.logpdf_adj(xs[i]);
                assert!(
                    lp[i].to_bits() == want.lp.to_bits(),
                    "{dist:?} row {i}: lp {} vs {}",
                    lp[i],
                    want.lp
                );
                for j in 0..MAX_DIST_PARAMS {
                    assert!(
                        dp[i][j].to_bits() == want.d_p[j].to_bits(),
                        "{dist:?} row {i}: d_p[{j}] {} vs {}",
                        dp[i][j],
                        want.d_p[j]
                    );
                }
            }
        }
        // degenerate Normal: whole plate rejects
        let bad = ScalarDist::Normal(Normal::new(0.0, 0.0));
        let mut lp = vec![0.0; n];
        let mut dp = vec![[1.0; MAX_DIST_PARAMS]; n];
        bad.logpdf_adj_rows(&xs, &mut lp, &mut dp);
        assert!(lp.iter().all(|&l| l == f64::NEG_INFINITY));
        assert!(dp.iter().all(|d| d == &[0.0; MAX_DIST_PARAMS]));

        let ddists: Vec<DiscreteDist<f64>> = vec![
            DiscreteDist::Bernoulli(Bernoulli::new(0.3)),
            DiscreteDist::BernoulliLogit(BernoulliLogit::new(0.7)),
            DiscreteDist::Poisson(Poisson::new(2.5)),
            DiscreteDist::Categorical(Categorical::from_probs(&[0.2, 0.8])),
        ];
        let ks = [0i64, 1, 3, -1, 2, 0, 1, 5];
        for dist in &ddists {
            let mut lp = vec![0.0; ks.len()];
            let mut dp = vec![0.0; ks.len()];
            dist.logpmf_adj_rows(&ks, &mut lp, &mut dp);
            for i in 0..ks.len() {
                let (wl, wd) = dist.logpmf_adj(ks[i]);
                assert!(lp[i].to_bits() == wl.to_bits(), "{dist:?} row {i}");
                assert!(dp[i].to_bits() == wd.to_bits(), "{dist:?} row {i}");
            }
        }
    }

    #[test]
    fn dual_gradients_match_finite_differences() {
        // d/dx of several log-densities via forward duals vs FD
        let fd_check = |f: &dyn Fn(f64) -> f64, fdual: &dyn Fn(Dual) -> Dual, x0: f64| {
            let g_fd = finite_diff_grad(|x| f(x[0]), &[x0], 1e-6)[0];
            let g_ad = fdual(Dual::var(x0)).d;
            assert!((g_fd - g_ad).abs() < 1e-5, "{g_fd} vs {g_ad} at {x0}");
        };
        fd_check(
            &|x| Normal::new(0.5, 2.0).logpdf(x),
            &|x| Normal::new(Dual::constant(0.5), Dual::constant(2.0)).logpdf(x),
            1.3,
        );
        fd_check(
            &|x| Gamma::new(2.0, 3.0).logpdf(x),
            &|x| Gamma::new(Dual::constant(2.0), Dual::constant(3.0)).logpdf(x),
            0.8,
        );
        fd_check(
            &|x| HalfCauchy::new(2.0).logpdf(x),
            &|x| HalfCauchy::new(Dual::constant(2.0)).logpdf(x),
            1.1,
        );
        // gradient w.r.t. a *parameter*
        let g_fd = finite_diff_grad(|m| Normal::new(m[0], 1.0).logpdf(0.7), &[0.2], 1e-6)[0];
        let g_ad = Normal::new(Dual::var(0.2), Dual::constant(1.0))
            .logpdf(Dual::constant(0.7))
            .d;
        assert!((g_fd - g_ad).abs() < 1e-6);
    }
}
