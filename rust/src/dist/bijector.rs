//! Constraint bijectors: the `link`/`invlink` pair of the paper's §2.2.
//!
//! `link` maps a constrained value into unconstrained coordinates (f64
//! only — it runs when a trace is specialized or a sampled value is
//! flattened). `invlink` is the hot-path inverse: generic over the AD
//! [`Scalar`] so the same code produces plain values, forward duals and
//! reverse-tape nodes, and it returns the log-absolute-determinant of the
//! Jacobian (the `logabsdetjac` correction added to prior terms).
//!
//! Transforms (matching Stan's reference manual):
//! - `Real`/`RealVec`: identity.
//! - `Positive`/`PositiveVec`: `x = exp(y)`, ladj `Σ y`.
//! - `Interval(lo, hi)`: `x = lo + (hi−lo)·σ(y)`,
//!   ladj `ln(hi−lo) + logσ(y) + logσ(−y)`.
//! - `Simplex(K)`: stick-breaking with centering offsets,
//!   `z_k = σ(y_k − ln(K−k))`, `x_k = z_k · stick_k`.
//! - discrete domains: no continuous coordinates, ladj 0.

use crate::ad::Scalar;

use super::Domain;

/// Constrained → unconstrained (f64 only), appending onto `out`.
pub fn link(domain: &Domain, x: &[f64], out: &mut Vec<f64>) {
    let start = out.len();
    out.resize(start + domain.unconstrained_dim(), 0.0);
    link_slice(domain, x, &mut out[start..]);
}

/// Constrained → unconstrained (f64 only), writing into a pre-sized slice
/// of length `domain.unconstrained_dim()` — the allocation-free form used
/// by in-place trace writes on the particle fast path.
pub fn link_slice(domain: &Domain, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(out.len(), domain.unconstrained_dim());
    match domain {
        Domain::Real | Domain::RealVec(_) => out.copy_from_slice(x),
        Domain::Positive | Domain::PositiveVec(_) => {
            for (o, &xi) in out.iter_mut().zip(x) {
                *o = xi.ln();
            }
        }
        Domain::Interval(lo, hi) => {
            debug_assert_eq!(x.len(), 1);
            let z = (x[0] - lo) / (hi - lo);
            out[0] = (z / (1.0 - z)).ln();
        }
        Domain::Simplex(k) => {
            debug_assert_eq!(x.len(), *k);
            let mut stick = 1.0;
            for (i, &xi) in x.iter().take(k - 1).enumerate() {
                let z = xi / stick;
                out[i] = (z / (1.0 - z)).ln() + ((k - i - 1) as f64).ln();
                stick -= xi;
            }
        }
        Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount => {}
    }
}

/// Unconstrained → constrained (generic over the AD scalar), appending the
/// constrained value onto `out` and returning the log-abs-det-Jacobian.
pub fn invlink<T: Scalar>(domain: &Domain, y: &[T], out: &mut Vec<T>) -> T {
    let start = out.len();
    out.resize(start + domain.constrained_dim(), T::constant(0.0));
    invlink_slice(domain, y, &mut out[start..])
}

/// Unconstrained → constrained into a pre-sized slice of length
/// `domain.constrained_dim()`, returning the log-abs-det-Jacobian. The
/// allocation-free form: `TypedVarInfo::refresh_constrained` and the typed
/// executors invlink directly into their destination buffers.
pub fn invlink_slice<T: Scalar>(domain: &Domain, y: &[T], out: &mut [T]) -> T {
    debug_assert_eq!(out.len(), domain.constrained_dim());
    match domain {
        Domain::Real | Domain::RealVec(_) => {
            out.copy_from_slice(y);
            T::constant(0.0)
        }
        Domain::Positive | Domain::PositiveVec(_) => {
            let mut ladj = T::constant(0.0);
            for (o, &yi) in out.iter_mut().zip(y) {
                *o = yi.exp();
                ladj = ladj + yi;
            }
            ladj
        }
        Domain::Interval(lo, hi) => {
            debug_assert_eq!(y.len(), 1);
            let width = hi - lo;
            let z = y[0].sigmoid();
            out[0] = z * width + *lo;
            T::constant(width.ln()) + y[0].log_sigmoid() + (-y[0]).log_sigmoid()
        }
        Domain::Simplex(k) => {
            debug_assert_eq!(y.len(), k - 1);
            let mut ladj = T::constant(0.0);
            let mut stick = T::constant(1.0);
            for (i, &yi) in y.iter().enumerate() {
                let offset = ((k - i - 1) as f64).ln();
                let z = (yi - offset).sigmoid();
                let xi = stick * z;
                out[i] = xi;
                ladj = ladj + z.ln() + (T::constant(1.0) - z).ln() + stick.ln();
                stick = stick - xi;
            }
            out[k - 1] = stick;
            ladj
        }
        Domain::DiscreteBool | Domain::DiscreteCategory(_) | Domain::DiscreteCount => {
            T::constant(0.0)
        }
    }
}

/// One scalar-domain invlink with its full analytic adjoint — the fused
/// form the arena executors use: constrained value, dx/dy, and the
/// log-abs-det-Jacobian with its derivative, all from one primal pass.
#[derive(Clone, Copy, Debug)]
pub struct ScalarLink {
    pub x: f64,
    pub dx_dy: f64,
    pub ladj: f64,
    pub dladj_dy: f64,
}

/// Analytic invlink adjoint for the scalar domains (`Real`, `Positive`,
/// `Interval`). Vector domains go through the generic
/// [`invlink_slice`] over arena variables instead.
#[inline]
pub fn invlink_scalar_adj(domain: &Domain, y: f64) -> ScalarLink {
    match domain {
        Domain::Real => ScalarLink {
            x: y,
            dx_dy: 1.0,
            ladj: 0.0,
            dladj_dy: 0.0,
        },
        Domain::Positive => {
            let x = y.exp();
            ScalarLink {
                x,
                dx_dy: x,
                ladj: y,
                dladj_dy: 1.0,
            }
        }
        Domain::Interval(lo, hi) => {
            let width = hi - lo;
            let s = crate::util::math::sigmoid(y);
            ScalarLink {
                x: s * width + lo,
                dx_dy: width * s * (1.0 - s),
                ladj: width.ln()
                    + crate::util::math::log_sigmoid(y)
                    + crate::util::math::log_sigmoid(-y),
                dladj_dy: 1.0 - 2.0 * s,
            }
        }
        other => panic!("invlink_scalar_adj on non-scalar domain {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::finite_diff_grad;

    fn roundtrip(domain: &Domain, x: &[f64]) {
        let mut y = Vec::new();
        link(domain, x, &mut y);
        assert_eq!(y.len(), domain.unconstrained_dim());
        let mut back: Vec<f64> = Vec::new();
        let _ = invlink(domain, &y, &mut back);
        assert_eq!(back.len(), domain.constrained_dim());
        for (a, b) in back.iter().zip(x) {
            assert!((a - b).abs() < 1e-10, "{domain:?}: {a} vs {b}");
        }
    }

    #[test]
    fn roundtrips_all_domains() {
        roundtrip(&Domain::Real, &[-1.3]);
        roundtrip(&Domain::RealVec(3), &[0.1, -2.0, 5.0]);
        roundtrip(&Domain::Positive, &[2.5]);
        roundtrip(&Domain::PositiveVec(2), &[0.3, 7.0]);
        roundtrip(&Domain::Interval(-1.0, 1.0), &[0.4]);
        roundtrip(&Domain::Simplex(4), &[0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn positive_ladj_is_sum_y() {
        let mut out = Vec::new();
        let ladj = invlink(&Domain::Positive, &[0.7f64], &mut out);
        assert!((out[0] - 0.7f64.exp()).abs() < 1e-14);
        assert!((ladj - 0.7).abs() < 1e-14);
    }

    #[test]
    fn interval_ladj_matches_sigmoid_identity() {
        // the StoVol test identity: phi in (-1,1) with width 2
        let u = 0.9f64;
        let mut out = Vec::new();
        let ladj = invlink(&Domain::Interval(-1.0, 1.0), &[u], &mut out);
        let expect = crate::util::math::log_sigmoid(u)
            + crate::util::math::log_sigmoid(-u)
            + 2.0f64.ln();
        assert!((ladj - expect).abs() < 1e-13);
        let phi = -1.0 + 2.0 * crate::util::math::sigmoid(u);
        assert!((out[0] - phi).abs() < 1e-14);
    }

    #[test]
    fn simplex_sums_to_one_and_ladj_matches_fd() {
        let y = [0.3f64, -0.8, 1.2];
        let mut x = Vec::new();
        let ladj = invlink(&Domain::Simplex(4), &y, &mut x);
        let s: f64 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(x.iter().all(|&v| v > 0.0 && v < 1.0));
        // ladj = ln |det ∂(x_1..x_{K-1})/∂y|; check via finite-diff
        // determinant of the 3×3 Jacobian.
        let f = |yy: &[f64], i: usize| -> f64 {
            let mut out = Vec::new();
            let _ = invlink(&Domain::Simplex(4), yy, &mut out);
            out[i]
        };
        let mut jac = [[0.0f64; 3]; 3];
        for (i, row) in jac.iter_mut().enumerate() {
            let g = finite_diff_grad(|yy| f(yy, i), &y, 1e-6);
            row.copy_from_slice(&g);
        }
        let det = jac[0][0] * (jac[1][1] * jac[2][2] - jac[1][2] * jac[2][1])
            - jac[0][1] * (jac[1][0] * jac[2][2] - jac[1][2] * jac[2][0])
            + jac[0][2] * (jac[1][0] * jac[2][1] - jac[1][1] * jac[2][0]);
        assert!(
            (ladj - det.abs().ln()).abs() < 1e-5,
            "{ladj} vs {}",
            det.abs().ln()
        );
    }

    #[test]
    fn slice_forms_match_vec_forms() {
        for (domain, x) in [
            (Domain::Positive, vec![2.5]),
            (Domain::Interval(-1.0, 1.0), vec![0.4]),
            (Domain::Simplex(4), vec![0.1, 0.2, 0.3, 0.4]),
        ] {
            let mut y_vec = Vec::new();
            link(&domain, &x, &mut y_vec);
            let mut y_slice = vec![0.0; domain.unconstrained_dim()];
            link_slice(&domain, &x, &mut y_slice);
            assert_eq!(y_vec, y_slice, "{domain:?}");

            let mut back_vec: Vec<f64> = Vec::new();
            let ladj_vec = invlink(&domain, &y_vec, &mut back_vec);
            let mut back_slice = vec![0.0; domain.constrained_dim()];
            let ladj_slice = invlink_slice(&domain, &y_slice, &mut back_slice);
            assert_eq!(back_vec, back_slice, "{domain:?}");
            assert_eq!(ladj_vec.to_bits(), ladj_slice.to_bits(), "{domain:?}");
        }
    }

    #[test]
    fn scalar_adj_matches_generic_invlink_and_fd() {
        for (domain, y) in [
            (Domain::Real, -0.8),
            (Domain::Positive, 0.6),
            (Domain::Interval(-1.0, 1.0), 0.9),
            (Domain::Interval(2.0, 7.0), -1.3),
        ] {
            let link = invlink_scalar_adj(&domain, y);
            // value + ladj agree with the generic slice form
            let mut out = [0.0f64];
            let ladj = invlink_slice(&domain, &[y], &mut out);
            assert_eq!(link.x.to_bits(), out[0].to_bits(), "{domain:?}");
            assert_eq!(link.ladj.to_bits(), ladj.to_bits(), "{domain:?}");
            // derivatives agree with finite differences
            let dx = finite_diff_grad(
                |yy| {
                    let mut o = [0.0f64];
                    let _ = invlink_slice(&domain, &[yy[0]], &mut o);
                    o[0]
                },
                &[y],
                1e-6,
            )[0];
            assert!((link.dx_dy - dx).abs() < 1e-6, "{domain:?}: {} vs {dx}", link.dx_dy);
            let dl = finite_diff_grad(
                |yy| {
                    let mut o = [0.0f64];
                    invlink_slice(&domain, &[yy[0]], &mut o)
                },
                &[y],
                1e-6,
            )[0];
            assert!(
                (link.dladj_dy - dl).abs() < 1e-6,
                "{domain:?}: {} vs {dl}",
                link.dladj_dy
            );
        }
    }

    #[test]
    fn discrete_domains_have_no_coordinates() {
        let mut out: Vec<f64> = Vec::new();
        let ladj = invlink(&Domain::DiscreteBool, &[], &mut out);
        assert!(out.is_empty());
        assert_eq!(ladj, 0.0);
    }
}
