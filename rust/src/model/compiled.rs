//! Static-structure compiler: record the tilde walk once, replay it as a
//! flat plate-vectorized density program.
//!
//! The dynamic fused path ([`super::typed_grad_fused_into`]) re-executes
//! the model *body* on every gradient: every tilde macro re-hashes its
//! `VarName`, re-matches its distribution constructor, and every scalar of
//! glue arithmetic re-dispatches through [`AVar`] operator calls. For a
//! model whose structure never changes between evaluations, all of that
//! discovery work is pure overhead. This module removes it:
//!
//! 1. **Record** — run the body once with `T =`[`crate::ad::record::RVar`]
//!    under the full-data [`Context::Default`]: every tilde statement
//!    becomes an [`Item`] (slot-indexed, varname-free) and every scalar of
//!    glue arithmetic becomes a register opcode ([`crate::ad::record::Op`]).
//! 2. **Verify** — record a second time at a perturbed point (θ ± 0.125
//!    per coordinate). Only if both recordings are *structurally
//!    identical* (same opcodes, same items, bitwise-equal embedded
//!    constants) is the model's walk considered static. Data-dependent
//!    branching on θ produces different recordings and the model stays on
//!    the dynamic path — transparently, with no behavioural change.
//! 3. **Compile** — single-use `Mul`/`Add` glue chains (dot products,
//!    linear predictors) are fused into one variable-arity tape node per
//!    chain ([`EOp::FusedAdd`]), and runs of consecutive observe sites
//!    sharing one distribution family and parameter slots are grouped
//!    into *plates* served by the row-batched `logpdf_adj_rows` /
//!    `logpmf_adj_rows` kernels in [`crate::dist`].
//! 4. **Cross-validate** — before the program is ever served, its
//!    log-density and gradient at the recording point are compared
//!    **bitwise** against the dynamic fused executor. Any divergence
//!    aborts the promotion.
//!
//! Replay then never re-enters the model body: assumes are a flat
//! slot-indexed kernel list with no varname hashing, observes are plate
//! kernels, and glue is an opcode interpreter over a register file. Every
//! per-statement decision (seed weights, observation windows, rejection)
//! reuses the same accumulator arithmetic as the dynamic executors, so
//! log-density and gradient stay bit-identical.
//!
//! ## Context policy (what is served, what demotes)
//!
//! A promoted program serves [`Context::Default`], [`Context::Likelihood`],
//! [`Context::Prior`] and [`Context::MiniBatch`] — the contexts whose
//! observation window covers every site, where the recorded walk is the
//! walk ([`servable`]). The rest route back to the dynamic executors:
//!
//! - `Subsample` / `ObsWindow`: window-aware model bodies `skip_obs` over
//!   out-of-window blocks, making the dynamic walk O(batch); the recorded
//!   program visits every site, so replaying it would be O(N). Demoting is
//!   both the correctness-preserving and the *faster* choice.
//! - `Profile`: per-site attribution needs the model body's varnames.
//! - Gibbs site-masked gradients ([`super::typed_grad_fused_masked_into`])
//!   never route through the compiled path — the mask is per-evaluation
//!   state the recording does not capture.
//! - A changed discrete sub-trace (Gibbs moves on an `assume_int` site)
//!   is detected by [`StaticProgram::matches_discrete`] and demotes until
//!   the density is re-compiled against the new snapshot.

use std::cell::RefCell;

use crate::ad::arena::{self, AVar};
use crate::ad::record::{self, Op, ROp, RVar, Src};
use crate::ad::Scalar;
use crate::context::{Accumulator, Context};
use crate::dist::{bijector, DiscreteDist, ScalarDist, VecDist, MAX_DIST_PARAMS};
use crate::obs::metrics::{self, Counter};
use crate::varinfo::TypedVarInfo;
use crate::varname::VarName;

use super::executors::{
    cursor_next_slot, fused_assume_scalar, fused_assume_vec, park_fused_scratch,
    seed_assume_scalar, seed_assume_vec, seed_params_scalar, take_fused_scratch, FusedScratch,
};
use super::{count_obs_sites, typed_grad_fused_into, Model, TildeApi};

/// Whether a promoted program may serve this context. Exactly the contexts
/// with a full observation window — see the module docs for why windowed
/// and profiled contexts demote.
pub fn servable(ctx: Context) -> bool {
    matches!(
        ctx,
        Context::Default | Context::Likelihood | Context::Prior | Context::MiniBatch { .. }
    )
}

// ------------------------------------------------------------- program IR

/// One recorded tilde site, slot-indexed and varname-free. Distribution
/// *families* are stored as `f64` templates (parameter values inside are
/// recording-time leftovers, dead at replay); live parameters enter
/// through the [`Src`] slots, resolved against the register file.
pub(crate) enum Item {
    AssumeScalar {
        slot: usize,
        out: u32,
        dist: ScalarDist<f64>,
        ps: [Src; MAX_DIST_PARAMS],
        np: usize,
    },
    AssumeVec {
        slot: usize,
        out: Vec<u32>,
        dist: VecDist<f64>,
        ps: [Src; MAX_DIST_PARAMS],
        np: usize,
    },
    AssumeInt {
        slot: usize,
        dist: DiscreteDist<f64>,
        p: Src,
    },
    Observe {
        dist: ScalarDist<f64>,
        ps: [Src; MAX_DIST_PARAMS],
        np: usize,
        obs: f64,
    },
    ObserveInt {
        dist: DiscreteDist<f64>,
        p: Src,
        obs: i64,
    },
    ObserveVec {
        dist: VecDist<f64>,
        ps: [Src; MAX_DIST_PARAMS],
        np: usize,
        obs: Vec<f64>,
    },
    ObsLogp {
        lp: Src,
    },
    PriorLogp {
        lp: Src,
    },
    SkipObs {
        n: usize,
    },
    /// ≥ 2 consecutive scalar observes sharing family + parameter slots,
    /// served by one row-batched `logpdf_adj_rows` kernel call.
    PlateScalar {
        dist: ScalarDist<f64>,
        ps: [Src; MAX_DIST_PARAMS],
        np: usize,
        obs: Vec<f64>,
    },
    /// ≥ 2 consecutive discrete observes sharing family + parameter slot.
    PlateInt {
        dist: DiscreteDist<f64>,
        p: Src,
        obs: Vec<i64>,
    },
}

/// A term of a fused add chain.
enum FTerm {
    /// A plain added operand.
    Src(Src),
    /// `reg * const` — a single-use `Mul` folded into its consuming `Add`
    /// (the dot-product pattern `acc + w[j] * x[j]`).
    MulRC(u32, f64),
}

/// An executable glue opcode: either one recorded scalar op replayed
/// through the matching [`AVar`] operation, or a fused add chain that
/// collapses a whole `Mul`/`Add` run into **one** variable-arity tape
/// node (a 2d-node dot product becomes a single d-parent node).
enum EOp {
    Plain(ROp),
    FusedAdd {
        out: u32,
        head: Src,
        terms: Vec<FTerm>,
    },
}

/// An item plus the index into the executable opcode stream up to which
/// glue must run before it.
pub(crate) struct RecItem {
    pub(crate) glue_end: usize,
    pub(crate) item: Item,
}

/// Raw output of one recording pass, before fusion and plate grouping.
pub(crate) struct Recording {
    pub(crate) ops: Vec<ROp>,
    pub(crate) n_regs: u32,
    pub(crate) items: Vec<RecItem>,
    pub(crate) n_obs: usize,
}

/// A compiled, immutable density program. Built by [`try_compile`]; serves
/// `logp_grad` evaluations without re-entering the model body.
pub struct StaticProgram {
    eops: Vec<EOp>,
    items: Vec<RecItem>,
    n_regs: usize,
    /// Discrete sub-trace snapshot at compile time: a Gibbs move on a
    /// discrete site invalidates the recorded `assume_int`/branching
    /// values, so serving requires [`Self::matches_discrete`].
    discrete: Vec<i64>,
    n_obs: usize,
    n_plates: usize,
    plate_rows: usize,
    dim: usize,
}

impl StaticProgram {
    /// Number of observe plates the compiler formed.
    pub fn n_plates(&self) -> usize {
        self.n_plates
    }

    /// Total observation rows served through plate kernels.
    pub fn plate_rows(&self) -> usize {
        self.plate_rows
    }

    /// Observation sites counted at recording (visited + skipped).
    pub fn n_obs(&self) -> usize {
        self.n_obs
    }

    /// Unconstrained dimension the program was compiled for.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Whether the trace's discrete sub-trace still matches the compile
    /// time snapshot (a mismatch demotes to the dynamic walk).
    pub fn matches_discrete(&self, tvi: &TypedVarInfo) -> bool {
        self.discrete == tvi.discrete
    }

    /// Compiled log-density + gradient — drop-in for
    /// [`super::typed_grad_fused_into`], bit-identical by construction
    /// (and cross-validated at promotion). The caller is responsible for
    /// only passing [`servable`] contexts and a matching discrete trace.
    pub fn logp_grad_into(
        &self,
        tvi: &TypedVarInfo,
        theta: &[f64],
        ctx: Context,
        grad: &mut [f64],
    ) -> f64 {
        debug_assert!(servable(ctx), "compiled program served a non-servable context");
        metrics::inc(Counter::GradEvals);
        arena::begin(theta.len());
        let (lp, stmts) = self.replay(tvi, theta, ctx);
        if !lp.is_finite() {
            metrics::inc(Counter::RejectedEvals);
            grad.fill(0.0);
            return lp;
        }
        arena::backward_into(grad, stmts);
        lp
    }

    /// Compiled log-density only — the value side of
    /// [`Self::logp_grad_into`] without the backward sweep. Bitwise equal
    /// to [`super::typed_logp_fused`] (and to the value returned by
    /// `logp_grad_into`) at any servable context, which lets full-joint
    /// consumers (Gibbs proposals, SMC trace scoring) ride the flat
    /// replay while staying bit-consistent with their dynamic fallback.
    pub fn logp(&self, tvi: &TypedVarInfo, theta: &[f64], ctx: Context) -> f64 {
        debug_assert!(servable(ctx), "compiled program served a non-servable context");
        metrics::inc(Counter::LogpEvals);
        arena::begin(theta.len());
        let (lp, _stmts) = self.replay(tvi, theta, ctx);
        if !lp.is_finite() {
            metrics::inc(Counter::RejectedEvals);
        }
        lp
    }

    /// Run the program: glue opcodes through the interpreter, items
    /// through the same fused kernels and accumulator arithmetic as the
    /// dynamic executors. Returns `(logp, tilde statements)`.
    fn replay(&self, tvi: &TypedVarInfo, theta: &[f64], ctx: Context) -> (f64, usize) {
        debug_assert_eq!(theta.len(), self.dim);
        let mut r = Replay {
            tvi,
            theta,
            acc: Accumulator::new(ctx),
            prior_w: ctx.prior_weight(),
            lik_w: ctx.lik_weight(),
            stmts: 0,
            rs: take_replay_scratch(),
            fs: take_fused_scratch(),
        };
        r.rs.regs.clear();
        r.rs.regs.resize(self.n_regs, (arena::NONE, 0.0));
        let mut cursor = 0usize;
        for ri in &self.items {
            for eop in &self.eops[cursor..ri.glue_end] {
                r.exec_eop(eop);
            }
            cursor = ri.glue_end;
            r.exec_item(&ri.item);
            if r.acc.rejected() {
                // −∞ is sticky and the caller zeroes the gradient on any
                // non-finite value, so the remaining items cannot change
                // the outcome — stop paying for them.
                break;
            }
        }
        let out = (r.acc.total(), r.stmts);
        park_fused_scratch(r.fs);
        park_replay_scratch(r.rs);
        out
    }
}

// ------------------------------------------------------------- recording

/// [`TildeApi`] impl that captures the walk. Runs strictly under
/// [`Context::Default`] (full data): window-aware bodies then visit every
/// observation site, so the recorded obs-site count matches
/// [`count_obs_sites`] and `skip_obs` blocks degenerate to zero-length
/// jumps — the recorder never double- or under-counts sites.
struct StructureRecorder<'a> {
    tvi: &'a TypedVarInfo,
    theta: &'a [f64],
    cursor: usize,
    acc: Accumulator<f64>,
    items: Vec<RecItem>,
}

impl<'a> StructureRecorder<'a> {
    fn push_item(&mut self, item: Item) {
        self.items.push(RecItem {
            glue_end: record::len(),
            item,
        });
    }
}

impl<'a> TildeApi<RVar> for StructureRecorder<'a> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<RVar>) -> RVar {
        let slot = cursor_next_slot(self.tvi, &mut self.cursor, &vn);
        let si = self.cursor - 1;
        let (ps, np) = dist.param_vars();
        let tpl = dist.with_f64_params(&[ps[0].value(), ps[1].value()]);
        // primal mirror of the fused kernel — rejection and branch
        // decisions resolve exactly as they would dynamically
        let link = bijector::invlink_scalar_adj(&slot.domain, self.theta[slot.unc_offset]);
        let adj = tpl.logpdf_adj(link.x);
        self.acc.add_prior(adj.lp + link.ladj);
        let out = record::alloc_reg();
        self.push_item(Item::AssumeScalar {
            slot: si,
            out,
            dist: tpl,
            ps: [ps[0].src(), ps[1].src()],
            np,
        });
        RVar::from_reg(out, link.x)
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<RVar>) -> Vec<RVar> {
        let slot = cursor_next_slot(self.tvi, &mut self.cursor, &vn);
        let si = self.cursor - 1;
        let (ps, np) = dist.param_vars();
        let tpl = dist.with_f64_params(&[ps[0].value(), ps[1].value()]);
        let n = slot.domain.constrained_dim();
        let off = slot.unc_offset;
        let mut xs = vec![0.0; n];
        let mut dx = vec![0.0; n];
        let lp = match &slot.domain {
            crate::dist::Domain::RealVec(_) => {
                xs.copy_from_slice(&self.theta[off..off + n]);
                tpl.logpdf_adj(&xs, &mut dx).lp
            }
            crate::dist::Domain::PositiveVec(_) => {
                let mut ladj = 0.0;
                for (i, x) in xs.iter_mut().enumerate() {
                    let y = self.theta[off + i];
                    ladj += y;
                    *x = y.exp();
                }
                tpl.logpdf_adj(&xs, &mut dx).lp + ladj
            }
            crate::dist::Domain::Simplex(_) => {
                let m = slot.domain.unconstrained_dim();
                let ladj =
                    bijector::invlink_slice(&slot.domain, &self.theta[off..off + m], &mut xs);
                tpl.logpdf_adj(&xs, &mut dx).lp + ladj
            }
            other => panic!("vector assume over scalar/discrete domain {other:?}"),
        };
        self.acc.add_prior(lp);
        let out: Vec<u32> = (0..n).map(|_| record::alloc_reg()).collect();
        let vals: Vec<RVar> = out
            .iter()
            .zip(&xs)
            .map(|(&r, &x)| RVar::from_reg(r, x))
            .collect();
        self.push_item(Item::AssumeVec {
            slot: si,
            out,
            dist: tpl,
            ps: [ps[0].src(), ps[1].src()],
            np,
        });
        vals
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<RVar>) -> i64 {
        let slot = cursor_next_slot(self.tvi, &mut self.cursor, &vn);
        let si = self.cursor - 1;
        let k = self.tvi.discrete[slot.disc_offset];
        let p = dist.param_var();
        let tpl = dist.with_f64_param(p.map_or(0.0, |p| p.value()));
        let (lp, _) = tpl.logpmf_adj(k);
        self.acc.add_prior(lp);
        self.push_item(Item::AssumeInt {
            slot: si,
            dist: tpl,
            p: p.map_or(Src::Const(0.0), |p| p.src()),
        });
        k
    }

    fn observe(&mut self, dist: &ScalarDist<RVar>, obs: f64) {
        let cw = self.acc.note_obs();
        let (ps, np) = dist.param_vars();
        let tpl = dist.with_f64_params(&[ps[0].value(), ps[1].value()]);
        if cw != 0.0 {
            self.acc.add_lik_weighted(tpl.logpdf_adj(obs).lp, cw);
        }
        self.push_item(Item::Observe {
            dist: tpl,
            ps: [ps[0].src(), ps[1].src()],
            np,
            obs,
        });
    }

    fn observe_int(&mut self, dist: &DiscreteDist<RVar>, obs: i64) {
        let cw = self.acc.note_obs();
        let p = dist.param_var();
        let tpl = dist.with_f64_param(p.map_or(0.0, |p| p.value()));
        if cw != 0.0 {
            self.acc.add_lik_weighted(tpl.logpmf_adj(obs).0, cw);
        }
        self.push_item(Item::ObserveInt {
            dist: tpl,
            p: p.map_or(Src::Const(0.0), |p| p.src()),
            obs,
        });
    }

    fn observe_vec(&mut self, dist: &VecDist<RVar>, obs: &[f64]) {
        let cw = self.acc.note_obs();
        let (ps, np) = dist.param_vars();
        let tpl = dist.with_f64_params(&[ps[0].value(), ps[1].value()]);
        if cw != 0.0 {
            let mut dx = vec![0.0; obs.len()];
            self.acc.add_lik_weighted(tpl.logpdf_adj(obs, &mut dx).lp, cw);
        }
        self.push_item(Item::ObserveVec {
            dist: tpl,
            ps: [ps[0].src(), ps[1].src()],
            np,
            obs: obs.to_vec(),
        });
    }

    fn add_obs_logp(&mut self, lp: RVar) {
        let cw = self.acc.note_obs();
        self.acc.add_lik_weighted(lp.value(), cw);
        self.push_item(Item::ObsLogp { lp: lp.src() });
    }

    fn add_prior_logp(&mut self, lp: RVar) {
        self.acc.add_prior(lp.value());
        self.push_item(Item::PriorLogp { lp: lp.src() });
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        Context::Default
    }

    fn skip_obs(&mut self, n: usize) {
        self.acc.skip_obs(n);
        if n > 0 {
            self.push_item(Item::SkipObs { n });
        }
    }
}

/// One recording pass at `theta`. `None` when the run rejected or went
/// non-finite — a truncated or degenerate recording must never promote.
fn record_run(model: &dyn Model, tvi: &TypedVarInfo, theta: &[f64]) -> Option<Recording> {
    debug_assert_eq!(theta.len(), tvi.dim());
    record::begin();
    let mut rec = StructureRecorder {
        tvi,
        theta,
        cursor: 0,
        acc: Accumulator::new(Context::Default),
        items: Vec::new(),
    };
    model.eval_record(&mut rec);
    let (ops, n_regs) = record::end();
    if rec.acc.rejected() || !rec.acc.total().is_finite() {
        return None;
    }
    Some(Recording {
        ops,
        n_regs,
        items: rec.items,
        n_obs: rec.acc.obs_seen(),
    })
}

/// Lenient recording entry point for the static analyzer (`crate::analysis`).
///
/// Lints want to inspect *any* complete walk — including degenerate ones
/// (e.g. a defective model whose recorded density is non-finite at the
/// init point is exactly what `dppl lint` exists to flag). Only a rejected
/// walk (truncated recording) is refused.
pub(crate) fn record_for_analysis(model: &dyn Model, tvi: &TypedVarInfo) -> Option<Recording> {
    debug_assert_eq!(tvi.unconstrained.len(), tvi.dim());
    record::begin();
    let mut rec = StructureRecorder {
        tvi,
        theta: &tvi.unconstrained,
        cursor: 0,
        acc: Accumulator::new(Context::Default),
        items: Vec::new(),
    };
    model.eval_record(&mut rec);
    let (ops, n_regs) = record::end();
    if rec.acc.rejected() {
        return None;
    }
    Some(Recording {
        ops,
        n_regs,
        items: rec.items,
        n_obs: rec.acc.obs_seen(),
    })
}

/// Strict double-record entry point for conjugacy certification: records at
/// θ and a perturbed θ ± 0.125 and returns the base recording only when
/// both are structurally identical — the same stability gate
/// [`try_compile`] uses, minus lowering/validation. A conjugacy certificate
/// must never be issued against a walk that changes shape with θ.
pub(crate) fn record_verified(model: &dyn Model, tvi: &TypedVarInfo) -> Option<Recording> {
    let rec0 = record_run(model, tvi, &tvi.unconstrained)?;
    let perturbed = |d: f64| -> Vec<f64> { tvi.unconstrained.iter().map(|x| x + d).collect() };
    let rec1 = record_run(model, tvi, &perturbed(0.125))
        .or_else(|| record_run(model, tvi, &perturbed(-0.125)))?;
    if !recordings_match(&rec0, &rec1) {
        return None;
    }
    Some(rec0)
}

// ------------------------------------------------- structural comparison

fn f64_bits_eq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

fn slice_bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| f64_bits_eq(*x, *y))
}

/// Family equality for scalar templates: parameter *values* are live data
/// compared through the [`Src`] slots, so only the variant matters here.
fn sdist_eq(a: &ScalarDist<f64>, b: &ScalarDist<f64>) -> bool {
    std::mem::discriminant(a) == std::mem::discriminant(b)
}

fn vdist_eq(a: &VecDist<f64>, b: &VecDist<f64>) -> bool {
    match (a, b) {
        (VecDist::IsoNormal(x), VecDist::IsoNormal(y)) => x.n == y.n,
        // Dirichlet α is data (never a parameter slot) — compare bitwise
        (VecDist::Dirichlet(x), VecDist::Dirichlet(y)) => slice_bits_eq(&x.alpha, &y.alpha),
        _ => false,
    }
}

fn ddist_eq(a: &DiscreteDist<f64>, b: &DiscreteDist<f64>) -> bool {
    match (a, b) {
        // Categorical probs are data — compare bitwise
        (DiscreteDist::Categorical(x), DiscreteDist::Categorical(y)) => {
            slice_bits_eq(&x.probs, &y.probs)
        }
        _ => std::mem::discriminant(a) == std::mem::discriminant(b),
    }
}

fn item_eq(a: &Item, b: &Item) -> bool {
    match (a, b) {
        (
            Item::AssumeScalar {
                slot: s1,
                out: o1,
                dist: d1,
                ps: p1,
                np: n1,
            },
            Item::AssumeScalar {
                slot: s2,
                out: o2,
                dist: d2,
                ps: p2,
                np: n2,
            },
        ) => s1 == s2 && o1 == o2 && n1 == n2 && p1 == p2 && sdist_eq(d1, d2),
        (
            Item::AssumeVec {
                slot: s1,
                out: o1,
                dist: d1,
                ps: p1,
                np: n1,
            },
            Item::AssumeVec {
                slot: s2,
                out: o2,
                dist: d2,
                ps: p2,
                np: n2,
            },
        ) => s1 == s2 && o1 == o2 && n1 == n2 && p1 == p2 && vdist_eq(d1, d2),
        (
            Item::AssumeInt {
                slot: s1,
                dist: d1,
                p: p1,
            },
            Item::AssumeInt {
                slot: s2,
                dist: d2,
                p: p2,
            },
        ) => s1 == s2 && p1 == p2 && ddist_eq(d1, d2),
        (
            Item::Observe {
                dist: d1,
                ps: p1,
                np: n1,
                obs: o1,
            },
            Item::Observe {
                dist: d2,
                ps: p2,
                np: n2,
                obs: o2,
            },
        ) => n1 == n2 && p1 == p2 && f64_bits_eq(*o1, *o2) && sdist_eq(d1, d2),
        (
            Item::ObserveInt {
                dist: d1,
                p: p1,
                obs: o1,
            },
            Item::ObserveInt {
                dist: d2,
                p: p2,
                obs: o2,
            },
        ) => p1 == p2 && o1 == o2 && ddist_eq(d1, d2),
        (
            Item::ObserveVec {
                dist: d1,
                ps: p1,
                np: n1,
                obs: o1,
            },
            Item::ObserveVec {
                dist: d2,
                ps: p2,
                np: n2,
                obs: o2,
            },
        ) => n1 == n2 && p1 == p2 && slice_bits_eq(o1, o2) && vdist_eq(d1, d2),
        (Item::ObsLogp { lp: a1 }, Item::ObsLogp { lp: a2 }) => a1 == a2,
        (Item::PriorLogp { lp: a1 }, Item::PriorLogp { lp: a2 }) => a1 == a2,
        (Item::SkipObs { n: n1 }, Item::SkipObs { n: n2 }) => n1 == n2,
        (
            Item::PlateScalar {
                dist: d1,
                ps: p1,
                np: n1,
                obs: o1,
            },
            Item::PlateScalar {
                dist: d2,
                ps: p2,
                np: n2,
                obs: o2,
            },
        ) => n1 == n2 && p1 == p2 && slice_bits_eq(o1, o2) && sdist_eq(d1, d2),
        (
            Item::PlateInt {
                dist: d1,
                p: p1,
                obs: o1,
            },
            Item::PlateInt {
                dist: d2,
                p: p2,
                obs: o2,
            },
        ) => p1 == p2 && o1 == o2 && ddist_eq(d1, d2),
        _ => false,
    }
}

/// Structural identity of two recordings — the promotion gate.
fn recordings_match(a: &Recording, b: &Recording) -> bool {
    a.n_regs == b.n_regs
        && a.n_obs == b.n_obs
        && a.ops == b.ops
        && a.items.len() == b.items.len()
        && a
            .items
            .iter()
            .zip(&b.items)
            .all(|(x, y)| x.glue_end == y.glue_end && item_eq(&x.item, &y.item))
}

// ----------------------------------------------------------- compilation

pub(crate) fn visit_op_srcs(op: &Op, f: &mut dyn FnMut(&Src)) {
    match op {
        Op::Add(a, b) | Op::Sub(a, b) | Op::Mul(a, b) | Op::Div(a, b) | Op::LogAddExp(a, b) => {
            f(a);
            f(b);
        }
        Op::Neg(r)
        | Op::Ln(r)
        | Op::Exp(r)
        | Op::Sqrt(r)
        | Op::Ln1p(r)
        | Op::Tanh(r)
        | Op::Sin(r)
        | Op::Cos(r)
        | Op::Lgamma(r)
        | Op::Abs(r)
        | Op::Log1pExp(r)
        | Op::LogSigmoid(r)
        | Op::Sigmoid(r) => f(&Src::Reg(*r)),
        Op::Powi(r, _) => f(&Src::Reg(*r)),
        Op::Powf(r, _) => f(&Src::Reg(*r)),
        Op::Lse(xs) => {
            for s in xs {
                f(s);
            }
        }
    }
}

pub(crate) fn visit_item_srcs(item: &Item, f: &mut dyn FnMut(&Src)) {
    match item {
        Item::AssumeScalar { ps, np, .. }
        | Item::AssumeVec { ps, np, .. }
        | Item::Observe { ps, np, .. }
        | Item::ObserveVec { ps, np, .. }
        | Item::PlateScalar { ps, np, .. } => {
            for s in &ps[..*np] {
                f(s);
            }
        }
        Item::AssumeInt { p, .. } | Item::ObserveInt { p, .. } | Item::PlateInt { p, .. } => f(p),
        Item::ObsLogp { lp } | Item::PriorLogp { lp } => f(lp),
        Item::SkipObs { .. } => {}
    }
}

/// Per-register read counts across the whole recording (ops + items).
/// Fusion folds an intermediate only when it is read exactly once — the
/// guarantee that collapsing it cannot reorder gradient accumulation
/// anywhere else.
fn count_uses(rec: &Recording) -> Vec<u32> {
    let mut uses = vec![0u32; rec.n_regs as usize];
    let mut bump = |s: &Src| {
        if let Src::Reg(r) = s {
            uses[*r as usize] += 1;
        }
    };
    for rop in &rec.ops {
        visit_op_srcs(&rop.op, &mut bump);
    }
    for ri in &rec.items {
        visit_item_srcs(&ri.item, &mut bump);
    }
    uses
}

/// Match one link of an add chain at `ops[i]`: an optional single-use
/// `Mul(reg, const)` feeding the `Add` immediately after it, or a bare
/// `Add`. Returns `(lhs, term, next index, out register)`. Only strictly
/// consecutive opcodes are considered — an interleaved op between links
/// breaks the chain, preserving the dynamic executor's gradient
/// accumulation order for any shared leaves.
fn parse_link(ops: &[ROp], i: usize, end: usize, uses: &[u32]) -> Option<(Src, FTerm, usize, u32)> {
    if i >= end {
        return None;
    }
    if i + 1 < end {
        if let Op::Mul(a, b) = &ops[i].op {
            let rc = match (a, b) {
                (Src::Reg(r), Src::Const(c)) | (Src::Const(c), Src::Reg(r)) => Some((*r, *c)),
                _ => None,
            };
            if let Some((r, c)) = rc {
                if uses[ops[i].out as usize] == 1 {
                    if let Op::Add(lhs, Src::Reg(m)) = &ops[i + 1].op {
                        if *m == ops[i].out {
                            return Some((*lhs, FTerm::MulRC(r, c), i + 2, ops[i + 1].out));
                        }
                    }
                }
            }
        }
    }
    if let Op::Add(lhs, t) = &ops[i].op {
        return Some((*lhs, FTerm::Src(*t), i + 1, ops[i].out));
    }
    None
}

/// Grow an add chain from `ops[i]`: follow links while each intermediate
/// sum is single-use and the next `Add` consumes it as its left operand.
/// Chains of ≥ 2 adds fuse; shorter runs stay plain.
fn try_chain(ops: &[ROp], i: usize, end: usize, uses: &[u32]) -> Option<(EOp, usize)> {
    let (head, t1, mut next, mut out) = parse_link(ops, i, end, uses)?;
    let mut terms = vec![t1];
    loop {
        if uses[out as usize] != 1 {
            break;
        }
        match parse_link(ops, next, end, uses) {
            Some((lhs, t, n2, o2)) if lhs == Src::Reg(out) => {
                terms.push(t);
                next = n2;
                out = o2;
            }
            _ => break,
        }
    }
    if terms.len() < 2 {
        return None;
    }
    Some((EOp::FusedAdd { out, head, terms }, next))
}

/// Lower one glue range into executable opcodes, fusing add chains.
fn fuse_range(ops: &[ROp], start: usize, end: usize, uses: &[u32], eops: &mut Vec<EOp>) {
    let mut i = start;
    while i < end {
        if let Some((eop, next)) = try_chain(ops, i, end, uses) {
            eops.push(eop);
            i = next;
        } else {
            eops.push(EOp::Plain(ops[i].clone()));
            i += 1;
        }
    }
}

/// Group runs of consecutive observe items that share one distribution
/// family and parameter slots (and have no glue between them) into plate
/// items. Returns `(items, n_plates, total plate rows)`.
fn group_plates(items: Vec<RecItem>) -> (Vec<RecItem>, usize, usize) {
    let mut out: Vec<RecItem> = Vec::with_capacity(items.len());
    let mut n_plates = 0usize;
    let mut plate_rows = 0usize;
    let mut iter = items.into_iter().peekable();
    while let Some(ri) = iter.next() {
        let RecItem { glue_end, item } = ri;
        match item {
            Item::Observe { dist, ps, np, obs } => {
                let mut rows = vec![obs];
                while let Some(nx) = iter.peek() {
                    let extend = nx.glue_end == glue_end
                        && matches!(
                            &nx.item,
                            Item::Observe { dist: d2, ps: p2, np: n2, .. }
                                if sdist_eq(&dist, d2) && ps == *p2 && np == *n2
                        );
                    if !extend {
                        break;
                    }
                    if let Some(RecItem {
                        item: Item::Observe { obs: o2, .. },
                        ..
                    }) = iter.next()
                    {
                        rows.push(o2);
                    }
                }
                let item = if rows.len() >= 2 {
                    n_plates += 1;
                    plate_rows += rows.len();
                    Item::PlateScalar {
                        dist,
                        ps,
                        np,
                        obs: rows,
                    }
                } else {
                    Item::Observe { dist, ps, np, obs }
                };
                out.push(RecItem { glue_end, item });
            }
            Item::ObserveInt { dist, p, obs } => {
                let mut rows = vec![obs];
                while let Some(nx) = iter.peek() {
                    let extend = nx.glue_end == glue_end
                        && matches!(
                            &nx.item,
                            Item::ObserveInt { dist: d2, p: p2, .. }
                                if ddist_eq(&dist, d2) && p == *p2
                        );
                    if !extend {
                        break;
                    }
                    if let Some(RecItem {
                        item: Item::ObserveInt { obs: o2, .. },
                        ..
                    }) = iter.next()
                    {
                        rows.push(o2);
                    }
                }
                let item = if rows.len() >= 2 {
                    n_plates += 1;
                    plate_rows += rows.len();
                    Item::PlateInt { dist, p, obs: rows }
                } else {
                    Item::ObserveInt { dist, p, obs }
                };
                out.push(RecItem { glue_end, item });
            }
            other => out.push(RecItem {
                glue_end,
                item: other,
            }),
        }
    }
    (out, n_plates, plate_rows)
}

/// Lower a verified recording into an executable program: fuse glue per
/// inter-item range (opcodes after the last item can influence nothing
/// and are dropped), then group observe plates.
fn build_program(rec: Recording, tvi: &TypedVarInfo) -> StaticProgram {
    let uses = count_uses(&rec);
    let Recording {
        ops,
        n_regs,
        items,
        n_obs,
    } = rec;
    let mut eops = Vec::new();
    let mut lowered = Vec::with_capacity(items.len());
    let mut cursor = 0usize;
    for ri in items {
        fuse_range(&ops, cursor, ri.glue_end, &uses, &mut eops);
        cursor = ri.glue_end;
        lowered.push(RecItem {
            glue_end: eops.len(),
            item: ri.item,
        });
    }
    let (items, n_plates, plate_rows) = group_plates(lowered);
    StaticProgram {
        eops,
        items,
        n_regs: n_regs as usize,
        discrete: tvi.discrete.clone(),
        n_obs,
        n_plates,
        plate_rows,
        dim: tvi.dim(),
    }
}

/// Attempt to compile `model` against its typed trace.
///
/// Records the walk twice — at the trace's stored unconstrained point and
/// at a perturbed point (θ + 0.125, falling back to θ − 0.125 if the
/// perturbation rejects) — and promotes only if the two recordings are
/// structurally identical, the recorded obs-site count agrees with
/// [`count_obs_sites`], and the compiled program reproduces the dynamic
/// fused executor's log-density and gradient **bitwise** at the recording
/// point. Any failure returns `None` and the model stays dynamic.
pub fn try_compile(model: &dyn Model, tvi: &TypedVarInfo) -> Option<StaticProgram> {
    let rec0 = record_run(model, tvi, &tvi.unconstrained)?;
    let expected_obs = count_obs_sites(model, tvi);
    if rec0.n_obs != expected_obs {
        debug_assert_eq!(
            rec0.n_obs, expected_obs,
            "recorder obs-site count drifted from the plain typed walk"
        );
        return None;
    }
    let perturbed = |d: f64| -> Vec<f64> { tvi.unconstrained.iter().map(|x| x + d).collect() };
    let rec1 = record_run(model, tvi, &perturbed(0.125))
        .or_else(|| record_run(model, tvi, &perturbed(-0.125)))?;
    if !recordings_match(&rec0, &rec1) {
        return None;
    }
    let program = build_program(rec0, tvi);
    // never serve an unvalidated program: bitwise lp + grad parity with
    // the dynamic fused walk at the recording point, or no promotion
    let mut gc = vec![0.0; tvi.dim()];
    let mut gd = vec![0.0; tvi.dim()];
    let lc = program.logp_grad_into(tvi, &tvi.unconstrained, Context::Default, &mut gc);
    let ld = typed_grad_fused_into(model, tvi, &tvi.unconstrained, Context::Default, &mut gd);
    if !f64_bits_eq(lc, ld) || !slice_bits_eq(&gc, &gd) {
        return None;
    }
    metrics::inc(Counter::StaticPromotions);
    Some(program)
}

// --------------------------------------------------------------- replay

/// Reused replay buffers, parked thread-locally between evaluations so the
/// steady-state compiled path allocates nothing.
#[derive(Default)]
struct ReplayScratch {
    /// Register file: `(tape node index, value)` per recording register.
    regs: Vec<(u32, f64)>,
    /// Fused-add parent/partial assembly buffers.
    parents: Vec<u32>,
    partials: Vec<f64>,
    /// Operand buffer for `Lse` replay.
    avars: Vec<AVar>,
    /// Plate kernel row outputs.
    lp_rows: Vec<f64>,
    dp_rows: Vec<[f64; MAX_DIST_PARAMS]>,
    dpi_rows: Vec<f64>,
}

thread_local! {
    static REPLAY_SCRATCH: RefCell<ReplayScratch> = RefCell::new(ReplayScratch::default());
}

fn take_replay_scratch() -> ReplayScratch {
    REPLAY_SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut()))
}

fn park_replay_scratch(s: ReplayScratch) {
    REPLAY_SCRATCH.with(|c| *c.borrow_mut() = s)
}

fn push_fused_parent(
    regs: &[(u32, f64)],
    parents: &mut Vec<u32>,
    partials: &mut Vec<f64>,
    t: &FTerm,
) {
    let (idx, d) = match t {
        FTerm::Src(Src::Reg(r)) => (regs[*r as usize].0, 1.0),
        FTerm::Src(Src::Const(_)) => return,
        FTerm::MulRC(r, c) => (regs[*r as usize].0, *c),
    };
    if idx != arena::NONE {
        parents.push(idx);
        partials.push(d);
    }
}

/// One in-flight replay: the accumulator/seed-weight arithmetic is a
/// verbatim copy of the dynamic `FusedCore`, and every item arm calls the
/// same fused kernels (`fused_assume_*`, `logpdf_adj`, `seed_*`) the
/// dynamic executors call — bit-identical totals by construction.
struct Replay<'a> {
    tvi: &'a TypedVarInfo,
    theta: &'a [f64],
    acc: Accumulator<f64>,
    prior_w: f64,
    lik_w: f64,
    stmts: usize,
    rs: ReplayScratch,
    fs: FusedScratch,
}

impl<'a> Replay<'a> {
    #[inline]
    fn prior_seed_weight(&mut self, lp: f64) -> f64 {
        let pre = self.acc.rejected();
        self.acc.add_prior(lp);
        if !pre && !self.acc.rejected() {
            self.prior_w
        } else {
            0.0
        }
    }

    #[inline]
    fn lik_seed_weight(&mut self, lp: f64, w: f64) -> f64 {
        let pre = self.acc.rejected();
        self.acc.add_lik_weighted(lp, w);
        if !pre && !self.acc.rejected() {
            w
        } else {
            0.0
        }
    }

    #[inline]
    fn rsrc(&self, s: Src) -> (u32, f64) {
        match s {
            Src::Const(c) => (arena::NONE, c),
            Src::Reg(r) => self.rs.regs[r as usize],
        }
    }

    #[inline]
    fn reg_avar(&self, r: u32) -> AVar {
        let (idx, v) = self.rs.regs[r as usize];
        if idx == arena::NONE {
            AVar::constant(v)
        } else {
            AVar::from_node(idx, v)
        }
    }

    #[inline]
    fn avar(&self, s: Src) -> AVar {
        match s {
            Src::Const(c) => AVar::constant(c),
            Src::Reg(r) => self.reg_avar(r),
        }
    }

    fn exec_eop(&mut self, eop: &EOp) {
        match eop {
            EOp::Plain(rop) => self.exec_plain(rop),
            EOp::FusedAdd { out, head, terms } => {
                let (h_idx, h_val) = self.rsrc(*head);
                let mut v = h_val;
                for t in terms {
                    v += match t {
                        FTerm::Src(s) => self.rsrc(*s).1,
                        FTerm::MulRC(r, c) => self.rs.regs[*r as usize].1 * c,
                    };
                }
                // parent order [tₙ … t₂, head, t₁] reproduces the dynamic
                // backward sweep's per-leaf accumulation order over the
                // chain's interleaved Mul/Add nodes
                self.rs.parents.clear();
                self.rs.partials.clear();
                for t in terms.iter().skip(1).rev() {
                    push_fused_parent(&self.rs.regs, &mut self.rs.parents, &mut self.rs.partials, t);
                }
                if h_idx != arena::NONE {
                    self.rs.parents.push(h_idx);
                    self.rs.partials.push(1.0);
                }
                push_fused_parent(
                    &self.rs.regs,
                    &mut self.rs.parents,
                    &mut self.rs.partials,
                    &terms[0],
                );
                let idx = if self.rs.parents.is_empty() {
                    arena::NONE
                } else {
                    arena::with_tape(|t| t.push(&self.rs.parents, &self.rs.partials))
                };
                self.rs.regs[*out as usize] = (idx, v);
            }
        }
    }

    /// Replay one plain opcode through the real [`AVar`] operation — the
    /// identical arithmetic (and identical value-dependent branches, for
    /// the composite kernels) the dynamic executor would run.
    fn exec_plain(&mut self, rop: &ROp) {
        let v = match &rop.op {
            Op::Add(a, b) => self.avar(*a) + self.avar(*b),
            Op::Sub(a, b) => self.avar(*a) - self.avar(*b),
            Op::Mul(a, b) => self.avar(*a) * self.avar(*b),
            Op::Div(a, b) => self.avar(*a) / self.avar(*b),
            Op::Neg(r) => -self.reg_avar(*r),
            Op::Ln(r) => self.reg_avar(*r).ln(),
            Op::Exp(r) => self.reg_avar(*r).exp(),
            Op::Sqrt(r) => self.reg_avar(*r).sqrt(),
            Op::Ln1p(r) => self.reg_avar(*r).ln_1p(),
            Op::Tanh(r) => self.reg_avar(*r).tanh(),
            Op::Sin(r) => self.reg_avar(*r).sin(),
            Op::Cos(r) => self.reg_avar(*r).cos(),
            Op::Lgamma(r) => self.reg_avar(*r).lgamma(),
            Op::Powi(r, n) => self.reg_avar(*r).powi(*n),
            Op::Powf(r, e) => self.reg_avar(*r).powf(*e),
            Op::Abs(r) => self.reg_avar(*r).abs(),
            Op::Log1pExp(r) => self.reg_avar(*r).log1p_exp(),
            Op::LogSigmoid(r) => self.reg_avar(*r).log_sigmoid(),
            Op::Sigmoid(r) => self.reg_avar(*r).sigmoid(),
            Op::LogAddExp(a, b) => self.avar(*a).log_add_exp(self.avar(*b)),
            Op::Lse(srcs) => {
                let mut buf = std::mem::take(&mut self.rs.avars);
                buf.clear();
                for s in srcs {
                    buf.push(self.avar(*s));
                }
                let v = AVar::log_sum_exp_slice(&buf);
                self.rs.avars = buf;
                v
            }
        };
        self.rs.regs[rop.out as usize] = (v.idx(), v.value());
    }

    fn exec_item(&mut self, item: &Item) {
        match item {
            Item::AssumeScalar {
                slot,
                out,
                dist,
                ps,
                ..
            } => {
                self.stmts += 1;
                let sl = &self.tvi.slots()[*slot];
                let d = dist.with_params(&[self.avar(ps[0]), self.avar(ps[1])]);
                let (x, lp, adj, link) =
                    fused_assume_scalar(self.theta, sl.unc_offset, &sl.domain, &d);
                let w = self.prior_seed_weight(lp);
                if w != 0.0 {
                    seed_assume_scalar(&x, sl.unc_offset, &d, &adj, &link, w);
                }
                self.rs.regs[*out as usize] = (x.idx(), x.value());
            }
            Item::AssumeVec {
                slot,
                out,
                dist,
                ps,
                ..
            } => {
                self.stmts += 1;
                let sl = &self.tvi.slots()[*slot];
                let d = dist.with_params(&[self.avar(ps[0]), self.avar(ps[1])]);
                let (xs, lp, adj, ladj) =
                    fused_assume_vec(self.theta, sl.unc_offset, &sl.domain, &d, &mut self.fs);
                let w = self.prior_seed_weight(lp);
                if w != 0.0 {
                    seed_assume_vec(
                        &xs,
                        sl.unc_offset,
                        &sl.domain,
                        &ladj,
                        &d,
                        &adj,
                        &self.fs.dx,
                        w,
                    );
                }
                for (r, x) in out.iter().zip(&xs) {
                    self.rs.regs[*r as usize] = (x.idx(), x.value());
                }
            }
            Item::AssumeInt { slot, dist, p } => {
                self.stmts += 1;
                let sl = &self.tvi.slots()[*slot];
                let k = self.tvi.discrete[sl.disc_offset];
                let (pi, pv) = self.rsrc(*p);
                let (lp, dp) = dist.with_f64_param(pv).logpmf_adj(k);
                let w = self.prior_seed_weight(lp);
                if w != 0.0 {
                    arena::seed(pi, dp * w);
                }
            }
            Item::Observe { dist, ps, obs, .. } => {
                self.stmts += 1;
                let cw = self.acc.note_obs();
                if cw == 0.0 {
                    return;
                }
                let d = dist.with_params(&[self.avar(ps[0]), self.avar(ps[1])]);
                let adj = d.logpdf_adj(*obs);
                let w = self.lik_seed_weight(adj.lp, cw);
                if w != 0.0 {
                    seed_params_scalar(&d, &adj, w);
                }
            }
            Item::ObserveInt { dist, p, obs } => {
                self.stmts += 1;
                let cw = self.acc.note_obs();
                if cw == 0.0 {
                    return;
                }
                let (pi, pv) = self.rsrc(*p);
                let (lp, dp) = dist.with_f64_param(pv).logpmf_adj(*obs);
                let w = self.lik_seed_weight(lp, cw);
                if w != 0.0 {
                    arena::seed(pi, dp * w);
                }
            }
            Item::ObserveVec { dist, ps, obs, .. } => {
                self.stmts += 1;
                let cw = self.acc.note_obs();
                if cw == 0.0 {
                    return;
                }
                self.fs.dx.clear();
                self.fs.dx.resize(obs.len(), 0.0);
                let d = dist.with_params(&[self.avar(ps[0]), self.avar(ps[1])]);
                let adj = d.logpdf_adj(obs, &mut self.fs.dx);
                let w = self.lik_seed_weight(adj.lp, cw);
                if w != 0.0 {
                    let (pvs, n) = d.param_vars();
                    arena::with_tape(|t| {
                        for (pv, dd) in pvs.iter().zip(adj.d_p).take(n) {
                            t.seed(pv.idx(), dd * w);
                        }
                    });
                }
            }
            Item::ObsLogp { lp } => {
                self.stmts += 1;
                let cw = self.acc.note_obs();
                if cw == 0.0 {
                    return;
                }
                let (idx, v) = self.rsrc(*lp);
                let w = self.lik_seed_weight(v, cw);
                if w != 0.0 {
                    arena::seed(idx, w);
                }
            }
            Item::PriorLogp { lp } => {
                self.stmts += 1;
                let (idx, v) = self.rsrc(*lp);
                let w = self.prior_seed_weight(v);
                arena::seed(idx, w);
            }
            Item::SkipObs { n } => {
                self.acc.skip_obs(*n);
            }
            Item::PlateScalar { dist, ps, np, obs } => {
                metrics::inc(Counter::PlateKernelCalls);
                let n = obs.len();
                self.rs.lp_rows.clear();
                self.rs.lp_rows.resize(n, 0.0);
                self.rs.dp_rows.clear();
                self.rs.dp_rows.resize(n, [0.0; MAX_DIST_PARAMS]);
                let p0 = self.rsrc(ps[0]);
                let p1 = self.rsrc(ps[1]);
                if self.lik_w != 0.0 {
                    // one row-batched kernel call for the whole plate;
                    // each row's lp/d_p is bitwise equal to the
                    // sequential logpdf_adj the dynamic walk runs
                    dist.with_f64_params(&[p0.1, p1.1]).logpdf_adj_rows(
                        obs,
                        &mut self.rs.lp_rows,
                        &mut self.rs.dp_rows,
                    );
                }
                let pis = [p0.0, p1.0];
                for i in 0..n {
                    self.stmts += 1;
                    let cw = self.acc.note_obs();
                    if cw == 0.0 {
                        continue;
                    }
                    let w = self.lik_seed_weight(self.rs.lp_rows[i], cw);
                    if w != 0.0 {
                        let dp = self.rs.dp_rows[i];
                        arena::with_tape(|t| {
                            for (pi, d) in pis.iter().zip(dp).take(*np) {
                                t.seed(*pi, d * w);
                            }
                        });
                    }
                }
            }
            Item::PlateInt { dist, p, obs } => {
                metrics::inc(Counter::PlateKernelCalls);
                let n = obs.len();
                self.rs.lp_rows.clear();
                self.rs.lp_rows.resize(n, 0.0);
                self.rs.dpi_rows.clear();
                self.rs.dpi_rows.resize(n, 0.0);
                let (pi, pv) = self.rsrc(*p);
                if self.lik_w != 0.0 {
                    dist.with_f64_param(pv).logpmf_adj_rows(
                        obs,
                        &mut self.rs.lp_rows,
                        &mut self.rs.dpi_rows,
                    );
                }
                for i in 0..n {
                    self.stmts += 1;
                    let cw = self.acc.note_obs();
                    if cw == 0.0 {
                        continue;
                    }
                    let w = self.lik_seed_weight(self.rs.lp_rows[i], cw);
                    if w != 0.0 {
                        arena::seed(pi, self.rs.dpi_rows[i] * w);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_typed;
    use crate::util::rng::Xoshiro256pp;

    fn promoted(name: &str) -> (Box<dyn Model>, TypedVarInfo, StaticProgram) {
        let bm = crate::models::build_small(name, 11);
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let prog = try_compile(bm.model.as_ref(), &tvi)
            .unwrap_or_else(|| panic!("{name} should promote"));
        (bm.model, tvi, prog)
    }

    fn assert_bitwise_match(model: &dyn Model, tvi: &TypedVarInfo, prog: &StaticProgram) {
        let theta: Vec<f64> = tvi
            .unconstrained
            .iter()
            .enumerate()
            .map(|(i, x)| x + 0.03 * ((i % 7) as f64 - 3.0))
            .collect();
        for ctx in [Context::Default, Context::Likelihood, Context::Prior] {
            let mut gc = vec![0.0; tvi.dim()];
            let mut gd = vec![0.0; tvi.dim()];
            let lc = prog.logp_grad_into(tvi, &theta, ctx, &mut gc);
            let ld = typed_grad_fused_into(model, tvi, &theta, ctx, &mut gd);
            assert_eq!(lc.to_bits(), ld.to_bits(), "{ctx:?}: logp bits");
            for (i, (a, b)) in gc.iter().zip(&gd).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{ctx:?}: grad[{i}] {a} vs {b}");
            }
        }
    }

    #[test]
    fn fuses_mul_add_chains() {
        // regs 0,1 play assume outputs; the op stream is the dot-product
        // pattern: m2 = r0*2, a3 = 0+m2, m4 = r1*3, a5 = a3+m4
        let ops = vec![
            ROp {
                out: 2,
                op: Op::Mul(Src::Reg(0), Src::Const(2.0)),
            },
            ROp {
                out: 3,
                op: Op::Add(Src::Const(0.0), Src::Reg(2)),
            },
            ROp {
                out: 4,
                op: Op::Mul(Src::Reg(1), Src::Const(3.0)),
            },
            ROp {
                out: 5,
                op: Op::Add(Src::Reg(3), Src::Reg(4)),
            },
        ];
        let uses = vec![1, 1, 1, 1, 1, 1];
        let (eop, next) = try_chain(&ops, 0, ops.len(), &uses).expect("chain fuses");
        assert_eq!(next, 4);
        match eop {
            EOp::FusedAdd { out, head, terms } => {
                assert_eq!(out, 5);
                assert_eq!(head, Src::Const(0.0));
                assert_eq!(terms.len(), 2);
                assert!(matches!(terms[0], FTerm::MulRC(0, c) if c == 2.0));
                assert!(matches!(terms[1], FTerm::MulRC(1, c) if c == 3.0));
            }
            EOp::Plain(_) => panic!("expected a fused add"),
        }
        // a multi-use intermediate must refuse to fuse past itself
        let mut uses2 = uses.clone();
        uses2[3] = 2;
        assert!(try_chain(&ops, 0, ops.len(), &uses2).is_none());
    }

    #[test]
    fn non_add_ops_stay_plain() {
        let ops = vec![ROp {
            out: 1,
            op: Op::Exp(0),
        }];
        let uses = vec![1, 1];
        let mut eops = Vec::new();
        fuse_range(&ops, 0, 1, &uses, &mut eops);
        assert_eq!(eops.len(), 1);
        assert!(matches!(&eops[0], EOp::Plain(r) if matches!(r.op, Op::Exp(0))));
    }

    #[test]
    fn logreg_tall_promotes_and_replays_bitwise() {
        let (model, tvi, prog) = promoted("logreg_tall");
        // per-row densities arrive via add_obs_logp with interleaved glue,
        // so no distribution plates form — the win is the fused dot chain
        assert_eq!(prog.n_plates(), 0);
        assert_eq!(prog.n_obs(), count_obs_sites(model.as_ref(), &tvi));
        assert_bitwise_match(model.as_ref(), &tvi, &prog);
    }

    #[test]
    fn hier_poisson_forms_poisson_plates() {
        let (model, tvi, prog) = promoted("hier_poisson");
        // 10 groups × 5 consecutive Poisson observes sharing one rate
        assert_eq!(prog.n_plates(), 10);
        assert_eq!(prog.plate_rows(), 50);
        assert_bitwise_match(model.as_ref(), &tvi, &prog);
    }

    #[test]
    fn gauss_unknown_promotes_and_replays_bitwise() {
        let (model, tvi, prog) = promoted("gauss_unknown");
        // the manual iid loop folds every observation into one raw-logp
        // site, so no distribution plates form — the win is the fused
        // glue chain feeding that site
        assert_eq!(prog.n_plates(), 0);
        assert_eq!(prog.n_obs(), 1);
        assert_bitwise_match(model.as_ref(), &tvi, &prog);
    }

    #[test]
    fn servable_contexts_are_exactly_full_window() {
        assert!(servable(Context::Default));
        assert!(servable(Context::Likelihood));
        assert!(servable(Context::Prior));
        assert!(servable(Context::MiniBatch { scale: 2.0 }));
        assert!(!servable(Context::Subsample {
            lo: 0,
            hi: 1,
            scale: 1.0
        }));
        let set = crate::context::register_subset(vec![0]);
        assert!(!servable(Context::SubsampleIdx { set, scale: 1.0 }));
        assert!(!servable(Context::ObsWindow { lo: 0, hi: 1 }));
        assert!(!servable(Context::Profile));
    }
}
