//! [`TildeApi`] implementations: the three ways a model body executes.

use rand_core::RngCore;

use crate::ad::Scalar;
use crate::context::{Accumulator, Context};
use crate::dist::{bijector, DiscreteDist, ScalarDist, VecDist};
use crate::value::Value;
use crate::varinfo::{flags, TypedVarInfo, UntypedVarInfo};
use crate::varname::VarName;

use super::TildeApi;

/// Draws missing variables from their priors into an [`UntypedVarInfo`].
///
/// - Variables already present (and not flagged `RESAMPLE`) keep their
///   stored value; their metadata (distribution) is refreshed since
///   parameters of the distribution may have changed.
/// - Missing or flagged variables are drawn fresh.
///
/// This executor is the paper's "initial sampling phase" and also serves
/// prior sampling and MH re-evaluation of boxed traces.
pub struct SampleExecutor<'a, R: RngCore> {
    rng: &'a mut R,
    vi: &'a mut UntypedVarInfo,
    acc: Accumulator<f64>,
    ctx: Context,
}

impl<'a, R: RngCore> SampleExecutor<'a, R> {
    pub fn new(rng: &'a mut R, vi: &'a mut UntypedVarInfo, ctx: Context) -> Self {
        Self {
            rng,
            vi,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }

    fn fetch_or_draw(&mut self, vn: VarName, dist: crate::dist::AnyDist) -> Value {
        if self.vi.contains(&vn) && !self.vi.is_flagged(&vn, flags::RESAMPLE) {
            let val = self.vi.get(&vn).unwrap().value.clone();
            self.vi.update(&vn, val.clone(), dist);
            val
        } else {
            let val = dist.sample(self.rng);
            if self.vi.contains(&vn) {
                self.vi.update(&vn, val.clone(), dist);
                self.vi.clear_flag(&vn, flags::RESAMPLE);
            } else {
                self.vi.insert(vn, val.clone(), dist);
            }
            val
        }
    }
}

impl<'a, R: RngCore> TildeApi<f64> for SampleExecutor<'a, R> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<f64>) -> f64 {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let x = val.as_f64().expect("scalar assume got non-scalar value");
        self.acc.add_prior(dist.logpdf(x));
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<f64>) -> Vec<f64> {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let x = val
            .as_slice()
            .expect("vector assume got non-vector value")
            .to_vec();
        self.acc.add_prior(dist.logpdf(&x));
        x
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<f64>) -> i64 {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let k = val.as_int().expect("discrete assume got non-integer value");
        self.acc.add_prior(dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<f64>, obs: f64) {
        self.acc.add_lik(dist.logpdf(obs));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<f64>, obs: i64) {
        self.acc.add_lik(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<f64>, obs: &[f64]) {
        self.acc.add_lik(dist.logpdf(obs));
    }

    fn add_obs_logp(&mut self, lp: f64) {
        self.acc.add_lik(lp);
    }

    fn add_prior_logp(&mut self, lp: f64) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }
}

/// Evaluates the log-density from a flat unconstrained slice using the
/// frozen [`TypedVarInfo`] layout — the specialized fast path.
///
/// Assumes are served by a cursor walk over the layout: slot `i` of the
/// layout must be visit `i` of the model (checked with `debug_assert`).
/// Each assume invlinks its coordinates (adding the Jacobian term) and
/// scores the prior. Generic over `T` so the same executor computes plain
/// values, forward duals and tape gradients.
pub struct TypedExecutor<'a, T: Scalar> {
    tvi: &'a TypedVarInfo,
    theta: &'a [T],
    cursor: usize,
    acc: Accumulator<T>,
    ctx: Context,
    buf: Vec<T>,
}

impl<'a> TypedExecutor<'a, f64> {
    pub fn new(tvi: &'a TypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        Self::new_generic(tvi, theta, ctx)
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }
}

impl<'a, T: Scalar> TypedExecutor<'a, T> {
    pub fn new_generic(tvi: &'a TypedVarInfo, theta: &'a [T], ctx: Context) -> Self {
        debug_assert_eq!(theta.len(), tvi.dim());
        Self {
            tvi,
            theta,
            cursor: 0,
            acc: Accumulator::new(ctx),
            ctx,
            buf: Vec::with_capacity(8),
        }
    }

    pub fn logp_t(&self) -> T {
        self.acc.total()
    }

    #[inline]
    fn next_slot(&mut self, vn: &VarName) -> &'a crate::varinfo::Slot {
        let slot = self
            .tvi
            .slots()
            .get(self.cursor)
            .unwrap_or_else(|| panic!("typed layout exhausted at {vn} — dynamic structure change; re-specialize the trace"));
        debug_assert_eq!(
            &slot.vn, vn,
            "typed layout mismatch: expected {}, model visited {vn}",
            slot.vn
        );
        self.cursor += 1;
        slot
    }
}

impl<'a, T: Scalar> TildeApi<T> for TypedExecutor<'a, T> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<T>) -> T {
        let slot = self.next_slot(&vn);
        self.buf.clear();
        let y = &self.theta[slot.unc_offset..slot.unc_offset + slot.unc_len];
        let mut out = std::mem::take(&mut self.buf);
        let ladj = bijector::invlink(&slot.domain, y, &mut out);
        let x = out[0];
        self.buf = out;
        self.acc.add_prior(dist.logpdf(x) + ladj);
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<T>) -> Vec<T> {
        let slot = self.next_slot(&vn);
        let y = &self.theta[slot.unc_offset..slot.unc_offset + slot.unc_len];
        let mut out = Vec::with_capacity(slot.cons_len);
        let ladj = bijector::invlink(&slot.domain, y, &mut out);
        self.acc.add_prior(dist.logpdf(&out) + ladj);
        out
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<T>) -> i64 {
        let slot = self.next_slot(&vn);
        let k = self.tvi.discrete[slot.disc_offset];
        self.acc.add_prior(dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<T>, obs: f64) {
        self.acc.add_lik(dist.logpdf(T::constant(obs)));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<T>, obs: i64) {
        self.acc.add_lik(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<T>, obs: &[f64]) {
        let obs_t: Vec<T> = obs.iter().map(|&o| T::constant(o)).collect();
        self.acc.add_lik(dist.logpdf(&obs_t));
    }

    fn add_obs_logp(&mut self, lp: T) {
        self.acc.add_lik(lp);
    }

    fn add_prior_logp(&mut self, lp: T) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }
}

/// Evaluates the log-density from a flat unconstrained slice **through the
/// boxed trace**: every assume re-derives its offset by hashing the
/// `VarName` and re-reads domain metadata through the `AnyDist` enum.
///
/// Semantically identical to [`TypedExecutor`]; mechanically it pays the
/// dynamic costs the paper's §2.2 attributes to `UntypedVarInfo` (abstract
/// element types defeating specialization). Offsets are recomputed each
/// run from the record order, mimicking `Vector{Real}` re-traversal.
pub struct UntypedFlatExecutor<'a, T: Scalar> {
    vi: &'a UntypedVarInfo,
    offsets: std::collections::HashMap<VarName, usize>,
    theta: &'a [T],
    acc: Accumulator<T>,
    ctx: Context,
}

impl<'a> UntypedFlatExecutor<'a, f64> {
    pub fn new(vi: &'a UntypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        Self::new_generic(vi, theta, ctx)
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }
}

impl<'a, T: Scalar> UntypedFlatExecutor<'a, T> {
    pub fn new_generic(vi: &'a UntypedVarInfo, theta: &'a [T], ctx: Context) -> Self {
        // Rebuild the VarName→offset map on every executor construction —
        // the boxed path has no frozen layout to reuse.
        let mut offsets = std::collections::HashMap::new();
        let mut off = 0;
        for rec in vi.records() {
            offsets.insert(rec.vn.clone(), off);
            off += rec.domain.unconstrained_dim();
        }
        debug_assert_eq!(off, theta.len());
        Self {
            vi,
            offsets,
            theta,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp_t(&self) -> T {
        self.acc.total()
    }

    fn lookup(&self, vn: &VarName) -> (usize, crate::dist::Domain) {
        let off = *self
            .offsets
            .get(vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace — dynamic structure change"));
        let rec = self.vi.get(vn).unwrap();
        (off, rec.domain.clone())
    }
}

impl<'a, T: Scalar> TildeApi<T> for UntypedFlatExecutor<'a, T> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<T>) -> T {
        let (off, domain) = self.lookup(&vn);
        let n = domain.unconstrained_dim();
        let mut out = Vec::with_capacity(1);
        let ladj = bijector::invlink(&domain, &self.theta[off..off + n], &mut out);
        let x = out[0];
        self.acc.add_prior(dist.logpdf(x) + ladj);
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<T>) -> Vec<T> {
        let (off, domain) = self.lookup(&vn);
        let n = domain.unconstrained_dim();
        let mut out = Vec::with_capacity(domain.constrained_dim());
        let ladj = bijector::invlink(&domain, &self.theta[off..off + n], &mut out);
        self.acc.add_prior(dist.logpdf(&out) + ladj);
        out
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<T>) -> i64 {
        let rec = self
            .vi
            .get(&vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace"));
        let k = rec.value.as_int().expect("discrete assume of non-integer");
        self.acc.add_prior(dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<T>, obs: f64) {
        self.acc.add_lik(dist.logpdf(T::constant(obs)));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<T>, obs: i64) {
        self.acc.add_lik(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<T>, obs: &[f64]) {
        let obs_t: Vec<T> = obs.iter().map(|&o| T::constant(o)).collect();
        self.acc.add_lik(dist.logpdf(&obs_t));
    }

    fn add_obs_logp(&mut self, lp: T) {
        self.acc.add_lik(lp);
    }

    fn add_prior_logp(&mut self, lp: T) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }
}
